"""Sweep throughput benchmark: fused batched executor vs the per-stage
batched executor vs serial Simulator.run, plus accuracy-target early stop.

Times a selector x SAA x hardware x seed grid at S in {4, 16, 64} cells
(n_learners=100) through three executions:

  batched (fused)    — the device-resident round pipeline (default);
  batched (stages)   — the PR-2 per-stage batched executor
                       (``fused_rounds=False`` cells), the baseline the
                       pipeline replaces;
  serial             — one full ``Simulator(cfg).run()`` per cell (fresh
                       substrate each), what the grid costs with no sweep
                       subsystem at all.

Parity is asserted before any speedup is reported: every cell's summary
must be bit-identical between the fused batched run and the serial run.
An early-stop row then re-runs the largest grid with ``target_accuracy``
set: cells that reach the target drop out of the lockstep batch (shrinking
bucket-padded repacking), and the row records the wall-clock saving and
per-cell parity against early-stopped serial runs.  Writes
``BENCH_sweeps.json`` at the repo root for the perf trajectory.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_sweeps             # full sweep
  PYTHONPATH=src python -m benchmarks.bench_sweeps --smoke     # small CI smoke
  PYTHONPATH=src python -m benchmarks.bench_sweeps --profile   # + pipeline
      dispatch/transfer stats for the largest grid
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

from repro.sweeps import SweepSpec, SweepRunner, assert_parity, run_serial

ROUNDS, EVAL_EVERY = 12, 6


def grid(s_cells: int, n_learners: int, rounds: int,
         target_accuracy=None) -> SweepSpec:
    base = dict(n_learners=n_learners, rounds=rounds, eval_every=EVAL_EVERY,
                mapping="label_uniform")
    if target_accuracy is not None:
        base["target_accuracy"] = target_accuracy
    axes = {
        4: {"selector": ["random", "priority"], "saa": [False, True]},
        16: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True], "hardware": ["HS1", "HS3"]},
        64: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True],
             "hardware": ["HS1", "HS2", "HS3", "HS4"]},
    }[s_cells]
    seeds = (0, 1) if s_cells == 64 else (0,)
    return SweepSpec(axes=axes, base=base, seeds=seeds)


def _stage_cells(cells):
    return [dataclasses.replace(
        c, config=dataclasses.replace(c.config, fused_rounds=False))
        for c in cells]


def _best_of(fn, trials: int = 2):
    """Best-of-N wall (bench_engine's protocol): the first trial warms the
    jit caches for this grid's padding buckets, the best trial measures the
    round loops + substrate builds rather than one-time compiles.  Every
    executor gets the same treatment."""
    best_out, best_wall = None, float("inf")
    for _ in range(trials):
        out, wall = fn()
        if wall < best_wall:
            best_out, best_wall = out, wall
    return best_out, best_wall


def _run_batched(cells):
    t0 = time.time()
    runner = SweepRunner(cells)
    results = runner.run()
    return (results, runner.last_stats), time.time() - t0


def bench(sizes, n_learners: int, rounds: int) -> list[dict]:
    out = []
    for s_cells in sizes:
        cells = grid(s_cells, n_learners, rounds).expand()
        assert len(cells) == s_cells
        (results, stats), fused_wall = _best_of(lambda: _run_batched(cells))
        (_, _), stage_wall = _best_of(
            lambda: _run_batched(_stage_cells(cells)))
        serial_summaries, serial_wall = _best_of(lambda: run_serial(cells))
        assert_parity(results, serial_summaries)
        row = {
            "s_cells": s_cells,
            "n_learners": n_learners,
            "rounds": rounds,
            "batched_wall_s": round(fused_wall, 3),
            "stages_wall_s": round(stage_wall, 3),
            "serial_wall_s": round(serial_wall, 3),
            "speedup": round(serial_wall / max(fused_wall, 1e-9), 2),
            "speedup_vs_stages": round(stage_wall / max(fused_wall, 1e-9), 2),
            "cells_per_sec_batched": round(s_cells / max(fused_wall, 1e-9), 2),
            "pipeline_stats": stats,
            "parity": True,
        }
        out.append(row)
        print(f"sweeps/S={s_cells},{1e3 * fused_wall / s_cells:.0f},"
              f"batched={fused_wall:.2f}s;stages={stage_wall:.2f}s;"
              f"serial={serial_wall:.2f}s;speedup={row['speedup']}x")
    return out


def bench_early_stop(s_cells: int, n_learners: int, rounds: int,
                     target: float = 0.2) -> dict:
    """Accuracy-target early stop: finished cells leave the lockstep batch,
    so the sweep's cost tracks live cells.  Reports the wall saving vs the
    same grid running every round, with per-cell parity against serial
    early-stopped runs asserted first."""
    full_cells = grid(s_cells, n_learners, rounds).expand()
    es_cells = grid(s_cells, n_learners, rounds, target_accuracy=target).expand()
    (_, _), full_wall = _best_of(lambda: _run_batched(full_cells))
    (results, _), es_wall = _best_of(lambda: _run_batched(es_cells))
    serial_summaries, _ = run_serial(es_cells)
    assert_parity(results, serial_summaries)
    stopped = sum(1 for r in results if r.summary["stopped_early"])
    rounds_run = sum(r.summary["rounds"] for r in results)
    row = {
        "s_cells": s_cells,
        "n_learners": n_learners,
        "rounds": rounds,
        "target_accuracy": target,
        "early_stop": True,
        "batched_wall_s": round(es_wall, 3),
        "full_run_wall_s": round(full_wall, 3),
        "speedup_vs_full": round(full_wall / max(es_wall, 1e-9), 2),
        "cells_stopped_early": stopped,
        "rounds_run_total": rounds_run,
        "rounds_full_total": s_cells * rounds,
        "parity": True,
    }
    print(f"sweeps_early_stop/S={s_cells},{1e3 * es_wall / s_cells:.0f},"
          f"wall={es_wall:.2f}s;full={full_wall:.2f}s;"
          f"speedup={row['speedup_vs_full']}x;stopped={stopped}/{s_cells}")
    return row


def main() -> None:
    smoke = "--smoke" in sys.argv
    profile = "--profile" in sys.argv
    sizes = (4,) if smoke else (4, 16, 64)
    n_learners = 60 if smoke else 100
    rounds = 6 if smoke else ROUNDS
    rows = bench(sizes, n_learners, rounds)
    result = {
        "bench": "sweeps",
        "mode": "smoke" if smoke else "full",
        "sweep": rows,
        "early_stop": [bench_early_stop(sizes[-1], n_learners, rounds,
                                        target=0.1 if smoke else 0.2)],
    }
    if profile:
        result["pipeline_profile"] = rows[-1]["pipeline_stats"]
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweeps.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
