"""Sweep throughput benchmark: batched executor vs serial Simulator.run.

Times a policy x SAA x hardware x seed grid at S in {4, 16, 64} cells
(n_learners=100) through the batched ``SweepRunner`` against the serial
baseline (one full ``Simulator(cfg).run()`` per cell, fresh substrate each —
what reproducing the grid costs without the subsystem).  Parity is asserted
before any speedup is reported: every cell's summary must be bit-identical
between the two executions.  Writes ``BENCH_sweeps.json`` at the repo root
for the perf trajectory.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_sweeps           # full sweep
  PYTHONPATH=src python -m benchmarks.bench_sweeps --smoke   # small CI smoke
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro.sweeps import (SweepSpec, assert_parity, run_batched, run_serial)

ROUNDS, EVAL_EVERY = 12, 6


def grid(s_cells: int, n_learners: int, rounds: int) -> SweepSpec:
    base = dict(n_learners=n_learners, rounds=rounds, eval_every=EVAL_EVERY,
                mapping="label_uniform")
    axes = {
        4: {"selector": ["random", "priority"], "saa": [False, True]},
        16: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True], "hardware": ["HS1", "HS3"]},
        64: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True],
             "hardware": ["HS1", "HS2", "HS3", "HS4"]},
    }[s_cells]
    seeds = (0, 1) if s_cells == 64 else (0,)
    return SweepSpec(axes=axes, base=base, seeds=seeds)


def _best_of(fn, trials: int = 2):
    """Best-of-N wall (bench_engine's protocol): the first trial warms the
    jit caches for this grid's cohort/packed-row buckets, the best trial
    measures the round loops + substrate builds rather than one-time
    compiles.  Both executors get the same treatment."""
    best_out, best_wall = None, float("inf")
    for _ in range(trials):
        out, wall = fn()
        if wall < best_wall:
            best_out, best_wall = out, wall
    return best_out, best_wall


def bench(sizes, n_learners: int, rounds: int) -> list[dict]:
    out = []
    for s_cells in sizes:
        cells = grid(s_cells, n_learners, rounds).expand()
        assert len(cells) == s_cells
        results, batched_wall = _best_of(lambda: run_batched(cells))
        serial_summaries, serial_wall = _best_of(lambda: run_serial(cells))
        assert_parity(results, serial_summaries)
        row = {
            "s_cells": s_cells,
            "n_learners": n_learners,
            "rounds": rounds,
            "batched_wall_s": round(batched_wall, 3),
            "serial_wall_s": round(serial_wall, 3),
            "speedup": round(serial_wall / max(batched_wall, 1e-9), 2),
            "cells_per_sec_batched": round(s_cells / max(batched_wall, 1e-9), 2),
            "parity": True,
        }
        out.append(row)
        print(f"sweeps/S={s_cells},{1e3 * batched_wall / s_cells:.0f},"
              f"batched={batched_wall:.2f}s;serial={serial_wall:.2f}s;"
              f"speedup={row['speedup']}x")
    return out


def main() -> None:
    smoke = "--smoke" in sys.argv
    sizes = (4,) if smoke else (4, 16, 64)
    n_learners = 60 if smoke else 100
    rounds = 6 if smoke else ROUNDS
    result = {
        "bench": "sweeps",
        "mode": "smoke" if smoke else "full",
        "sweep": bench(sizes, n_learners, rounds),
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweeps.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
