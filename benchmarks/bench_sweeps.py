"""Sweep throughput benchmark: fused batched executor vs the per-stage
batched executor vs serial Simulator.run, plus accuracy-target early stop
and the sharded / multi-round-chunked execution variants.

Times a selector x SAA x hardware x seed grid at S in {4, 16, 64} cells
(n_learners=100) through three executions:

  batched (fused)    — the device-resident round pipeline (default);
  batched (stages)   — the PR-2 per-stage batched executor
                       (``fused_rounds=False`` cells), the baseline the
                       pipeline replaces;
  serial             — one full ``Simulator(cfg).run()`` per cell (fresh
                       substrate each), what the grid costs with no sweep
                       subsystem at all.

Parity is asserted before any speedup is reported: every cell's summary
must be bit-identical between the fused batched run and the serial run.
An early-stop row then re-runs the largest grid with ``target_accuracy``
set: cells that reach the target drop out of the lockstep batch (shrinking
bucket-padded repacking), and the row records the wall-clock saving and
per-cell parity against early-stopped serial runs.  Variant rows re-run
the largest grid sharded over the local device mesh (``shard=True``),
chunked (``rounds_per_dispatch=8``: K rounds per dispatch via lax.scan),
and both combined — each parity-asserted against the plain batched
results.  A zoo row races every registered selection strategy
(``repro.selection``) on one shared-seed grid and records per-selector
accuracy / resource use.  Writes ``BENCH_sweeps.json`` at the repo root for the perf
trajectory; ``benchmarks/check_regression.py`` compares a fresh smoke run
against the checked-in rows.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_sweeps             # full sweep
  PYTHONPATH=src python -m benchmarks.bench_sweeps --smoke     # small CI smoke
  PYTHONPATH=src python -m benchmarks.bench_sweeps --profile   # + pipeline
      dispatch/transfer stats for the largest grid
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

import jax

from repro.sweeps import SweepSpec, SweepRunner, assert_parity, run_serial
from repro.sweeps.runner import summaries_equal

ROUNDS, EVAL_EVERY = 12, 6


def grid(s_cells: int, n_learners: int, rounds: int,
         target_accuracy=None) -> SweepSpec:
    base = dict(n_learners=n_learners, rounds=rounds, eval_every=EVAL_EVERY,
                mapping="label_uniform")
    if target_accuracy is not None:
        base["target_accuracy"] = target_accuracy
    axes = {
        4: {"selector": ["random", "priority"], "saa": [False, True]},
        16: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True], "hardware": ["HS1", "HS3"]},
        64: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True],
             "hardware": ["HS1", "HS2", "HS3", "HS4"]},
    }[s_cells]
    seeds = (0, 1) if s_cells == 64 else (0,)
    return SweepSpec(axes=axes, base=base, seeds=seeds)


def _stage_cells(cells):
    return [dataclasses.replace(
        c, config=dataclasses.replace(c.config, fused_rounds=False))
        for c in cells]


def _best_of(fn, trials: int = 2):
    """Best-of-N wall (bench_engine's protocol): the first trial warms the
    jit caches for this grid's padding buckets, the best trial measures the
    round loops + substrate builds rather than one-time compiles.  Every
    executor gets the same treatment."""
    best_out, best_wall = None, float("inf")
    for _ in range(trials):
        out, wall = fn()
        if wall < best_wall:
            best_out, best_wall = out, wall
    return best_out, best_wall


def _run_batched(cells):
    t0 = time.time()
    runner = SweepRunner(cells)
    results = runner.run()
    return (results, runner.last_stats), time.time() - t0


def bench(sizes, n_learners: int, rounds: int) -> tuple[list[dict], dict]:
    """Returns (rows, measured) where ``measured[s_cells]`` is the fused
    run's (results, wall) — reusable as a variant baseline when the variant
    grid is the same grid (saves re-measuring it)."""
    out, measured = [], {}
    for s_cells in sizes:
        cells = grid(s_cells, n_learners, rounds).expand()
        assert len(cells) == s_cells
        (results, stats), fused_wall = _best_of(lambda: _run_batched(cells))
        measured[s_cells] = (results, fused_wall)
        (_, _), stage_wall = _best_of(
            lambda: _run_batched(_stage_cells(cells)))
        serial_summaries, serial_wall = _best_of(lambda: run_serial(cells))
        assert_parity(results, serial_summaries)
        row = {
            "s_cells": s_cells,
            "n_learners": n_learners,
            "rounds": rounds,
            "batched_wall_s": round(fused_wall, 3),
            "stages_wall_s": round(stage_wall, 3),
            "serial_wall_s": round(serial_wall, 3),
            "speedup": round(serial_wall / max(fused_wall, 1e-9), 2),
            "speedup_vs_stages": round(stage_wall / max(fused_wall, 1e-9), 2),
            "cells_per_sec_batched": round(s_cells / max(fused_wall, 1e-9), 2),
            "pipeline_stats": stats,
            "parity": True,
        }
        out.append(row)
        print(f"sweeps/S={s_cells},{1e3 * fused_wall / s_cells:.0f},"
              f"batched={fused_wall:.2f}s;stages={stage_wall:.2f}s;"
              f"serial={serial_wall:.2f}s;speedup={row['speedup']}x")
    return out, measured


def bench_early_stop(s_cells: int, n_learners: int, rounds: int,
                     target: float = 0.2) -> dict:
    """Accuracy-target early stop: finished cells leave the lockstep batch,
    so the sweep's cost tracks live cells.  Reports the wall saving vs the
    same grid running every round, with per-cell parity against serial
    early-stopped runs asserted first."""
    full_cells = grid(s_cells, n_learners, rounds).expand()
    es_cells = grid(s_cells, n_learners, rounds, target_accuracy=target).expand()
    (_, _), full_wall = _best_of(lambda: _run_batched(full_cells))
    (results, _), es_wall = _best_of(lambda: _run_batched(es_cells))
    serial_summaries, _ = run_serial(es_cells)
    assert_parity(results, serial_summaries)
    stopped = sum(1 for r in results if r.summary["stopped_early"])
    rounds_run = sum(r.summary["rounds"] for r in results)
    row = {
        "s_cells": s_cells,
        "n_learners": n_learners,
        "rounds": rounds,
        "target_accuracy": target,
        "early_stop": True,
        "batched_wall_s": round(es_wall, 3),
        "full_run_wall_s": round(full_wall, 3),
        "speedup_vs_full": round(full_wall / max(es_wall, 1e-9), 2),
        "cells_stopped_early": stopped,
        "rounds_run_total": rounds_run,
        "rounds_full_total": s_cells * rounds,
        "parity": True,
    }
    print(f"sweeps_early_stop/S={s_cells},{1e3 * es_wall / s_cells:.0f},"
          f"wall={es_wall:.2f}s;full={full_wall:.2f}s;"
          f"speedup={row['speedup_vs_full']}x;stopped={stopped}/{s_cells}")
    return row


def bench_variants(s_cells: int, n_learners: int, rounds: int,
                   baseline=None) -> list[dict]:
    """Sharded / chunked execution variants, each parity-asserted (bitwise,
    per cell) against the plain batched run of the same grid.

    The grid is **feedback-selector-free** (no oort/ucb/contribution): a
    feedback cell's per-round stat-utility fetch forces
    ``rounds_per_dispatch=1`` for its (selector-uniform) compat batch,
    which would turn that batch's chunked variant into a K=1
    re-measurement — the variant rows measure chunking, so they keep to
    selectors that chunk.
    On a single-device host the sharded variants run the shard_map path on
    a trivial 1-device mesh (the multi-device CI leg forces 4 CPU devices
    via ``XLA_FLAGS=--xla_force_host_platform_device_count``); chunking
    dispatches ``rounds_per_dispatch=8`` rounds per launch, so its win
    tracks per-dispatch overhead — small on CPU, the point on real
    accelerator backends.
    """
    axes = {
        4: {"selector": ["random", "priority"], "saa": [False, True]},
        16: {"selector": ["random", "priority"], "saa": [False, True],
             "hardware": ["HS1", "HS2", "HS3", "HS4"]},
        64: {"selector": ["random", "priority", "safa"],
             "saa": [False, True], "hardware": ["HS1", "HS2", "HS3", "HS4"]},
    }[s_cells]
    seeds = (0, 1) if s_cells == 64 else (0,)
    base = dict(n_learners=n_learners, rounds=rounds, eval_every=EVAL_EVERY,
                mapping="label_uniform")
    cells = SweepSpec(axes=axes, base=base, seeds=seeds).expand()
    # the S=4 variant grid IS grid(4), so bench() already measured its
    # baseline; the larger variant grid is Oort-free and needs its own
    if baseline is not None and len(baseline[0]) == len(cells):
        baseline, base_wall = baseline
    else:
        (baseline, _), base_wall = _best_of(lambda: _run_batched(cells))

    def chunked(cs):
        return [dataclasses.replace(
            c, config=dataclasses.replace(c.config, rounds_per_dispatch=8))
            for c in cs]

    variants = {
        "sharded": (cells, dict(shard=True)),
        "chunked": (chunked(cells), {}),
        "sharded_chunked": (chunked(cells), dict(shard=True)),
    }
    out = []
    for name, (vcells, kw) in variants.items():
        def run():
            t0 = time.time()
            runner = SweepRunner(vcells, **kw)
            return (runner.run(), runner.last_stats), time.time() - t0
        (results, stats), wall = _best_of(run)
        for a, b in zip(baseline, results):
            assert summaries_equal(dict(a.summary), dict(b.summary)), \
                f"{name} parity violation at {a.cell.name}"
        row = {
            "variant": name,
            "s_cells": len(vcells),
            "n_learners": n_learners,
            "rounds": rounds,
            "n_devices": len(jax.devices()),
            "rounds_per_dispatch": stats["rounds_per_dispatch"],
            "batched_wall_s": round(wall, 3),
            "baseline_wall_s": round(base_wall, 3),
            "speedup_vs_baseline": round(base_wall / max(wall, 1e-9), 2),
            "dispatches_per_round": stats["dispatches_per_round"],
            "parity": True,
        }
        out.append(row)
        print(f"sweeps_{name}/S={len(vcells)},{1e3 * wall / len(vcells):.0f},"
              f"wall={wall:.2f}s;baseline={base_wall:.2f}s;"
              f"devices={row['n_devices']};"
              f"disp_per_round={row['dispatches_per_round']}")
    return out


ZOO_SELECTORS = ("random", "oort", "priority", "safa", "flips", "ucb",
                 "contribution")


def bench_zoo(n_learners: int, rounds: int) -> dict:
    """Selector-zoo race: every registered strategy on one shared-seed grid
    (matched datasets / device populations / availability traces), batched
    vs serial parity asserted.  ``selector_key`` lives in ``pipeline_key``,
    so the zoo splits into selector-uniform compat batches — the feedback
    selectors (oort/ucb/contribution) run K=1 with the l2s fetch while the
    rest chunk freely — and the row records per-selector accuracy and
    resource use for ``benchmarks/figures.py``'s zoo figure.  Smoke and
    full mode share this config, so the checked-in row doubles as the CI
    regression baseline (check_regression matches on s_cells/n_learners/
    rounds)."""
    spec = SweepSpec(axes={"selector": list(ZOO_SELECTORS)},
                     base=dict(n_learners=n_learners, rounds=rounds,
                               eval_every=EVAL_EVERY, saa=True,
                               mapping="label_uniform"),
                     seeds=(0,))
    cells = spec.expand()
    (results, stats), wall = _best_of(lambda: _run_batched(cells))
    serial_summaries, serial_wall = _best_of(lambda: run_serial(cells))
    assert_parity(results, serial_summaries)
    per_selector = {
        r.cell.coord("selector"): {
            "final_accuracy": round(r.summary["final_accuracy"], 4),
            "resource_used_s": round(r.summary["resource_used"], 1),
        } for r in results}
    row = {
        "s_cells": len(cells),
        "n_learners": n_learners,
        "rounds": rounds,
        "selectors": list(ZOO_SELECTORS),
        "batched_wall_s": round(wall, 3),
        "serial_wall_s": round(serial_wall, 3),
        "speedup": round(serial_wall / max(wall, 1e-9), 2),
        "feedback_fetches": stats["feedback_fetches"],
        "per_selector": per_selector,
        "parity": True,
    }
    print(f"sweeps_zoo/S={len(cells)},{1e3 * wall / len(cells):.0f},"
          f"batched={wall:.2f}s;serial={serial_wall:.2f}s;"
          f"speedup={row['speedup']}x")
    return row


def main() -> None:
    smoke = "--smoke" in sys.argv
    profile = "--profile" in sys.argv
    sizes = (4,) if smoke else (4, 16, 64)
    # smoke shares the full run's S=4 grid config, so the checked-in full
    # rows double as the regression guard's baseline for CI smoke runs
    n_learners, rounds = 100, ROUNDS
    rows, measured = bench(sizes, n_learners, rounds)
    # early-stop and variant rows cover the smallest and largest grid: the
    # small grid is the config CI smoke re-measures (the regression guard
    # matches rows by config), the large one is the headline measurement
    es_sizes = (sizes[0],) if len(sizes) == 1 else (sizes[0], sizes[-1])
    result = {
        "bench": "sweeps",
        "mode": "smoke" if smoke else "full",
        "sweep": rows,
        "early_stop": [bench_early_stop(s, n_learners, rounds, target=0.2)
                       for s in es_sizes],
        "variants": [row for s in es_sizes
                     for row in bench_variants(s, n_learners, rounds,
                                               baseline=measured.get(s))],
        "zoo": [bench_zoo(n_learners, rounds)],
    }
    if profile:
        result["pipeline_profile"] = rows[-1]["pipeline_stats"]
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweeps.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
