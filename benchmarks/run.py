"""Benchmark harness — one function per paper figure/table.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time per simulated
round; derived = accuracy/resource/waste/unique metrics).

Usage:
  PYTHONPATH=src python -m benchmarks.run               # all figures
  PYTHONPATH=src python -m benchmarks.run fig02 fig10   # subset
  REPRO_BENCH_SCALE=full ... python -m benchmarks.run   # paper-scale (slow)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks.figures import ALL_FIGURES
    sel = set(sys.argv[1:])
    print("name,us_per_call,derived")
    t0 = time.time()
    for fn in ALL_FIGURES:
        tag = fn.__name__.split("_")[0]
        if sel and tag not in sel and fn.__name__ not in sel:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — a figure failing must not hide others
            print(f"{fn.__name__},0,ERROR={e!r}")
    print(f"# total wall time: {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
