"""Engine throughput benchmark: flat fast path vs the seed-legacy baseline.

Measures rounds/sec of the full simulation loop at n_learners in {100, 500,
1000} and the server-aggregation microbenchmark (µs per aggregate), then
writes ``BENCH_engine.json`` at the repo root so the perf trajectory is
tracked PR over PR.  Both paths run the same seeds; the harness asserts the
simulated schedule/accounting metrics are identical before reporting speedup.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_engine           # full sweep
  PYTHONPATH=src python -m benchmarks.bench_engine --smoke   # 10-round CI smoke
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import aggregation as agg
from repro.sim import SimConfig, Simulator

PARITY_KEYS = ("rounds", "sim_time", "resource_used", "resource_wasted",
               "unique_participants")


def _run(n_learners: int, rounds: int, fast: bool) -> dict:
    cfg = SimConfig(n_learners=n_learners, rounds=rounds, eval_every=10,
                    seed=0, saa=True, setting="OC", fast_path=fast)
    # warm the jit caches with a tiny run of the same shape family, so the
    # timed wall measures the round loop rather than one-time compiles;
    # best-of-2 trials damps scheduler noise on shared machines
    Simulator(dataclasses.replace(cfg, n_learners=min(n_learners, 100),
                                  rounds=3, eval_every=2)).run()
    best = None
    for _ in range(2):
        t0 = time.time()
        sim = Simulator(cfg)
        t_init = time.time() - t0
        t0 = time.time()
        summary = sim.run().summary()
        wall = time.time() - t0
        if best is None or wall < best["wall_s"]:
            best = {
                "init_s": round(t_init, 3),
                "wall_s": round(wall, 3),
                "rounds_per_sec": round(summary["rounds"] / max(wall, 1e-9), 2),
                "summary": {k: (round(v, 6) if isinstance(v, float) else v)
                            for k, v in summary.items()},
            }
    return best


def bench_engine(sizes, rounds: int) -> list[dict]:
    out = []
    for n in sizes:
        fast = _run(n, rounds, fast=True)
        legacy = _run(n, rounds, fast=False)
        for k in PARITY_KEYS:
            assert fast["summary"][k] == legacy["summary"][k], \
                f"parity violation at n={n}: {k}"
        row = {
            "n_learners": n,
            "rounds": rounds,
            "fast": fast,
            "legacy": legacy,
            "speedup": round(fast["rounds_per_sec"]
                             / max(legacy["rounds_per_sec"], 1e-9), 2),
            "parity": True,
        }
        out.append(row)
        print(f"engine/n={n},{1e6 / max(fast['rounds_per_sec'], 1e-9):.0f},"
              f"rounds_per_sec={fast['rounds_per_sec']};"
              f"legacy={legacy['rounds_per_sec']};speedup={row['speedup']}x")
    return out


def bench_server_agg(n_updates: int = 16, d: int = 12963, iters: int = 30) -> dict:
    """µs per server aggregation on a typical round's stacked updates."""
    rng = np.random.default_rng(0)
    stacked = rng.standard_normal((n_updates, d)).astype(np.float32)
    fresh = np.array([True] * (n_updates // 2) + [False] * (n_updates -
                                                            n_updates // 2))
    tau = np.where(fresh, 0, 3).astype(np.int32)

    def timed(**kw):
        # warm the jit cache, then time
        agg.stale_synchronous_aggregate_flat(stacked, fresh, tau, **kw)
        t0 = time.time()
        for _ in range(iters):
            a, _ = agg.stale_synchronous_aggregate_flat(stacked, fresh, tau, **kw)
        np.asarray(a)
        return round((time.time() - t0) / iters * 1e6, 1)

    res = {
        "n_updates": n_updates, "d": d,
        "compiled_us": timed(),
        "eager_us": timed(compiled=False),
        "fused_kernel_us": timed(use_kernel=True),
    }
    print(f"server_agg/flat,{res['compiled_us']},"
          f"eager={res['eager_us']};fused_kernel={res['fused_kernel_us']}")
    return res


def main() -> None:
    smoke = "--smoke" in sys.argv
    sizes = (100,) if smoke else (100, 500, 1000)
    rounds = 10 if smoke else 50
    result = {
        "bench": "engine",
        "mode": "smoke" if smoke else "full",
        "engine": bench_engine(sizes, rounds),
        "server_agg": bench_server_agg(iters=5 if smoke else 30),
    }
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
