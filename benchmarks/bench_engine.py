"""Engine throughput benchmark: fused device-resident pipeline vs the
per-stage flat path vs the seed-legacy baseline.

Measures rounds/sec of the full simulation loop at n_learners in {100, 500,
1000} for three engine substrates:

  fused  — single-dispatch device-resident round pipeline (default engine);
  flat   — per-stage flat fast path (``fused_rounds=False``), the pre-fused
           "current fast path" the pipeline is measured against;
  legacy — per-learner scalar loops (``fast_path=False``), the seed baseline.

All three run the same seeds; the harness asserts the simulated
schedule/accounting metrics are identical across the three (and the fused
path's full summary — accuracy included — bit-equal to the flat path's)
before reporting speedups.  A ``participant`` section times the
participant-axis-sharded pipeline (``SimConfig.shard_participants``) at
n in {1000, 10000} learners against the unsharded run (bit-parity
asserted), the scaling path for 10k+ cohorts.  Also runs the
server-aggregation microbenchmark (µs per aggregate) and writes
``BENCH_engine.json``.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_engine             # full sweep
  PYTHONPATH=src python -m benchmarks.bench_engine --smoke     # 10-round CI smoke
  PYTHONPATH=src python -m benchmarks.bench_engine --profile   # + pipeline
      dispatch/transfer stats, with the round loop under
      jax.transfer_guard("disallow") so implicit host transfers fail
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import aggregation as agg
from repro.sim import SimConfig, Simulator
from repro.sim.engine import Substrate

PARITY_KEYS = ("rounds", "sim_time", "resource_used", "resource_wasted",
               "unique_participants")

MODES = {
    "fused": {},
    "flat": {"fused_rounds": False},
    "legacy": {"fast_path": False},
}


def _cfg(n_learners: int, rounds: int, mode: str) -> SimConfig:
    return SimConfig(n_learners=n_learners, rounds=rounds, eval_every=10,
                     seed=0, saa=True, setting="OC", **MODES[mode])


def _run(n_learners: int, rounds: int, mode: str, trials: int = 2) -> dict:
    cfg = _cfg(n_learners, rounds, mode)
    # warm the jit caches with a full run of the same shape family, so the
    # timed wall measures the round loop rather than one-time compiles;
    # best-of-N trials damps scheduler noise on shared machines
    Simulator(cfg).run()
    best = None
    for _ in range(trials):
        t0 = time.time()
        sim = Simulator(cfg)
        t_init = time.time() - t0
        t0 = time.time()
        summary = sim.run().summary()
        wall = time.time() - t0
        if best is None or wall < best["wall_s"]:
            best = {
                "init_s": round(t_init, 3),
                "wall_s": round(wall, 3),
                "rounds_per_sec": round(summary["rounds"] / max(wall, 1e-9), 2),
                "summary": {k: (round(v, 6) if isinstance(v, float) else v)
                            for k, v in summary.items()},
            }
    return best


def bench_engine(sizes, rounds: int, trials: int = 2) -> list[dict]:
    out = []
    for n in sizes:
        res = {m: _run(n, rounds, m, trials) for m in MODES}
        for m in ("flat", "legacy"):
            for k in PARITY_KEYS:
                assert res["fused"]["summary"][k] == res[m]["summary"][k], \
                    f"parity violation at n={n} vs {m}: {k}"
        # the fused pipeline must be bit-identical to the per-stage flat
        # path on the full summary, accuracy included
        assert res["fused"]["summary"] == res["flat"]["summary"], \
            f"fused/flat summary divergence at n={n}"
        rps = {m: res[m]["rounds_per_sec"] for m in MODES}
        row = {
            "n_learners": n,
            "rounds": rounds,
            **res,
            "speedup_fused_vs_flat": round(rps["fused"]
                                           / max(rps["flat"], 1e-9), 2),
            "speedup_fused_vs_legacy": round(rps["fused"]
                                             / max(rps["legacy"], 1e-9), 2),
            "parity": True,
        }
        out.append(row)
        print(f"engine/n={n},{1e6 / max(rps['fused'], 1e-9):.0f},"
              f"fused={rps['fused']};flat={rps['flat']};"
              f"legacy={rps['legacy']};"
              f"speedup_vs_flat={row['speedup_fused_vs_flat']}x")
    return out


def bench_participant(sizes=((1000, 64), (10000, 256)), rounds: int = 6,
                      trials: int = 2) -> list[dict]:
    """Participant-axis sharding at large cohort pools: n in {1000, 10000}
    learners, cohort rows split over all local devices vs the unsharded
    pipeline, full-summary bit-parity asserted before any speedup is
    reported.  Each n shares ONE substrate build across modes and trials
    (``shard_participants`` is not part of the substrate key), so the rows
    time the round loop, not 10k-learner world construction.  On a
    single-device host the mesh is trivial — the row measures shard_map
    overhead and guards the code path; the parallel win needs a real
    multi-chip backend (the multi-device CI leg proves correctness).
    Row configs are identical in smoke and full runs so the regression
    guard always finds a matching baseline row.
    """
    import jax
    out = []
    for n, n_target in sizes:
        cfg = SimConfig(n_learners=n, rounds=rounds, eval_every=rounds // 2,
                        seed=0, saa=True, setting="OC", selector="priority",
                        mapping="label_uniform", n_target=n_target)
        sub = Substrate.build(cfg)

        def run(c):
            Simulator(c, substrate=sub).run()         # warm the jit caches
            best = None
            for _ in range(trials):
                t0 = time.time()
                summary = Simulator(c, substrate=sub).run().summary()
                wall = time.time() - t0
                if best is None or wall < best["wall_s"]:
                    best = {
                        "wall_s": round(wall, 3),
                        "rounds_per_sec": round(
                            summary["rounds"] / max(wall, 1e-9), 2),
                        "summary": {k: (round(v, 6) if isinstance(v, float)
                                        else v) for k, v in summary.items()},
                    }
            return best

        res_u = run(cfg)
        res_s = run(dataclasses.replace(cfg, shard_participants=True))
        assert res_u["summary"] == res_s["summary"], \
            f"participant-sharded divergence at n={n}"
        rps_u, rps_s = res_u["rounds_per_sec"], res_s["rounds_per_sec"]
        row = {
            "n_learners": n,
            "n_target": n_target,
            "rounds": rounds,
            "n_devices": len(jax.devices()),
            "unsharded": res_u,
            "sharded": res_s,
            "speedup_sharded": round(rps_s / max(rps_u, 1e-9), 2),
            "parity": True,
        }
        out.append(row)
        print(f"participant/n={n},{1e6 / max(rps_s, 1e-9):.0f},"
              f"sharded={rps_s};unsharded={rps_u};"
              f"devices={row['n_devices']};"
              f"speedup={row['speedup_sharded']}x")
    return out


def bench_telemetry(n_learners: int = 1000, rounds: int = 6,
                    trials: int = 2) -> dict:
    """Overhead of full telemetry (level 2: device lane + spans + JSONL round
    log) over a telemetry-off run of the same config, sharing one substrate.
    Asserts the summaries are bit-identical (the lane may not perturb the
    round math), then reports the rounds/sec regression fraction — the
    acceptance bar is < 5% at n=1000."""
    import tempfile

    from repro.telemetry import TelemetrySession

    cfg = SimConfig(n_learners=n_learners, rounds=rounds,
                    eval_every=rounds // 2, seed=0, saa=True, setting="OC",
                    selector="priority", mapping="label_uniform")
    sub = Substrate.build(cfg)

    def run(c, telemetry=None):
        Simulator(c, substrate=sub).run(telemetry=telemetry)   # warm compiles
        best = None
        for _ in range(trials):
            t0 = time.time()
            summary = Simulator(c, substrate=sub).run(
                telemetry=telemetry).summary()
            wall = time.time() - t0
            if best is None or wall < best["wall_s"]:
                best = {
                    "wall_s": round(wall, 3),
                    "rounds_per_sec": round(
                        summary["rounds"] / max(wall, 1e-9), 2),
                    "summary": {k: (round(v, 6) if isinstance(v, float)
                                    else v) for k, v in summary.items()},
                }
        return best

    res_off = run(cfg)
    with tempfile.TemporaryDirectory() as tmp:
        session = TelemetrySession(tmp)
        try:
            res_on = run(dataclasses.replace(cfg, telemetry=2),
                         telemetry=session)
        finally:
            session.close()
    assert res_off["summary"] == res_on["summary"], \
        "telemetry level 2 perturbed the run summary"
    rps_off, rps_on = res_off["rounds_per_sec"], res_on["rounds_per_sec"]
    row = {
        "n_learners": n_learners,
        "rounds": rounds,
        "off": res_off,
        "full": res_on,
        "overhead_frac": round(max(0.0, 1.0 - rps_on / max(rps_off, 1e-9)), 4),
        "parity": True,
    }
    print(f"telemetry/n={n_learners},{1e6 / max(rps_on, 1e-9):.0f},"
          f"full={rps_on};off={rps_off};"
          f"overhead={100 * row['overhead_frac']:.1f}%")
    return row


LM_KNOBS = (("d_ff", 8), ("d_model", 4), ("n_heads", 1), ("n_layers", 1))


def bench_lm(rounds: int = 20, trials: int = 2) -> list[dict]:
    """Learner-model zoo through the fused pipeline: rounds/sec and
    eval-loss-at-budget for the ``mlp`` classifier baseline vs a tiny
    ``transformer`` LM at matched flat dimension (D 12,835 vs 8,364 — the
    same order of magnitude, so the rows compare round machinery, not
    model size).  Each model row runs fused AND per-stage flat on one
    shared substrate and asserts the summaries bit-equal before reporting
    the fused rounds/sec.  The transformer row additionally races
    selectors at the same budget (``selector_race``: eval loss per
    selector) — selector choice must move LM eval loss, the claim the
    model zoo exists to test.  Row configs are identical in smoke and
    full runs so the regression guard always finds a matching baseline
    row; a baseline file without the ``lm`` section skips cleanly."""
    base = dict(n_learners=32, rounds=rounds, eval_every=max(rounds // 4, 1),
                seed=0, saa=True, n_target=6, local_steps=2, local_batch=4,
                dynamic_availability=False)
    cells = {
        "mlp": SimConfig(**base),
        "transformer": SimConfig(benchmark="tokens_skew", model="transformer",
                                 model_params=LM_KNOBS, **base),
    }
    out = []
    for name, cfg in cells.items():
        sub = Substrate.build(cfg)

        def run(c):
            acct = Simulator(c, substrate=sub).run()      # warm the jit caches
            best = None
            for _ in range(trials):
                t0 = time.time()
                acct = Simulator(c, substrate=sub).run()
                wall = time.time() - t0
                if best is None or wall < best["wall_s"]:
                    losses = [r.loss for r in acct.records
                              if r.loss == r.loss]
                    summary = acct.summary()
                    best = {
                        "wall_s": round(wall, 3),
                        "rounds_per_sec": round(
                            summary["rounds"] / max(wall, 1e-9), 2),
                        "eval_loss": round(float(losses[-1]), 6),
                        "summary": {k: (round(v, 6) if isinstance(v, float)
                                        else v) for k, v in summary.items()},
                    }
            return best

        res_f = run(cfg)
        res_flat = run(dataclasses.replace(cfg, fused_rounds=False))
        assert res_f["summary"] == res_flat["summary"], \
            f"fused/flat divergence for model={name}"
        row = {
            "model": name,
            "n_learners": cfg.n_learners,
            "rounds": rounds,
            "d": int(np.asarray(Simulator(cfg, substrate=sub)
                                .flat_params).size),
            **res_f,
            "flat_rounds_per_sec": res_flat["rounds_per_sec"],
            "parity": True,
        }
        if name == "transformer":
            race = {"random": res_f["eval_loss"]}
            for sel in ("flips", "priority"):
                race[sel] = run(dataclasses.replace(cfg, selector=sel))[
                    "eval_loss"]
            assert len(set(race.values())) > 1, \
                "selector choice did not move LM eval loss"
            row["selector_race"] = race
        out.append(row)
        print(f"lm/model={name},{1e6 / max(res_f['rounds_per_sec'], 1e-9):.0f},"
              f"d={row['d']};fused={res_f['rounds_per_sec']};"
              f"flat={res_flat['rounds_per_sec']};"
              f"eval_loss={res_f['eval_loss']}")
    return out


def profile_pipeline(n_learners: int, rounds: int) -> dict:
    """Per-stage dispatch counts and host-transfer bytes of the fused round
    loop, run under ``jax.transfer_guard("disallow")`` — an implicit host
    transfer anywhere in the hot path raises instead of silently slowing
    the loop down."""
    from repro.sim.pipeline import RoundPipeline
    cfg = _cfg(n_learners, rounds, "fused")
    Simulator(cfg).run()                      # warm compiles outside the guard
    pipe = RoundPipeline([Simulator(cfg)])
    pipe.run(transfer_guard=True)
    stats = pipe.stats.as_dict()
    stats["transfer_guard"] = "disallow"
    print(f"profile/n={n_learners},{stats['dispatches_per_round']},"
          f"h2d_per_round={stats['h2d_bytes_per_round']}B;"
          f"d2h_per_round={stats['d2h_bytes_per_round']}B")
    return stats


def bench_server_agg(n_updates: int = 16, d: int = 12963, iters: int = 30) -> dict:
    """µs per server aggregation on a typical round's stacked updates."""
    rng = np.random.default_rng(0)
    stacked = rng.standard_normal((n_updates, d)).astype(np.float32)
    fresh = np.array([True] * (n_updates // 2) + [False] * (n_updates -
                                                            n_updates // 2))
    tau = np.where(fresh, 0, 3).astype(np.int32)

    def timed(**kw):
        # warm the jit cache, then time
        agg.stale_synchronous_aggregate_flat(stacked, fresh, tau, **kw)
        t0 = time.time()
        for _ in range(iters):
            a, _ = agg.stale_synchronous_aggregate_flat(stacked, fresh, tau, **kw)
        np.asarray(a)
        return round((time.time() - t0) / iters * 1e6, 1)

    res = {
        "n_updates": n_updates, "d": d,
        "compiled_us": timed(),
        "eager_us": timed(compiled=False),
        "fused_kernel_us": timed(use_kernel=True),
    }
    print(f"server_agg/flat,{res['compiled_us']},"
          f"eager={res['eager_us']};fused_kernel={res['fused_kernel_us']}")
    return res


def main() -> None:
    smoke = "--smoke" in sys.argv
    profile = "--profile" in sys.argv
    sizes = (100,) if smoke else (100, 500, 1000)
    rounds = 10 if smoke else 50
    result = {
        "bench": "engine",
        "mode": "smoke" if smoke else "full",
        "engine": bench_engine(sizes, rounds, trials=2 if smoke else 3),
        # identical configs in smoke and full (the guard matches rows)
        "participant": bench_participant(trials=2),
        "lm": bench_lm(trials=2),
        "telemetry": [bench_telemetry(trials=2)],
        "server_agg": bench_server_agg(iters=5 if smoke else 30),
    }
    if profile:
        result["pipeline_profile"] = profile_pipeline(sizes[-1], rounds)
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
