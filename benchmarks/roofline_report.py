"""Assemble the EXPERIMENTS.md roofline table from experiments/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh_filter=None):
    recs = []
    base = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    for fn in glob.glob(os.path.join(base, "*.json")):
        with open(fn) as f:
            r = json.load(f)
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        recs.append(r)
    recs.sort(key=lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"])
                             if r["shape"] in ORDER_SHAPES else 9, r["mesh"]))
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.mesh)
    if args.md:
        print("| arch | shape | mesh | t_compute | t_memory | t_collective |"
              " bottleneck | useful | HBM/chip |")
        print("|---|---|---|---|---|---|---|---|---|")
    else:
        print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
              "bottleneck,useful_ratio,hbm_per_chip_gb,flops_per_chip,"
              "coll_bytes_per_chip")
    for r in recs:
        ro = r["roofline"]
        hbm = (r["memory_analysis"].get("argument_size_in_bytes", 0)
               + r["memory_analysis"].get("temp_size_in_bytes", 0)) / 2**30
        if args.md:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                  f" {fmt_s(ro['t_compute'])} | {fmt_s(ro['t_memory'])} |"
                  f" {fmt_s(ro['t_collective'])} | {ro['bottleneck']} |"
                  f" {ro['useful_ratio']:.2f} | {hbm:.1f}GiB |")
        else:
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{ro['t_compute']:.4e},{ro['t_memory']:.4e},"
                  f"{ro['t_collective']:.4e},{ro['bottleneck']},"
                  f"{ro['useful_ratio']:.3f},{hbm:.1f},"
                  f"{ro['hlo_flops']:.3e},{ro['collective_bytes']:.3e}")


if __name__ == "__main__":
    main()
