"""Benchmark regression guard: compare a fresh (smoke) bench run against
the checked-in ``BENCH_*.json`` baselines.

Philosophy: **fail on parity mismatches, not on noise.**  Parity flags in
the *current* files must all be true — a false one means the executors
diverged, which no amount of scheduler noise excuses.  Performance metrics
(engine rounds/sec, sweep wall seconds) are compared only between rows
whose configuration keys match exactly, with a generous multiplicative
tolerance (default 2x) that absorbs CI-runner variance; rows without a
matching baseline are reported and skipped.  Metrics where bigger is
better (rounds/sec) fail when ``current < baseline / tol``; smaller-is-
better metrics (wall seconds) fail when ``current > baseline * tol``.

When ``--summary-out`` is given (or ``$GITHUB_STEP_SUMMARY`` is set, as on
GitHub Actions), a markdown comparison table — baseline vs fresh, ratio,
parity flags — is appended there, so regressions are readable straight
from the Actions run page without downloading artifacts.

Usage (the CI copies the checked-in files aside before the benches
overwrite them):

  cp BENCH_engine.json BENCH_sweeps.json .bench_baseline/
  PYTHONPATH=src python -m benchmarks.bench_engine --smoke
  PYTHONPATH=src python -m benchmarks.bench_sweeps --smoke
  PYTHONPATH=src python -m benchmarks.check_regression \
      --baseline-dir .bench_baseline [--current-dir .] [--tolerance 2.0]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

# (file, section, match keys, metric, higher_is_better) — one spec per
# comparable row family
COMPARISONS = [
    ("BENCH_engine.json", "engine", ("n_learners", "rounds"),
     lambda r: r["fused"]["rounds_per_sec"], True, "fused rounds/sec"),
    ("BENCH_engine.json", "engine", ("n_learners", "rounds"),
     lambda r: r["flat"]["rounds_per_sec"], True, "flat rounds/sec"),
    ("BENCH_engine.json", "participant",
     ("n_learners", "n_target", "rounds", "n_devices"),
     lambda r: r["sharded"]["rounds_per_sec"], True,
     "participant-sharded rounds/sec"),
    ("BENCH_engine.json", "participant",
     ("n_learners", "n_target", "rounds", "n_devices"),
     lambda r: r["unsharded"]["rounds_per_sec"], True,
     "participant-unsharded rounds/sec"),
    ("BENCH_engine.json", "telemetry", ("n_learners", "rounds"),
     lambda r: r["full"]["rounds_per_sec"], True,
     "telemetry-full rounds/sec"),
    ("BENCH_engine.json", "lm", ("model", "n_learners", "rounds"),
     lambda r: r["rounds_per_sec"], True, "lm fused rounds/sec"),
    ("BENCH_engine.json", "lm", ("model", "n_learners", "rounds"),
     lambda r: r["eval_loss"], False, "lm eval loss at budget"),
    ("BENCH_sweeps.json", "sweep", ("s_cells", "n_learners", "rounds"),
     lambda r: r["batched_wall_s"], False, "batched wall s"),
    ("BENCH_sweeps.json", "early_stop",
     ("s_cells", "n_learners", "rounds", "target_accuracy"),
     lambda r: r["batched_wall_s"], False, "early-stop wall s"),
    ("BENCH_sweeps.json", "variants",
     ("variant", "s_cells", "n_learners", "rounds", "n_devices"),
     lambda r: r["batched_wall_s"], False, "variant wall s"),
    ("BENCH_sweeps.json", "zoo", ("s_cells", "n_learners", "rounds"),
     lambda r: r["batched_wall_s"], False, "selector-zoo wall s"),
]


def _walk_parity(node, path, failures):
    """Every ``parity`` flag anywhere in the current payload must be true."""
    if isinstance(node, dict):
        for k, v in node.items():
            if k == "parity" and v is not True:
                failures.append(f"parity flag false at {path}")
            _walk_parity(v, f"{path}.{k}", failures)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _walk_parity(v, f"{path}[{i}]", failures)


def _row_key(row: dict, keys: tuple):
    try:
        return tuple(row[k] for k in keys)
    except KeyError:
        return None


def _summary_markdown(rows: list, parity_fails: list, tolerance: float) -> str:
    """Markdown comparison table for ``$GITHUB_STEP_SUMMARY`` — regressions
    readable from the Actions run page, no artifact download needed."""
    out = ["## Benchmark regression guard",
           f"Tolerance {tolerance}x; higher-is-better metrics fail below "
           f"`baseline / {tolerance}`, lower-is-better above "
           f"`baseline * {tolerance}`.", ""]
    if parity_fails:
        out += ["### :x: Parity failures", ""]
        out += [f"- `{p}`" for p in parity_fails] + [""]
    else:
        out += ["All parity flags true.", ""]
    out += ["| status | row | metric | baseline | current | ratio |",
            "|---|---|---|---|---|---|"]
    icon = {"OK": ":white_check_mark:", "FAIL": ":x:", "SKIP": ":fast_forward:"}
    for r in rows:
        base = "—" if r["baseline"] is None else f"{r['baseline']}"
        curv = "—" if r["current"] is None else f"{r['current']}"
        ratio = ("—" if not (r["baseline"] and r["current"] is not None)
                 else f"{r['current'] / r['baseline']:.2f}x")
        out.append(f"| {icon[r['status']]} {r['status']} | `{r['tag']}` | "
                   f"{r['label']} | {base} | {curv} | {ratio} |")
    counts = {s: sum(1 for r in rows if r["status"] == s)
              for s in ("OK", "SKIP", "FAIL")}
    out += ["", f"{counts['OK']} compared, {counts['SKIP']} skipped, "
            f"{counts['FAIL'] + len(parity_fails)} failures."]
    return "\n".join(out) + "\n"


PROFILE_KEYS = ("dispatches_per_round", "h2d_bytes_per_round",
                "d2h_bytes_per_round")


def _transfer_profile(baseline_dir, current_dir, failures) -> str:
    """Markdown "Transfer profile" section: the fused pipeline's
    dispatches-per-round and host-transfer bytes-per-round vs the baseline.
    These are deterministic counts, not timings — a dispatch-count increase
    is a real architecture regression and fails outright; runs whose
    BENCH_engine.json lacks a profile (benches without ``--profile``) skip
    silently."""
    def load(d):
        p = d / "BENCH_engine.json"
        if not p.exists():
            return None
        return json.loads(p.read_text()).get("pipeline_profile")

    cur = load(current_dir)
    if cur is None:
        return ""
    base = load(baseline_dir)
    out = ["### Transfer profile (fused pipeline)", "",
           "| metric | baseline | current |", "|---|---|---|"]
    for k in PROFILE_KEYS:
        b = "—" if base is None or k not in base else base[k]
        out.append(f"| {k} | {b} | {cur.get(k, '—')} |")
    if base is not None and all(k in base and k in cur for k in PROFILE_KEYS):
        if cur["dispatches_per_round"] > base["dispatches_per_round"]:
            failures.append(
                "pipeline_profile: dispatches_per_round rose from "
                f"{base['dispatches_per_round']} to "
                f"{cur['dispatches_per_round']}")
            out.append("")
            out.append(":x: dispatches-per-round regression")
    guard = cur.get("transfer_guard")
    if guard:
        out += ["", f"Round loop ran under `jax.transfer_guard(\"{guard}\")`."]
    return "\n".join(out) + "\n\n"


def check(baseline_dir: pathlib.Path, current_dir: pathlib.Path,
          tolerance: float, summary_path=None) -> int:
    failures, skipped, compared = [], [], []
    parity_fails, rows_md = [], []
    current_cache = {}
    for fname, section, keys, metric, hib, label in COMPARISONS:
        cur_path = current_dir / fname
        base_path = baseline_dir / fname
        if not cur_path.exists():
            failures.append(f"missing current file {cur_path}")
            rows_md.append({"status": "FAIL", "tag": f"{fname}:{section}",
                            "label": "missing current file", "baseline": None,
                            "current": None})
            continue
        if fname not in current_cache:
            current_cache[fname] = json.loads(cur_path.read_text())
            _walk_parity(current_cache[fname], fname, parity_fails)
        cur = current_cache[fname]
        if not base_path.exists():
            skipped.append(f"{fname}:{section} — no baseline file")
            rows_md.append({"status": "SKIP", "tag": f"{fname}:{section}",
                            "label": "no baseline file", "baseline": None,
                            "current": None})
            continue
        base = json.loads(base_path.read_text())
        base_rows = {_row_key(r, keys): r for r in base.get(section, [])}
        for row in cur.get(section, []):
            key = _row_key(row, keys)
            ref = base_rows.get(key)
            tag = f"{section}{list(key) if key else ''} {label}"
            if ref is None:
                skipped.append(f"{tag} — no matching baseline row")
                rows_md.append({"status": "SKIP",
                                "tag": f"{section}{list(key) if key else ''}",
                                "label": label, "baseline": None,
                                "current": metric(row)})
                continue
            c, b = metric(row), metric(ref)
            if hib:
                ok, detail = c >= b / tolerance, f"{c} vs baseline {b}"
            else:
                ok, detail = c <= b * tolerance, f"{c}s vs baseline {b}s"
            rows_md.append({"status": "OK" if ok else "FAIL",
                            "tag": f"{section}{list(key)}", "label": label,
                            "baseline": b, "current": c})
            (compared if ok else failures).append(
                f"{tag}: {detail}" + ("" if ok else
                                      f" (beyond {tolerance}x tolerance)"))
    profile_md = _transfer_profile(baseline_dir, current_dir, failures)
    failures = parity_fails + failures

    for line in compared:
        print(f"OK    {line}")
    for line in skipped:
        print(f"SKIP  {line}")
    for line in failures:
        print(f"FAIL  {line}", file=sys.stderr)
    print(f"# {len(compared)} compared, {len(skipped)} skipped, "
          f"{len(failures)} failures (tolerance {tolerance}x)")

    if summary_path:
        md = _summary_markdown(rows_md, parity_fails, tolerance)
        with open(summary_path, "a") as f:
            f.write(md)
            if profile_md:
                f.write("\n" + profile_md)
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", required=True, type=pathlib.Path,
                    help="directory holding the checked-in BENCH_*.json")
    ap.add_argument("--current-dir", default=".", type=pathlib.Path,
                    help="directory holding the fresh bench outputs")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="multiplicative noise tolerance (default 2x)")
    ap.add_argument("--summary-out", default=None,
                    help="append a markdown comparison table here (defaults "
                         "to $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args(argv)
    return check(args.baseline_dir, args.current_dir, args.tolerance,
                 summary_path=(args.summary_out
                               or os.environ.get("GITHUB_STEP_SUMMARY")))


if __name__ == "__main__":
    sys.exit(main())
