"""Benchmark regression guard: compare a fresh (smoke) bench run against
the checked-in ``BENCH_*.json`` baselines.

Philosophy: **fail on parity mismatches, not on noise.**  Parity flags in
the *current* files must all be true — a false one means the executors
diverged, which no amount of scheduler noise excuses.  Performance metrics
(engine rounds/sec, sweep wall seconds) are compared only between rows
whose configuration keys match exactly, with a generous multiplicative
tolerance (default 2x) that absorbs CI-runner variance; rows without a
matching baseline are reported and skipped.  Metrics where bigger is
better (rounds/sec) fail when ``current < baseline / tol``; smaller-is-
better metrics (wall seconds) fail when ``current > baseline * tol``.

Usage (the CI copies the checked-in files aside before the benches
overwrite them):

  cp BENCH_engine.json BENCH_sweeps.json .bench_baseline/
  PYTHONPATH=src python -m benchmarks.bench_engine --smoke
  PYTHONPATH=src python -m benchmarks.bench_sweeps --smoke
  PYTHONPATH=src python -m benchmarks.check_regression \
      --baseline-dir .bench_baseline [--current-dir .] [--tolerance 2.0]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# (file, section, match keys, metric, higher_is_better) — one spec per
# comparable row family
COMPARISONS = [
    ("BENCH_engine.json", "engine", ("n_learners", "rounds"),
     lambda r: r["fused"]["rounds_per_sec"], True, "fused rounds/sec"),
    ("BENCH_engine.json", "engine", ("n_learners", "rounds"),
     lambda r: r["flat"]["rounds_per_sec"], True, "flat rounds/sec"),
    ("BENCH_sweeps.json", "sweep", ("s_cells", "n_learners", "rounds"),
     lambda r: r["batched_wall_s"], False, "batched wall s"),
    ("BENCH_sweeps.json", "early_stop",
     ("s_cells", "n_learners", "rounds", "target_accuracy"),
     lambda r: r["batched_wall_s"], False, "early-stop wall s"),
    ("BENCH_sweeps.json", "variants",
     ("variant", "s_cells", "n_learners", "rounds", "n_devices"),
     lambda r: r["batched_wall_s"], False, "variant wall s"),
]


def _walk_parity(node, path, failures):
    """Every ``parity`` flag anywhere in the current payload must be true."""
    if isinstance(node, dict):
        for k, v in node.items():
            if k == "parity" and v is not True:
                failures.append(f"parity flag false at {path}")
            _walk_parity(v, f"{path}.{k}", failures)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _walk_parity(v, f"{path}[{i}]", failures)


def _row_key(row: dict, keys: tuple):
    try:
        return tuple(row[k] for k in keys)
    except KeyError:
        return None


def check(baseline_dir: pathlib.Path, current_dir: pathlib.Path,
          tolerance: float) -> int:
    failures, skipped, compared = [], [], []
    current_cache = {}
    for fname, section, keys, metric, hib, label in COMPARISONS:
        cur_path = current_dir / fname
        base_path = baseline_dir / fname
        if not cur_path.exists():
            failures.append(f"missing current file {cur_path}")
            continue
        if fname not in current_cache:
            current_cache[fname] = json.loads(cur_path.read_text())
            _walk_parity(current_cache[fname], fname, failures)
        cur = current_cache[fname]
        if not base_path.exists():
            skipped.append(f"{fname}:{section} — no baseline file")
            continue
        base = json.loads(base_path.read_text())
        base_rows = {_row_key(r, keys): r for r in base.get(section, [])}
        for row in cur.get(section, []):
            key = _row_key(row, keys)
            ref = base_rows.get(key)
            tag = f"{section}{list(key) if key else ''} {label}"
            if ref is None:
                skipped.append(f"{tag} — no matching baseline row")
                continue
            c, b = metric(row), metric(ref)
            if hib:
                ok, detail = c >= b / tolerance, f"{c} vs baseline {b}"
            else:
                ok, detail = c <= b * tolerance, f"{c}s vs baseline {b}s"
            (compared if ok else failures).append(
                f"{tag}: {detail}" + ("" if ok else
                                      f" (beyond {tolerance}x tolerance)"))

    for line in compared:
        print(f"OK    {line}")
    for line in skipped:
        print(f"SKIP  {line}")
    for line in failures:
        print(f"FAIL  {line}", file=sys.stderr)
    print(f"# {len(compared)} compared, {len(skipped)} skipped, "
          f"{len(failures)} failures (tolerance {tolerance}x)")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", required=True, type=pathlib.Path,
                    help="directory holding the checked-in BENCH_*.json")
    ap.add_argument("--current-dir", default=".", type=pathlib.Path,
                    help="directory holding the fresh bench outputs")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="multiplicative noise tolerance (default 2x)")
    args = ap.parse_args(argv)
    return check(args.baseline_dir, args.current_dir, args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
