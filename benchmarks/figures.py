"""One function per paper figure/table (DESIGN.md §8 index).

Each emits ``name,us_per_call,derived`` CSV rows via benchmarks.common.emit.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, N_LEARNERS, ROUNDS, emit, run_variant


def fig02_safa_waste():
    """SAFA vs oracle (SAFA+O) vs FedAvg-Random: resource usage & wastage.
    Paper: SAFA consumes ~5x the oracle's resources, wasting ~80% at scale."""
    kw = dict(model_mbits=688.0, deadline=150.0)   # ResNet34-scale updates
    _, s, w = run_variant("safa", selector="safa", setting="DL", saa=True,
                          staleness_threshold=5,
                          safa_target_ratio=0.10, mapping="fedscale", **kw)
    emit("fig02", "SAFA", s, w)
    oracle = dict(s)
    oracle["resource_used"] = s["resource_used"] - s["resource_wasted"]
    oracle["resource_wasted"] = 0.0
    oracle["waste_fraction"] = 0.0
    emit("fig02", "SAFA+O(oracle)", oracle, w)
    _, s, w = run_variant("fedavg10", selector="random", setting="DL",
                          n_target=10, mapping="fedscale", **kw)
    emit("fig02", "FedAvg-Random10", s, w)
    _, s, w = run_variant("fedavg30", selector="random", setting="DL",
                          n_target=30, mapping="fedscale", **kw)
    emit("fig02", "FedAvg-Random30", s, w)


def fig03_heterogeneity():
    """Oort vs Random under IID and label-limited mappings, AllAvail.
    Paper: Oort wins IID; Random wins non-IID via diversity."""
    for mapping in ("uniform", "label_uniform"):
        for sel in ("oort", "random"):
            _, s, w = run_variant(f"{sel}-{mapping}", selector=sel,
                                  mapping=mapping, dynamic_availability=False)
            emit("fig03", f"{sel}/{mapping}", s, w)


def fig04_availability():
    """Random selection, AllAvail vs DynAvail, IID vs non-IID.
    Paper: availability dynamics cost ~10 accuracy points in non-IID."""
    for mapping in ("uniform", "label_uniform"):
        for dyn in (False, True):
            tag = "DynAvail" if dyn else "AllAvail"
            _, s, w = run_variant(f"rand-{mapping}-{tag}", selector="random",
                                  mapping=mapping, dynamic_availability=dyn)
            emit("fig04", f"{mapping}/{tag}", s, w)


def fig06_selection():
    """RELAY vs Oort vs Random vs Priority under OC+DynAvail, non-IID maps."""
    for mapping in ("fedscale", "label_uniform", "label_zipf"):
        variants = {
            "RELAY": dict(selector="priority", saa=True, apt=True),
            "Priority": dict(selector="priority"),
            "Oort": dict(selector="oort"),
            "Random": dict(selector="random"),
        }
        for name, kw in variants.items():
            _, s, w = run_variant(f"{name}-{mapping}", mapping=mapping,
                                  setting="OC", dynamic_availability=True, **kw)
            emit("fig06", f"{name}/{mapping}", s, w)


def fig07_safa_vs_relay():
    """DL+DynAvail head-to-head; paper: similar run time, RELAY uses ~20-60%
    fewer resources and wins on accuracy in non-IID."""
    for mapping in ("fedscale", "label_uniform"):
        _, s, w = run_variant(f"safa-{mapping}", selector="safa", setting="DL",
                              saa=True, staleness_threshold=5, deadline=100.0,
                              safa_target_ratio=0.10, mapping=mapping,
                              model_mbits=688.0)
        emit("fig07", f"SAFA/{mapping}", s, w)
        _, s, w = run_variant(f"relay-{mapping}", selector="priority",
                              setting="DL", saa=True, staleness_threshold=5,
                              deadline=100.0, apt=True, mapping=mapping,
                              model_mbits=688.0)
        emit("fig07", f"RELAY/{mapping}", s, w)


def fig08_apt():
    """Adaptive participant target with 50 participants, OC setting."""
    n50 = max(20, N_LEARNERS // 4)
    for dyn in (False, True):
        tag = "DynAvail" if dyn else "AllAvail"
        for name, kw in {
            "RELAY": dict(selector="priority", saa=True),
            "RELAY+APT": dict(selector="priority", saa=True, apt=True),
            "Oort": dict(selector="oort"),
            "Random": dict(selector="random"),
        }.items():
            _, s, w = run_variant(f"{name}-{tag}", mapping="label_uniform",
                                  setting="OC", n_target=n50,
                                  dynamic_availability=dyn, **kw)
            emit("fig08", f"{name}/{tag}", s, w)


def fig09_stale_agg():
    """SAA contribution in OC+AllAvail (IPS degenerates to random)."""
    for mapping in ("uniform", "label_uniform"):
        for name, kw in {
            "RELAY(SAA)": dict(selector="priority", saa=True),
            "Oort": dict(selector="oort"),
            "Random": dict(selector="random"),
        }.items():
            _, s, w = run_variant(f"{name}-{mapping}", mapping=mapping,
                                  setting="OC", dynamic_availability=False, **kw)
            emit("fig09", f"{name}/{mapping}", s, w)


def fig10_scaling_rules():
    """Equal vs DynSGD vs AdaSGD vs RELAY's Eq. 2, OC+DynAvail."""
    for mapping in ("uniform", "label_uniform", "label_zipf"):
        for rule in ("equal", "dynsgd", "adasgd", "relay"):
            _, s, w = run_variant(f"{rule}-{mapping}", selector="priority",
                                  saa=True, scaling_rule=rule, mapping=mapping,
                                  setting="OC", deadline=60.0,
                                  dynamic_availability=True)
            emit("fig10", f"{rule}/{mapping}", s, w)


def fig11_scale():
    """3x learner population: resource blow-up of select-all vs RELAY."""
    n3 = 3 * N_LEARNERS
    for mapping in ("uniform", "label_uniform"):
        _, s, w = run_variant(f"safa3x-{mapping}", selector="safa",
                              setting="DL", saa=True, staleness_threshold=5,
                              deadline=100.0, n_learners=n3, mapping=mapping,
                              rounds=ROUNDS // 2, model_mbits=688.0)
        emit("fig11", f"SAFA-3x/{mapping}", s, w)
        _, s, w = run_variant(f"relay3x-{mapping}", selector="priority",
                              saa=True, apt=True, n_learners=n3,
                              mapping=mapping, rounds=ROUNDS // 2)
        emit("fig11", f"RELAY-3x/{mapping}", s, w)


def fig12_hardware():
    """Future-hardware scenarios HS1-HS4: Oort degrades non-IID, RELAY gains."""
    for hs in ("HS1", "HS2", "HS4"):
        for sel, kw in {"Oort": dict(selector="oort"),
                        "RELAY": dict(selector="priority", saa=True, apt=True)}.items():
            _, s, w = run_variant(f"{sel}-{hs}", mapping="label_uniform",
                                  hardware_scenario=hs, setting="OC",
                                  dynamic_availability=True, **kw)
            emit("fig12", f"{sel}/{hs}", s, w)


def thm1_convergence():
    """Theorem 1 empirics: gradient-norm decay vs (n, K, tau)."""
    import sys
    sys.path.insert(0, "tests")
    from test_convergence import run_stale_fedavg
    import time
    for tag, kw in {
        "sync(n4,K2)": dict(tau=0), "stale(tau5)": dict(tau=5),
        "n16": dict(n=16), "K8": dict(K=8),
    }.items():
        t0 = time.time()
        norms = run_stale_fedavg(T=300, **kw)
        print(f"thm1/{tag},{(time.time()-t0)/300*1e6:.0f},"
              f"final_grad_norm={norms[-50:].mean():.4f};"
              f"early_grad_norm={norms[20:60].mean():.4f}")


def forecaster_accuracy():
    """§5.2 analogue: per-device forecaster metrics on synthetic traces.
    (The paper reports Prophet R^2=0.93 on the most-regular Stunner devices;
    our renewal traces carry irreducible session noise, so we report R^2 over
    the binary truth plus classification skill over the base rate.)"""
    import time
    from repro.core.availability import AvailabilityForecaster, DAY
    from repro.sim.traces import make_traces
    rng = np.random.default_rng(0)
    traces = make_traces(40, rng)
    r2s, maes, accs, bases = [], [], [], []
    t0 = time.time()
    for tr in traces:
        f = AvailabilityForecaster()
        for t in np.arange(0, 7 * DAY, 900.0):
            f.observe(float(t), tr.available(float(t)))
        ts = np.arange(7 * DAY, 10 * DAY, 3600.0)
        m = f.score(tr.available, ts)
        r2s.append(m["r2"])
        maes.append(m["mae"])
        truth = np.array([tr.available(float(t)) for t in ts])
        preds = np.array([f.predict_window(float(t), float(t) + 1800) for t in ts]) > 0.5
        accs.append(float((preds == truth).mean()))
        bases.append(float(max(truth.mean(), 1 - truth.mean())))
    print(f"forecaster/seasonal,{(time.time()-t0)/40*1e6:.0f},"
          f"r2={np.mean(r2s):.3f};mae={np.mean(maes):.3f};"
          f"acc={np.mean(accs):.3f};base_rate={np.mean(bases):.3f};devices=40")


def ablation_beta():
    """Beyond-paper ablation: Eq. 2's averaging weight beta (paper fixes 0.35).
    beta=0 reduces to pure DynSGD damping; beta=1 to pure deviation boosting."""
    for beta in (0.0, 0.35, 0.7, 1.0):
        _, s, w = run_variant(f"beta{beta}", selector="priority", saa=True,
                              scaling_rule="relay", beta=beta,
                              mapping="label_uniform", setting="OC",
                              dynamic_availability=True)
        emit("ablation_beta", f"beta={beta}", s, w)


def ablation_staleness_threshold():
    """Beyond-paper ablation: bounding staleness (RELAY default: unbounded)."""
    for thr in (None, 2, 5, 10):
        _, s, w = run_variant(f"thr{thr}", selector="priority", saa=True,
                              staleness_threshold=thr, mapping="label_uniform",
                              setting="DL", deadline=60.0,
                              dynamic_availability=True)
        emit("ablation_thr", f"thr={thr}", s, w)


def baseline_fedprox():
    """Extra baseline (cited family, Li et al. MLSys'20): FedProx's proximal
    client regularization vs plain FedAvg, with and without RELAY on top —
    showing RELAY composes with client-side heterogeneity mitigation."""
    for name, kw in {
        "FedAvg": dict(selector="random"),
        "FedProx(mu=0.1)": dict(selector="random", prox_mu=0.1),
        "RELAY": dict(selector="priority", saa=True, apt=True),
        "RELAY+Prox": dict(selector="priority", saa=True, apt=True, prox_mu=0.1),
    }.items():
        _, s, w = run_variant(name, mapping="label_uniform", setting="OC",
                              dynamic_availability=True, **kw)
        emit("fedprox", name, s, w)


ALL_FIGURES = [fig02_safa_waste, fig03_heterogeneity, fig04_availability,
               fig06_selection, fig07_safa_vs_relay, fig08_apt,
               fig09_stale_agg, fig10_scaling_rules, fig11_scale,
               fig12_hardware, thm1_convergence, forecaster_accuracy,
               ablation_beta, ablation_staleness_threshold, baseline_fedprox]


# ---------------------------------------------------------------------------
# Telemetry round-log rendering (repro.telemetry rounds.jsonl -> PNG curves)
# ---------------------------------------------------------------------------


def load_round_log(path) -> dict:
    """Parse a telemetry ``rounds.jsonl`` into {cell: list of event dicts}
    (pinned schema: repro.telemetry.schema.ROUND_EVENT_KEYS, null -> NaN)."""
    import json
    by_cell: dict = {}
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            by_cell.setdefault(ev["cell"], []).append(ev)
    for evs in by_cell.values():
        evs.sort(key=lambda e: e["round"])
    return by_cell


def _series(events, key):
    """Per-round numpy column; JSON null (serialized NaN) comes back NaN."""
    return np.array([float("nan") if e[key] is None else float(e[key])
                     for e in events])


def render_telemetry(telemetry_dir, out_dir) -> list:
    """Render the exported run timeline into paper-style curves:

      * ``resource_to_accuracy.png`` — cumulative resource seconds vs eval
        accuracy per cell (the paper's headline efficiency view);
      * ``waste_staleness.png`` — waste fraction and stale landings per round;
      * ``l2_band.png`` — per-round update-norm min/mean/max band plus
        guard-rejected rows (chaos-visible health view);
      * ``accuracy_under_attack.png`` — accuracy vs round, color keyed by
        aggregator and linestyle by attack kind, emitted only when the
        sweep carried an ``attack`` axis (cell names encode the grid
        coordinates) — the attack x defense headline view;
      * ``resource_to_accuracy_by_selector.png`` — the zoo race: one
        resource-to-accuracy curve per selection strategy (color = selector,
        seeds/other axes share the color), emitted only when the sweep
        carried a ``selector`` axis
        (``python -m repro.sweeps --selector ... --telemetry-dir DIR``).

    Headless (Agg); returns the list of written paths."""
    import pathlib

    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    tdir, odir = pathlib.Path(telemetry_dir), pathlib.Path(out_dir)
    odir.mkdir(parents=True, exist_ok=True)
    by_cell = load_round_log(tdir / "rounds.jsonl")
    if not by_cell:
        return []
    written = []

    fig, ax = plt.subplots(figsize=(6, 4))
    for cell, evs in sorted(by_cell.items()):
        res = _series(evs, "resource_used")
        acc = _series(evs, "accuracy")
        m = ~np.isnan(acc)
        if m.any():
            ax.plot(res[m], 100 * acc[m], marker="o", ms=3, label=cell)
    ax.set_xlabel("resource used (participant seconds)")
    ax.set_ylabel("eval accuracy (%)")
    ax.set_title("resource-to-accuracy")
    ax.legend(fontsize=6)
    fig.tight_layout()
    p = odir / "resource_to_accuracy.png"
    fig.savefig(p, dpi=120)
    plt.close(fig)
    written.append(p)

    fig, (ax1, ax2) = plt.subplots(2, 1, figsize=(6, 5), sharex=True)
    for cell, evs in sorted(by_cell.items()):
        rnd = _series(evs, "round")
        used = _series(evs, "resource_used")
        waste = _series(evs, "resource_wasted")
        frac = np.where(used > 0, waste / np.maximum(used, 1e-9), 0.0)
        ax1.plot(rnd, 100 * frac, label=cell)
        ax2.plot(rnd, _series(evs, "stale_landed"), label=cell)
    ax1.set_ylabel("waste fraction (%)")
    ax2.set_ylabel("stale landings")
    ax2.set_xlabel("round")
    ax1.set_title("resource wastage and staleness over rounds")
    ax1.legend(fontsize=6)
    fig.tight_layout()
    p = odir / "waste_staleness.png"
    fig.savefig(p, dpi=120)
    plt.close(fig)
    written.append(p)

    fig, ax = plt.subplots(figsize=(6, 4))
    for cell, evs in sorted(by_cell.items()):
        rnd = _series(evs, "round")
        lo, mid, hi = (_series(evs, k) for k in ("l2_min", "l2_mean", "l2_max"))
        (line,) = ax.plot(rnd, mid, label=cell)
        ax.fill_between(rnd, lo, hi, alpha=0.15, color=line.get_color())
        rej = (_series(evs, "rejected_nonfinite")
               + _series(evs, "rejected_norm"))
        bad = rej > 0
        if bad.any():
            ax.scatter(rnd[bad], mid[bad], marker="x", s=30,
                       color=line.get_color())
    ax.set_xlabel("round")
    ax.set_ylabel("update L2 norm (min/mean/max band; x = guard rejections)")
    ax.set_title("update-norm health")
    ax.legend(fontsize=6)
    fig.tight_layout()
    p = odir / "l2_band.png"
    fig.savefig(p, dpi=120)
    plt.close(fig)
    written.append(p)

    # accuracy under attack: sweeps grown from an `attack` axis carry the
    # coordinate in the cell name ("/attack=<kind>/"); clean runs skip it
    def _coord(cell, axis):
        for part in cell.split("/"):
            if part.startswith(axis + "="):
                return part.split("=", 1)[1]
        return None

    if any(_coord(c, "attack") is not None for c in by_cell):
        fig, ax = plt.subplots(figsize=(6, 4))
        aggs = sorted({_coord(c, "aggregator") or "saa" for c in by_cell})
        atks = sorted({_coord(c, "attack") or "none" for c in by_cell})
        cmap = plt.get_cmap("tab10")
        styles = ["-", "--", ":", "-.", (0, (3, 1, 1, 1))]
        for cell, evs in sorted(by_cell.items()):
            rnd = _series(evs, "round")
            acc = _series(evs, "accuracy")
            m = ~np.isnan(acc)
            if not m.any():
                continue
            a = _coord(cell, "aggregator") or "saa"
            k = _coord(cell, "attack") or "none"
            ax.plot(rnd[m], 100 * acc[m], marker="o", ms=3,
                    color=cmap(aggs.index(a) % 10),
                    linestyle=styles[atks.index(k) % len(styles)],
                    label=f"{a} / {k}")
        ax.set_xlabel("round")
        ax.set_ylabel("eval accuracy (%)")
        ax.set_title("accuracy under attack "
                     "(color = aggregator, linestyle = attack)")
        ax.legend(fontsize=6)
        fig.tight_layout()
        p = odir / "accuracy_under_attack.png"
        fig.savefig(p, dpi=120)
        plt.close(fig)
        written.append(p)

    # selector-zoo race: sweeps grown from a `selector` axis get the
    # paper-style resource-to-accuracy view with one color per strategy,
    # so matched-seed cells of the same selector read as one family
    if any(_coord(c, "selector") is not None for c in by_cell):
        fig, ax = plt.subplots(figsize=(6, 4))
        sels = sorted({_coord(c, "selector") or "?" for c in by_cell})
        cmap = plt.get_cmap("tab10")
        seen = set()
        for cell, evs in sorted(by_cell.items()):
            res = _series(evs, "resource_used")
            acc = _series(evs, "accuracy")
            m = ~np.isnan(acc)
            if not m.any():
                continue
            sel = _coord(cell, "selector") or "?"
            ax.plot(res[m], 100 * acc[m], marker="o", ms=3,
                    color=cmap(sels.index(sel) % 10),
                    label=None if sel in seen else sel)
            seen.add(sel)
        ax.set_xlabel("resource used (participant seconds)")
        ax.set_ylabel("eval accuracy (%)")
        ax.set_title("selector zoo: resource-to-accuracy (color = selector)")
        ax.legend(fontsize=7)
        fig.tight_layout()
        p = odir / "resource_to_accuracy_by_selector.png"
        fig.savefig(p, dpi=120)
        plt.close(fig)
        written.append(p)
    return written


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description="Render telemetry round logs into figures "
                    "(python -m benchmarks.figures --telemetry-dir DIR)")
    ap.add_argument("--telemetry-dir", required=True,
                    help="directory holding a run's rounds.jsonl")
    ap.add_argument("--out-dir", default=None,
                    help="where to write PNGs (default: <telemetry-dir>/figures)")
    args = ap.parse_args(argv)
    out = args.out_dir or f"{args.telemetry_dir}/figures"
    written = render_telemetry(args.telemetry_dir, out)
    if not written:
        raise SystemExit(f"no round events in {args.telemetry_dir}/rounds.jsonl")
    for p in written:
        print(f"wrote {p}")


if __name__ == "__main__":
    main()
