"""Shared benchmark helpers: run simulator variants, emit CSV rows.

Scale note: the paper uses 1000-3000 learners / 500-1000 rounds on a GPU
cluster; these benchmarks run the same *system* at CPU scale (default 100
learners, 60 rounds) — the comparisons, not the absolute numbers, are the
reproduction target.  Scale up with REPRO_BENCH_SCALE=full.
"""
from __future__ import annotations

import os
import time

from repro.sim import SimConfig, Simulator

FULL = os.environ.get("REPRO_BENCH_SCALE", "small") == "full"
N_LEARNERS = 1000 if FULL else 100
ROUNDS = 500 if FULL else 60
EVAL_EVERY = 20 if FULL else 15


def run_variant(name: str, **overrides):
    cfg_kw = dict(n_learners=N_LEARNERS, rounds=ROUNDS, eval_every=EVAL_EVERY,
                  seed=overrides.pop("seed", 0))
    cfg_kw.update(overrides)
    t0 = time.time()
    acct = Simulator(SimConfig(**cfg_kw)).run()
    wall = time.time() - t0
    s = acct.summary()
    return acct, s, wall


def emit(table: str, variant: str, s: dict, wall: float, extra: str = ""):
    """name,us_per_call,derived CSV convention."""
    us_per_round = wall / max(s["rounds"], 1) * 1e6
    derived = (f"acc={s['final_accuracy']:.4f};res={s['resource_used']:.0f}s;"
               f"waste={s['waste_fraction']:.3f};time={s['sim_time']:.0f}s;"
               f"unique={s['unique_participants']}")
    if extra:
        derived += ";" + extra
    print(f"{table}/{variant},{us_per_round:.0f},{derived}")
