"""Doc-snippet checker: extract fenced code blocks from the markdown docs
and verify they are not stale.

Two block classes, two verification modes:

* ``python`` blocks are **executed**, in order, in one shared namespace
  per file — so a doc can build something in one block and use it in the
  next (the ``docs/extending.md`` worked example registers a selector,
  then runs a Simulator against it).  Docs are written to be runnable at
  smoke scale by construction; an exception fails the check.
* ``bash``/``sh``/``shell`` blocks are **statically validated** line by
  line: for every ``python -m <module>`` invocation the module must
  import (``find_spec``), and every ``--flag`` token on the line must
  appear in that module's ``--help`` output (captured once per module) —
  so renaming or dropping a CLI flag fails the doc that still shows it.
  ``python path/to/script.py`` lines check the script exists and its
  flags against its ``--help``.  Env-var prefixes (``PYTHONPATH=src``)
  and line continuations are understood; non-python commands (``cp``,
  ``git``...) are skipped.

A fence opened with ```` ```python no-run ```` (or ``bash no-check``) is
skipped — for illustrative fragments that are not meant to execute.

Usage:
  PYTHONPATH=src python tools/check_docs.py README.md docs/extending.md
"""
from __future__ import annotations

import importlib.util
import os
import pathlib
import re
import shlex
import subprocess
import sys

FENCE = re.compile(r"^```(\w+)?([^\n]*)$")
_HELP_CACHE: dict = {}

# doc commands are written to run from the repo root (with PYTHONPATH=src);
# make the checker resolve modules the same way regardless of how it was
# launched
_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _find_spec(mod: str):
    try:
        return importlib.util.find_spec(mod)
    except (ImportError, ModuleNotFoundError, ValueError):
        return None


def extract_blocks(text: str):
    """Yield (lang, tags, code, start_line) for every fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i].strip())
        if m and m.group(1):
            lang = m.group(1).lower()
            tags = (m.group(2) or "").split()
            body, start = [], i + 1
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                body.append(lines[i])
                i += 1
            yield lang, tags, "\n".join(body), start
        i += 1


def _help_text(argv0: list) -> str:
    """``--help`` output for a ``python -m mod`` / ``python script`` target,
    captured once (argparse prints the full option set)."""
    key = tuple(argv0)
    if key not in _HELP_CACHE:
        env = dict(os.environ)
        env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + str(_ROOT)
        proc = subprocess.run(
            [sys.executable, *argv0, "--help"],
            capture_output=True, text=True, timeout=120,
            cwd=str(_ROOT), env=env)
        _HELP_CACHE[key] = proc.stdout + proc.stderr
    return _HELP_CACHE[key]


def _join_continuations(text: str):
    out, acc = [], ""
    for raw in text.splitlines():
        line = acc + raw
        if line.rstrip().endswith("\\"):
            acc = line.rstrip()[:-1] + " "
            continue
        acc = ""
        if line.strip():
            out.append(line.strip())
    return out


def check_shell_block(code: str, where: str) -> list:
    failures = []
    for line in _join_continuations(code):
        try:
            toks = shlex.split(line, comments=True)
        except ValueError:
            continue
        while toks and "=" in toks[0] and not toks[0].startswith("-"):
            toks = toks[1:]                      # strip FOO=bar prefixes
        if not toks or not re.match(r"python[0-9.]*$", toks[0]):
            continue                             # non-python commands: skip
        toks = toks[1:]
        if toks[:1] == ["-m"]:
            if len(toks) < 2:
                continue
            mod, target = toks[1], ["-m", toks[1]]
            if _find_spec(mod) is None:
                failures.append(f"{where}: module {mod!r} not importable "
                                f"(stale command: {line})")
                continue
            rest = toks[2:]
        elif toks and toks[0].endswith(".py"):
            target = [toks[0]]
            if not pathlib.Path(toks[0]).exists():
                failures.append(f"{where}: script {toks[0]!r} missing "
                                f"(stale command: {line})")
                continue
            rest = toks[1:]
        else:
            continue
        flags = [t.split("=", 1)[0] for t in rest if t.startswith("--")]
        if not flags:
            continue
        helptext = _help_text(target)
        for fl in flags:
            if fl not in helptext:
                failures.append(f"{where}: flag {fl!r} not in "
                                f"`python {' '.join(target)} --help` "
                                f"(stale command: {line})")
    return failures


def check_file(path: pathlib.Path) -> list:
    failures = []
    ns: dict = {"__name__": f"__doc_snippet__{path.stem}"}
    for lang, tags, code, line in extract_blocks(path.read_text()):
        where = f"{path}:{line}"
        if any(t.startswith("no-") for t in tags):
            print(f"skip  {where} ({lang} {' '.join(tags)})")
            continue
        if lang == "python":
            print(f"exec  {where} (python, {len(code.splitlines())} lines)")
            try:
                exec(compile(code, where, "exec"), ns)   # noqa: S102
            except Exception as e:                       # noqa: BLE001
                failures.append(f"{where}: python block raised "
                                f"{type(e).__name__}: {e}")
        elif lang in ("bash", "sh", "shell", "console"):
            print(f"check {where} ({lang})")
            failures.extend(check_shell_block(code, where))
    return failures


def main(argv=None) -> int:
    paths = [pathlib.Path(p) for p in (argv or sys.argv[1:])]
    if not paths:
        print("usage: python tools/check_docs.py FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    failures = []
    for p in paths:
        failures.extend(check_file(p))
    for f in failures:
        print(f"FAIL  {f}", file=sys.stderr)
    print(f"# {len(paths)} files checked, {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
