"""Telemetry subsystem contracts (ISSUE PR-7):

  * the device lane / round-event schema is pinned — renaming, reordering
    or widening it is an intentional breaking change that must edit this
    file;
  * level-2 telemetry is bit-transparent: the instrumented run's summary
    AND per-round records equal a telemetry-off run's on every fused
    substrate (single-dispatch, K-round chunked, participant-sharded);
  * the lane rides the existing round program: still at most ONE
    cross-shard collective (the aggregation psum) in the compiled HLO, and
    the hot loop stays clean under ``jax.transfer_guard("disallow")``;
  * guard accounting has ONE writer — the session's registry counters, the
    pipeline's ``stats.guard`` view and the per-cell ``Accounting`` fields
    all agree under injected faults;
  * exports are loadable: ``rounds.jsonl`` rows carry exactly
    ``ROUND_EVENT_KEYS`` in order, ``trace.json`` is a Chrome trace-event
    JSON (Perfetto-loadable), ``metrics.prom`` parses as Prometheus 0.0.4
    text.
"""
import dataclasses
import json
import math
import re

import jax
import numpy as np
import pytest

from repro.faults import FaultPlan, FaultSpec
from repro.sim import SimConfig, Simulator
from repro.sim.pipeline import RoundPipeline
from repro.sweeps.runner import summaries_equal
from repro.telemetry import (MetricsRegistry, TelemetrySession, Tracer,
                             write_prometheus)
from repro.telemetry.registry import CounterView
from repro.telemetry.schema import (GUARD_COUNTERS, LANE_FIELDS,
                                    LANE_INT_FIELDS, LANE_WIDTH, N_LANE_HOST,
                                    ROUND_EVENT_KEYS)

BASE = dict(n_learners=30, rounds=8, eval_every=4, n_target=4,
            mapping="label_uniform", saa=True, selector="priority")
N_DEV = len(jax.devices())


def _cfg(**kw):
    return SimConfig(**{**BASE, **kw})


def _records_equal(a, b) -> bool:
    if len(a.records) != len(b.records):
        return False
    for ra, rb in zip(a.records, b.records):
        ka = (ra.round_idx, ra.sim_time, ra.n_selected, ra.n_fresh,
              ra.n_stale, ra.resource_used, ra.resource_wasted,
              ra.unique_participants)
        kb = (rb.round_idx, rb.sim_time, rb.n_selected, rb.n_fresh,
              rb.n_stale, rb.resource_used, rb.resource_wasted,
              rb.unique_participants)
        accs = (ra.accuracy == rb.accuracy
                or (ra.accuracy != ra.accuracy and rb.accuracy != rb.accuracy))
        if ka != kb or not accs:
            return False
    return True


# ---------------------------------------------------------------------------
# Pinned schema
# ---------------------------------------------------------------------------


def test_lane_schema_is_pinned():
    assert LANE_FIELDS == (
        "round", "sim_time", "cohort", "fresh", "stale_landed",
        "cache_occupancy", "l2_min", "l2_mean", "l2_max", "nonfinite_rows",
        "rejected_nonfinite", "rejected_norm", "robust_rejected",
        "robust_trimmed", "survivors", "applied")
    assert LANE_WIDTH == 16
    assert N_LANE_HOST == 6
    assert LANE_FIELDS[:N_LANE_HOST] == (
        "round", "sim_time", "cohort", "fresh", "stale_landed",
        "cache_occupancy")
    assert LANE_INT_FIELDS <= set(LANE_FIELDS)


def test_round_event_schema_is_pinned():
    assert ROUND_EVENT_KEYS == ("event", "cell") + LANE_FIELDS + (
        "resource_used", "resource_wasted", "unique_participants",
        "accuracy", "loss")


# ---------------------------------------------------------------------------
# Registry / tracer units
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(4)
    assert reg.value("c_total") == 5
    assert reg.counter("c_total") is c          # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("c_total")                    # kind mismatch
    reg.gauge("g").set(2.5)
    h = reg.histogram("h")
    for v in (0.0005, 0.05, 5.0, 50.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["c_total"] == 5 and snap["g"] == 2.5
    txt = reg.prometheus_text()
    assert "# TYPE c_total counter" in txt
    assert 'h_bucket{le="+Inf"} 4' in txt
    assert "h_count 4" in txt


def test_counter_view_is_a_dict_over_registry_counters():
    reg = MetricsRegistry()
    view = CounterView(reg, "guard_", ("a", "b"))
    view["a"] += 3
    view["b"] = 7
    assert reg.value("guard_a") == 3 and reg.value("guard_b") == 7
    assert dict(view) == {"a": 3, "b": 7}
    assert view == {"a": 3, "b": 7} and len(view) == 2 and "a" in view


def test_tracer_spans_and_export(tmp_path):
    tr = Tracer()
    with tr.span("outer", rounds=2):
        with tr.span("inner"):
            pass
    tr.instant("mark", round=1)
    doc = tr.chrome_trace()
    names = [e["name"] for e in doc["traceEvents"]]
    assert set(names) == {"outer", "inner", "mark"}
    by = {e["name"]: e for e in doc["traceEvents"]}
    assert by["inner"]["ph"] == "X" and by["mark"]["ph"] == "i"
    # nesting: inner lies within outer on the timeline
    assert by["outer"]["ts"] <= by["inner"]["ts"]
    assert (by["inner"]["ts"] + by["inner"]["dur"]
            <= by["outer"]["ts"] + by["outer"]["dur"])
    p = tmp_path / "trace.json"
    tr.export(p)
    assert json.loads(p.read_text())["traceEvents"]
    off = Tracer(enabled=False)
    with off.span("x"):
        pass
    assert not off.chrome_trace()["traceEvents"]


# ---------------------------------------------------------------------------
# Level-2 bit-transparency on every fused substrate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sub", ["fused", "chunked", "sharded"])
def test_level2_is_bit_transparent(sub, tmp_path):
    extra = {"fused": {},
             "chunked": {"rounds_per_dispatch": 4},
             "sharded": {"shard_participants": True}}[sub]
    ref = Simulator(_cfg(**extra)).run()
    sess = TelemetrySession(str(tmp_path / sub))
    got = Simulator(_cfg(telemetry=2, **extra)).run(telemetry=sess)
    sess.close()
    assert summaries_equal(dict(ref.summary()), dict(got.summary())), \
        (sub, ref.summary(), got.summary())
    assert _records_equal(ref, got)
    # one pinned-schema event per recorded round, in the JSONL and in memory
    evs = [json.loads(l) for l in
           (tmp_path / sub / "rounds.jsonl").read_text().splitlines()]
    assert len(evs) == got.summary()["rounds"]
    assert got.round_events == evs
    for ev in evs:
        assert tuple(ev) == ROUND_EVENT_KEYS
        assert ev["event"] == "round"
        for k in LANE_INT_FIELDS:
            assert isinstance(ev[k], int), k


def test_round_events_reflect_the_schedule(tmp_path):
    """Device-computed lane values agree with the host accounting records:
    cohort/fresh/stale per event match the Accounting row for that round."""
    sess = TelemetrySession(str(tmp_path))
    acct = Simulator(_cfg(telemetry=2)).run(telemetry=sess)
    sess.close()
    assert len(acct.round_events) == len(acct.records)
    for ev, rec in zip(acct.round_events, acct.records):
        assert ev["round"] == rec.round_idx
        assert ev["cohort"] == rec.n_selected
        assert ev["fresh"] == rec.n_fresh
        assert ev["stale_landed"] == rec.n_stale
        assert ev["resource_used"] == rec.resource_used
        eva = math.nan if ev["accuracy"] is None else ev["accuracy"]
        assert eva == rec.accuracy or (eva != eva
                                       and rec.accuracy != rec.accuracy)
        if ev["applied"]:
            assert ev["l2_max"] >= ev["l2_mean"] >= ev["l2_min"] > 0


# ---------------------------------------------------------------------------
# Guard accounting: one writer, three agreeing views
# ---------------------------------------------------------------------------


def test_guard_counters_single_writer(tmp_path):
    plan = FaultPlan(n_learners=BASE["n_learners"], rounds=BASE["rounds"],
                     specs=(FaultSpec("nan", prob=0.2),
                            FaultSpec("scale", prob=0.1, scale=1e4)), seed=7)
    sess = TelemetrySession(str(tmp_path))
    sim = Simulator(_cfg(telemetry=2, guard=True, guard_reject_mult=5.0),
                    fault_plan=plan)
    pipe = RoundPipeline([sim], telemetry=sess)
    accts = pipe.run()
    s = accts[0].summary()
    assert s["rejected_nonfinite"] > 0
    # stats.guard is a live view over the session registry's counters
    assert dict(pipe.stats.guard) == {
        "rejected_nonfinite": sess.registry.value("guard_rejected_nonfinite"),
        "rejected_norm": sess.registry.value("guard_rejected_norm"),
        "quorum_skips": sess.registry.value("guard_quorum_skips"),
        "robust_rejected": sess.registry.value("guard_robust_rejected"),
        "robust_trimmed": sess.registry.value("guard_robust_trimmed")}
    # ... and both equal the sum over the per-cell Accounting fields
    assert pipe.stats.guard["rejected_nonfinite"] == sum(
        a.rejected_nonfinite for a in accts)
    assert pipe.stats.guard["rejected_norm"] == sum(
        a.rejected_norm for a in accts)
    assert pipe.stats.guard["quorum_skips"] == sum(
        a.quorum_skips for a in accts)
    for name in GUARD_COUNTERS:
        assert name in sess.registry
    # the lane's guard tail reconciles with the same totals
    assert sum(e["rejected_nonfinite"] for e in accts[0].round_events) \
        == s["rejected_nonfinite"]
    sess.close()


# ---------------------------------------------------------------------------
# Program-structure invariants survive the lane
# ---------------------------------------------------------------------------


def test_lane_program_keeps_one_collective():
    cfg = _cfg(telemetry=2, shard_participants=True, rounds_per_dispatch=4)
    pipe = RoundPipeline([Simulator(cfg)],
                         telemetry=TelemetrySession())
    orig, captured = pipe._prog, []

    def wrapper(*args):
        if not captured:
            captured.append(orig.lower(*args).compile().as_text())
        return orig(*args)

    pipe._prog = wrapper
    pipe.run()
    txt = captured[0]
    n_all_reduce = len(re.findall(r"all-reduce(?:-start)?\(", txt))
    for op in ("all-gather", "all-to-all", "collective-permute",
               "reduce-scatter"):
        assert f"{op}(" not in txt, f"unexpected {op} with the lane enabled"
    if N_DEV > 1:
        assert n_all_reduce == 1, f"expected 1 all-reduce, found {n_all_reduce}"
    else:
        assert n_all_reduce <= 1


def test_lane_clean_under_transfer_guard(tmp_path):
    cfg = _cfg(telemetry=2, shard_participants=True, rounds_per_dispatch=4)
    RoundPipeline([Simulator(cfg)]).run()            # warm compiles
    sess = TelemetrySession(str(tmp_path))
    pipe = RoundPipeline([Simulator(cfg)], telemetry=sess)
    accts = pipe.run(transfer_guard=True)
    sess.close()
    assert accts[0].summary()["rounds"] > 0
    assert len(accts[0].round_events) == accts[0].summary()["rounds"]


# ---------------------------------------------------------------------------
# Session exports + host-level (level 1) spans
# ---------------------------------------------------------------------------


def test_session_exports_are_loadable(tmp_path):
    sess = TelemetrySession(str(tmp_path))
    Simulator(_cfg(telemetry=2)).run(telemetry=sess)
    sess.close()
    trace = json.loads((tmp_path / "trace.json").read_text())
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"schedule", "pack", "dispatch", "fetch"} <= names
    for e in trace["traceEvents"]:
        assert e["ph"] in ("X", "i")
        assert isinstance(e["ts"], (int, float))
    prom = (tmp_path / "metrics.prom").read_text()
    assert re.search(r"^pipeline_rounds \d+$", prom, re.M)
    assert re.search(r"^guard_rejected_nonfinite \d+$", prom, re.M)
    # span durations land as histograms (wall-clock — prom snapshot only)
    assert re.search(r"^span_seconds_dispatch_count \d+$", prom, re.M)
    # close() is idempotent and the registry snapshot stays readable
    sess.close()
    assert sess.registry.value("pipeline_rounds") > 0


def test_write_prometheus_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("x_total").inc(3)
    p = tmp_path / "m.prom"
    write_prometheus(reg, p)
    assert "x_total 3" in p.read_text()


def test_level1_spans_without_lane(tmp_path):
    """telemetry=1 on the legacy engine loop: spans + registry, no lane, no
    round events, summary untouched."""
    ref = Simulator(_cfg(fast_path=False, fused_rounds=False)).run()
    sess = TelemetrySession(str(tmp_path))
    got = Simulator(_cfg(fast_path=False, fused_rounds=False,
                         telemetry=1)).run(telemetry=sess)
    sess.close()
    assert summaries_equal(dict(ref.summary()), dict(got.summary()))
    assert got.round_events == []
    trace = json.loads((tmp_path / "trace.json").read_text())
    assert {"schedule", "dispatch", "fetch"} <= \
        {e["name"] for e in trace["traceEvents"]}


def test_sweep_round_logs_accessor(tmp_path):
    from repro.sweeps import SweepRunner, SweepSpec
    cells = SweepSpec(axes={"saa": [False, True]},
                      base={k: v for k, v in BASE.items() if k != "saa"},
                      seeds=(0,)).expand()
    cells = [dataclasses.replace(c, config=dataclasses.replace(
        c.config, telemetry=2)) for c in cells]
    sess = TelemetrySession(str(tmp_path))
    results = SweepRunner(cells, telemetry=sess).run()
    sess.close()
    logs = results.round_logs()
    assert set(logs) == {c.name for c in cells}
    for name, evs in logs.items():
        assert all(ev["cell"] == name for ev in evs)
    # the summary payload stays lean: no round logs in the JSON dict
    assert "round_logs" not in results.to_json_dict()
    # per-cell JSONL rows equal the in-memory logs, interleaved by round
    evs = [json.loads(l) for l in
           (tmp_path / "rounds.jsonl").read_text().splitlines()]
    for name in logs:
        assert [e for e in evs if e["cell"] == name] == logs[name]
