"""FL simulation engine invariants + paper-trend reproduction (small scale)."""
import numpy as np
import pytest

from repro.sim import SimConfig, Simulator
from repro.sim.devices import sample_profiles
from repro.sim.partition import label_coverage, make_dataset, partition
from repro.sim.traces import make_traces


def _run(**kw):
    base = dict(n_learners=60, rounds=30, eval_every=15, seed=1)
    base.update(kw)
    return Simulator(SimConfig(**base)).run()


def test_accounting_invariants():
    acct = _run(selector="random")
    s = acct.summary()
    assert s["resource_used"] > 0
    assert 0 <= s["resource_wasted"] <= s["resource_used"]
    assert 0 < s["unique_participants"] <= 60
    assert s["rounds"] <= 30


def test_model_learns_above_chance():
    acct = _run(selector="random", rounds=50, mapping="uniform")
    # speech-like benchmark has 35 classes; chance ~ 2.9%
    assert acct.summary()["final_accuracy"] > 0.5


def test_saa_reduces_waste():
    """Accepting stale updates converts wasted overcommit work into progress."""
    no_saa = _run(selector="random", saa=False, setting="OC").summary()
    saa = _run(selector="random", saa=True, setting="OC").summary()
    assert saa["waste_fraction"] < no_saa["waste_fraction"]


def test_priority_increases_unique_participants():
    rnd = _run(selector="random", rounds=40, dynamic_availability=True).summary()
    pri = _run(selector="priority", rounds=40, dynamic_availability=True).summary()
    assert pri["unique_participants"] >= rnd["unique_participants"]


def test_safa_burns_resources_faster():
    """SAFA's select-all policy consumes learner compute at a much higher RATE
    (resource per unit simulated time) than target-count selection — the
    root of its wastage at scale (paper Fig. 2/11)."""
    safa = _run(selector="safa", setting="DL", saa=True,
                staleness_threshold=5).summary()
    rnd = _run(selector="random", setting="DL").summary()
    safa_rate = safa["resource_used"] / max(safa["sim_time"], 1)
    rnd_rate = rnd["resource_used"] / max(rnd["sim_time"], 1)
    assert safa_rate > 2 * rnd_rate


def test_allavail_makes_priority_degenerate():
    """Paper §5.2: with all learners available, IPS reverts to random-like
    behavior (all report p=1)."""
    acct = _run(selector="priority", dynamic_availability=False)
    assert acct.summary()["final_accuracy"] > 0.3


# ---------------------------------------------------------------------------
# substrate pieces
# ---------------------------------------------------------------------------


def test_device_profiles_heterogeneous():
    rng = np.random.default_rng(0)
    profs = sample_profiles(500, rng)
    times = np.array([p.per_sample_time for p in profs])
    assert times.max() / times.min() > 10  # long tail (paper App. C)
    assert len({p.cluster for p in profs}) == 6


def test_hardware_scenarios_speed_up():
    rng = np.random.default_rng(0)
    hs1 = sample_profiles(200, np.random.default_rng(0), "HS1")
    hs4 = sample_profiles(200, np.random.default_rng(0), "HS4")
    t1 = np.mean([p.per_sample_time for p in hs1])
    t4 = np.mean([p.per_sample_time for p in hs4])
    assert np.isclose(t4, t1 / 2, rtol=0.05)


def test_traces_diurnal_and_short_sessions():
    rng = np.random.default_rng(0)
    traces = make_traces(300, rng)
    # session length long tail: most availability sessions < 10 min (paper §C)
    sessions = []
    for t in traces[:100]:
        for i, s in enumerate(t.states[:-1]):
            if s:
                sessions.append(t.boundaries[i + 1] - t.boundaries[i])
    frac_short = np.mean(np.array(sessions) < 600)
    assert frac_short > 0.5
    # availability varies across the day (diurnality)
    hours = np.arange(0, 24 * 3600, 3600)
    avail = [np.mean([t.available(float(h)) for t in traces]) for h in hours]
    assert max(avail) - min(avail) > 0.1


@pytest.mark.parametrize("mapping,kind", [
    ("uniform", "iid"), ("fedscale", "realistic"), ("label_uniform", "limited"),
    ("label_balanced", "limited"), ("label_zipf", "limited")])
def test_partitions(mapping, kind):
    rng = np.random.default_rng(0)
    x, y, _, _ = make_dataset("speech", rng)
    shards = partition(y, 100, mapping, rng)
    assert len(shards) == 100
    assert all(len(s) > 0 for s in shards)
    per_learner_labels = np.mean([len(np.unique(y[s])) for s in shards])
    if kind == "iid":
        assert per_learner_labels > 20     # near-IID: most labels everywhere
    elif kind == "realistic":
        # power-law sizes: label diversity between IID and label-limited
        assert 6 < per_learner_labels <= 20
    else:
        assert per_learner_labels <= 6     # label-limited: ~10% of 35 labels


def test_zipf_partition_is_skewed():
    rng = np.random.default_rng(0)
    x, y, _, _ = make_dataset("speech", rng)
    shards = partition(y, 50, "label_zipf", rng)
    # within a shard, label counts should be highly skewed
    ratios = []
    for s in shards[:20]:
        _, counts = np.unique(y[s], return_counts=True)
        if len(counts) > 1:
            ratios.append(counts.max() / counts.min())
    assert np.median(ratios) > 3
