"""Sharding-rule tests: every assigned arch's param specs must divide evenly
on the production mesh axes (structure-level — the 512-device compile itself
is exercised by repro.launch.dryrun)."""
import math
import types

import jax
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, adapt_for_shape, get_config
from repro.launch.shardings import input_specs, param_pspecs
from repro.models import init_params


class FakeMesh:
    """Duck-typed mesh: shape dict + axis_names, no devices needed."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisibility(pspecs, shapes, mesh):
    for (path, spec), leaf in zip(
            jax.tree_util.tree_flatten_with_path(
                pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0],
            jax.tree.leaves(shapes)):
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = math.prod(mesh.shape[a] for a in axes)
            assert dim % n == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["pod", "multipod"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg, shapes, mesh)
    # structurally identical trees
    assert jax.tree.structure(jax.tree.map(lambda x: 0, shapes)) == \
        jax.tree.structure(jax.tree.map(
            lambda x: 0, pspecs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    _check_divisibility(pspecs, shapes, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["pod", "multipod"])
def test_input_specs_divide(arch, shape_name, mesh):
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_for_shape(get_config(arch), shape)
    for cohort in (("vmap", "stream") if shape.kind == "train" else ("-",)):
        spec = input_specs(cfg, shape, mesh, cohort=cohort)
        for name, tree in spec.args.items():
            specs = spec.arg_specs[name]
            flat_args = jax.tree.leaves(tree)
            flat_specs = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            for leaf, sp in zip(flat_args, flat_specs):
                for dim, ax in zip(leaf.shape, sp):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = math.prod(mesh.shape[a] for a in axes)
                    assert dim % n == 0, (arch, shape_name, name, leaf.shape, sp)


def test_long_500k_uses_subquadratic_attention():
    """DESIGN.md §4: every arch with full attention switches to SWA for
    long_500k; SSM archs are untouched."""
    shape = INPUT_SHAPES["long_500k"]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        adapted = adapt_for_shape(cfg, shape)
        if "attn" in cfg.block_pattern:
            assert adapted.window is not None, arch
        else:
            assert adapted.window == cfg.window, arch


def test_train_enables_remat_and_loss_chunking():
    shape = INPUT_SHAPES["train_4k"]
    cfg = adapt_for_shape(get_config("qwen2.5-3b"), shape)
    assert cfg.remat and cfg.loss_chunk > 0
