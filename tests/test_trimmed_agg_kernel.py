"""trimmed_agg Pallas kernel: rank-select band means vs the sort-based
oracle (the same formula the robust aggregators use), across mixed
per-cell trim depths / valid counts, +inf-padded rows, ties, and
non-multiple-of-D_BLK feature sizes (the ops wrapper's zero padding)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.trimmed_agg import ops as tops
from repro.kernels.trimmed_agg.ref import sweep_trimmed_ref
from repro.kernels.trimmed_agg.trimmed_agg import D_BLK


def _operand(rng, s, n, d, c):
    """Rows past c are the +inf exclusion padding the robust layer emits."""
    y = rng.normal(size=(s, n, d)).astype(np.float32)
    for i, ci in enumerate(c):
        y[i, ci:] = np.inf
    return y


@pytest.mark.parametrize("n,d", [(6, D_BLK), (9, 2 * D_BLK), (16, D_BLK)])
def test_kernel_matches_sort_oracle_mixed_k_and_c(n, d):
    rng = np.random.default_rng(n * d)
    s = 5
    c = np.array([n, n - 1, max(n - 3, 1), 2, 1], np.int32)
    k = np.array([0, 1, (int(c[2]) - 1) // 2, 0, 0], np.int32)
    y = jnp.asarray(_operand(rng, s, n, d, c))
    got = tops.sweep_trimmed_aggregate(y, jnp.asarray(k), jnp.asarray(c))
    want = sweep_trimmed_ref(y, jnp.asarray(k), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_kernel_pads_feature_axis_and_truncates_back():
    rng = np.random.default_rng(7)
    s, n, d = 3, 8, D_BLK + 37                    # not a D_BLK multiple
    c = np.array([8, 5, 3], np.int32)
    k = np.array([2, 1, 1], np.int32)
    y = jnp.asarray(_operand(rng, s, n, d, c))
    got = tops.sweep_trimmed_aggregate(y, jnp.asarray(k), jnp.asarray(c))
    assert got.shape == (s, n, d)[:1] + (d,)
    want = sweep_trimmed_ref(y, jnp.asarray(k), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_kernel_tie_ranks_agree_with_stable_sort():
    """Duplicated values force the rank tie-break (row index) to matter:
    the kernel's stable-rank order must select the same band members as
    the stable sort."""
    n, d = 6, D_BLK
    y = np.ones((1, n, d), np.float32)
    y[0, 3] = 2.0
    y[0, 4] = 0.0
    c = jnp.asarray([n], jnp.int32)
    k = jnp.asarray([1], jnp.int32)
    got = tops.sweep_trimmed_aggregate(jnp.asarray(y), k, c)
    want = sweep_trimmed_ref(jnp.asarray(y), k, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_kernel_median_band_even_and_odd():
    """Maximal trim k=(c-1)//2 is the coordinate median (even c averages
    the middle pair) — the coord_median aggregator's kernel route."""
    rng = np.random.default_rng(1)
    n, d = 10, D_BLK
    for c_val in (9, 10):                          # odd, even
        c = np.array([c_val], np.int32)
        k = (c - 1) // 2
        y = jnp.asarray(_operand(rng, 1, n, d, c))
        got = np.asarray(tops.sweep_trimmed_aggregate(
            y, jnp.asarray(k), jnp.asarray(c)))[0]
        want = np.median(np.asarray(y)[0, :c_val].astype(np.float64),
                         axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_kernel_degenerate_cells():
    """c=0 (denominator floor) and an all-padding cell stay finite zero;
    c=1 passes the single row through."""
    n, d = 4, D_BLK
    y = np.full((2, n, d), np.inf, np.float32)
    y[1, 0] = 3.0
    c = jnp.asarray([0, 1], jnp.int32)
    k = jnp.asarray([0, 0], jnp.int32)
    got = np.asarray(tops.sweep_trimmed_aggregate(jnp.asarray(y), k, c))
    np.testing.assert_array_equal(got[0], 0.0)
    np.testing.assert_array_equal(got[1], 3.0)
