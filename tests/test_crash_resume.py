"""Crash-safe bit-exact resume (chaos harness).

Contract: run(2R) == run(R) -> crash -> resume(R), *bitwise*, on every
substrate — fused pipeline (any ``rounds_per_dispatch``), flat per-stage
path, legacy engine, and whole sweeps.  Snapshots are taken only at
round/chunk boundaries and the fault plan rides along (crash disarmed on
restore), so the resumed run re-enters the identical decision sequence.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import (SnapshotError, load_snapshot, resume_run,
                              save_snapshot)
from repro.faults import FaultPlan, FaultSpec, InjectedCrash
from repro.sim.engine import SimConfig, Simulator
from repro.sweeps import SweepSpec, resume_sweep
from repro.sweeps.runner import run_batched, summaries_equal

BASE = dict(n_learners=30, rounds=8, eval_every=4, n_target=4,
            saa=True, selector="priority")


def _cfg(**kw):
    return SimConfig(**{**BASE, **kw})


def _crash_plan(after=3, specs=()):
    return FaultPlan(n_learners=BASE["n_learners"], rounds=BASE["rounds"],
                     specs=specs, seed=7, crash_after=after,
                     crash_mode="soft")


SUBSTRATES = {
    "fused": {},
    "chunked": {"rounds_per_dispatch": 4},
    "yogi": {"aggregator": "yogi"},
    "flat": {"fused_rounds": False},
    "legacy": {"fast_path": False, "fused_rounds": False},
}


@pytest.mark.parametrize("sub", sorted(SUBSTRATES))
def test_soft_crash_resume_is_bit_exact(sub, tmp_path):
    extra = SUBSTRATES[sub]
    ckpt = str(tmp_path / "run.pkl")
    ref = Simulator(_cfg(**extra)).run().summary()

    with pytest.raises(InjectedCrash):
        Simulator(_cfg(**extra), fault_plan=_crash_plan()) \
            .run(checkpoint_path=ckpt, checkpoint_every=2)
    payload = load_snapshot(ckpt)
    assert payload["next_round"] <= 4    # crashed mid-run, not at the end
    acct = resume_run(ckpt)
    assert summaries_equal(dict(acct.summary()), dict(ref)), \
        (sub, acct.summary(), ref)


def test_crash_resume_under_corruption_faults(tmp_path):
    """The fault plan rides along in the snapshot: a guarded run with NaN
    corruption resumes into the identical remaining faults (crash
    disarmed), matching the uninterrupted faulted run bitwise."""
    specs = (FaultSpec("nan", prob=0.2),)
    ckpt = str(tmp_path / "run.pkl")
    ref = Simulator(_cfg(guard=True), fault_plan=_crash_plan(None, specs)) \
        .run().summary()
    with pytest.raises(InjectedCrash):
        Simulator(_cfg(guard=True), fault_plan=_crash_plan(3, specs)) \
            .run(checkpoint_path=ckpt, checkpoint_every=2)
    acct = resume_run(ckpt)
    s = acct.summary()
    assert summaries_equal(dict(s), dict(ref))
    assert s["rejected_nonfinite"] == ref["rejected_nonfinite"] > 0


def test_crash_resume_under_attack_with_guards_and_robust(tmp_path):
    """SIGKILL-grade contract, soft flavor: a guarded *robust* run under a
    live coordinated attack crashes mid-attack and resumes bit-exactly —
    the armed attack rides the snapshot's fault plan, so the resumed tail
    replays the identical attacker sets, and the guard/robust counters
    land exactly where the uninterrupted run's do (telemetry round log
    byte-continues too)."""
    from repro.telemetry import TelemetrySession

    cfg = _cfg(aggregator="coord_median", attack="collude_signflip",
               attack_frac=0.25, attack_scale=10.0, guard=True,
               guard_reject_mult=5.0, quorum=1, telemetry=2,
               n_target=6, setting="DL", deadline=1e6)
    specs = (FaultSpec("nan", prob=0.25),)
    ckpt = str(tmp_path / "run.pkl")
    dir_a, dir_b = str(tmp_path / "clean"), str(tmp_path / "crashed")

    sess = TelemetrySession(dir_a)
    ref = Simulator(cfg, fault_plan=_crash_plan(None, specs)) \
        .run(telemetry=sess)
    sess.close()
    s_ref = ref.summary()
    assert s_ref["robust_trimmed"] > 0          # the defense actually ran
    assert s_ref["rejected_nonfinite"] > 0      # ... under live faults

    sess = TelemetrySession(dir_b)
    with pytest.raises(InjectedCrash):
        Simulator(cfg, fault_plan=_crash_plan(3, specs)).run(
            checkpoint_path=ckpt, checkpoint_every=2, telemetry=sess)
    sess.close()
    sess = TelemetrySession(dir_b)
    acct = resume_run(ckpt, telemetry=sess)
    sess.close()

    s = acct.summary()
    assert summaries_equal(dict(s), dict(s_ref)), (s, s_ref)
    assert s["robust_trimmed"] == s_ref["robust_trimmed"]
    assert s["rejected_nonfinite"] == s_ref["rejected_nonfinite"]
    a = open(os.path.join(dir_a, "rounds.jsonl"), "rb").read()
    b = open(os.path.join(dir_b, "rounds.jsonl"), "rb").read()
    assert a == b and a
    assert acct.round_events == ref.round_events


def test_midrun_snapshot_of_clean_run_resumes_identically(tmp_path):
    """Checkpointing is passive: a run that never crashes leaves its last
    mid-run snapshot behind, and resuming *that* still reproduces the full
    run bitwise (the resumed tail == the original tail)."""
    ckpt = str(tmp_path / "run.pkl")
    ref = Simulator(_cfg()).run(checkpoint_path=ckpt,
                                checkpoint_every=2).summary()
    payload = load_snapshot(ckpt)
    assert 0 < payload["next_round"] < BASE["rounds"]
    acct = resume_run(ckpt)
    assert summaries_equal(dict(acct.summary()), dict(ref))


def test_sweep_soft_crash_resume_is_bit_exact(tmp_path):
    spec = SweepSpec(
        axes={"policy": ["random", "relay"], "saa": [False, True]},
        base=dict(n_learners=40, rounds=8, eval_every=4, n_target=4,
                  mapping="label_uniform"),
        seeds=(0,))
    cells = spec.expand()
    ref, _ = run_batched(cells)
    ckpt = str(tmp_path / "sweep.pkl")
    plan = FaultPlan(n_learners=40, rounds=8, crash_after=3,
                     crash_mode="soft")
    with pytest.raises(InjectedCrash):
        run_batched(cells, fault_plan=plan, checkpoint_path=ckpt,
                    checkpoint_every=2)
    results, _ = resume_sweep(ckpt)
    assert len(results) == len(ref)
    for got, want in zip(results, ref):
        assert got.cell.name == want.cell.name
        assert summaries_equal(dict(got.summary), dict(want.summary)), \
            got.cell.name


def test_crash_resume_round_log_byte_continues(tmp_path):
    """Telemetry joins the resume contract: an uninterrupted level-2 run's
    ``rounds.jsonl`` is byte-equal to the crashed run's log after resume —
    the session truncates back to the snapshot's byte offset (dropping
    rounds logged after the last snapshot) and the resumed tail re-emits
    them identically.  The in-memory round log rides the snapshot the same
    way."""
    import dataclasses

    from repro.telemetry import TelemetrySession

    cfg = dataclasses.replace(_cfg(), telemetry=2)
    dir_a, dir_b = str(tmp_path / "clean"), str(tmp_path / "crashed")
    ckpt = str(tmp_path / "run.pkl")

    sess = TelemetrySession(dir_a)
    ref = Simulator(cfg).run(telemetry=sess)
    sess.close()

    sess = TelemetrySession(dir_b)
    with pytest.raises(InjectedCrash):
        Simulator(cfg, fault_plan=_crash_plan()).run(
            checkpoint_path=ckpt, checkpoint_every=2, telemetry=sess)
    sess.close()
    sess = TelemetrySession(dir_b)          # reopen the crashed run's dir
    acct = resume_run(ckpt, telemetry=sess)
    sess.close()

    assert summaries_equal(dict(acct.summary()), dict(ref.summary()))
    a = open(os.path.join(dir_a, "rounds.jsonl"), "rb").read()
    b = open(os.path.join(dir_b, "rounds.jsonl"), "rb").read()
    assert a == b and a
    assert acct.round_events == ref.round_events
    # the crash itself is on the (wall-order, contract-exempt) event log
    evs = open(os.path.join(dir_b, "events.jsonl")).read()
    assert '"event": "crash"' in evs


def test_snapshot_error_paths(tmp_path):
    with pytest.raises(SnapshotError):
        load_snapshot(str(tmp_path / "missing.pkl"))
    bad = str(tmp_path / "bad.pkl")
    save_snapshot(bad, {"version": 999, "kind": "pipeline"})
    with pytest.raises(SnapshotError, match="version"):
        load_snapshot(bad)
    with pytest.raises(SnapshotError, match="unknown snapshot kind"):
        save_snapshot(bad, {"version": 1, "kind": "mystery"})
        resume_run(bad)


def test_save_snapshot_is_atomic(tmp_path):
    """A crash mid-write must leave the previous snapshot readable: writes
    go to a tmp file and ``os.replace`` in."""
    p = str(tmp_path / "snap.pkl")
    save_snapshot(p, {"version": 1, "kind": "engine", "tag": "old"})
    save_snapshot(p, {"version": 1, "kind": "engine", "tag": "new"})
    assert load_snapshot(p)["tag"] == "new"
    assert not os.path.exists(p + ".tmp")


@pytest.mark.skipif(os.environ.get("CHAOS_SUBPROCESS") != "1",
                    reason="set CHAOS_SUBPROCESS=1 to run the SIGKILL leg "
                           "(CI chaos job does; it shells out a full sweep)")
def test_hard_crash_sigkill_and_cli_resume(tmp_path):
    """The CI chaos leg in-process: ``--crash-after R --crash-hard``
    SIGKILLs the sweep (exit 137), then ``--resume`` completes it with
    results bit-identical to an uninterrupted smoke run."""
    env = {**os.environ, "PYTHONPATH": "src"}
    ckpt = str(tmp_path / "sweep.pkl")
    clean_json = str(tmp_path / "clean.json")
    resume_json = str(tmp_path / "resumed.json")
    run = lambda *a: subprocess.run(
        [sys.executable, "-m", "repro.sweeps", *a],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True)

    clean = run("--smoke", "--out", clean_json)
    assert clean.returncode == 0, clean.stderr[-2000:]
    crashed = run("--smoke", "--checkpoint", ckpt, "--crash-after", "3",
                  "--crash-hard")
    assert crashed.returncode in (137, -9), \
        (crashed.returncode, crashed.stderr[-2000:])
    resumed = run("--resume", ckpt, "--out", resume_json)
    assert resumed.returncode == 0, resumed.stderr[-2000:]

    import json
    a = json.load(open(clean_json))["results"]
    b = json.load(open(resume_json))["results"]
    assert a == b
