"""Property: batching simulations along the sweep axis (packed cohort rows,
shared padding buckets, batched aggregation/eval) never changes any cell's
metrics vs a serial ``Simulator.run`` of the same config/seed.

Uses the hypothesis shim (``tests/_hypothesis_compat.py``): real hypothesis
when installed, deterministic fixed-seed draws otherwise.
"""
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.sim import SimConfig, Simulator
from repro.sweeps import Cell, SweepRunner
from repro.sweeps.runner import summaries_equal

BASE = dict(n_learners=30, rounds=6, eval_every=3, n_target=4,
            mapping="label_uniform", fast_path=True)


def _cells(*cfgs):
    return [Cell(name=f"cell{i}", coords=(("seed", c.seed),), config=c)
            for i, c in enumerate(cfgs)]


def _assert_cellwise_parity(cfgs):
    batched = SweepRunner(_cells(*cfgs)).run()
    for res, cfg in zip(batched, cfgs):
        serial = Simulator(cfg).run().summary()
        assert summaries_equal(dict(res.summary), dict(serial)), \
            (res.cell.name, res.summary, serial)
        # the full per-round schedule must match, not just the summary
        for rb, rs in zip(res.acct.records, Simulator(cfg).run().records):
            assert (rb.sim_time, rb.n_selected, rb.n_fresh, rb.n_stale) == \
                   (rs.sim_time, rs.n_selected, rs.n_fresh, rs.n_stale)


@settings(max_examples=6, deadline=None)
@given(selector=st.sampled_from(["random", "priority", "safa", "oort"]),
       saa=st.booleans(),
       setting=st.sampled_from(["OC", "DL"]),
       hardware=st.sampled_from(["HS1", "HS3"]),
       seed=st.integers(0, 2))
def test_batched_cells_match_serial(selector, saa, setting, hardware, seed):
    """A 2-cell batch (the drawn scenario + a fixed companion sharing the
    seed) reproduces each serial run bit-for-bit — companion included, so the
    drawn cell's presence never perturbs another cell."""
    drawn = SimConfig(selector=selector, saa=saa, setting=setting,
                      hardware_scenario=hardware, seed=seed,
                      deadline=60.0, **BASE)
    companion = SimConfig(selector="random", saa=True, seed=seed, **BASE)
    _assert_cellwise_parity([drawn, companion])


def test_heterogeneous_batch_matches_serial():
    """All four selectors + both settings in ONE batch, two shared seeds."""
    cfgs = [SimConfig(selector=s, saa=True, seed=sd, **BASE)
            for s in ("random", "priority", "safa", "oort") for sd in (0, 1)]
    _assert_cellwise_parity(cfgs)


def test_single_cell_batch_matches_serial():
    """S=1: the batched executor degenerates to the serial engine."""
    _assert_cellwise_parity([SimConfig(selector="priority", apt=True,
                                       saa=True, seed=2, **BASE)])


def test_shared_substrate_does_not_leak_state():
    """Two cells sharing one Substrate must each see the pristine seed world:
    their summaries equal two standalone serial runs, and running the pair
    twice gives identical results (no mutation of cached state)."""
    cfgs = [SimConfig(selector="random", seed=0, **BASE),
            SimConfig(selector="priority", seed=0, **BASE)]
    a = SweepRunner(_cells(*cfgs)).run()
    b = SweepRunner(_cells(*cfgs)).run()
    for ra, rb in zip(a, b):
        assert summaries_equal(dict(ra.summary), dict(rb.summary))
    _assert_cellwise_parity(cfgs)


def test_runner_rejects_legacy_path_cells():
    cfg = SimConfig(seed=0, **{**BASE, "fast_path": False})
    try:
        SweepRunner(_cells(cfg))
    except ValueError as e:
        assert "fast_path" in str(e)
    else:
        raise AssertionError("legacy-path cell accepted")
