"""Pin the fixed-key ``SimSummary`` schema that downstream layers (sweep
results, benchmarks, examples) consume."""
import math

from repro.sim import SimConfig, Simulator
from repro.sim.metrics import SUMMARY_KEYS, Accounting, RoundRecord, SimSummary

EXPECTED_KEYS = ("rounds", "sim_time", "resource_used", "resource_wasted",
                 "waste_fraction", "unique_participants", "final_accuracy",
                 "best_accuracy", "stopped_early", "rejected_nonfinite",
                 "rejected_norm", "quorum_skips", "robust_rejected",
                 "robust_trimmed")


def test_summary_keys_are_pinned():
    assert SUMMARY_KEYS == EXPECTED_KEYS
    assert tuple(SimSummary.__annotations__) == EXPECTED_KEYS


def test_empty_accounting_summary_schema():
    s = Accounting().summary()
    assert tuple(s) == EXPECTED_KEYS
    assert s["rounds"] == 0 and s["resource_used"] == 0.0
    assert s["waste_fraction"] == 0.0
    assert math.isnan(s["final_accuracy"]) and math.isnan(s["best_accuracy"])


def test_populated_summary_schema_and_types():
    acct = Accounting()
    acct.charge(100.0, wasted=False)
    acct.charge(20.0, wasted=True)
    acct.unique.update({1, 2, 3})
    acct.records.append(RoundRecord(0, 55.0, 5, 4, 1, 120.0, 20.0, 3,
                                    accuracy=0.5, loss=1.2))
    s = acct.summary()
    assert tuple(s) == EXPECTED_KEYS
    assert isinstance(s["rounds"], int) and s["rounds"] == 1
    assert isinstance(s["unique_participants"], int)
    assert s["sim_time"] == 55.0
    assert s["waste_fraction"] == 20.0 / 120.0
    assert s["final_accuracy"] == 0.5 == s["best_accuracy"]
    assert s["stopped_early"] is False
    acct.stopped_early = True
    assert acct.summary()["stopped_early"] is True


def test_simulator_summary_conforms():
    s = Simulator(SimConfig(n_learners=20, rounds=4, eval_every=2,
                            n_target=3)).run().summary()
    assert tuple(s) == EXPECTED_KEYS
    for k in EXPECTED_KEYS:
        assert isinstance(s[k], (int, float)), k   # bool is an int subtype
