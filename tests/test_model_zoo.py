"""Learner-model zoo contracts (the `MODEL_TABLE` strategy table):

  * ``model="mlp"`` IS the default — naming it changes nothing, bitwise,
    and the default cell stays bit-identical across the fused / chunked /
    participant-sharded / per-stage-flat substrates (PARITY_KEYS-level
    agreement with the legacy pytree engine, which never grew an
    accuracy-parity contract);
  * a tiny transformer LM (``benchmark="tokens"``) runs end-to-end through
    the same substrates with full bit-parity, fused vs flat vs chunked
    (vs sharded on multi-device legs);
  * the D-blocked kernel layout — ``use_agg_kernel=True`` keeps all round
    buffers at D rounded up to the kernel's 2048-column block — matches
    the unblocked per-stage reference bitwise, and the pad columns stay
    exactly zero for the life of the run;
  * the LM round program keeps the hot-path hygiene invariants: clean
    under ``jax.transfer_guard("disallow")`` and at most ONE cross-shard
    collective (the aggregation psum) at level-2 telemetry;
  * FLIPS on token workloads clusters on top-k unigram histograms
    (closed-form oracle) instead of crashing on missing class labels;
  * static-key plumbing: ``model_key`` rides ``pipeline_key``, knob typos
    and data-kind mismatches fail loudly at config/build time.
"""
import dataclasses
import re

import jax
import numpy as np
import pytest

from repro.learners import MODEL_TABLE, DataMeta, build_model, model_key
from repro.selection.flips import (FlipsSelector, kmeans_labels,
                                   learner_histograms, token_histograms)
from repro.sim import SimConfig, Simulator
from repro.sim.pipeline import RoundPipeline, pipeline_key
from repro.sweeps.runner import summaries_equal

N_DEV = len(jax.devices())

# the schedule/accounting fields the legacy pytree engine is pinned on
PARITY_KEYS = ("rounds", "sim_time", "resource_used", "resource_wasted",
               "unique_participants")

BASE = dict(n_learners=24, rounds=4, eval_every=2, n_target=4,
            mapping="label_uniform", saa=True, seed=0)

TINY_LM = (("d_ff", 8), ("d_model", 4), ("n_heads", 1), ("n_layers", 1))
LM_BASE = dict(benchmark="tokens", model="transformer", model_params=TINY_LM,
               n_learners=16, rounds=4, eval_every=2, n_target=4,
               local_steps=1, local_batch=4, saa=True,
               dynamic_availability=False, seed=0)


def _records_equal(a, b):
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert (ra.sim_time, ra.n_selected, ra.n_fresh, ra.n_stale,
                ra.resource_used, ra.resource_wasted) == \
               (rb.sim_time, rb.n_selected, rb.n_fresh, rb.n_stale,
                rb.resource_used, rb.resource_wasted)


# ---------------------------------------------------------------------------
# mlp: the registered default, bit-identical however the cell executes
# ---------------------------------------------------------------------------


def test_mlp_is_the_registered_default():
    cfg = SimConfig(**BASE)
    assert cfg.model == "mlp" and cfg.model_params == ()
    named = dataclasses.replace(cfg, model="mlp")
    assert pipeline_key(named) == pipeline_key(cfg)
    a, b = Simulator(cfg).run(), Simulator(named).run()
    assert summaries_equal(dict(a.summary()), dict(b.summary()))
    _records_equal(a, b)


SUBSTRATES = {
    "chunked": dict(rounds_per_dispatch=2),
    "sharded": dict(shard_participants=True),
    "flat": dict(fused_rounds=False),
    "legacy": dict(fast_path=False),
}


@pytest.mark.parametrize("name", sorted(SUBSTRATES))
def test_mlp_default_parity_across_substrates(name):
    cfg = SimConfig(model="mlp", **BASE)
    ref = dict(Simulator(cfg).run().summary())
    got = dict(Simulator(
        dataclasses.replace(cfg, **SUBSTRATES[name])).run().summary())
    if name == "legacy":
        # the legacy pytree engine pins schedule/accounting, not accuracy
        for k in PARITY_KEYS:
            assert got[k] == ref[k], (name, k)
    else:
        assert summaries_equal(ref, got), (name, ref, got)


# ---------------------------------------------------------------------------
# tiny transformer: full bit-parity through every fast-path substrate
# ---------------------------------------------------------------------------


LM_VARIANTS = {
    "flat": dict(fused_rounds=False),
    "chunked": dict(rounds_per_dispatch=2),
    "sharded": dict(shard_participants=True),
}


@pytest.mark.parametrize("name", sorted(LM_VARIANTS))
def test_transformer_substrate_parity(name):
    cfg = SimConfig(**LM_BASE)
    ref = Simulator(cfg).run()
    got = Simulator(dataclasses.replace(cfg, **LM_VARIANTS[name])).run()
    assert summaries_equal(dict(ref.summary()), dict(got.summary())), \
        (name, ref.summary(), got.summary())
    _records_equal(ref, got)


def test_legacy_engine_rejects_non_mlp_models():
    with pytest.raises(ValueError, match="flat fast path"):
        SimConfig(fast_path=False, **LM_BASE)


# ---------------------------------------------------------------------------
# D-blocked kernel layout vs the unblocked reference
# ---------------------------------------------------------------------------


def test_dblocked_kernel_matches_unblocked_reference():
    """use_agg_kernel keeps the fused pipeline's buffers at d_pad (a 2048
    multiple > D for the LM); the per-stage flat path pads transiently per
    kernel call.  Same math, same bits."""
    cfg = SimConfig(use_agg_kernel=True, **LM_BASE)
    blocked = Simulator(cfg).run()
    unblocked = Simulator(
        dataclasses.replace(cfg, fused_rounds=False)).run()
    assert summaries_equal(dict(blocked.summary()),
                           dict(unblocked.summary()))
    _records_equal(blocked, unblocked)


@pytest.mark.skipif(N_DEV < 2, reason="needs a multi-device mesh")
def test_dblocked_kernel_sharded_matches_unblocked_reference():
    cfg = SimConfig(use_agg_kernel=True, shard_participants=2, **LM_BASE)
    sharded = Simulator(cfg).run()
    unblocked = Simulator(dataclasses.replace(
        cfg, shard_participants=False, fused_rounds=False)).run()
    assert summaries_equal(dict(sharded.summary()),
                           dict(unblocked.summary()))
    _records_equal(sharded, unblocked)


def test_padded_layout_pad_columns_stay_zero():
    from repro.kernels.staleness_agg.staleness_agg import D_BLK
    cfg = SimConfig(use_agg_kernel=True, **LM_BASE)
    pipe = RoundPipeline([Simulator(cfg)])
    assert pipe.d_pad > pipe.d and pipe.d_pad % D_BLK == 0
    pipe.run()
    rows = np.asarray(jax.device_get(pipe.params)).reshape(-1, pipe.d_pad)
    assert (rows[:, pipe.d:] == 0).all(), \
        "pad columns leaked nonzero values into the persistent layout"
    # without the kernel there is nothing to block for: layout is exact-D
    flat_pipe = RoundPipeline(
        [Simulator(dataclasses.replace(cfg, use_agg_kernel=False))])
    assert flat_pipe.d_pad == flat_pipe.d


# ---------------------------------------------------------------------------
# LM hot-path hygiene: transfer-guard clean, one collective at telemetry 2
# ---------------------------------------------------------------------------


def test_lm_round_loop_transfer_clean_single_collective():
    from repro.telemetry import TelemetrySession
    cfg = SimConfig(telemetry=2, shard_participants=True, **LM_BASE)
    RoundPipeline([Simulator(cfg)]).run()            # warm compiles
    pipe = RoundPipeline([Simulator(cfg)], telemetry=TelemetrySession())
    orig, captured = pipe._prog, []

    def wrapper(*args):
        if not captured:
            captured.append(orig.lower(*args).compile().as_text())
        return orig(*args)

    pipe._prog = wrapper
    accts = pipe.run(transfer_guard=True)
    assert accts[0].summary()["rounds"] == LM_BASE["rounds"]
    txt = captured[0]
    n_all_reduce = len(re.findall(r"all-reduce(?:-start)?\(", txt))
    for op in ("all-gather", "all-to-all", "collective-permute",
               "reduce-scatter"):
        assert f"{op}(" not in txt, f"unexpected {op} in the LM round program"
    if N_DEV > 1:
        assert n_all_reduce == 1, \
            f"expected exactly 1 all-reduce (the psum), found {n_all_reduce}"
    else:
        assert n_all_reduce <= 1


# ---------------------------------------------------------------------------
# FLIPS on token workloads: top-k unigram histogram adapter + quotas
# ---------------------------------------------------------------------------


class _TokData:
    kind = "tokens"
    vocab = 16
    x_train = np.array([[0, 0, 1], [2, 2, 2], [3, 3, 0]], np.int32)
    shards = (np.array([0]), np.array([1, 2]))


class _ClsData:
    kind = "classifier"
    n_classes = 3
    y_train = np.array([0, 0, 1, 2])
    shards = (np.array([0, 1]), np.array([2, 3]))


def test_token_histograms_closed_form():
    # global counts: tok0 x3, tok2 x3, tok3 x2, tok1 x1 -> top-2 = [0, 2]
    # (count desc, token id asc on ties)
    h = token_histograms(_TokData(), top_k=2)
    np.testing.assert_allclose(h, [[1.0, 0.0],        # shard0: [0,0,1]
                                   [0.25, 0.75]])     # shard1: 2x3, 3x2, 0x1
    # the adapter dispatches on FederatedDataset.kind
    np.testing.assert_allclose(learner_histograms(_TokData(), top_k=2), h)
    cls = learner_histograms(_ClsData())
    np.testing.assert_allclose(cls, [[1.0, 0.0, 0.0], [0.0, 0.5, 0.5]])


def test_token_quota_closed_form():
    sel = FlipsSelector(np.array([0, 0, 0, 0, 0, 1, 1, 1, 2]))
    # equal split 2/2/2; cluster 2 holds 1 member -> spill 1 goes to the
    # largest cluster with headroom
    assert sel.quotas([5, 3, 1], 6) == [3, 2, 1]
    # end-to-end: a token clustering's cohort honors the quota split
    rng = np.random.default_rng(0)
    chosen = sel.select_ids(0, list(range(9)), 6, rng)
    counts = np.bincount(sel.cluster_of[chosen], minlength=3)
    assert list(counts) == [3, 2, 1]


def test_flips_selects_on_token_benchmark():
    cfg = SimConfig(**dict(LM_BASE, selector="flips",
                           selector_params={"n_clusters": 3,
                                            "token_top_k": 32}))
    acct = Simulator(cfg).run()
    assert acct.summary()["rounds"] == LM_BASE["rounds"]


# ---------------------------------------------------------------------------
# static keys + loud failures
# ---------------------------------------------------------------------------


def test_model_key_rides_pipeline_key():
    a = SimConfig(**LM_BASE)
    b = dataclasses.replace(a, model_params=TINY_LM[:-1] + (("n_layers", 2),))
    c = dataclasses.replace(a, model="rwkv6", model_params=TINY_LM)
    assert model_key(a) != model_key(b) != model_key(c)
    assert len({pipeline_key(a), pipeline_key(b), pipeline_key(c)}) == 3


def test_unknown_model_and_knob_typos_fail_at_config_time():
    with pytest.raises(ValueError, match="unknown model"):
        SimConfig(model="resnet", **BASE)
    with pytest.raises((KeyError, ValueError)):
        SimConfig(model="transformer", model_params=(("dmodel", 4),),
                  **{k: v for k, v in LM_BASE.items()
                     if k not in ("model", "model_params")})


def test_data_kind_mismatch_fails_at_build_time():
    cfg = SimConfig(model="transformer", model_params=TINY_LM, **BASE)
    with pytest.raises(ValueError, match="tokens"):
        Simulator(cfg)


def test_model_table_lists_the_zoo():
    assert {"mlp", "transformer", "moe", "rwkv6"} <= set(MODEL_TABLE)
    meta = DataMeta(kind="tokens", vocab=64, seq_len=8)
    fns = build_model("transformer", TINY_LM, meta)
    assert fns is build_model("transformer", TINY_LM, meta), \
        "build_model must return cached-identical function objects"
