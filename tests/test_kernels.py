"""Pallas kernel sweeps: shapes x dtypes, allclose vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.staleness_agg import ops as agg_ops
from repro.kernels.staleness_agg import ref as agg_ref
from repro.kernels.swa_attention import ops as swa_ops
from repro.kernels.swa_attention import ref as swa_ref
from repro.kernels.wkv6 import ops as wkv_ops
from repro.kernels.wkv6.ref import wkv6_scan


# ---------------------------------------------------------------------------
# staleness_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,D", [(2, 2048), (5, 2048), (8, 4096 + 77),
                                 (3, 1000), (16, 8192)])
@pytest.mark.parametrize("rule", ["equal", "dynsgd", "adasgd", "relay"])
def test_staleness_agg_matches_oracle(n, D, rule):
    rng = np.random.default_rng(n * D)
    U = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    fresh = jnp.asarray([True] + list(rng.random(n - 1) < 0.5))
    tau = jnp.where(fresh, 0, jnp.asarray(rng.integers(1, 6, n)))
    agg_k, w_k = agg_ops.staleness_aggregate(U, fresh, tau, rule=rule)
    agg_r, w_r = agg_ref.staleness_aggregate_ref(U, fresh, tau, rule=rule)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(agg_k), np.asarray(agg_r),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,D", [(2, 2048), (5, 2048), (8, 4096 + 77)])
@pytest.mark.parametrize("rule", ["equal", "dynsgd", "adasgd", "relay"])
def test_fused_staleness_agg_matches_two_pass(n, D, rule):
    """Single-traversal fused kernel == two-launch pipeline == jnp oracle."""
    rng = np.random.default_rng(n + D)
    U = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    fresh = jnp.asarray([True] + list(rng.random(n - 1) < 0.5))
    tau = jnp.where(fresh, 0, jnp.asarray(rng.integers(1, 6, n)))
    agg_f, w_f = agg_ops.staleness_aggregate(U, fresh, tau, rule=rule,
                                             fused=True)
    agg_2, w_2 = agg_ops.staleness_aggregate(U, fresh, tau, rule=rule,
                                             fused=False)
    agg_r, w_r = agg_ref.staleness_aggregate_ref(U, fresh, tau, rule=rule)
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(agg_f), np.asarray(agg_r),
                               rtol=1e-4, atol=1e-5)


def test_fused_staleness_apply_in_place_step():
    """params + lr * aggregate, computed in the same grid traversal with the
    params buffer aliased input->output."""
    rng = np.random.default_rng(42)
    n, D = 6, 4096 + 33
    U = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    p0 = jnp.asarray(rng.standard_normal(D), jnp.float32)
    fresh = jnp.asarray([True, True, True, False, False, False])
    tau = jnp.asarray([0, 0, 0, 2, 3, 5], jnp.int32)
    agg_r, w_r = agg_ref.staleness_aggregate_ref(U, fresh, tau, rule="relay")
    new_p, w = agg_ops.staleness_apply(p0, U, fresh, tau, rule="relay",
                                       server_lr=0.5)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_p),
                               np.asarray(p0 + 0.5 * agg_r),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("rule", ["equal", "relay"])
def test_sweep_staleness_apply_matches_aggregate_kernel(rule):
    """Sweep-axis fused server step == the sweep aggregate kernel's result
    applied with per-cell lr (same blockwise partials math, params buffer
    aliased input->output), and an all-invalid cell keeps its bits."""
    rng = np.random.default_rng(7)
    S, n, D = 3, 6, 4096 + 33
    U = rng.standard_normal((S, n, D)).astype(np.float32)
    params = rng.standard_normal((S, D)).astype(np.float32)
    fresh = rng.random((S, n)) < 0.5
    fresh[:, 0] = True
    tau = np.where(fresh, 0, rng.integers(1, 5, (S, n))).astype(np.int32)
    valid = np.ones((S, n), bool)
    valid[2] = False                                  # all-invalid cell
    beta = np.array([0.2, 0.35, 0.5], np.float32)
    lr = np.array([1.0, 0.5, 2.0], np.float32)
    agg_k, w_k = agg_ops.sweep_staleness_aggregate(U, fresh, tau, valid=valid,
                                                   rule=rule, beta=beta)
    new_p, w_a = agg_ops.sweep_staleness_apply(params, U, fresh, tau,
                                               valid=valid, rule=rule,
                                               beta=beta, server_lr=lr)
    np.testing.assert_array_equal(np.asarray(w_a), np.asarray(w_k))
    np.testing.assert_array_equal(
        np.asarray(new_p), params + lr[:, None] * np.asarray(agg_k))
    np.testing.assert_array_equal(np.asarray(new_p)[2], params[2])


def test_staleness_agg_deviation_partials():
    from repro.kernels.staleness_agg.staleness_agg import deviation_partials
    from repro.kernels.staleness_agg.ref import deviation_partials_ref
    rng = np.random.default_rng(0)
    U = jnp.asarray(rng.standard_normal((6, 4096)), jnp.float32)
    fresh = jnp.asarray([True, True, True, False, False, False])
    num_k, den_k = deviation_partials(U, fresh)
    num_r, den_r = deviation_partials_ref(U, fresh)
    np.testing.assert_allclose(np.asarray(num_k), np.asarray(num_r), rtol=1e-4)
    np.testing.assert_allclose(float(den_k), float(den_r), rtol=1e-5)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,Hkv,Dh,W", [
    (1, 256, 2, 1, 64, 128),
    (2, 384, 4, 2, 64, 256),
    (1, 200, 2, 2, 128, 128),   # unaligned S -> padding path
    (1, 512, 8, 2, 64, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_matches_oracle(B, S, H, Hkv, Dh, W, dtype):
    rng = np.random.default_rng(S + W)
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), dtype)
    out_k = swa_ops.swa_attention(q, k, v, window=W)
    out_r = swa_ref.swa_attention_ref(q, k, v, window=W)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


def test_swa_attention_respects_window():
    """Tokens beyond the window must have zero influence."""
    B, S, H, Dh, W = 1, 384, 1, 64, 128
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    out1 = swa_ops.swa_attention(q, k, v, window=W)
    # perturb keys/values far outside the last query's window
    k2 = k.at[:, :S - W - 1].set(rng.standard_normal((B, S - W - 1, H, Dh)))
    v2 = v.at[:, :S - W - 1].set(rng.standard_normal((B, S - W - 1, H, Dh)))
    out2 = swa_ops.swa_attention(q, k2, v2, window=W)
    np.testing.assert_allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,N", [(2, 128, 2, 16), (1, 200, 3, 32),
                                     (2, 256, 1, 64), (1, 384, 4, 8)])
def test_wkv6_matches_oracle(B, S, H, N):
    rng = np.random.default_rng(B * S)
    r, k, v = (jnp.asarray(rng.standard_normal((B, S, H, N)), jnp.float32) * 0.5
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.8, 0.999, (B, S, H, N)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, N)), jnp.float32) * 0.1
    s0 = jnp.asarray(rng.standard_normal((B, H, N, N)), jnp.float32) * 0.1
    y_k, s_k = wkv_ops.wkv6(r, k, v, w, u, state0=s0)
    y_r, s_r = wkv6_scan(r, k, v, w, u, state0=s0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-5)


def test_wkv6_state_continuation():
    """Running [0:S/2] then [S/2:S] with carried state == one full pass."""
    B, S, H, N = 1, 256, 2, 16
    rng = np.random.default_rng(7)
    r, k, v = (jnp.asarray(rng.standard_normal((B, S, H, N)), jnp.float32) * 0.5
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, S, H, N)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, N)), jnp.float32) * 0.1
    y_full, s_full = wkv_ops.wkv6(r, k, v, w, u)
    h = S // 2
    y1, s1 = wkv_ops.wkv6(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u)
    y2, s2 = wkv_ops.wkv6(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, state0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-5)
