"""Property: the fused device-resident round pipeline (single dispatch per
round, device stale cache, in-program batch gather, fused aggregate+apply)
reproduces the per-stage flat path bit for bit — full summary, accuracy
included — across selectors, settings, aggregators and scaling rules.

Also pins the pipeline's hot-path hygiene: the round loop runs clean under
``jax.transfer_guard("disallow")`` (every upload is an explicit
device_put), one round program dispatch per round, and donation safety
(running twice from fresh Simulators gives identical results).
"""
import dataclasses

from _hypothesis_compat import given, settings, st
from repro.sim import SimConfig, Simulator
from repro.sim.pipeline import RoundPipeline
from repro.sweeps.runner import summaries_equal

BASE = dict(n_learners=30, rounds=6, eval_every=3, n_target=4,
            mapping="label_uniform")


def _parity(cfg_fused: SimConfig):
    cfg_flat = dataclasses.replace(cfg_fused, fused_rounds=False)
    fused = Simulator(cfg_fused).run()
    flat = Simulator(cfg_flat).run()
    assert summaries_equal(dict(fused.summary()), dict(flat.summary())), \
        (cfg_fused, fused.summary(), flat.summary())
    # the full per-round schedule must match, not just the summary
    for rf, rl in zip(fused.records, flat.records):
        assert (rf.sim_time, rf.n_selected, rf.n_fresh, rf.n_stale,
                rf.resource_used, rf.resource_wasted) == \
               (rl.sim_time, rl.n_selected, rl.n_fresh, rl.n_stale,
                rl.resource_used, rl.resource_wasted)


@settings(max_examples=8, deadline=None)
@given(selector=st.sampled_from(["random", "priority", "safa", "oort"]),
       saa=st.booleans(),
       setting=st.sampled_from(["OC", "DL"]),
       rule=st.sampled_from(["relay", "dynsgd", "equal"]),
       seed=st.integers(0, 2))
def test_fused_rounds_match_per_stage_path(selector, saa, setting, rule, seed):
    _parity(SimConfig(selector=selector, saa=saa, setting=setting,
                      scaling_rule=rule, seed=seed, deadline=60.0, **BASE))


def test_fused_yogi_and_apt_match():
    _parity(SimConfig(selector="priority", saa=True, apt=True,
                      aggregator="yogi", seed=1, **BASE))


def test_fused_staleness_threshold_match():
    _parity(SimConfig(selector="safa", saa=True, staleness_threshold=1,
                      seed=0, **BASE))


def test_round_loop_is_transfer_clean():
    """The fused hot loop performs no implicit host transfers: the round
    loop runs to completion under jax.transfer_guard('disallow'), with one
    round-program dispatch per executed round and only explicit uploads."""
    cfg = SimConfig(selector="priority", saa=True, seed=0, **BASE)
    Simulator(cfg).run()                     # warm compiles outside the guard
    pipe = RoundPipeline([Simulator(cfg)])
    accts = pipe.run(transfer_guard=True)
    stats = pipe.stats.as_dict()
    assert stats["dispatches"]["round"] == stats["rounds"] > 0
    assert accts[0].summary()["rounds"] > 0
    # per-round host traffic is index arrays only — a few KB, far below the
    # size of even a single flat update row
    d = len(Simulator(cfg).flat_params)
    assert stats["h2d_bytes_per_round"] < min(64 * 1024, d * 4)


def test_donated_buffers_fresh_runs_identical():
    """Donation must never leak state between runs: two fresh Simulators of
    the same config produce identical summaries."""
    cfg = SimConfig(selector="random", saa=True, seed=3, **BASE)
    a = Simulator(cfg).run().summary()
    b = Simulator(cfg).run().summary()
    assert summaries_equal(dict(a), dict(b))


def test_oort_feedback_fetches_stat_utils():
    """Oort is the only selector that consumes the per-row stat-utility
    feedback; with an Oort cell the pipeline fetches it and the selector's
    utility table fills in (matching the per-stage path bit for bit, which
    the parity property above already asserts)."""
    cfg = SimConfig(selector="oort", saa=True, seed=0, **BASE)
    sim = Simulator(cfg)
    sim.run()
    assert len(sim.selector._stat_util) > 0
    assert all(v >= 0.0 for v in sim.selector._stat_util.values())


def test_pipeline_rejects_incompatible_batch():
    c1 = SimConfig(seed=0, **BASE)
    c2 = dataclasses.replace(c1, local_lr=0.01)
    try:
        RoundPipeline([Simulator(c1), Simulator(c2)])
    except AssertionError as e:
        assert "incompatible" in str(e)
    else:
        raise AssertionError("incompatible batch accepted")
