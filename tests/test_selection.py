"""Selector behavior tests (paper Alg. 1 + baselines)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.selection import (LearnerView, OortSelector, PrioritySelector,
                                  RandomSelector, SafaSelector)


def _views(n, rng, probs=None, durations=None):
    return [LearnerView(i,
                        availability_prob=(probs[i] if probs is not None
                                           else rng.random()),
                        est_duration=(durations[i] if durations is not None
                                      else rng.uniform(10, 300)))
            for i in range(n)]


def test_random_selects_target_count():
    rng = np.random.default_rng(0)
    sel = RandomSelector()
    chosen = sel.select(0, _views(50, rng), 10, rng)
    assert len(chosen) == 10 and len(set(chosen)) == 10


def test_safa_selects_everyone():
    rng = np.random.default_rng(0)
    chosen = SafaSelector().select(0, _views(37, rng), 10, rng)
    assert len(chosen) == 37


def test_priority_picks_least_available():
    """Alg. 1: ascending availability order."""
    rng = np.random.default_rng(0)
    probs = np.linspace(0.05, 0.95, 20)
    chosen = PrioritySelector(holdoff=0).select(0, _views(20, rng, probs=probs),
                                                5, rng)
    assert sorted(chosen) == [0, 1, 2, 3, 4]


def test_priority_tie_shuffling():
    rng = np.random.default_rng(1)
    probs = np.full(30, 0.5)
    counts = np.zeros(30)
    for r in range(200):
        sel = PrioritySelector(holdoff=0)
        for lid in sel.select(r, _views(30, rng, probs=probs), 5, rng):
            counts[lid] += 1
    assert counts.min() > 0  # ties broken randomly -> everyone gets picked


def test_priority_holdoff():
    """Participants hold off for `holdoff` rounds after selection."""
    rng = np.random.default_rng(0)
    probs = np.linspace(0.05, 0.95, 20)
    sel = PrioritySelector(holdoff=5)
    first = sel.select(0, _views(20, rng, probs=probs), 5, rng)
    second = sel.select(1, _views(20, rng, probs=probs), 5, rng)
    assert not set(first) & set(second)


def test_oort_prefers_high_utility():
    rng = np.random.default_rng(0)
    durations = np.full(20, 50.0)
    sel = OortSelector(eps0=0.0)  # pure exploitation
    for lid in range(20):
        sel.update_feedback(lid, stat_util=float(lid), duration=50.0)
    chosen = sel.select(0, _views(20, rng, durations=durations), 5, rng)
    assert set(chosen) == {15, 16, 17, 18, 19}


def test_oort_penalizes_slow_learners():
    rng = np.random.default_rng(0)
    sel = OortSelector(eps0=0.0, alpha=2.0)
    sel.t_pref = 100.0
    # same stat utility, one much slower than t_pref
    sel.update_feedback(0, stat_util=10.0, duration=50.0)
    sel.update_feedback(1, stat_util=10.0, duration=400.0)
    views = _views(2, rng, durations=np.array([50.0, 400.0]))
    chosen = sel.select(0, views, 1, rng)
    assert chosen == [0]


def test_oort_explores_unexplored():
    rng = np.random.default_rng(0)
    sel = OortSelector(eps0=1.0, eps_min=1.0)  # pure exploration
    for lid in range(5):
        sel.update_feedback(lid, stat_util=100.0, duration=10.0)
    views = _views(10, rng, durations=np.linspace(10, 100, 10))
    chosen = sel.select(0, views, 5, rng)
    assert set(chosen) & set(range(5, 10))  # includes unexplored learners


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 60), k=st.integers(1, 20), seed=st.integers(0, 50))
def test_selectors_return_valid_subsets(n, k, seed):
    rng = np.random.default_rng(seed)
    views = _views(n, rng)
    for sel in (RandomSelector(), PrioritySelector(), OortSelector()):
        chosen = sel.select(0, views, k, rng)
        assert len(chosen) <= max(k, n)
        assert len(set(chosen)) == len(chosen)
        assert set(chosen) <= set(range(n))
        assert len(chosen) == min(k, n)
