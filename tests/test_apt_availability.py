"""APT (§4.1) + availability forecaster tests."""
import numpy as np

from repro.core.apt import AdaptiveParticipantTarget
from repro.core.availability import AvailabilityForecaster, DAY, HOUR
from repro.sim.traces import LearnerTrace


def test_apt_ewma():
    apt = AdaptiveParticipantTarget(n0=10, alpha=0.25)
    apt.update_round_duration(100.0)
    assert apt.mu == 100.0
    mu = apt.update_round_duration(200.0)
    # mu = (1-alpha)*D + alpha*mu_prev = 0.75*200 + 0.25*100
    assert np.isclose(mu, 175.0)


def test_apt_target_shrinks_with_inflight_stragglers():
    apt = AdaptiveParticipantTarget(n0=10)
    apt.update_round_duration(100.0)
    assert apt.target([]) == 10
    assert apt.target([50.0, 80.0, 99.0]) == 7      # all land within mu
    assert apt.target([500.0, 600.0]) == 10         # none land
    assert apt.target([10.0] * 50) == 1             # floor at 1


def test_apt_slot():
    apt = AdaptiveParticipantTarget(n0=5)
    apt.update_round_duration(60.0)
    assert apt.next_slot == (60.0, 120.0)


def test_forecaster_learns_diurnal_pattern():
    """Night-charger device: the forecaster must rank night >> day."""
    f = AvailabilityForecaster()
    for day in range(5):
        for hod in range(24):
            t = day * DAY + hod * HOUR
            f.observe(t, available=(hod >= 22 or hod < 6))
    t0 = 6 * DAY
    p_night = f.predict_window(t0 + 23 * HOUR, t0 + 23.5 * HOUR)
    p_day = f.predict_window(t0 + 12 * HOUR, t0 + 12.5 * HOUR)
    assert p_night > 0.6 > p_day


def test_forecaster_scores_against_trace():
    """End-to-end: train on the first half of a synthetic trace, predict the
    second half — R^2 well above the trivial predictor (paper §5.2 analogue)."""
    trace = LearnerTrace(seed=5, phase_hours=0.0, night_owl=0.9)
    f = AvailabilityForecaster()
    train_ts = np.arange(0, 7 * DAY, 900.0)
    for t in train_ts:
        f.observe(float(t), trace.available(float(t)))
    eval_ts = np.arange(7 * DAY, 10 * DAY, 1800.0)
    m = f.score(trace.available, eval_ts)
    assert m["mae"] < 0.5
    assert m["r2"] > 0.0


def test_forecaster_score_constant_truth_reports_nan_r2():
    """R^2 divides by the truth variance; an always-available learner has
    var == 0, so the score must report NaN rather than a bogus ratio —
    while MSE/MAE stay finite and meaningful."""
    f = AvailabilityForecaster()
    for t in np.arange(0, 2 * DAY, 900.0):
        f.observe(float(t), True)
    m = f.score(lambda t: True, np.arange(2 * DAY, 3 * DAY, 1800.0))
    assert m["r2"] != m["r2"]                     # NaN
    assert np.isfinite(m["mse"]) and np.isfinite(m["mae"])
    assert m["mae"] < 0.5


def test_forecaster_score_varying_truth_reports_finite_r2():
    trace = LearnerTrace(seed=5, phase_hours=0.0, night_owl=0.9)
    f = AvailabilityForecaster()
    for t in np.arange(0, 7 * DAY, 900.0):
        f.observe(float(t), trace.available(float(t)))
    m = f.score(trace.available, np.arange(7 * DAY, 9 * DAY, 1800.0))
    assert np.isfinite(m["r2"])
