"""Per-assigned-architecture smoke tests: REDUCED variant of the same family,
one forward + one FL train step on CPU; output shapes + no NaNs.
Also decode-vs-forward consistency for every mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.launch.train import make_fl_train_step
from repro.models import (ModelConfig, decode_step, forward, init_decode_state,
                          init_params)
from repro.models.transformer import lm_loss, prefill, _logits


def _batch(cfg, key, B=2, S=16, lead=()):
    b = {"tokens": jax.random.randint(key, lead + (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, lead + (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        b["frontend_embeds"] = jnp.ones(
            lead + (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    assert not cfg.moe or cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)

    batch = _batch(cfg, key)
    x, aux, _ = forward(cfg, params, batch)
    S_total = 16 + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert x.shape == (2, S_total, cfg.d_model)
    assert bool(jnp.isfinite(x.astype(jnp.float32)).all())

    loss = lm_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))

    # one FL train step (Alg. 2) with a stale participant
    step = jax.jit(make_fl_train_step(cfg, local_lr=1e-2))
    pb = _batch(cfg, key, B=2, S=16, lead=(3,))
    new_params, metrics = step(params, pb,
                               jnp.asarray([True, True, False]),
                               jnp.asarray([0, 0, 2], jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert np.isclose(float(metrics["weights"].sum()), 1.0, atol=1e-4)
    changed = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B = 2
    state = init_decode_state(cfg, B, 32)
    logits, state = decode_step(cfg, params, state,
                                jnp.zeros((B,), jnp.int32),
                                jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("family_cfg", [
    ("gqa", dict()),
    ("gqa-swa", dict(window=4)),
    ("mla", dict(attn_type="mla", kv_lora_rank=32, qk_nope_dim=16,
                 qk_rope_dim=8, v_head_dim=16)),
    ("rwkv6", dict(block_pattern=("rwkv6",), rwkv_lora_rank=8,
                   rwkv_w_lora_rank=8)),
    ("hybrid", dict(block_pattern=("mamba", "attn"), n_layers=4)),
], ids=lambda fc: fc[0])
def test_decode_matches_forward(family_cfg):
    """Incremental decode must reproduce full-sequence logits exactly."""
    _, over = family_cfg
    kw = dict(n_layers=2, d_model=64, n_heads=4,
              n_kv_heads=4 if "mla" in str(over) else 2,
              d_ff=128, vocab_size=97, param_dtype=jnp.float32)
    kw.update(over)
    cfg = ModelConfig(**kw)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    x, _, _ = forward(cfg, params, {"tokens": toks})
    full = _logits(cfg, params, x)
    st = init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, st = decode_step(cfg, params, st, toks[:, t],
                             jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-3, atol=2e-3)


def test_prefill_continues_into_decode():
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=97, param_dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S + 1), 0, 97)
    # ground truth: full forward over S+1 tokens
    x, _, _ = forward(cfg, params, {"tokens": toks})
    want = _logits(cfg, params, x)[:, -1]
    # prefill S tokens, then one decode step
    logits_p, states = prefill(cfg, params, {"tokens": toks[:, :S]})
    st = init_decode_state(cfg, B, S + 1)
    # load prefill kv into the decode cache
    def load(cache_leaf, pre_leaf):
        if cache_leaf.ndim >= 2 and pre_leaf.shape[-2:] == cache_leaf.shape[-2:] \
                and cache_leaf.shape[-3] >= pre_leaf.shape[-3]:
            pass
        return cache_leaf
    # (simplified: re-run decode from scratch instead of cache transplant)
    st = init_decode_state(cfg, B, S + 1)
    for t in range(S + 1):
        lg, st = decode_step(cfg, params, st, toks[:, t],
                             jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                               rtol=1e-3, atol=2e-3)


def test_vmap_and_stream_cohorts_agree():
    cfg = get_reduced("qwen2.5-3b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    pb = _batch(cfg, key, B=2, S=16, lead=(4,))
    fresh = jnp.asarray([True, True, True, False])
    tau = jnp.asarray([0, 0, 0, 2], jnp.int32)
    n1, m1 = jax.jit(make_fl_train_step(cfg, cohort="vmap"))(params, pb, fresh, tau)
    n2, m2 = jax.jit(make_fl_train_step(cfg, cohort="stream"))(params, pb, fresh, tau)
    np.testing.assert_allclose(np.asarray(m1["weights"]),
                               np.asarray(m2["weights"]), rtol=1e-3, atol=1e-5)
    for a, b in zip(jax.tree.leaves(n1), jax.tree.leaves(n2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-2, atol=1e-5)


def test_yogi_server_pod_step():
    """YoGi-server variant of the pod FL step (paper's default aggregator for
    the non-CIFAR benchmarks) trains and threads its state."""
    from repro.core.aggregation import yogi_init
    from repro.launch.train import make_fl_train_step_yogi
    cfg = get_reduced("internlm2-1.8b")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    pb = _batch(cfg, key, B=2, S=16, lead=(3,))
    fresh = jnp.asarray([True, True, False])
    tau = jnp.asarray([0, 0, 1], jnp.int32)
    st = yogi_init(params)
    step = jax.jit(make_fl_train_step_yogi(cfg))
    p, st, m = step(params, st, pb, fresh, tau)
    p, st, m = step(p, st, pb, fresh, tau)
    assert int(st["t"]) == 2
    assert bool(jnp.isfinite(m["loss"]))
    assert np.isclose(float(m["weights"].sum()), 1.0, atol=1e-4)
