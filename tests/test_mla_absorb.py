"""MLA absorbed-decode (beyond-paper perf variant) must match the naive path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, decode_step, init_decode_state, init_params


def test_absorbed_mla_decode_matches_naive():
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=97, attn_type="mla",
                      kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16, param_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 97)
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)

    st_n = init_decode_state(cfg, B, S)
    st_a = init_decode_state(cfg_a, B, S)
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        lg_n, st_n = decode_step(cfg, params, st_n, toks[:, t], pos)
        lg_a, st_a = decode_step(cfg_a, params, st_a, toks[:, t], pos)
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_n),
                                   rtol=1e-3, atol=1e-3)
