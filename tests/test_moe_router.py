"""MoE router/dispatch unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.moe import (load_balance_loss, moe_forward, moe_init,
                              router_topk)


def test_router_topk_normalized():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((32, 8)),
                         jnp.float32)
    gates, idx = router_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (32, 3)
    assert len(np.unique(np.asarray(idx[0]))) == 3  # distinct experts


def test_topk_selects_argmax():
    logits = jnp.zeros((4, 8)).at[:, 5].set(10.0)
    _, idx = router_topk(logits, 1)
    assert (np.asarray(idx) == 5).all()


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss == E * E*(1/E^2) == 1."""
    N, E = 1024, 8
    logits = jnp.zeros((N, E))
    idx = jnp.tile(jnp.arange(E), N // E)[:N, None]
    lb = load_balance_loss(logits, idx, E)
    np.testing.assert_allclose(float(lb), 1.0, rtol=1e-2)


def test_load_balance_loss_penalizes_collapse():
    N, E = 1024, 8
    logits = jnp.zeros((N, E)).at[:, 0].set(5.0)
    idx = jnp.zeros((N, 1), jnp.int32)
    lb_collapsed = load_balance_loss(logits, idx, E)
    uniform_idx = jnp.tile(jnp.arange(E), N // E)[:N, None]
    lb_uniform = load_balance_loss(jnp.zeros((N, E)), uniform_idx, E)
    assert float(lb_collapsed) > 2 * float(lb_uniform)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([8, 16]),
       e=st.sampled_from([4, 8]), k=st.integers(1, 3), seed=st.integers(0, 20))
def test_moe_forward_properties(b, s, e, k, seed):
    d, f = 32, 16
    key = jax.random.PRNGKey(seed)
    p = moe_init(key, d, f, e, 1, f, jnp.float32)
    x = jax.random.normal(key, (b, s, d), jnp.float32)
    out, aux = moe_forward(p, x, n_experts=e, top_k=min(k, e), group_size=64)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))


def test_moe_capacity_overflow_drops_tokens_gracefully():
    """With capacity_factor ~0, most tokens overflow — output stays finite and
    shrinks toward the shared-expert-only path."""
    d, f, e = 16, 8, 4
    key = jax.random.PRNGKey(0)
    p = moe_init(key, d, f, e, 0, f, jnp.float32)
    x = jax.random.normal(key, (2, 32, d), jnp.float32)
    full, _ = moe_forward(p, x, n_experts=e, top_k=2, group_size=64,
                          capacity_factor=4.0)
    tiny, _ = moe_forward(p, x, n_experts=e, top_k=2, group_size=64,
                          capacity_factor=0.01)
    assert bool(jnp.isfinite(tiny).all())
    assert float(jnp.abs(tiny).mean()) <= float(jnp.abs(full).mean()) + 1e-6


def test_rwkv_kernel_path_matches_scan_in_model():
    """cfg.use_kernels routes rwkv6 through the Pallas kernel — same logits."""
    from repro.models import ModelConfig, init_params
    from repro.models.transformer import forward
    import dataclasses
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=97, block_pattern=("rwkv6",),
                      rwkv_lora_rank=8, rwkv_w_lora_rank=8,
                      param_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, 97)}
    x1, _, _ = forward(cfg, params, toks)
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    x2, _, _ = forward(cfg_k, params, toks)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=1e-4, atol=1e-4)


def test_swa_kernel_path_matches_blocked_in_model():
    """cfg.use_kernels + sliding window routes GQA through the Pallas flash-SWA
    kernel — same hidden states as the blocked-jnp path."""
    from repro.models import ModelConfig, init_params
    from repro.models.transformer import forward
    import dataclasses
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=97, window=128,
                      param_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 256), 0, 97)}
    x1, _, _ = forward(cfg, params, toks)
    x2, _, _ = forward(dataclasses.replace(cfg, use_kernels=True), params, toks)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=1e-4, atol=1e-4)


def test_fedprox_local_train():
    """FedProx's proximal term shrinks local drift from the global model."""
    from repro.sim.learner import local_train, mlp_init
    key = jax.random.PRNGKey(0)
    params = mlp_init(key, 16, 5)
    xs = jax.random.normal(key, (8, 4, 16))
    ys = jax.random.randint(key, (8, 4), 0, 5)
    d0, _, _ = local_train(params, xs, ys, 0.1, 0.0)
    dp, _, _ = local_train(params, xs, ys, 0.1, 1.0)
    n0 = sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(d0))
    np_ = sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(dp))
    assert np_ < n0  # proximal term bounds the delta
