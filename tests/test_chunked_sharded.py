"""Properties of the chunked (K rounds per dispatch) and sweep-axis-sharded
round pipeline:

- ``rounds_per_dispatch = K`` is bit-identical to K=1 — full summary and
  per-round records — across selectors, aggregators, staleness thresholds
  and accuracy-target early stop (chunks break at eval boundaries, so the
  round semantics never change);
- placing the sweep axis on a 1-D device mesh (``shard_map`` over "s") is
  bit-identical per cell to the unsharded run, including shard-awkward
  shapes: S not divisible by the device count, S=1 on a multi-device mesh,
  and early-stop shrinking that repacks live cells across shard boundaries;
- the sharded + chunked hot loop stays clean under
  ``jax.transfer_guard("disallow")``;
- ``ShardedSlotAccounts`` keeps per-shard slot discipline (LIFO reuse,
  uniform growth, no double-free).

The mesh spans all local devices: on the default CI leg that is one device
(the sharded code path with a trivial mesh); the multi-device CI leg forces
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the same tests
exercise real 4-way sharding, cross-shard repacking included.
"""
import dataclasses

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.stale_cache import ShardedSlotAccounts
from repro.sim import SimConfig, Simulator
from repro.sim.pipeline import RoundPipeline
from repro.sweeps import Cell, SweepRunner, SweepSpec, sweep_mesh
from repro.sweeps.runner import summaries_equal
from repro.sweeps.sharding import Placement, local_capacity

BASE = dict(n_learners=30, rounds=8, eval_every=4, n_target=4,
            mapping="label_uniform")


def _records_equal(a, b) -> bool:
    if len(a.records) != len(b.records):
        return False
    for ra, rb in zip(a.records, b.records):
        ka = (ra.round_idx, ra.sim_time, ra.n_selected, ra.n_fresh,
              ra.n_stale, ra.resource_used, ra.resource_wasted,
              ra.unique_participants)
        kb = (rb.round_idx, rb.sim_time, rb.n_selected, rb.n_fresh,
              rb.n_stale, rb.resource_used, rb.resource_wasted,
              rb.unique_participants)
        accs = (ra.accuracy == rb.accuracy
                or (ra.accuracy != ra.accuracy and rb.accuracy != rb.accuracy))
        if ka != kb or not accs:
            return False
    return True


def _chunk_parity(cfg: SimConfig, k: int):
    ck = dataclasses.replace(cfg, rounds_per_dispatch=k)
    a = Simulator(cfg).run()
    b = Simulator(ck).run()
    assert summaries_equal(dict(a.summary()), dict(b.summary())), \
        (cfg, a.summary(), b.summary())
    assert _records_equal(a, b)


# ---------------------------------------------------------------------------
# Multi-round chunking: K rounds per dispatch == K=1, bitwise
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(selector=st.sampled_from(["random", "priority", "safa", "oort"]),
       saa=st.booleans(),
       k=st.sampled_from([3, 8]),
       seed=st.integers(0, 2))
def test_chunked_rounds_match_k1(selector, saa, k, seed):
    _chunk_parity(SimConfig(selector=selector, saa=saa, seed=seed,
                            deadline=60.0, **BASE), k)


def test_chunked_yogi_apt_threshold():
    _chunk_parity(SimConfig(selector="priority", saa=True, apt=True,
                            aggregator="yogi", seed=1, **BASE), 8)
    _chunk_parity(SimConfig(selector="safa", saa=True,
                            staleness_threshold=1, seed=0, **BASE), 8)


def test_chunked_early_stop_matches():
    """Chunks break at eval boundaries, so accuracy-target early stop fires
    at the identical round — and stops mid-chunk-schedule are impossible."""
    _chunk_parity(SimConfig(selector="priority", saa=True, seed=0,
                            target_accuracy=0.15, **BASE), 8)


def test_chunked_fewer_dispatches():
    cfg = SimConfig(selector="priority", saa=True, seed=0,
                    rounds_per_dispatch=4, **BASE)
    pipe = RoundPipeline([Simulator(cfg)])
    pipe.run()
    st_ = pipe.stats.as_dict()
    assert st_["rounds"] > 0
    # 8 rounds with eval_every=4 -> chunks of 4: at most ceil(rounds/4)+1
    assert st_["dispatches"]["round"] <= -(-st_["rounds"] // 4) + 1
    assert st_["rounds_per_dispatch"] == 4


def test_oort_forces_single_round_chunks():
    """Oort's stat-utility feedback is device data consumed by the next
    round's selection, so prescheduling caps at one round."""
    cfg = SimConfig(selector="oort", saa=True, seed=0,
                    rounds_per_dispatch=8, **BASE)
    pipe = RoundPipeline([Simulator(cfg)])
    pipe.run()
    st_ = pipe.stats.as_dict()
    assert st_["rounds_per_dispatch"] == 1
    assert st_["dispatches"]["round"] == st_["rounds"]


# ---------------------------------------------------------------------------
# Sweep-axis sharding: mesh == unsharded, bitwise
# ---------------------------------------------------------------------------


def _grid(n_cells: int, **base) -> list:
    axes = {
        4: {"selector": ["random", "priority"], "saa": [False, True]},
        5: {"selector": ["random", "priority", "safa", "oort"],
            "saa": [True]},
        16: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True], "hardware": ["HS1", "HS3"]},
        64: {"selector": ["random", "oort", "priority", "safa"],
             "saa": [False, True], "hardware": ["HS1", "HS2", "HS3", "HS4"]},
    }[n_cells]
    seeds = (0, 1) if n_cells == 64 else (0,)
    cells = SweepSpec(axes=axes, base={**BASE, **base}, seeds=seeds).expand()
    return cells[:n_cells]


def _sharded_parity(cells, **runner_kw):
    ref = SweepRunner(cells).run()
    got = SweepRunner(cells, shard=True, **runner_kw).run()
    for a, b in zip(ref, got):
        assert summaries_equal(dict(a.summary), dict(b.summary)), \
            (a.cell.name, a.summary, b.summary)
        assert _records_equal(a.acct, b.acct), a.cell.name
    return got


def test_sharded_s64_matches_unsharded():
    """The acceptance grid: a 64-cell sweep on the full local mesh is
    bit-identical per cell to the unsharded run (4-way sharded on the
    multi-device CI leg)."""
    cells = _grid(64, n_learners=20, rounds=4, eval_every=2)
    _sharded_parity(cells)


def test_sharded_indivisible_s():
    """S=5 on the local mesh: shard loads differ (e.g. 2/1/1/1 on four
    devices) and the padded buckets stay uniform across shards."""
    _sharded_parity(_grid(5))


def test_single_cell_on_mesh():
    """S=1 on a (possibly) multi-device mesh: every other shard runs pure
    padding rows — results identical to the serial engine."""
    cfg = SimConfig(selector="priority", saa=True, seed=2, **BASE)
    a = Simulator(cfg).run()
    pipe = RoundPipeline([Simulator(cfg)], mesh=sweep_mesh())
    b = pipe.run()[0]
    assert summaries_equal(dict(a.summary()), dict(b.summary()))
    assert _records_equal(a, b)


def test_sharded_chunked_matches():
    """Sharding composes with multi-round chunking: shard_map over the mesh
    with a K-round scan inside, still bitwise the K=1 unsharded run."""
    base = dict(n_learners=30, rounds=12, eval_every=3, n_target=4,
                mapping="label_uniform")
    cells = _grid(4)
    cells = [dataclasses.replace(c, config=dataclasses.replace(
        c.config, **base, rounds_per_dispatch=4)) for c in cells]
    ref_cells = [dataclasses.replace(c, config=dataclasses.replace(
        c.config, rounds_per_dispatch=1)) for c in cells]
    ref = SweepRunner(ref_cells).run()
    got = SweepRunner(cells, shard=True).run()
    for a, b in zip(ref, got):
        assert summaries_equal(dict(a.summary), dict(b.summary)), \
            (a.cell.name, a.summary, b.summary)


def test_sharded_early_stop_repacks_across_shards():
    """Early-stopped cells leave the batch; once enough stop, the bucketed
    per-shard capacity drops and live cells compact across shard
    boundaries.  The repacked run stays bit-identical, and on a multi-device
    mesh the repack actually fires."""
    base = dict(n_learners=30, rounds=12, eval_every=3, n_target=4,
                mapping="label_uniform", target_accuracy=0.12)
    axes = {"selector": ["random", "priority", "safa"], "saa": [False, True]}
    cells = SweepSpec(axes=axes, base=base, seeds=(0, 1)).expand()

    ref = SweepRunner(cells).run()
    runner = SweepRunner(cells, shard=True)
    got = runner.run()
    for a, b in zip(ref, got):
        assert summaries_equal(dict(a.summary), dict(b.summary)), \
            (a.cell.name, a.summary, b.summary)
    stopped = sum(1 for r in got if r.summary["stopped_early"])
    assert stopped >= len(cells) // 2          # the scenario must shrink
    if len(jax.devices()) > 1:
        assert runner.last_stats["dispatches"]["repack"] >= 1


def test_sharded_kernel_cells():
    """The sweep-axis Pallas kernel inside shard_map: its grid covers the
    local S and per-cell results stay bitwise the unsharded kernel's."""
    _sharded_parity(_grid(4, use_agg_kernel=True, saa=True))


def test_sharded_transfer_guard_clean():
    """The sharded chunked hot loop performs no implicit transfers: index
    uploads are explicit (sharded) device_puts, eviction fetches explicit
    device_gets.  A directly-built pipeline batch must be selector-uniform
    (``selector_key`` is part of ``pipeline_key``; the sweep runner's
    ``compat_key`` grouping guarantees this for sweeps), so the 4 cells
    vary saa x hardware on one selector."""
    axes = {"saa": [False, True], "hardware": ["HS1", "HS3"]}
    cells = SweepSpec(axes=axes,
                      base={**BASE, "selector": "priority",
                            "rounds_per_dispatch": 4},
                      seeds=(0,)).expand()
    cfgs = [c.config for c in cells]
    mesh = sweep_mesh()
    RoundPipeline([Simulator(c) for c in cfgs], mesh=mesh).run()  # warm
    pipe = RoundPipeline([Simulator(c) for c in cfgs], mesh=mesh)
    accts = pipe.run(transfer_guard=True)
    st_ = pipe.stats.as_dict()
    assert st_["dispatches"]["round"] > 0
    assert all(a.summary()["rounds"] > 0 for a in accts)


# ---------------------------------------------------------------------------
# Host-side unit tests: placement + per-shard slot accounting
# ---------------------------------------------------------------------------


def test_placement_balanced_and_bucketed():
    pl = Placement.build(range(10), 4)
    assert [len(s) for s in pl.shards] == [3, 3, 2, 2]
    assert pl.s_loc == local_capacity(10, 4) == 4        # bucket_pow2(3)
    rows = {pl.flat_row(i) for i in range(10)}
    assert len(rows) == 10
    scr = {pl.scratch_flat(j) for j in range(4)}
    assert not rows & scr                                # scratch never a cell
    # shrink in whole-shard bucket steps
    assert Placement.build(range(8), 4).s_loc == 2
    assert Placement.build(range(3), 4).s_loc == 1
    assert Placement.build([7], 4).shards[0] == (7,)


def test_sharded_slot_accounts_discipline():
    acc = ShardedSlotAccounts(2, capacity=2)
    s0, grew = acc.alloc(0, 2)
    assert s0 == [0, 1] and not grew
    # shard 1's slot space is independent of shard 0's
    s1, _ = acc.alloc(1, 1)
    assert s1 == [0]
    assert acc.shard_len(0) == 2 and acc.shard_len(1) == 1
    # growth is uniform: shard 0 is full, so one more alloc doubles both
    s2, grew = acc.alloc(0, 1)
    assert grew and acc.capacity == 4 and s2 == [2]
    assert acc.trash_slot == 4
    # freed slots are reused LIFO within their shard
    acc.free(0, [1])
    s3, _ = acc.alloc(0, 1)
    assert s3 == [1]
    with pytest.raises(KeyError):
        acc.free(0, [0, 0])
    assert acc.flat_index(1, 3) == 1 * (acc.capacity + 1) + 3


def test_sharded_slot_accounts_growth_preserves_ids():
    acc = ShardedSlotAccounts(3, capacity=1)
    first = [acc.alloc(j, 1)[0][0] for j in range(3)]
    assert first == [0, 0, 0]
    acc.alloc(0, 2)         # forces growth (and only then new ids)
    assert acc.capacity == 4
    assert acc.occupied(0) == [0, 1, 2]
    assert acc.occupied(1) == [0]
