"""Selector-zoo plugin interface: ported-strategy bit-parity, the new
strategies' closed-form oracles, selector_key program-variant folding, and
the sweep/CLI surfaces.

The ported selectors (random/oort/priority/safa) moved from
``repro.core.selection`` onto the strategy table verbatim; the frozen
pre-refactor implementations embedded here are driven through identical
RNG streams and feedback sequences to pin that the move changed no
selection decision (RNG-stream bit-parity — the host half of the zoo's
"bit-identical to HEAD" gate; the substrate half is the batched-vs-serial
parity asserts below and in tests/test_sweep_parity.py).
"""
import copy
import math
import pickle

import numpy as np
import pytest

from repro.selection import (SELECTOR_TABLE, ContributionSelector,
                             FlipsSelector, LearnerView, OortSelector,
                             PrioritySelector, RandomSelector, SafaSelector,
                             Selector, SelectorSpec, UcbSelector,
                             build_selector, normalize_selector_params,
                             register_selector, selector_key)
from repro.selection.flips import kmeans_labels, label_histograms
from repro.sim.engine import SimConfig, Simulator
from repro.sim.pipeline import pipeline_key
from repro.sweeps import SweepSpec, assert_parity, run_batched, run_serial
from repro.sweeps.grid import axis_updates
from repro.sweeps.runner import compat_key

# ---------------------------------------------------------------------------
# Frozen pre-refactor implementations (verbatim selection logic at the time
# of the move to repro.selection; do NOT "fix" these — they are the oracle)
# ---------------------------------------------------------------------------


class _LegacyRandom:
    def select_ids(self, round_idx, ids, n_target, rng):
        if len(ids) <= n_target:
            return list(ids)
        return list(rng.choice(ids, size=n_target, replace=False))


class _LegacyPriority:
    def __init__(self, holdoff=5):
        self.holdoff = holdoff
        self._held_until = {}

    def select(self, round_idx, checked_in, n_target, rng):
        eligible = [v for v in checked_in
                    if self._held_until.get(v.learner_id, -1) < round_idx]
        if not eligible:
            eligible = list(checked_in)
        jitter = rng.random(len(eligible))
        order = sorted(range(len(eligible)),
                       key=lambda i: (eligible[i].availability_prob, jitter[i]))
        chosen = [eligible[i].learner_id for i in order[:n_target]]
        for lid in chosen:
            self._held_until[lid] = round_idx + self.holdoff
        return chosen


class _LegacyOort:
    def __init__(self, alpha=2.0, pacer_delta=10.0, pacer_window=20,
                 eps0=0.9, eps_min=0.2, eps_decay=0.98):
        self.alpha = alpha
        self.pacer_delta = pacer_delta
        self.pacer_window = pacer_window
        self.eps = eps0
        self.eps_min = eps_min
        self.eps_decay = eps_decay
        self.t_pref = None
        self._util_history = []
        self._stat_util = {}
        self._duration = {}

    def _utility(self, v):
        stat = self._stat_util.get(v.learner_id, v.last_stat_util)
        dur = self._duration.get(v.learner_id, v.est_duration) or 1.0
        if self.t_pref is not None and dur > self.t_pref:
            stat *= (self.t_pref / dur) ** self.alpha
        return stat

    def select(self, round_idx, checked_in, n_target, rng):
        if self.t_pref is None:
            durs = [v.est_duration for v in checked_in if v.est_duration > 0]
            self.t_pref = float(np.percentile(durs, 50)) if durs else 100.0
        explored = [v for v in checked_in if v.learner_id in self._stat_util]
        unexplored = [v for v in checked_in
                      if v.learner_id not in self._stat_util]
        n_explore = int(round(self.eps * n_target))
        n_exploit = n_target - n_explore
        exploit_order = sorted(explored, key=self._utility, reverse=True)
        chosen = [v.learner_id for v in exploit_order[:n_exploit]]
        unexplored.sort(key=lambda v: v.est_duration or 1e9)
        chosen += [v.learner_id for v in unexplored[:n_target - len(chosen)]]
        if len(chosen) < n_target:
            rest = [v.learner_id for v in exploit_order[n_exploit:]
                    if v.learner_id not in chosen]
            chosen += rest[:n_target - len(chosen)]
        self.eps = max(self.eps_min, self.eps * self.eps_decay)
        window_util = sum(self._utility(v) for v in checked_in
                          if v.learner_id in chosen)
        self._util_history.append(window_util)
        h = self._util_history
        if len(h) >= 2 * self.pacer_window:
            recent = sum(h[-self.pacer_window:])
            prev = sum(h[-2 * self.pacer_window:-self.pacer_window])
            if recent <= prev:
                self.t_pref += self.pacer_delta
                self._util_history = h[-self.pacer_window:]
        return chosen[:n_target]

    def update_feedback(self, learner_id, *, stat_util=None, duration=None,
                        round_idx=None):
        if stat_util is not None:
            self._stat_util[learner_id] = stat_util
        if duration is not None:
            self._duration[learner_id] = duration


def _views(rng, n):
    return [LearnerView(learner_id=i,
                        availability_prob=float(rng.random()),
                        est_duration=float(10 + 90 * rng.random()))
            for i in range(n)]


def test_random_ported_bit_identical():
    legacy, new = _LegacyRandom(), RandomSelector()
    for seed in range(5):
        r1 = np.random.default_rng(seed)
        r2 = np.random.default_rng(seed)
        ids = list(range(30))
        for r in range(10):
            assert (legacy.select_ids(r, ids, 7, r1)
                    == new.select_ids(r, ids, 7, r2))


def test_safa_ported_bit_identical():
    new = SafaSelector()
    ids = [3, 5, 9, 12]
    assert new.select_ids(0, ids, 2, np.random.default_rng(0)) == ids


def test_priority_ported_bit_identical():
    legacy, new = _LegacyPriority(), PrioritySelector()
    setup = np.random.default_rng(7)
    views = _views(setup, 25)
    r1 = np.random.default_rng(1)
    r2 = np.random.default_rng(1)
    for r in range(20):
        assert legacy.select(r, views, 6, r1) == new.select(r, views, 6, r2)
    assert legacy._held_until == new._held_until


def test_oort_ported_bit_identical():
    legacy, new = _LegacyOort(), OortSelector()
    setup = np.random.default_rng(11)
    views = _views(setup, 30)
    fb = np.random.default_rng(13)
    r1 = np.random.default_rng(2)
    r2 = np.random.default_rng(2)
    for r in range(50):
        a = legacy.select(r, views, 8, r1)
        b = new.select(r, views, 8, r2)
        assert a == b
        # identical post-round feedback (same utilities, same durations)
        for lid in a:
            u, d = float(fb.random()), float(10 + 50 * fb.random())
            legacy.update_feedback(lid, stat_util=u, duration=d, round_idx=r)
            new.update_feedback(lid, stat_util=u, duration=d, round_idx=r)
    assert legacy.eps == new.eps
    assert legacy.t_pref == new.t_pref
    assert legacy._util_history == new._util_history


# ---------------------------------------------------------------------------
# New strategies: closed-form oracles
# ---------------------------------------------------------------------------


def test_flips_quotas_oracle():
    f = FlipsSelector(np.zeros(1))
    # even split
    assert f.quotas([10, 10, 10, 10], 8) == [2, 2, 2, 2]
    # remainder to the largest clusters first, cluster id breaks ties
    assert f.quotas([5, 3, 2], 7) == [3, 2, 2]
    assert f.quotas([3, 5, 2], 7) == [2, 3, 2]
    assert f.quotas([4, 4, 2], 7) == [3, 2, 2]
    # overflow past a cluster's population is redistributed
    assert f.quotas([1, 9], 6) == [1, 5]
    assert f.quotas([0, 4, 4], 6) == [0, 3, 3]
    # cannot exceed the total population
    assert f.quotas([1, 1], 6) == [1, 1]
    rng = np.random.default_rng(0)
    for _ in range(50):
        sizes = list(rng.integers(0, 8, size=int(rng.integers(1, 6))))
        n_t = int(rng.integers(1, 12))
        q = f.quotas(sizes, n_t)
        assert all(0 <= qc <= s for qc, s in zip(q, sizes))
        assert sum(q) == min(n_t, sum(sizes))


def test_flips_cluster_balanced_selection():
    cluster_of = np.array([0, 0, 0, 0, 1, 1, 1, 1, 2, 2])
    f = FlipsSelector(cluster_of)
    chosen = f.select_ids(0, list(range(10)), 6, np.random.default_rng(0))
    counts = np.bincount(cluster_of[chosen], minlength=3)
    assert list(counts) == [2, 2, 2]
    assert len(set(chosen)) == 6


def test_flips_kmeans_deterministic():
    rng = np.random.default_rng(3)
    hists = rng.random((40, 10))
    hists /= hists.sum(1, keepdims=True)
    a = kmeans_labels(hists, 4, seed=17)
    b = kmeans_labels(hists, 4, seed=17)
    assert (a == b).all()
    assert a.shape == (40,) and set(a) <= set(range(4))


def test_flips_label_histograms_from_shards():
    class Data:
        y_train = np.array([0, 0, 1, 1, 2, 2])
        n_classes = 3
        shards = [np.array([0, 1, 2]), np.array([4, 5])]
    h = label_histograms(Data())
    assert h.shape == (2, 3)
    np.testing.assert_allclose(h[0], [2 / 3, 1 / 3, 0])
    np.testing.assert_allclose(h[1], [0, 0, 1])


def test_ucb_score_formula_and_ordering():
    sel = UcbSelector(c=1.5)
    for lid, (s, n) in {0: (3.0, 3), 1: (1.0, 1), 2: (4.0, 2)}.items():
        sel._sum[lid], sel._n[lid] = s, n
    sel.rounds = 10
    means = {0: 1.0, 1: 1.0, 2: 2.0}
    for lid in means:
        expect = (means[lid] / 2.0
                  + 1.5 * math.sqrt(2 * math.log(10) / sel._n[lid]))
        assert sel.score(lid) == pytest.approx(expect)
    # unexplored arms take strict priority over any explored score
    chosen = sel.select_ids(10, [0, 1, 2, 7, 8], 2, np.random.default_rng(0))
    assert set(chosen) == {7, 8}
    # with no unexplored arms left, picks descend by UCB score (the
    # under-pulled arm 1 wins on its exploration bonus)
    chosen = sel.select_ids(11, [0, 1, 2], 2, np.random.default_rng(0))
    scores = sel._scores()           # rounds already advanced by the call
    assert chosen == sorted([0, 1, 2], key=lambda a: -scores[a])[:2]
    assert chosen[0] == 1


def test_contribution_decay_and_fairness_floor():
    sel = ContributionSelector(decay=0.5, fairness_frac=0.2)
    sel.update_feedback(3, stat_util=4.0)
    sel.update_feedback(3, stat_util=1.0)
    assert sel._score[3] == pytest.approx(0.5 * 4.0 + 1.0)
    # ceil(0.2 * 5) = 1 slot reserved for the longest-starved learner even
    # when its contribution score is the lowest on the board
    sel = ContributionSelector(decay=0.9, fairness_frac=0.2)
    ids = list(range(10))
    for lid in range(9):
        sel._score[lid] = 10.0 + lid
        sel._last_sel[lid] = 5
    sel._score[9] = 0.0              # never selected, worst score
    chosen = sel.select_ids(6, ids, 5, np.random.default_rng(0))
    assert 9 in chosen
    top = sorted(range(9), key=lambda k: -sel._score[k])[:4]
    assert set(chosen) - {9} == set(top)
    assert sel._last_sel[9] == 6


def test_zoo_selectors_pickle_and_deepcopy():
    # capture_state deep-copies the selector for crash-safe resume; every
    # zoo strategy must round-trip plain pickle too (checkpoint files)
    cfg = SimConfig(n_learners=20, rounds=2)
    for name in SELECTOR_TABLE:
        sel = build_selector(
            SimConfig(n_learners=20, rounds=2, selector=name),
            substrate=Simulator(cfg).substrate)
        sel2 = pickle.loads(pickle.dumps(sel))
        assert type(sel2) is type(sel)
        copy.deepcopy(sel)


# ---------------------------------------------------------------------------
# selector_key: per-selector program variants, selector-uniform batches
# ---------------------------------------------------------------------------


def test_selector_key_structure():
    assert selector_key(SimConfig(selector="random")) == \
        ("random", (), False, False)
    assert selector_key(SimConfig(selector="oort"))[2] is True
    assert selector_key(SimConfig(selector="safa"))[3] is True
    k = selector_key(SimConfig(selector="ucb",
                               selector_params={"c": 2.0}))
    assert k == ("ucb", (("c", 2.0),), True, False)


def test_selector_key_folds_into_pipeline_and_compat_key():
    base = SimConfig(rounds=10)
    for name in SELECTOR_TABLE:
        cfg = SimConfig(rounds=10, selector=name)
        assert selector_key(cfg) in pipeline_key(cfg)
        if name != "random":
            assert pipeline_key(cfg) != pipeline_key(base)
            assert compat_key(cfg) != compat_key(base)
    # knob values split program variants too
    a = SimConfig(rounds=10, selector="flips")
    b = SimConfig(rounds=10, selector="flips",
                  selector_params={"n_clusters": 2})
    assert compat_key(a) != compat_key(b)


def test_unknown_selector_and_knob_rejected():
    with pytest.raises(ValueError, match="unknown selector"):
        SimConfig(selector="nope")
    with pytest.raises(ValueError, match="unknown knob"):
        SimConfig(selector="random", selector_params={"k": 1})
    with pytest.raises(ValueError, match="unknown knob"):
        normalize_selector_params("ucb", {"c": 1.0, "zz": 2})
    with pytest.raises(ValueError, match="selector"):
        axis_updates("selector", "nope")
    assert axis_updates("selector", "flips") == {"selector": "flips"}


def test_register_selector_name_collision():
    spec = SELECTOR_TABLE["random"]
    register_selector(spec)            # idempotent re-registration is fine
    clash = SelectorSpec(name="random", factory=lambda p, c: RandomSelector())
    with pytest.raises(ValueError, match="already registered"):
        register_selector(clash)


# ---------------------------------------------------------------------------
# Substrate parity: every zoo strategy, batched vs serial vs chunked
# ---------------------------------------------------------------------------

_ZOO_BASE = dict(n_learners=30, rounds=4, eval_every=2, n_target=4,
                 mapping="label_uniform")


def test_zoo_batched_vs_serial_parity():
    spec = SweepSpec(axes={"selector": list(SELECTOR_TABLE)},
                     base=dict(_ZOO_BASE), seeds=(0,))
    cells = spec.expand()
    results, _ = run_batched(cells)
    serial, _ = run_serial(cells)
    assert_parity(results, serial)


def test_feedback_free_selectors_chunk_bit_identically():
    import dataclasses
    free = [n for n, s in SELECTOR_TABLE.items()
            if not s.needs_feedback and not s.select_all]
    assert {"random", "priority", "flips"} <= set(free)
    spec = SweepSpec(axes={"selector": free}, base=dict(_ZOO_BASE), seeds=(0,))
    cells = spec.expand()
    results, _ = run_batched(cells)
    chunked = [dataclasses.replace(c, config=dataclasses.replace(
        c.config, rounds_per_dispatch=2)) for c in cells]
    results_k, _ = run_batched(chunked)
    for a, b in zip(results, results_k):
        assert dict(a.summary) == dict(b.summary), a.cell.name


def test_feedback_selector_forces_k1():
    from repro.sim.pipeline import RoundPipeline
    for name, want_k in (("ucb", 1), ("flips", 2)):
        cfg = SimConfig(selector=name, rounds_per_dispatch=2, **_ZOO_BASE)
        sim = Simulator(cfg)
        pipe = RoundPipeline([sim])
        assert pipe.k_rounds == want_k
        assert pipe._fetch_l2s == (name == "ucb")


def test_selector_params_reach_the_policy():
    cfg = SimConfig(selector="priority", selector_params={"holdoff": 2},
                    **_ZOO_BASE)
    assert cfg.selector_params == (("holdoff", 2),)
    sim = Simulator(cfg)
    assert sim.selector.holdoff == 2
    cfg2 = SimConfig(selector="flips", selector_params={"n_clusters": 2},
                     **_ZOO_BASE)
    sim2 = Simulator(cfg2)
    assert len(set(sim2.selector.cluster_of.tolist())) <= 2


def test_list_selectors_cli(capsys):
    from repro.sweeps.__main__ import main
    main(["--list-selectors", "--list-aggregators"])
    out = capsys.readouterr().out
    for name in SELECTOR_TABLE:
        assert name in out
    assert "trimmed_mean" in out
