"""Accuracy-target early stop: resource accrual freezes at the stop round,
summaries match a serial run truncated at the same round, and mixed
finished/live cells in one sweep batch stay parity-correct as the lockstep
buckets shrink."""
import dataclasses

import numpy as np

from repro.sim import SimConfig, Simulator
from repro.sweeps import Cell, SweepRunner
from repro.sweeps.runner import summaries_equal

BASE = dict(n_learners=40, rounds=30, eval_every=3, n_target=5,
            mapping="label_uniform", saa=True)

# this config crosses ~0.5 accuracy around round 20 of 30, so the target
# stops several eval windows before the round budget
TARGET = 0.45


def _cells(*cfgs):
    return [Cell(name=f"cell{i}", coords=(("seed", c.seed),), config=c)
            for i, c in enumerate(cfgs)]


def test_engine_stops_at_first_target_eval():
    cfg = SimConfig(seed=0, target_accuracy=TARGET, **BASE)
    acct = Simulator(cfg).run()
    s = acct.summary()
    assert s["stopped_early"], s
    assert s["rounds"] < BASE["rounds"]
    last = acct.records[-1]
    assert last.accuracy == last.accuracy and last.accuracy >= TARGET
    # the stop round is an eval round — earlier rounds never trigger
    for rec in acct.records[:-1]:
        assert not (rec.accuracy == rec.accuracy and rec.accuracy >= TARGET
                    and rec is not acct.records[-1])


def test_early_stop_prefix_matches_untargeted_run():
    """A targeted run is the untargeted run truncated at the stop round:
    identical per-round records up to and including the stop round, and no
    resource accrual afterwards."""
    cfg = SimConfig(seed=0, target_accuracy=TARGET, **BASE)
    full = Simulator(dataclasses.replace(cfg, target_accuracy=None)).run()
    part = Simulator(cfg).run()
    n = len(part.records)
    assert n < len(full.records)
    for rp, rf in zip(part.records, full.records[:n]):
        assert (rp.sim_time, rp.n_fresh, rp.n_stale, rp.resource_used,
                rp.resource_wasted) == \
               (rf.sim_time, rf.n_fresh, rf.n_stale, rf.resource_used,
                rf.resource_wasted)
        assert (rp.accuracy == rf.accuracy
                or (rp.accuracy != rp.accuracy and rf.accuracy != rf.accuracy))
    # resource_used frozen at the stop round (in-flight work may still be
    # marked wasted at finalize, but nothing new is charged)
    assert part.resource_used == full.records[n - 1].resource_used


def test_early_stop_fused_flat_parity():
    cfg = SimConfig(seed=1, target_accuracy=TARGET, **BASE)
    fused = Simulator(cfg).run().summary()
    flat = Simulator(dataclasses.replace(cfg, fused_rounds=False)).run().summary()
    assert summaries_equal(dict(fused), dict(flat)), (fused, flat)


def test_mixed_finished_live_batch_matches_serial():
    """One batch mixing cells that stop at different rounds (and one that
    never stops): every cell's summary is bit-identical to its serial run,
    so shrinking the lockstep batch never perturbs the surviving cells."""
    cfgs = [
        SimConfig(seed=0, target_accuracy=TARGET, **BASE),
        SimConfig(seed=0, target_accuracy=None, **BASE),          # never stops
        SimConfig(seed=1, target_accuracy=TARGET, selector="priority", **BASE),
        SimConfig(seed=0, target_accuracy=1.1, **BASE),           # unreachable
    ]
    batched = SweepRunner(_cells(*cfgs)).run()
    stopped = [r.summary["stopped_early"] for r in batched]
    assert any(stopped) and not all(stopped), stopped
    for res, cfg in zip(batched, cfgs):
        serial = Simulator(cfg).run().summary()
        assert summaries_equal(dict(res.summary), dict(serial)), \
            (res.summary, serial)


def test_finished_cells_stop_accruing_resource():
    """After a cell stops, later rounds of the surviving batch add nothing
    to its accounting."""
    cfgs = [SimConfig(seed=0, target_accuracy=TARGET, **BASE),
            SimConfig(seed=0, target_accuracy=None, **BASE)]
    batched = SweepRunner(_cells(*cfgs)).run()
    es, full = batched[0], batched[1]
    assert es.summary["stopped_early"] and not full.summary["stopped_early"]
    assert es.summary["rounds"] < full.summary["rounds"]
    assert es.summary["resource_used"] < full.summary["resource_used"]
    # and its records end at the stop round
    assert len(es.acct.records) == es.summary["rounds"]
