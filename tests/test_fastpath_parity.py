"""Parity: the flat/banked fast path must reproduce the scalar substrate.

Three layers, matching the refactor:
  - TraceBank / ForecasterBank vs the scalar LearnerTrace / AvailabilityForecaster
    (bit-for-bit on random schedules);
  - stale_synchronous_aggregate_flat vs the pytree path and the fused kernel;
  - the full engine: fast_path=True vs the seed-equivalent legacy path gives
    the same schedule, accounting, and (to float tolerance) accuracy.
"""
import jax
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.availability import AvailabilityForecaster, ForecasterBank
from repro.sim import SimConfig, Simulator
from repro.sim.traces import TraceBank, make_traces

# ---------------------------------------------------------------------------
# TraceBank
# ---------------------------------------------------------------------------


def test_trace_bank_matches_scalar_traces():
    rng = np.random.default_rng(7)
    traces = make_traces(25, rng)
    bank = TraceBank(traces)
    lids = np.arange(25)
    # random times, including beyond the 14-day horizon
    for t in rng.uniform(0.0, 16 * 24 * 3600.0, size=120):
        t = float(t)
        np.testing.assert_array_equal(
            bank.available_all(t),
            [tr.available(t) for tr in traces])
        np.testing.assert_array_equal(
            bank.next_unavailable_after_batch(lids, t),
            [tr.next_unavailable_after(t) for tr in traces])
        t1 = t + float(rng.uniform(1.0, 3600.0))
        np.testing.assert_array_equal(
            bank.available_through_batch(lids, t, t1),
            [tr.available_through(t, t1) for tr in traces])


def test_trace_bank_view_is_scalar_compatible():
    rng = np.random.default_rng(3)
    traces = make_traces(5, rng)
    bank = TraceBank(traces)
    v = bank.view(2)
    for t in rng.uniform(0.0, 10 * 24 * 3600.0, size=40):
        t = float(t)
        assert v.available(t) == traces[2].available(t)
        assert v.next_unavailable_after(t) == traces[2].next_unavailable_after(t)


def test_trace_bank_static_availability():
    traces = make_traces(4, np.random.default_rng(0), dynamic=False)
    bank = TraceBank(traces)
    assert bank.available_all(1e9).all()
    assert np.isinf(bank.next_unavailable_after_batch(np.arange(4), 123.0)).all()


# ---------------------------------------------------------------------------
# ForecasterBank
# ---------------------------------------------------------------------------


def test_forecaster_bank_matches_scalar_forecasters():
    rng = np.random.default_rng(11)
    n = 12
    scalars = [AvailabilityForecaster() for _ in range(n)]
    bank = ForecasterBank(n)
    t = 0.0
    for _ in range(300):
        t += float(rng.uniform(60.0, 7200.0))
        lids = np.sort(rng.choice(n, size=rng.integers(1, n + 1), replace=False))
        avail = rng.random(len(lids)) < 0.5
        for lid, a in zip(lids, avail):
            scalars[lid].observe(t, bool(a))
        bank.observe_batch(lids, t, avail.astype(float))
    np.testing.assert_array_equal(
        bank.counts, np.stack([f.counts for f in scalars]))
    np.testing.assert_array_equal(
        bank.avail_counts, np.stack([f.avail_counts for f in scalars]))
    np.testing.assert_array_equal(
        bank.recent, [f.recent for f in scalars])
    for _ in range(25):
        t0 = float(rng.uniform(0, 14 * 24 * 3600.0))
        t1 = t0 + float(rng.uniform(0.0, 4 * 3600.0))
        np.testing.assert_array_equal(
            bank.predict_window_batch(np.arange(n), t0, t1),
            [f.predict_window(t0, t1) for f in scalars])


def test_forecaster_bank_observe_all_matches_loop():
    n = 6
    scalars = [AvailabilityForecaster() for _ in range(n)]
    bank = ForecasterBank(n)
    rng = np.random.default_rng(5)
    for step in range(100):
        t = step * 1800.0
        avail = rng.random(n) < 0.4
        for f, a in zip(scalars, avail):
            f.observe(t, bool(a))
        bank.observe_all(t, avail.astype(float))
    np.testing.assert_array_equal(bank.recent, [f.recent for f in scalars])
    np.testing.assert_array_equal(
        bank.avail_counts, np.stack([f.avail_counts for f in scalars]))


def test_forecaster_view_predicts_like_scalar():
    bank = ForecasterBank(3)
    scalar = AvailabilityForecaster()
    v = bank.view(1)
    for step in range(50):
        t = step * 3600.0
        a = step % 3 == 0
        scalar.observe(t, a)
        v.observe(t, a)
    assert v.predict_window(1e5, 1.1e5) == scalar.predict_window(1e5, 1.1e5)


# ---------------------------------------------------------------------------
# Flat aggregation
# ---------------------------------------------------------------------------


def _trees(n, seed=0, shapes=((4, 5), (9,), (3, 3))):
    rng = np.random.default_rng(seed)
    return [{f"p{i}": np.asarray(rng.standard_normal(s), np.float32)
             for i, s in enumerate(shapes)} for _ in range(n)]


@pytest.mark.parametrize("rule", ["equal", "dynsgd", "adasgd", "relay"])
def test_flat_aggregate_matches_pytree_path(rule):
    trees = _trees(7, seed=2)
    fresh = [True, True, True, False, False, False, False]
    tau = [0, 0, 0, 1, 2, 4, 4]
    stacked = np.stack([np.asarray(agg.flatten_update(t)[0]) for t in trees])
    spec = agg.make_flat_spec(trees[0])

    tree_agg, w_tree = agg.stale_synchronous_aggregate(trees, fresh, tau,
                                                       rule=rule)
    flat_agg, w_flat = agg.stale_synchronous_aggregate_flat(stacked, fresh, tau,
                                                            rule=rule)
    eager_agg, w_eager = agg.stale_synchronous_aggregate_flat(
        stacked, fresh, tau, rule=rule, compiled=False)
    np.testing.assert_allclose(np.asarray(w_flat), np.asarray(w_eager),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(flat_agg), np.asarray(eager_agg),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w_flat), np.asarray(w_tree),
                               rtol=1e-6, atol=1e-7)
    back = agg.unflatten_update(flat_agg, spec)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree_agg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_flat_aggregate_matches_fused_kernel():
    trees = _trees(5, seed=9)
    fresh = [True, True, False, False, False]
    tau = [0, 0, 1, 3, 6]
    stacked = np.stack([np.asarray(agg.flatten_update(t)[0]) for t in trees])
    a1, w1 = agg.stale_synchronous_aggregate_flat(stacked, fresh, tau,
                                                  rule="relay")
    a2, w2 = agg.stale_synchronous_aggregate_flat(stacked, fresh, tau,
                                                  rule="relay", use_kernel=True)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-4, atol=1e-5)


def test_flat_dim_and_spec_roundtrip():
    tree = _trees(1, seed=1)[0]
    spec = agg.make_flat_spec(tree)
    flat, spec2 = agg.flatten_update(tree)
    assert agg.flat_dim(spec) == flat.shape[0]
    back = agg.unflatten_update(flat, spec)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(selector="random", saa=True, setting="OC"),
    dict(selector="safa", setting="DL", saa=True, staleness_threshold=3),
    dict(selector="priority", apt=True),
])
def test_engine_fast_path_matches_legacy(kw):
    """Same seed => same schedule, accounting, and accuracy (float tolerance:
    the flat cohort program may fuse arithmetic differently than the pytree
    one, but the simulated schedule is host-side and must be exact)."""
    base = dict(n_learners=40, rounds=12, eval_every=6, seed=3)
    base.update(kw)
    fast = Simulator(SimConfig(fast_path=True, **base)).run()
    legacy = Simulator(SimConfig(fast_path=False, **base)).run()
    sf, sl = fast.summary(), legacy.summary()
    for k in ("rounds", "sim_time", "resource_used", "resource_wasted",
              "unique_participants"):
        assert sf[k] == sl[k], (k, sf[k], sl[k])
    assert np.isclose(sf["final_accuracy"], sl["final_accuracy"], atol=1e-3)
    for rf, rl in zip(fast.records, legacy.records):
        assert (rf.sim_time, rf.n_selected, rf.n_fresh, rf.n_stale) == \
               (rl.sim_time, rl.n_selected, rl.n_fresh, rl.n_stale)
