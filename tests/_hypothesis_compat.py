"""Use hypothesis when installed; otherwise a deterministic stand-in.

The property tests only need four strategies (integers, floats, sampled_from,
booleans) and the ``@settings(max_examples=..., deadline=...)`` /
``@given(**kwargs)`` decorator pair.  The fallback draws ``max_examples``
pseudo-random examples from a fixed seed, so runs are reproducible and the
suite collects and passes without the dependency.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class st:  # noqa: N801 — mirrors ``hypothesis.strategies``
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(max_examples=20, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NB: no functools.wraps — copying __wrapped__ would make pytest
            # see the original signature and demand fixtures for each param.
            def runner():
                n = getattr(runner, "_max_examples", 20)
                rng = np.random.default_rng(0x5EED)
                for _ in range(n):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
