"""Unit + property tests for SAA weight scaling (paper §4.2.4, Eq. 2)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.staleness import (SCALING_RULES, deviation_scores,
                                  fresh_average, staleness_weights)


def _mk(n, d, seed=0, n_fresh=None):
    rng = np.random.default_rng(seed)
    U = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    n_fresh = n_fresh if n_fresh is not None else max(1, n // 2)
    fresh = jnp.asarray([i < n_fresh for i in range(n)])
    tau = jnp.asarray([0] * n_fresh + list(rng.integers(1, 8, n - n_fresh)),
                      jnp.int32)
    return U, fresh, tau


@pytest.mark.parametrize("rule", list(SCALING_RULES))
def test_weights_normalized(rule):
    U, fresh, tau = _mk(7, 33)
    w = staleness_weights(U, fresh, tau, rule=rule)
    assert np.isclose(float(w.sum()), 1.0, atol=1e-5)
    assert (np.asarray(w) >= 0).all()


def test_fresh_only_is_plain_average():
    U, _, _ = _mk(5, 16)
    fresh = jnp.ones(5, bool)
    tau = jnp.zeros(5, jnp.int32)
    w = staleness_weights(U, fresh, tau, rule="relay")
    np.testing.assert_allclose(np.asarray(w), np.full(5, 0.2), rtol=1e-6)


def test_equal_rule_uniform():
    U, fresh, tau = _mk(6, 10)
    w = staleness_weights(U, fresh, tau, rule="equal")
    np.testing.assert_allclose(np.asarray(w), np.full(6, 1 / 6), rtol=1e-6)


def test_dynsgd_monotone_in_tau():
    """1/(tau+1): more stale => strictly less weight."""
    U, fresh, _ = _mk(6, 10, n_fresh=2)
    tau = jnp.asarray([0, 0, 1, 2, 4, 7], jnp.int32)
    w = np.asarray(staleness_weights(U, fresh, tau, rule="dynsgd"))
    assert w[2] > w[3] > w[4] > w[5]


def test_adasgd_decays_faster_than_dynsgd():
    U, fresh, _ = _mk(4, 10, n_fresh=2)
    tau = jnp.asarray([0, 0, 5, 5], jnp.int32)
    w_dyn = np.asarray(staleness_weights(U, fresh, tau, rule="dynsgd"))
    w_ada = np.asarray(staleness_weights(U, fresh, tau, rule="adasgd"))
    # relative to fresh weight, adasgd dampens stale harder
    assert w_ada[2] / w_ada[0] < w_dyn[2] / w_dyn[0]


def test_relay_boosts_deviant_update():
    """Paper's core claim for Eq. 2: among equally-stale updates, the one
    deviating more from the fresh mean gets MORE weight (it carries novel
    data), unlike DynSGD/AdaSGD which ignore content."""
    rng = np.random.default_rng(3)
    base = rng.standard_normal(32).astype(np.float32)
    U = jnp.asarray(np.stack([
        base, base + 0.01 * rng.standard_normal(32),  # 2 fresh, similar
        base + 0.02 * rng.standard_normal(32),        # stale, low deviation
        base + 5.0 * rng.standard_normal(32),         # stale, high deviation
    ]))
    fresh = jnp.asarray([True, True, False, False])
    tau = jnp.asarray([0, 0, 3, 3], jnp.int32)
    w = np.asarray(staleness_weights(U, fresh, tau, rule="relay", beta=0.35))
    assert w[3] > w[2]


def test_deviation_zero_for_fresh():
    U, fresh, _ = _mk(6, 12)
    lam = np.asarray(deviation_scores(U, fresh))
    assert (lam[np.asarray(fresh)] == 0).all()
    assert (lam[~np.asarray(fresh)] > 0).all()


def test_deviation_closed_form():
    """Lam_s == ||u_hat - u_s||^2 / ((n_F+1)^2 ||u_hat||^2)."""
    U, fresh, _ = _mk(5, 20, seed=9)
    lam = np.asarray(deviation_scores(U, fresh))
    uh = np.asarray(fresh_average(U, fresh))
    nf = int(np.asarray(fresh).sum())
    for s in range(5):
        if not bool(fresh[s]):
            expect = (np.sum((uh - np.asarray(U[s])) ** 2)
                      / ((nf + 1) ** 2 * np.sum(uh ** 2)))
            np.testing.assert_allclose(lam[s], expect, rtol=1e-4)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 12), d=st.integers(1, 64),
       n_fresh=st.integers(1, 11), seed=st.integers(0, 100),
       rule=st.sampled_from(list(SCALING_RULES)),
       beta=st.floats(0.0, 1.0))
def test_weights_property(n, d, n_fresh, seed, rule, beta):
    """Invariants for ANY configuration: weights form a probability vector,
    fresh updates all share the max weight."""
    n_fresh = min(n_fresh, n)
    U, fresh, tau = _mk(n, d, seed=seed, n_fresh=n_fresh)
    w = np.asarray(staleness_weights(U, fresh, tau, rule=rule, beta=beta))
    assert np.isclose(w.sum(), 1.0, atol=1e-4)
    assert (w >= -1e-7).all()
    f = np.asarray(fresh)
    if f.any() and (~f).any():
        assert w[f].min() >= w[~f].max() - 1e-5 or rule == "equal"
