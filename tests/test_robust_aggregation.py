"""Byzantine-resilient aggregation (ISSUE PR-8).

Contracts under test:

  * **static parity** — configs whose robust/attack descriptors reduce to
    ``None`` (``saa``, ``trimmed_mean`` with ``trim_k=0``, ``multi_krum``
    with ``krum_f=0``, knobless ``norm_median_clip``, ``attack="none"``)
    compile to today's program and run bit-identical to plain SAA on every
    substrate;
  * **strategy oracles** — ``krum_select`` and the trimmed/median
    coordinate-wise aggregate match independent numpy implementations, and
    the untrimmed band recovers the SAA weighted aggregate
    (robust-of-weighted composition);
  * **attack formulas** — each coordinated rewrite matches its closed
    form, no-attacker rounds pass through bit-exactly, and the attacker
    stream is decorrelated from the fault draws (shared-seed pairing);
  * **substrate parity under attack** — an attacked robust cell produces
    identical summaries on the fused, chunked, flat per-stage and legacy
    paths, with or without the trimmed-mean kernel;
  * **exact accounting** — rejection/trim counters equal the closed-form
    counts (``multi_krum`` rejects exactly ``f`` per applied round;
    ``trimmed_mean`` trims exactly ``2k``; a norm-screen defense rejects
    exactly the plan's scheduled attacker rows);
  * **breakdown** — below the breakdown point the robust aggregators hold
    near the clean baseline under ``collude_signflip`` while plain SAA
    demonstrably degrades;
  * **program structure** — the robust round program keeps the
    one-psum-per-round and transfer-guard invariants.
"""
import math
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.faults import FaultPlan, FaultSpec
from repro.faults.attacks import AttackSpec, apply_attack, attack_key
from repro.robust.aggregators import (ROBUST_AGGREGATORS, krum_select,
                                      robust_host_aggregate, robust_key,
                                      trimmed_weighted_aggregate,
                                      weighted_rows)
from repro.sim.engine import SimConfig, Simulator
from repro.sweeps.runner import summaries_equal

BASE = dict(n_learners=30, rounds=8, eval_every=4, n_target=4,
            saa=True, selector="priority")

SIGNFLIP = dict(attack="collude_signflip", attack_frac=0.25,
                attack_scale=10.0)


def _cfg(**kw):
    return SimConfig(**{**BASE, **kw})


# ---------------------------------------------------------------------------
# static keys + config migration
# ---------------------------------------------------------------------------


def test_robust_key_static_delegation():
    """Statically-inactive configs map to None == today's program."""
    assert robust_key(_cfg()) is None
    assert robust_key(_cfg(aggregator="trimmed_mean", trim_k=0)) is None
    assert robust_key(_cfg(aggregator="multi_krum", krum_f=0)) is None
    assert robust_key(_cfg(aggregator="norm_median_clip")) is None
    assert robust_key(_cfg(aggregator="trimmed_mean", trim_k=2)) == \
        ("trimmed_mean", 2)
    assert robust_key(_cfg(aggregator="coord_median")) == ("coord_median",)
    assert robust_key(_cfg(aggregator="krum", krum_f=1)) == ("krum", 1, 1)
    assert robust_key(_cfg(aggregator="multi_krum", krum_f=2)) == \
        ("multi_krum", 2, None)
    assert robust_key(_cfg(aggregator="multi_krum", krum_f=0,
                           multi_krum_m=3)) == ("multi_krum", 0, 3)
    assert robust_key(_cfg(aggregator="norm_median_clip",
                           guard_reject_mult=5.0)) == \
        ("norm_median_clip", None, 5.0)
    with pytest.raises(ValueError, match="unknown aggregator"):
        _cfg(aggregator="bogus")


def test_attack_key_static_delegation():
    assert attack_key(_cfg()) is None
    assert attack_key(_cfg(attack="alie", attack_frac=0.0)) is None
    assert attack_key(_cfg(**SIGNFLIP)) == ("collude_signflip", 10.0, 1.5)
    with pytest.raises(ValueError, match="unknown attack"):
        _cfg(attack="bogus")
    with pytest.raises(ValueError):
        AttackSpec("bogus")


def test_server_opt_migration_from_old_aggregator_field():
    """Pre-PR-8 configs used ``aggregator`` for the server optimizer; they
    must keep loading (snapshots carry SimConfig) with the old value
    rerouted to ``server_opt`` and the robust slot reset to saa."""
    old = _cfg(aggregator="yogi")
    assert old.server_opt == "yogi" and old.aggregator == "saa"
    old = _cfg(aggregator="fedavg")
    assert old.server_opt == "fedavg" and old.aggregator == "saa"
    assert _cfg().server_opt == "fedavg"
    assert set(("saa", "coord_median", "trimmed_mean", "krum", "multi_krum",
                "norm_median_clip")) == set(ROBUST_AGGREGATORS)


# ---------------------------------------------------------------------------
# strategy oracles (numpy references)
# ---------------------------------------------------------------------------


def _np_krum(u, valid, f, m):
    """Independent numpy (multi-)Krum: score by the sum of the
    max(c-f-2, 1) smallest squared distances to other valid rows."""
    n = len(u)
    c = int(valid.sum())
    d = ((u[:, None, :] - u[None, :, :]) ** 2).sum(-1)
    scores = np.full(n, np.inf)
    kk = int(np.clip(c - f - 2, 1, n))
    for i in range(n):
        if not valid[i]:
            continue
        others = sorted(d[i, j] for j in range(n) if valid[j] and j != i)
        if len(others) >= kk:
            scores[i] = sum(others[:kk])
    m_eff = int(np.clip(c - f if m is None else m, 1, n))
    order = np.argsort(scores, kind="stable")
    sel = np.zeros(n, bool)
    sel[order[:m_eff]] = True
    return sel & valid


@pytest.mark.parametrize("f,m", [(1, 1), (2, None), (1, 3), (0, None)])
def test_krum_select_matches_numpy_oracle(f, m):
    rng = np.random.default_rng(f * 10 + (0 if m is None else m))
    u = rng.normal(size=(9, 16)).astype(np.float32)
    valid = np.array([True] * 7 + [False, True])
    got = np.asarray(krum_select(jnp.asarray(u), jnp.asarray(valid),
                                 f=f, m=m))
    want = _np_krum(u.astype(np.float64), valid, f, m)
    np.testing.assert_array_equal(got, want)


def test_krum_rejects_the_planted_outliers():
    rng = np.random.default_rng(0)
    u = rng.normal(size=(8, 32)).astype(np.float32) * 0.1
    u[2] += 50.0
    u[5] -= 50.0                       # two colluding-ish outliers
    valid = np.ones(8, bool)
    sel = np.asarray(krum_select(jnp.asarray(u), jnp.asarray(valid),
                                 f=2, m=None))
    assert not sel[2] and not sel[5]
    assert sel.sum() == 6              # m = c - f keeps the honest rows


def test_trimmed_and_median_match_numpy_oracle():
    """Equal weights make y == u, so the trimmed aggregate must equal the
    per-coordinate numpy trimmed mean of the valid rows."""
    rng = np.random.default_rng(3)
    n, d = 7, 12
    u = rng.normal(size=(n, d)).astype(np.float32)
    valid = np.array([True] * 5 + [False, True])          # c = 6 (even)
    rows = u[valid].astype(np.float64)
    fresh = jnp.ones(n, bool)
    tau = jnp.zeros(n, jnp.int32)
    for trim_k, median in ((1, False), (2, False), (0, True)):
        out, n_trim = trimmed_weighted_aggregate(
            jnp.asarray(u), fresh, tau, jnp.asarray(valid),
            0.4, 0, trim_k=trim_k, median=median)
        srt = np.sort(rows, axis=0)
        k = (len(rows) - 1) // 2 if median else trim_k
        want = srt[k:len(rows) - k].mean(axis=0)
        np.testing.assert_allclose(np.asarray(out), want,
                                   rtol=1e-5, atol=1e-6)
        assert int(n_trim) == 2 * k
    # even c: coord_median averages the two middle order statistics
    out, _ = trimmed_weighted_aggregate(
        jnp.asarray(u), fresh, tau, jnp.asarray(valid),
        0.4, 0, trim_k=0, median=True)
    np.testing.assert_allclose(
        np.asarray(out), np.median(rows, axis=0), rtol=1e-5, atol=1e-6)


def test_untrimmed_band_recovers_saa_weighted_aggregate():
    """Robust-of-weighted composition: the k=0 trimmed mean of the
    rescaled rows y = c*w*u equals the SAA weighted aggregate."""
    rng = np.random.default_rng(11)
    n, d = 6, 10
    u = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    fresh = jnp.asarray([True, True, False, True, False, True])
    tau = jnp.asarray([0, 0, 3, 0, 1, 0], jnp.int32)
    valid = jnp.asarray([True] * 5 + [False])
    want, _ = agg.weights_and_aggregate_by_id(u, fresh, tau, valid, 0.4,
                                              jnp.int32(3))
    y, c = weighted_rows(u, fresh, tau, valid, 0.4, jnp.int32(3))
    got = np.where(np.asarray(valid)[:, None], np.asarray(y), 0.0) \
        .sum(axis=0) / int(c)
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-6)
    assert int(c) == 5
    assert np.all(np.asarray(y)[5] == np.inf)      # invalid row -> +inf


# ---------------------------------------------------------------------------
# attack formulas + plan determinism
# ---------------------------------------------------------------------------


def test_attack_formulas_match_closed_forms():
    rng = np.random.default_rng(5)
    n, d = 8, 16
    u = rng.normal(size=(n, d)).astype(np.float32)
    att = np.zeros(n, bool)
    att[[1, 4]] = True
    valid = np.ones(n, bool)
    valid[7] = False
    honest = u[valid & ~att].astype(np.float64)
    run = lambda kind, **kw: np.asarray(apply_attack(
        jnp.asarray(u), jnp.asarray(att), jnp.asarray(valid),
        kind=kind, scale=kw.get("scale", 10.0), z=kw.get("z", 1.5)))

    out = run("collude_signflip", scale=3.0)
    np.testing.assert_array_equal(out[1], -3.0 * u[1])
    np.testing.assert_array_equal(out[0], u[0])

    out = run("collude_same_value", scale=2.0)
    np.testing.assert_array_equal(out[1], out[4])   # maximal collusion
    np.testing.assert_allclose(np.linalg.norm(out[1]), 2.0, rtol=1e-5)

    out = run("alie", z=1.5)
    mu, sd = honest.mean(0), honest.std(0)
    np.testing.assert_allclose(out[4], mu - 1.5 * sd, rtol=1e-4, atol=1e-5)

    out = run("adaptive", scale=4.0)
    med = np.median(np.sort((honest ** 2).sum(-1))[: len(honest)]) \
        if len(honest) % 2 else \
        np.sort((honest ** 2).sum(-1))[(len(honest) - 1) // 2]
    target = 4.0 * math.sqrt(float(med))
    np.testing.assert_allclose(np.linalg.norm(out[1]), target, rtol=1e-4)
    np.testing.assert_allclose(
        out[1] / np.linalg.norm(out[1]), -u[1] / np.linalg.norm(u[1]),
        rtol=1e-4, atol=1e-5)


def test_attack_noop_mask_is_bit_exact():
    rng = np.random.default_rng(9)
    u = rng.normal(size=(2, 5, 8)).astype(np.float32)
    att = np.zeros((2, 5), bool)
    valid = np.ones((2, 5), bool)
    for kind in ("collude_signflip", "collude_same_value", "alie",
                 "adaptive"):
        out = np.asarray(apply_attack(jnp.asarray(u), jnp.asarray(att),
                                      jnp.asarray(valid), kind=kind,
                                      scale=10.0, z=1.5))
        np.testing.assert_array_equal(out, u)


def test_with_attack_keeps_fault_draws_and_is_deterministic():
    """Shared-seed pairing: arming an attack never perturbs the fault
    stream, and the attacker sets are a pure function of (seed, spec)."""
    mk = lambda: FaultPlan(30, 8, (FaultSpec("nan", prob=0.3),), seed=11)
    plain, armed = mk(), mk().with_attack(AttackSpec("alie", frac=0.2))
    np.testing.assert_array_equal(plain.corrupt, armed.corrupt)
    armed2 = mk().with_attack(AttackSpec("alie", frac=0.2))
    for r in range(8):
        ids = armed.attackers(r)
        np.testing.assert_array_equal(ids, armed2.attackers(r))
        assert len(ids) == math.ceil(0.2 * 30)
        flags = armed.attack_flags(r, np.arange(30))
        assert set(np.nonzero(flags)[0]) == set(ids.tolist())
    assert plain.attackers(0).size == 0
    assert not plain.attack_flags(0, [1, 2]).any()


# ---------------------------------------------------------------------------
# static parity: inactive configs == plain SAA, bitwise, every substrate
# ---------------------------------------------------------------------------


SUBSTRATES = {
    "fused": {},
    "chunked": {"rounds_per_dispatch": 4},
    "flat": {"fused_rounds": False},
    "legacy": {"fast_path": False, "fused_rounds": False},
    "kernel": {"use_agg_kernel": True},
}

INACTIVE = {
    "trim0": {"aggregator": "trimmed_mean", "trim_k": 0},
    "mkrum0": {"aggregator": "multi_krum", "krum_f": 0},
    "nmc_off": {"aggregator": "norm_median_clip"},
    "att_off": {"attack": "collude_signflip", "attack_frac": 0.0},
}


@pytest.mark.parametrize("sub", sorted(SUBSTRATES))
@pytest.mark.parametrize("inactive", sorted(INACTIVE))
def test_inactive_robust_config_is_bit_identical_to_saa(sub, inactive):
    extra = SUBSTRATES[sub]
    ref = Simulator(_cfg(**extra)).run().summary()
    got = Simulator(_cfg(**extra, **INACTIVE[inactive])).run().summary()
    assert summaries_equal(dict(ref), dict(got)), (sub, inactive, ref, got)
    assert got["robust_rejected"] == 0 and got["robust_trimmed"] == 0


# ---------------------------------------------------------------------------
# active robust + attack: substrate parity
# ---------------------------------------------------------------------------


ACTIVE = {
    "coord_median": {"aggregator": "coord_median"},
    "trimmed_mean": {"aggregator": "trimmed_mean", "trim_k": 1},
    "multi_krum": {"aggregator": "multi_krum", "krum_f": 2},
    "norm_median_clip": {"aggregator": "norm_median_clip",
                         "guard_reject_mult": 4.0},
}


@pytest.mark.parametrize("kind", sorted(ACTIVE))
def test_attacked_robust_cell_fused_flat_chunked_parity(kind):
    mk = lambda **extra: Simulator(
        _cfg(**ACTIVE[kind], **SIGNFLIP, **extra)).run().summary()
    fused, flat, chunked = mk(), mk(fused_rounds=False), \
        mk(rounds_per_dispatch=4)
    assert summaries_equal(dict(fused), dict(flat)), (kind, fused, flat)
    assert summaries_equal(dict(fused), dict(chunked)), kind
    assert fused["robust_rejected"] + fused["robust_trimmed"] > 0, kind
    assert math.isfinite(fused["final_accuracy"])


@pytest.mark.parametrize("kind", ["trimmed_mean", "multi_krum"])
def test_attacked_robust_cell_legacy_parity(kind):
    fused = Simulator(_cfg(**ACTIVE[kind], **SIGNFLIP)).run().summary()
    legacy = Simulator(_cfg(**ACTIVE[kind], **SIGNFLIP, fast_path=False,
                            fused_rounds=False)).run().summary()
    for k in ("rounds", "robust_rejected", "robust_trimmed",
              "unique_participants"):
        assert legacy[k] == fused[k], (kind, k)
    assert abs(legacy["final_accuracy"] - fused["final_accuracy"]) < 1e-3


def test_trimmed_kernel_routing_matches_jnp_path():
    """``use_agg_kernel`` routes the coordinate-wise statistic through the
    trimmed_agg Pallas kernel; fused==flat stays bitwise and the kernel's
    result matches the sort-based path."""
    mk = lambda **extra: Simulator(_cfg(
        aggregator="trimmed_mean", trim_k=1, **SIGNFLIP,
        **extra)).run().summary()
    kern, kern_flat, soft = mk(use_agg_kernel=True), \
        mk(use_agg_kernel=True, fused_rounds=False), mk()
    assert summaries_equal(dict(kern), dict(kern_flat))
    assert kern["robust_trimmed"] == soft["robust_trimmed"] > 0
    assert abs(kern["final_accuracy"] - soft["final_accuracy"]) < 1e-4


# ---------------------------------------------------------------------------
# exact counter accounting
# ---------------------------------------------------------------------------


def test_multi_krum_rejects_exactly_f_per_round():
    """multi_krum keeps m = clip(c - f, 1, n) of c valid rows, so each
    round rejects exactly min(f, c - 1) — reconcile the counter against
    the per-round operand sizes from the accounting records."""
    f = 2
    acct = Simulator(_cfg(aggregator="multi_krum", krum_f=f)).run()
    s = acct.summary()
    expected = sum(min(f, max(rec.n_fresh + rec.n_stale - 1, 0))
                   for rec in acct.records)
    assert s["robust_rejected"] == expected > 0
    assert s["robust_trimmed"] == 0


def test_trimmed_mean_trims_exactly_2k_per_round():
    k = 1
    acct = Simulator(_cfg(aggregator="trimmed_mean", trim_k=k)).run()
    s = acct.summary()
    expected = sum(2 * min(k, max(rec.n_fresh + rec.n_stale - 1, 0) // 2)
                   for rec in acct.records)
    assert s["robust_trimmed"] == expected > 0
    assert s["robust_rejected"] == 0


def test_counters_match_scheduled_attacker_rows_exactly():
    """ISSUE acceptance: the defense's rejection counter equals the plan's
    scheduled attacker count.  A norm-screen defense against huge-scale
    signflip rejects exactly the attacked rows — replay every round's
    operand through the host entry and reconcile row by row."""
    n, d, rounds = 16, 32, 6
    plan = FaultPlan(n, rounds, seed=4).with_attack(
        AttackSpec("collude_signflip", frac=0.25, scale=1e3))
    rng = np.random.default_rng(0)
    total = 0
    for r in range(rounds):
        u = rng.normal(size=(n, d)).astype(np.float32) * 0.1
        att = plan.attack_flags(r, np.arange(n))
        out, info = robust_host_aggregate(
            u, np.ones(n, bool), np.zeros(n, np.int32), att,
            attack=("collude_signflip", 1e3, 1.5), guard=None,
            robust=("norm_median_clip", None, 5.0), use_kernel=False,
            beta=0.4, rule="equal")
        assert info["robust_rejected"] == int(att.sum()) \
            == len(plan.attackers(r))
        assert info["survivors"] == n - int(att.sum())
        assert np.all(np.isfinite(np.asarray(out)))
        total += info["robust_rejected"]
    assert total == math.ceil(0.25 * n) * rounds


# ---------------------------------------------------------------------------
# breakdown property: below the breakdown point the defenses hold
# ---------------------------------------------------------------------------


def test_breakdown_robust_defends_where_saa_fails():
    """collude_signflip with attacker counts below every defense's
    breakdown point (trim_k / krum_f >= scheduled attackers, attackers <
    half of any cohort): the defenses land near the clean baseline while
    plain SAA is dragged demonstrably below it (matched cohorts — the
    attacker stream is independent of the schedule, same seed)."""
    big = dict(n_learners=40, rounds=10, eval_every=5, n_target=10,
               saa=True, selector="priority", setting="DL", deadline=1e6)
    atk = dict(attack="collude_signflip", attack_frac=0.1,
               attack_scale=50.0)
    defenses = {
        "coord_median": {"aggregator": "coord_median"},
        "trimmed_mean": {"aggregator": "trimmed_mean", "trim_k": 4},
        "multi_krum": {"aggregator": "multi_krum", "krum_f": 4},
    }
    clean = Simulator(SimConfig(**big)).run().summary()["final_accuracy"]
    saa = Simulator(SimConfig(**big, **atk)).run().summary()[
        "final_accuracy"]
    assert math.isfinite(clean)
    assert saa < clean - 0.3          # the attack demonstrably lands
    for kind, extra in defenses.items():
        s = Simulator(SimConfig(**big, **atk, **extra)).run().summary()
        acc = s["final_accuracy"]
        assert acc > saa + 0.3, (kind, acc, saa, clean)
        assert acc > clean - 0.15, (kind, acc, clean)
        assert s["robust_rejected"] + s["robust_trimmed"] > 0, kind


# ---------------------------------------------------------------------------
# program structure: one psum, transfer-guard clean
# ---------------------------------------------------------------------------


def test_robust_attacked_program_keeps_one_collective():
    from repro.sim.pipeline import RoundPipeline
    cfg = _cfg(aggregator="coord_median", **SIGNFLIP,
               shard_participants=True, rounds_per_dispatch=2)
    pipe = RoundPipeline([Simulator(cfg)])
    orig, captured = pipe._prog, []

    def wrapper(*args):
        if not captured:
            captured.append(orig.lower(*args).compile().as_text())
        return orig(*args)

    pipe._prog = wrapper
    pipe.run()
    txt = captured[0]
    n_all_reduce = len(re.findall(r"all-reduce(?:-start)?\(", txt))
    for op in ("all-gather", "all-to-all", "collective-permute",
               "reduce-scatter"):
        assert f"{op}(" not in txt, f"unexpected {op} in the robust program"
    if len(jax.devices()) > 1:
        assert n_all_reduce == 1, f"expected 1 all-reduce, got {n_all_reduce}"
    else:
        assert n_all_reduce <= 1


def test_robust_attacked_pipeline_clean_under_transfer_guard():
    from repro.sim.pipeline import RoundPipeline
    cfg = _cfg(aggregator="multi_krum", krum_f=2, **SIGNFLIP)
    RoundPipeline([Simulator(cfg)]).run()          # warm compiles
    accts = RoundPipeline([Simulator(cfg)]).run(transfer_guard=True)
    s = accts[0].summary()
    assert s["rounds"] > 0 and s["robust_rejected"] > 0
    assert math.isfinite(s["final_accuracy"])


# ---------------------------------------------------------------------------
# sweep integration: batched==serial for robust cells, guard_totals gating
# ---------------------------------------------------------------------------


def test_robust_attack_sweep_batched_equals_serial():
    from repro.sweeps import SweepSpec, assert_parity, run_batched, run_serial
    spec = SweepSpec(
        axes={"aggregator": ["saa", "coord_median"],
              "attack": ["none", "collude_signflip"]},
        base=dict(n_learners=24, rounds=6, eval_every=3, n_target=6,
                  saa=True, selector="priority", setting="DL",
                  deadline=1e6, attack_frac=0.25, attack_scale=10.0),
        seeds=(0,))
    cells = spec.expand()
    results, _ = run_batched(cells)
    serial, _ = run_serial(cells)
    assert_parity(results, serial)
    totals = results.guard_totals()
    assert "robust_rejected" in totals and "robust_trimmed" in totals
    assert totals["robust_trimmed"] > 0        # coord_median cells trimmed
    assert "rejected_nonfinite" not in totals  # guard never enabled


def test_guard_totals_robust_keys_absent_when_feature_off():
    from repro.sweeps import SweepSpec, run_batched
    spec = SweepSpec(
        axes={"saa": [False, True]},
        base=dict(n_learners=20, rounds=4, eval_every=2, n_target=3,
                  selector="priority"),
        seeds=(0,))
    results, _ = run_batched(spec.expand())
    assert results.guard_totals() == {}        # absent, not silent zeros
