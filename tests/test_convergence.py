"""Theorem 1 sanity: Stale-Synchronous FedAvg (Alg. 2) on a controlled
non-convex problem — staleness tau must not change the asymptote ("asynchrony
for free"), and the rate improves with n and K.  Fully jitted (lax.scan)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


def _loss(x, z):
    """Smooth non-convex objective + noise sample z."""
    return jnp.sum((x[1:] - x[:-1] ** 2) ** 2) + 0.1 * jnp.sum((x - z) ** 2)


@functools.partial(jax.jit, static_argnames=("n", "K", "tau", "T", "d"))
def _run(key0, *, n, K, tau, T, d, gamma):
    grad = jax.grad(_loss)

    def local_delta(x, key):
        def k_step(y, kk):
            z = 0.3 * jax.random.normal(kk, (d,))
            return y - gamma * grad(y, z), None
        y, _ = jax.lax.scan(k_step, x, jax.random.split(key, K))
        return y - x

    def round_fn(carry, key):
        x, buf, ptr = carry                      # buf: (tau+1, d) delay line
        deltas = jax.vmap(lambda kk: local_delta(x, kk))(jax.random.split(key, n))
        buf = buf.at[ptr % (tau + 1)].set(deltas.mean(0))
        ready = (ptr >= tau).astype(jnp.float32)
        x = x + ready * buf[(ptr - tau) % (tau + 1)]
        gn = jnp.linalg.norm(grad(x, jnp.zeros(d)))
        return (x, buf, ptr + 1), gn

    init = (jnp.ones((d,)) * 2.0, jnp.zeros((tau + 1, d)), jnp.asarray(0))
    _, norms = jax.lax.scan(round_fn, init, jax.random.split(key0, T))
    return norms


def run_stale_fedavg(n=4, K=2, tau=0, T=300, gamma=0.02, d=6, seed=0):
    """Direct implementation of Alg. 2 with fixed round delay tau."""
    norms = _run(jax.random.PRNGKey(seed), n=n, K=K, tau=tau, T=T, d=d,
                 gamma=gamma)
    return np.asarray(norms)


def test_converges_with_staleness():
    norms = run_stale_fedavg(tau=3)
    assert norms[-50:].mean() < 0.2 * norms[:10].mean()


def test_asynchrony_for_free():
    """tau only affects the transient: late-phase gradient norms match sync."""
    sync = run_stale_fedavg(tau=0, T=400)
    stale = run_stale_fedavg(tau=5, T=400)
    assert stale[-50:].mean() < 2.0 * sync[-50:].mean() + 1e-3


def test_rate_improves_with_n():
    """More participants per round -> smaller stationary gradient norm
    (variance reduction, the 1/sqrt(n) factor)."""
    small = run_stale_fedavg(n=1, T=400, seed=1)[-100:].mean()
    big = run_stale_fedavg(n=16, T=400, seed=1)[-100:].mean()
    assert big < small


def test_rate_improves_with_K():
    """More local steps -> faster progress per round (the 1/sqrt(K) factor)."""
    k1 = run_stale_fedavg(K=1, T=150, seed=2)
    k4 = run_stale_fedavg(K=4, T=150, seed=2)
    assert k4[100:].mean() < k1[100:].mean()


def test_large_staleness_slows_transient():
    """The O(1/T) term grows with tau: with a step size satisfying Theorem 1's
    gamma <= O(1/(L sqrt(tau K (n tau K + M)))) bound, large tau still
    converges but the transient is slower than synchronous."""
    gamma = 0.004  # small enough for tau=8 per the Theorem-1 step-size bound
    sync = run_stale_fedavg(tau=0, T=250, seed=3, gamma=gamma)
    stale = run_stale_fedavg(tau=8, T=250, seed=3, gamma=gamma)
    assert np.isfinite(stale).all()
    assert sync[60:120].mean() < stale[60:120].mean()   # slower transient
    assert stale[-50:].mean() < 0.5 * stale[:10].mean()  # ...but converges


def test_step_size_bound_matters():
    """Violating the tau-dependent step-size bound diverges — the instability
    Theorem 1 guards against is real, not an artifact."""
    diverged = run_stale_fedavg(tau=20, T=80, seed=3, gamma=0.02)
    assert not np.isfinite(diverged[-10:]).all()
