"""Checkpoint round-trip + data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np

import pytest

from repro.checkpoint import CheckpointError, load_pytree, save_pytree
from repro.configs import get_reduced
from repro.data import federated_token_shards, token_batches
from repro.models import init_params


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("internlm2-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, params)
    template = jax.eval_shape(lambda: params)
    back = load_pytree(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    tree = {"a": jnp.ones((3,), jnp.bfloat16),
            "nested": [{"b": jnp.arange(4, dtype=jnp.int32)},
                       jnp.zeros((2, 2), jnp.float32)]}
    path = str(tmp_path / "m.npz")
    save_pytree(path, tree)
    back = load_pytree(path, tree)
    assert back["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["nested"][0]["b"]),
                                  np.arange(4))


def test_checkpoint_missing_and_extra_keys_raise(tmp_path):
    path = str(tmp_path / "m.npz")
    save_pytree(path, {"a": jnp.ones(2), "b": jnp.zeros(3)})
    with pytest.raises(CheckpointError, match="missing keys \\['c'\\]"):
        load_pytree(path, {"a": jnp.ones(2), "c": jnp.zeros(3)})
    with pytest.raises(CheckpointError, match="unexpected keys \\['b'\\]"):
        load_pytree(path, {"a": jnp.ones(2)})


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "m.npz")
    save_pytree(path, {"w": jnp.ones((2, 3))})
    with pytest.raises(CheckpointError, match="shape"):
        load_pytree(path, {"w": jnp.ones((3, 2))})


def test_checkpoint_bf16_roundtrip_is_bit_exact(tmp_path):
    """bf16 has no npz dtype: leaves travel as a uint16 view and must come
    back bit-identical (including values that would change under an
    fp32 round-trip's rounding)."""
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(64,)) * 1e3, jnp.bfloat16)
    path = str(tmp_path / "bf16.npz")
    save_pytree(path, {"w": vals})
    back = load_pytree(path, {"w": jnp.zeros((64,), jnp.bfloat16)})["w"]
    assert back.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(vals).view(np.uint16),
                                  np.asarray(back).view(np.uint16))


def test_token_batches_shapes_and_determinism():
    g1 = token_batches(128, 4, 32, seed=7)
    g2 = token_batches(128, 4, 32, seed=7)
    b1, b2 = next(g1), next(g2)
    assert b1["tokens"].shape == (4, 32) and b1["labels"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 128


def test_federated_token_shards_skew():
    shards = federated_token_shards(256, 8, 16, 32, skew=0.5)
    assert len(shards) == 8
    # skewed shards have different unigram distributions
    h = [np.bincount(s["tokens"].ravel(), minlength=256) for s in shards]
    corr = np.corrcoef(np.stack(h))
    off_diag = corr[np.triu_indices(8, 1)]
    assert off_diag.mean() < 0.999
