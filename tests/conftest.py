import os
import sys

# smoke tests and benches must see 1 device — dryrun.py (and only dryrun.py)
# forces 512. Make sure a stray env doesn't leak in.
os.environ.pop("XLA_FORCE_HOST_PLATFORM_DEVICE_COUNT", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # for _hypothesis_compat
