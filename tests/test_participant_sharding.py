"""Properties of the participant-axis-sharded round pipeline:

- sharding the packed cohort rows over a participant device mesh axis
  (``SimConfig.shard_participants`` / ``SweepRunner(shard_participants=)``)
  is **bit-identical per round** to the unsharded pipeline — full summary
  and per-round records — across selectors, aggregators, staleness
  thresholds, the Pallas aggregation kernel, multi-round chunking, the
  2-D ``("s", "p")`` sweep composition and accuracy-target early stop;
- indivisible shapes work: a cohort that does not split evenly over the
  shards (and n=1000 learners on 3 shards), plus stragglers whose cached
  update lands rounds later when their cell's rows occupy a *different*
  p-shard than the one that trained (and caches) them;
- the hot loop performs exactly ONE cross-shard collective per round (the
  aggregation-operand psum), asserted against the compiled HLO;
- the sharded round loop stays clean under ``jax.transfer_guard("disallow")``.

On the default CI leg the mesh degenerates to one device (the sharded code
path with a trivial psum); the multi-device CI leg forces
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the same tests
exercise real 4-way row splits, cross-shard landings included, plus the
n=10000 sharded smoke.
"""
import dataclasses
import re

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.sim import SimConfig, Simulator
from repro.sim.participant_sharding import (as_round_mesh, participant_mesh,
                                            round_mesh, split_balanced)
from repro.sim.pipeline import RoundPipeline
from repro.sweeps import SweepRunner, SweepSpec
from repro.sweeps.runner import summaries_equal

BASE = dict(n_learners=30, rounds=8, eval_every=4, n_target=4,
            mapping="label_uniform")
N_DEV = len(jax.devices())


def _records_equal(a, b) -> bool:
    if len(a.records) != len(b.records):
        return False
    for ra, rb in zip(a.records, b.records):
        ka = (ra.round_idx, ra.sim_time, ra.n_selected, ra.n_fresh,
              ra.n_stale, ra.resource_used, ra.resource_wasted,
              ra.unique_participants)
        kb = (rb.round_idx, rb.sim_time, rb.n_selected, rb.n_fresh,
              rb.n_stale, rb.resource_used, rb.resource_wasted,
              rb.unique_participants)
        accs = (ra.accuracy == rb.accuracy
                or (ra.accuracy != ra.accuracy and rb.accuracy != rb.accuracy))
        if ka != kb or not accs:
            return False
    return True


def _parity(cfg: SimConfig, n_p=True):
    a = Simulator(cfg).run()
    b = Simulator(dataclasses.replace(cfg, shard_participants=n_p)).run()
    assert summaries_equal(dict(a.summary()), dict(b.summary())), \
        (cfg, a.summary(), b.summary())
    assert _records_equal(a, b)


# ---------------------------------------------------------------------------
# Bit-parity with the unsharded pipeline
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(selector=st.sampled_from(["random", "priority", "safa", "oort"]),
       saa=st.booleans(),
       seed=st.integers(0, 2))
def test_participant_sharded_matches_unsharded(selector, saa, seed):
    _parity(SimConfig(selector=selector, saa=saa, seed=seed, deadline=60.0,
                      **BASE))


def test_participant_yogi_apt_threshold_kernel():
    _parity(SimConfig(selector="priority", saa=True, apt=True,
                      aggregator="yogi", seed=1, **BASE))
    _parity(SimConfig(selector="safa", saa=True, staleness_threshold=1,
                      seed=0, **BASE))
    _parity(SimConfig(selector="priority", saa=True, use_agg_kernel=True,
                      seed=0, **BASE))


def test_participant_sharded_chunked():
    """Participant sharding composes with K-round scan chunking: the psum
    sits inside the scan body, one collective per round either way."""
    _parity(SimConfig(selector="priority", saa=True, seed=0,
                      rounds_per_dispatch=4, **BASE))


def test_participant_early_stop():
    _parity(SimConfig(selector="priority", saa=True, seed=0,
                      target_accuracy=0.15, **BASE))


def test_indivisible_cohort_shapes():
    """Cohort rows that do not split evenly over the p-shards: balanced
    contiguous blocks differ in size and the padded local bucket is shared."""
    _parity(SimConfig(selector="priority", saa=True, seed=0, n_target=5,
                      n_learners=30, rounds=8, eval_every=4,
                      mapping="label_uniform"), n_p=min(3, N_DEV))


def test_n1000_on_three_shards():
    """The issue's indivisible case: an n=1000 cohort pool on 3 participant
    shards (clamped to the local device count on smaller hosts)."""
    _parity(SimConfig(selector="priority", saa=True, seed=0, n_target=16,
                      n_learners=1000, rounds=4, eval_every=2,
                      mapping="label_uniform"), n_p=3)


def test_straggler_lands_cross_shard():
    """A straggler's cached update stays on the p-shard that trained it;
    rounds later its cell's rows may occupy other shards, so the landing
    crosses shards through the aggregation psum.  Parity holds, and on a
    multi-device mesh the crossing actually happens."""
    cfg = SimConfig(selector="priority", saa=True, seed=0, n_learners=60,
                    rounds=16, eval_every=4, n_target=8,
                    mapping="label_uniform")
    _parity(cfg)
    pipe = RoundPipeline([Simulator(
        dataclasses.replace(cfg, shard_participants=True))])
    accts = pipe.run()
    assert sum(r.n_stale for r in accts[0].records) > 0
    if N_DEV > 1:
        assert pipe.stats.cross_shard_landings >= 1


def test_sweep_participant_composition():
    """The 2-D ("s", "p") mesh: sweep cells partitioned over "s", each
    cell's cohort rows split over "p" — still bitwise the unsharded run,
    early-stop repacking (which crosses s-shard boundaries) included."""
    base = dict(n_learners=30, rounds=12, eval_every=3, n_target=4,
                mapping="label_uniform", target_accuracy=0.12)
    axes = {"selector": ["random", "priority", "safa"], "saa": [False, True]}
    cells = SweepSpec(axes=axes, base=base, seeds=(0, 1)).expand()
    n_p = 2 if N_DEV % 2 == 0 and N_DEV > 1 else 1
    ref = SweepRunner(cells).run()
    got = SweepRunner(cells, shard=True, shard_participants=n_p).run()
    for a, b in zip(ref, got):
        assert summaries_equal(dict(a.summary), dict(b.summary)), \
            (a.cell.name, a.summary, b.summary)
        assert _records_equal(a.acct, b.acct), a.cell.name


def test_participant_only_sweep():
    """``shard_participants`` without ``shard``: all cells on every device's
    s-block (n_s = 1), rows split over the whole mesh."""
    cells = SweepSpec(axes={"selector": ["random", "priority"],
                            "saa": [True]}, base=BASE, seeds=(0,)).expand()
    ref = SweepRunner(cells).run()
    got = SweepRunner(cells, shard_participants=True).run()
    for a, b in zip(ref, got):
        assert summaries_equal(dict(a.summary), dict(b.summary)), a.cell.name


def test_transfer_guard_clean():
    """The participant-sharded hot loop performs no implicit transfers."""
    cfg = SimConfig(selector="priority", saa=True, seed=0,
                    shard_participants=True, rounds_per_dispatch=4, **BASE)
    RoundPipeline([Simulator(cfg)]).run()            # warm compiles
    pipe = RoundPipeline([Simulator(cfg)])
    accts = pipe.run(transfer_guard=True)
    assert pipe.stats.dispatches["round"] > 0
    assert accts[0].summary()["rounds"] > 0


# ---------------------------------------------------------------------------
# The collective-per-round invariant, against the compiled HLO
# ---------------------------------------------------------------------------


def _captured_hlo(cfg) -> str:
    pipe = RoundPipeline([Simulator(cfg)])
    orig, captured = pipe._prog, []

    def wrapper(*args):
        if not captured:
            captured.append(orig.lower(*args).compile().as_text())
        return orig(*args)

    pipe._prog = wrapper
    pipe.run()
    assert captured, "round program never dispatched"
    return captured[0]


def test_single_collective_per_round():
    """Exactly one cross-shard collective — the aggregation-operand psum —
    in the compiled round program (it sits inside the scan body, so one op
    covers every round of a chunk), and no other collective kinds at all."""
    cfg = SimConfig(selector="priority", saa=True, seed=0,
                    shard_participants=True, rounds_per_dispatch=4, **BASE)
    txt = _captured_hlo(cfg)
    n_all_reduce = len(re.findall(r"all-reduce(?:-start)?\(", txt))
    for op in ("all-gather", "all-to-all", "collective-permute",
               "reduce-scatter"):
        assert f"{op}(" not in txt, f"unexpected {op} in the round program"
    if N_DEV > 1:
        assert n_all_reduce == 1, f"expected 1 all-reduce, found {n_all_reduce}"
    else:
        assert n_all_reduce <= 1


def test_unsharded_program_has_no_collectives():
    txt = _captured_hlo(SimConfig(selector="priority", saa=True, seed=0,
                                  **BASE))
    for op in ("all-reduce", "all-gather", "all-to-all",
               "collective-permute", "reduce-scatter"):
        assert f"{op}(" not in txt


# ---------------------------------------------------------------------------
# n=10000 sharded smoke (multi-device CI leg; heavy for the 1-device legs)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(N_DEV < 2, reason="10k smoke runs on the multi-device leg")
def test_n10000_sharded_smoke():
    """Tens-of-thousands cohort pool, sharded rows, parity vs unsharded.
    The substrate is built once and shared (shard_participants is not part
    of the substrate key)."""
    cfg = SimConfig(n_learners=10000, rounds=3, eval_every=3, n_target=64,
                    saa=True, selector="priority", mapping="label_uniform",
                    seed=0)
    sub = Simulator(cfg).substrate
    a = Simulator(cfg, substrate=sub).run()
    b = Simulator(dataclasses.replace(cfg, shard_participants=True),
                  substrate=sub).run()
    assert summaries_equal(dict(a.summary()), dict(b.summary()))
    assert a.summary()["rounds"] >= 1      # availability can skip a round


# ---------------------------------------------------------------------------
# Host-side unit tests: row split + mesh plumbing
# ---------------------------------------------------------------------------


def test_split_balanced():
    assert split_balanced(10, 4) == [3, 3, 2, 2]
    assert split_balanced(4, 4) == [1, 1, 1, 1]
    assert split_balanced(3, 4) == [1, 1, 1, 0]
    assert split_balanced(0, 2) == [0, 0]
    assert sum(split_balanced(1000, 3)) == 1000


def test_mesh_builders():
    m = participant_mesh(True)
    assert m.axis_names == ("s", "p")
    assert int(m.shape["s"]) == 1 and int(m.shape["p"]) == N_DEV
    # over-asking clamps to the local device count
    assert int(participant_mesh(64).shape["p"]) == N_DEV
    from repro.sweeps.sharding import sweep_mesh
    m2 = as_round_mesh(sweep_mesh())
    assert m2.axis_names == ("s", "p") and int(m2.shape["p"]) == 1
    assert as_round_mesh(m) is m
    with pytest.raises(ValueError):
        round_mesh(N_DEV + 1, 2)


def test_runner_rejects_bad_composition():
    cells = SweepSpec(axes={"saa": [False, True]}, base=BASE,
                      seeds=(0,)).expand()
    with pytest.raises(ValueError):
        SweepRunner(cells, shard=True, shard_participants=True)


def test_shard_participants_never_silently_dropped():
    """The flag must error, not silently fall back: the per-stage/legacy
    substrates have no sharded round program, and an explicit mesh plus the
    config flag is ambiguous."""
    with pytest.raises(ValueError):
        Simulator(SimConfig(shard_participants=2, fused_rounds=False,
                            **BASE)).run()
    with pytest.raises(ValueError):
        Simulator(SimConfig(shard_participants=2, fast_path=False,
                            **BASE)).run()
    with pytest.raises(ValueError):
        RoundPipeline([Simulator(SimConfig(shard_participants=2, **BASE))],
                      mesh=participant_mesh(True))
