"""End-to-end behaviour tests: the full RELAY system against its baselines
(reduced scale), reproducing the paper's headline claims qualitatively."""
import numpy as np
import pytest

from repro.sim import SimConfig, Simulator


def _acc_at_resource(acct, budget):
    """Best accuracy reached while cumulative resource <= budget (the paper's
    resource-to-accuracy currency, Fig. 2/6/7 x-axis)."""
    best = 0.0
    for r in acct.records:
        if r.resource_used <= budget and r.accuracy == r.accuracy:
            best = max(best, r.accuracy)
    return best


@pytest.fixture(scope="module")
def runs():
    """One shared set of simulations (module-scoped: they cost seconds each)."""
    out = {}
    common = dict(n_learners=60, rounds=40, eval_every=10, seed=3,
                  mapping="label_uniform", dynamic_availability=True)
    out["relay"] = Simulator(SimConfig(
        selector="priority", saa=True, apt=True, scaling_rule="relay",
        **common)).run()
    out["random"] = Simulator(SimConfig(
        selector="random", **common)).run()
    out["oort"] = Simulator(SimConfig(
        selector="oort", **common)).run()
    return out


def test_relay_is_resource_efficient(runs):
    """Headline claim (Figs. 2/6/7): at EQUAL resource budget, RELAY reaches
    at-least-comparable accuracy — i.e. better resource-to-accuracy."""
    budget = runs["relay"].summary()["resource_used"]
    relay_acc = runs["relay"].summary()["final_accuracy"]
    random_acc_at_budget = _acc_at_resource(runs["random"], budget)
    assert runs["relay"].summary()["resource_used"] < \
        runs["random"].summary()["resource_used"]
    assert relay_acc > random_acc_at_budget - 0.02


def test_relay_low_waste(runs):
    assert runs["relay"].summary()["waste_fraction"] < 0.15


def test_all_selectors_train(runs):
    for k, acct in runs.items():
        assert acct.summary()["final_accuracy"] > 0.2, k


def test_stale_synchronous_fedavg_full_loop():
    """DL setting with SAA: stale updates must actually be aggregated."""
    cfg = SimConfig(n_learners=50, rounds=25, selector="random", setting="DL",
                    deadline=30.0, saa=True, eval_every=25, seed=0)
    sim = Simulator(cfg)
    acct = sim.run()
    stale_counts = [r.n_stale for r in acct.records]
    assert sum(stale_counts) > 0  # stragglers contributed late updates
    assert acct.summary()["final_accuracy"] > 0.2


def test_kernel_backed_aggregation_end_to_end():
    """The fused Pallas SAA kernel drives a full simulation run."""
    cfg = SimConfig(n_learners=30, rounds=10, selector="random", saa=True,
                    use_agg_kernel=True, eval_every=10, seed=0)
    acct = Simulator(cfg).run()
    assert np.isfinite(acct.summary()["final_accuracy"])


def test_seed_reproducibility():
    a = Simulator(SimConfig(n_learners=40, rounds=10, seed=11, eval_every=10)).run()
    b = Simulator(SimConfig(n_learners=40, rounds=10, seed=11, eval_every=10)).run()
    assert a.summary() == b.summary()
