"""Device-resident stale cache: slot accounting, eviction order, mask
correctness, and value parity with a host-list reference model under
hypothesis-driven round traces (real hypothesis when installed, the
deterministic shim otherwise)."""
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.core.stale_cache import CacheOverflow, DeviceStaleCache

D = 8


def _row(rng):
    return rng.standard_normal(D).astype(np.float32)


def test_alloc_free_roundtrip_and_masks():
    c = DeviceStaleCache(D, capacity=4)
    s1, ev = c.alloc(3)
    assert ev == [] and s1 == [0, 1, 2] and len(c) == 3
    assert list(np.nonzero(c.valid_mask())[0]) == [0, 1, 2]
    c.free([1])
    assert len(c) == 2 and not c.valid_mask()[1]
    s2, _ = c.alloc(2)                      # refills 1 (LIFO) then 3
    assert set(s2) == {1, 3} and len(c) == 4
    assert c.valid_mask().all()
    assert c.trash_slot == 4


def test_rows_roundtrip_exact_bits():
    rng = np.random.default_rng(0)
    c = DeviceStaleCache(D, capacity=8)
    slots, _ = c.alloc(5)
    rows = np.stack([_row(rng) for _ in slots])
    c.put(slots, rows)
    np.testing.assert_array_equal(c.gather(slots), rows)
    # overwrite one slot; others keep their exact bits
    c.put([slots[2]], rows[:1])
    np.testing.assert_array_equal(c.gather([slots[2]])[0], rows[0])
    np.testing.assert_array_equal(c.gather([slots[0]])[0], rows[0])
    np.testing.assert_array_equal(c.gather([slots[4]])[0], rows[4])


def test_growth_preserves_rows_and_trash_moves():
    rng = np.random.default_rng(1)
    c = DeviceStaleCache(D, capacity=2, grow=True)
    slots, _ = c.alloc(2)
    rows = np.stack([_row(rng), _row(rng)])
    c.put(slots, rows)
    more, ev = c.alloc(3)                   # forces growth 2 -> 4 -> 8
    assert ev == [] and c.capacity == 8 and c.grow_events == 2
    assert c.trash_slot == 8
    np.testing.assert_array_equal(c.gather(slots), rows)
    assert len(set(slots + more)) == 5      # no slot handed out twice


def test_eviction_order_is_insertion_order():
    c = DeviceStaleCache(D, capacity=3, grow=False)
    a, _ = c.alloc(3)
    _, ev1 = c.alloc(1)                     # evicts the oldest: a[0]
    assert ev1 == [a[0]]
    _, ev2 = c.alloc(2)                     # then a[1], a[2]
    assert ev2 == [a[1], a[2]]
    with_room, ev3 = c.alloc(0)
    assert with_room == [] and ev3 == []


def test_eviction_overflow_raises():
    c = DeviceStaleCache(D, capacity=2, grow=False)
    c.alloc(2)
    try:
        c.alloc(3)                          # can't evict enough for 3 > cap
    except CacheOverflow:
        pass
    else:
        raise AssertionError("expected CacheOverflow")


def test_double_free_raises():
    c = DeviceStaleCache(D, capacity=2)
    s, _ = c.alloc(1)
    c.free(s)
    try:
        c.free(s)
    except KeyError:
        pass
    else:
        raise AssertionError("double free must raise")


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), capacity=st.integers(2, 6),
       grow=st.booleans(), ops=st.integers(10, 40))
def test_random_round_traces_match_host_model(seed, capacity, grow, ops):
    """Random put/land(free)/evict traces: the device cache's live contents
    and masks always match a host-side dict model, and every gathered row
    is bit-identical to what was put."""
    rng = np.random.default_rng(seed)
    c = DeviceStaleCache(D, capacity=capacity, grow=grow)
    model = {}                              # slot -> row (host reference)
    order = []                              # insertion order of live slots
    for _ in range(ops):
        if model and rng.random() < 0.4:
            # land: free a random live slot
            k = min(len(model), 1 + int(rng.integers(2)))
            victims = [order.pop(int(rng.integers(len(order))))
                       for _ in range(k)]
            c.free(victims)
            for v in victims:
                del model[v]
        else:
            k = 1 + int(rng.integers(2))
            if not grow and k > c.capacity:
                continue
            slots, evicted = c.alloc(k)
            assert evicted == order[:len(evicted)]   # oldest-first eviction
            for e in evicted:
                del model[e]
            order = order[len(evicted):]
            rows = np.stack([_row(rng) for _ in slots])
            c.put(slots, rows)
            for s_, r_ in zip(slots, rows):
                model[s_] = r_
                order.append(s_)
        # invariants after every op
        assert len(c) == len(model)
        assert set(c.occupied()) == set(model)
        assert c.occupied() == order
        mask = c.valid_mask()
        assert set(np.nonzero(mask)[0]) == set(model)
        if model:
            live = sorted(model)
            np.testing.assert_array_equal(c.gather(live),
                                          np.stack([model[s_] for s_ in live]))
