"""Unit tests for the sweep subsystem: grid expansion, batched aggregation
(jnp + sweep-axis Pallas kernel), results/report layers, yogi/kernel cells."""
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.sim import SimConfig
from repro.sweeps import Cell, SweepRunner, SweepSpec, axis_updates, compat_key
from repro.sweeps.report import markdown_table, text_table
from repro.sweeps.runner import summaries_equal

# ---------------------------------------------------------------------------
# Grid expansion
# ---------------------------------------------------------------------------


def test_grid_expansion_shared_seed_pairing():
    spec = SweepSpec(axes={"policy": ["random", "relay"],
                           "hardware": ["HS1", "HS3"]},
                     base=dict(n_learners=20, rounds=4),
                     seeds=(0, 7))
    cells = spec.expand()
    assert len(cells) == spec.size == 8
    # every axis combination appears once per seed, with cfg.seed == seed
    seeds = sorted({c.config.seed for c in cells})
    assert seeds == [0, 7]
    relay = [c for c in cells if c.coord("policy") == "relay"]
    assert all(c.config.selector == "priority" and c.config.saa
               and c.config.apt for c in relay)
    hs3 = [c for c in cells if c.coord("hardware") == "HS3"]
    assert all(c.config.hardware_scenario == "HS3" for c in hs3)
    assert all(c.config.n_learners == 20 for c in cells)
    assert len({c.name for c in cells}) == len(cells)


def test_grid_rejects_axis_order_that_collapses_cells():
    """A saa axis BEFORE a policy axis whose presets pin saa would produce
    differently-labeled cells with identical configs — expand() refuses."""
    bad = SweepSpec(axes={"saa": [False, True], "policy": ["safa", "relay"]},
                    base=dict(n_learners=20, rounds=4))
    with pytest.raises(ValueError, match="identical config"):
        bad.expand()
    # the reverse order is the supported toggle-within-preset pattern
    good = SweepSpec(axes={"policy": ["safa", "relay"], "saa": [False, True]},
                     base=dict(n_learners=20, rounds=4))
    assert len(good.expand()) == 4


def test_grid_axis_registry_and_raw_fields():
    assert axis_updates("saa", True) == {"saa": True}
    assert axis_updates("availability", "static") == \
        {"dynamic_availability": False}
    assert axis_updates("n_target", 25) == {"n_target": 25}  # raw field
    with pytest.raises(KeyError):
        axis_updates("not_an_axis", 1)
    with pytest.raises(ValueError):
        axis_updates("hardware", "HS9")


def test_compat_key_splits_incompatible_cells():
    a = SimConfig(rounds=10)
    b = SimConfig(rounds=20)
    c = SimConfig(rounds=10, saa=True, hardware_scenario="HS4")
    assert compat_key(a) != compat_key(b)
    assert compat_key(a) == compat_key(c)  # host-side knobs batch together
    # selector_key is part of pipeline_key: an Oort cell gets its own
    # (K=1, l2s-fetching) batch instead of capping everyone's prescheduling
    d = SimConfig(rounds=10, selector="oort")
    e = SimConfig(rounds=10, selector="oort",
                  selector_params=(("alpha", 1.5),))
    assert compat_key(a) != compat_key(d)
    assert compat_key(d) != compat_key(e)  # knobs split variants too


# ---------------------------------------------------------------------------
# Batched aggregation: jnp sweep path and the sweep-axis Pallas kernel
# ---------------------------------------------------------------------------


def _round_updates(rng, n, d):
    rows = [rng.standard_normal(d).astype(np.float32) for _ in range(n)]
    n_fresh = max(1, n // 2)
    fresh = [True] * n_fresh + [False] * (n - n_fresh)
    tau = [0] * n_fresh + list(rng.integers(1, 5, n - n_fresh))
    return rows, fresh, tau


@pytest.mark.parametrize("rule", ["equal", "dynsgd", "adasgd", "relay"])
def test_sweep_aggregate_matches_per_cell_flat(rule):
    """Each cell's slice of the batched aggregate is bit-identical to the
    serial flat aggregation of the same rows (including a no-update cell)."""
    rng = np.random.default_rng(0)
    d = 257
    cell_updates = [_round_updates(rng, n, d) for n in (3, 7, 5)]
    cell_updates.insert(1, None)
    u, fresh, tau, valid, has = agg.sweep_bucket_pad(cell_updates, d)
    assert u.shape == (4, 8, d) and list(has) == [True, False, True, True]
    beta = np.array([0.35, 0.35, 0.5, 0.2], np.float32)
    out, w = agg.sweep_aggregate_flat(u, fresh, tau, valid, beta, rule=rule)
    out, w = np.asarray(out), np.asarray(w)
    np.testing.assert_array_equal(out[1], np.zeros(d))
    for s, cell in enumerate(cell_updates):
        if cell is None:
            continue
        rows, fr, ta = cell
        ref, w_ref = agg.stale_synchronous_aggregate_flat(
            np.stack(rows), fr, ta, rule=rule, beta=float(beta[s]))
        np.testing.assert_array_equal(out[s], np.asarray(ref))
        np.testing.assert_array_equal(w[s][:len(rows)], np.asarray(w_ref))


def test_sweep_aggregate_mixed_rules_in_one_program():
    """scaling_rule is a traced per-cell operand on the jnp path: a batch
    mixing all four rules matches each rule's static serial aggregation
    bit-for-bit; the kernel path refuses mixed rules."""
    rng = np.random.default_rng(11)
    d = 180
    rules = ["equal", "dynsgd", "adasgd", "relay"]
    cell_updates = [_round_updates(rng, 6, d) for _ in rules]
    u, fresh, tau, valid, _ = agg.sweep_bucket_pad(cell_updates, d)
    beta = np.full(4, 0.35, np.float32)
    out, w = agg.sweep_aggregate_flat(u, fresh, tau, valid, beta, rule=rules)
    for s, (rows, fr, ta) in enumerate(cell_updates):
        ref, w_ref = agg.stale_synchronous_aggregate_flat(
            np.stack(rows), fr, ta, rule=rules[s], beta=0.35)
        np.testing.assert_array_equal(np.asarray(out)[s], np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(w)[s][:6], np.asarray(w_ref))
    with pytest.raises(ValueError, match="mixed rules"):
        agg.sweep_aggregate_flat(u, fresh, tau, valid, beta, rule=rules,
                                 use_kernel=True)


def test_runner_scaling_rule_axis_batches_together():
    """A scaling_rule axis stays in ONE lockstep batch (per-cell rule switch)
    and every cell still matches its serial run exactly."""
    from repro.sim import Simulator
    spec = SweepSpec(axes={"scaling_rule": ["equal", "dynsgd", "adasgd",
                                            "relay"]},
                     base={**SMALL, "saa": True, "setting": "DL",
                           "deadline": 40.0}, seeds=(0,))
    cells = spec.expand()
    assert len({compat_key(c.config) for c in cells}) == 1
    results = SweepRunner(cells).run()
    for res in results:
        serial = Simulator(res.cell.config).run().summary()
        assert summaries_equal(dict(res.summary), dict(serial)), res.cell.name


def test_sweep_kernel_matches_jnp_path():
    rng = np.random.default_rng(3)
    d = 300   # not lane-aligned: exercises the kernel wrapper's padding
    cell_updates = [_round_updates(rng, n, d) for n in (4, 6)]
    u, fresh, tau, valid, _ = agg.sweep_bucket_pad(cell_updates, d)
    beta = np.array([0.35, 0.45], np.float32)
    a_jnp, w_jnp = agg.sweep_aggregate_flat(u, fresh, tau, valid, beta,
                                            rule="relay")
    a_k, w_k = agg.sweep_aggregate_flat(u, fresh, tau, valid, beta,
                                        rule="relay", use_kernel=True)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_jnp),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_jnp),
                               rtol=1e-4, atol=1e-5)


def test_sweep_kernel_matches_per_cell_fused_kernel():
    """The sweep-grid kernel row-for-row vs the existing per-cell kernel."""
    from repro.kernels.staleness_agg import ops as agg_ops
    rng = np.random.default_rng(5)
    d = 2048
    cell_updates = [_round_updates(rng, 5, d) for _ in range(3)]
    u, fresh, tau, valid, _ = agg.sweep_bucket_pad(cell_updates, d)
    a_sweep, w_sweep = agg_ops.sweep_staleness_aggregate(
        u, fresh, tau, valid=valid, rule="relay", beta=0.35)
    for s, (rows, fr, ta) in enumerate(cell_updates):
        a_cell, w_cell = agg_ops.staleness_aggregate(
            np.stack(rows), np.asarray(fr), np.asarray(ta), rule="relay",
            beta=0.35)
        np.testing.assert_allclose(np.asarray(a_sweep)[s], np.asarray(a_cell),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(w_sweep)[s][:5],
                                   np.asarray(w_cell), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Runner: yogi and kernel-backed cells, results/report layers
# ---------------------------------------------------------------------------

SMALL = dict(n_learners=25, rounds=5, eval_every=5, n_target=4,
             mapping="label_uniform")


def _run_spec(spec):
    return SweepRunner(spec.expand()).run()


def test_runner_yogi_and_kernel_cells():
    from repro.sim import Simulator
    for extra in (dict(aggregator="yogi"), dict(use_agg_kernel=True)):
        spec = SweepSpec(axes={"selector": ["random", "priority"]},
                         base={**SMALL, **extra, "saa": True}, seeds=(0,))
        results = _run_spec(spec)
        for res in results:
            serial = Simulator(res.cell.config).run().summary()
            assert summaries_equal(dict(res.summary), dict(serial)), \
                (extra, res.cell.name)


def test_mixed_compat_groups_run_in_one_sweep():
    """Cells with different rounds/aggregators split into separate lockstep
    batches but come back as one result set in input order."""
    cells = (SweepSpec(axes={"selector": ["random"]}, base=SMALL).expand()
             + SweepSpec(axes={"selector": ["random"]},
                         base={**SMALL, "rounds": 3}).expand())
    results = SweepRunner(cells).run()
    assert [r.cell.config.rounds for r in results] == [5, 3]
    assert results[0].summary["rounds"] >= results[1].summary["rounds"]


def test_results_soa_filter_and_reports():
    spec = SweepSpec(axes={"policy": ["random", "relay"]},
                     base=SMALL, seeds=(0, 1))
    results = _run_spec(spec)
    soa = results.soa()
    assert len(soa["final_accuracy"]) == 4
    assert set(soa["policy"]) == {"random", "relay"}
    only_relay = results.filter(policy="relay")
    assert len(only_relay) == 2
    stats = results.group_stats()
    assert all("policy" in row and "final_accuracy" in row for row in stats)
    assert all(row["n"] == 2 for row in stats)

    md = markdown_table(results)
    txt = text_table(results)
    assert "policy=relay" in md and "policy=random" in md
    assert len(md.splitlines()) == 4  # header + separator + 2 policy rows
    assert "accuracy" in txt.splitlines()[0]

    js = results.to_json_dict()
    assert len(js["cells"]) == 4
    assert set(js["cells"][0]["summary"]) >= {"final_accuracy",
                                              "resource_used"}
