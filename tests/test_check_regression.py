"""The benchmark regression guard fails on parity mismatches and on
beyond-tolerance slowdowns, but not on noise or missing baselines."""
import json
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.check_regression import check


def _engine(rps_fused, rps_flat=300.0, parity=True):
    return {"bench": "engine", "mode": "smoke", "engine": [{
        "n_learners": 100, "rounds": 10,
        "fused": {"rounds_per_sec": rps_fused},
        "flat": {"rounds_per_sec": rps_flat},
        "parity": parity,
    }]}


def _sweeps(wall, parity=True, s_cells=4):
    return {"bench": "sweeps", "mode": "smoke", "sweep": [{
        "s_cells": s_cells, "n_learners": 100, "rounds": 12,
        "batched_wall_s": wall, "parity": parity,
    }], "early_stop": [], "variants": []}


def _write(tmp_path, name, base, cur):
    b, c = tmp_path / "base", tmp_path / "cur"
    b.mkdir(exist_ok=True), c.mkdir(exist_ok=True)
    (b / name).write_text(json.dumps(base))
    (c / name).write_text(json.dumps(cur))
    return b, c


def test_noise_within_tolerance_passes(tmp_path):
    _write(tmp_path, "BENCH_engine.json", _engine(400.0), _engine(250.0))
    b, c = _write(tmp_path, "BENCH_sweeps.json", _sweeps(1.0), _sweeps(1.8))
    assert check(b, c, 2.0) == 0


def test_slowdown_beyond_tolerance_fails(tmp_path):
    b, c = _write(tmp_path, "BENCH_engine.json",
                  _engine(400.0), _engine(150.0))
    (b / "BENCH_sweeps.json").write_text(json.dumps(_sweeps(1.0)))
    (c / "BENCH_sweeps.json").write_text(json.dumps(_sweeps(1.0)))
    assert check(b, c, 2.0) == 1


def test_sweep_wall_regression_fails(tmp_path):
    _write(tmp_path, "BENCH_engine.json", _engine(400.0), _engine(400.0))
    b, c = _write(tmp_path, "BENCH_sweeps.json", _sweeps(1.0), _sweeps(2.5))
    assert check(b, c, 2.0) == 1


def test_parity_false_fails_regardless_of_speed(tmp_path):
    _write(tmp_path, "BENCH_engine.json",
           _engine(400.0), _engine(1000.0, parity=False))
    b, c = _write(tmp_path, "BENCH_sweeps.json", _sweeps(1.0), _sweeps(0.5))
    assert check(b, c, 2.0) == 1


def test_unmatched_rows_are_skipped_not_failed(tmp_path):
    # baseline rows at a different grid config: nothing comparable -> OK
    _write(tmp_path, "BENCH_engine.json", _engine(400.0), _engine(100.0)
           | {"engine": [{**_engine(100.0)["engine"][0], "rounds": 99}]})
    b, c = _write(tmp_path, "BENCH_sweeps.json",
                  _sweeps(1.0, s_cells=64), _sweeps(9.9, s_cells=4))
    assert check(b, c, 2.0) == 0


def _participant(rps_sharded, rps_unsharded=100.0, parity=True):
    return _engine(400.0) | {"participant": [{
        "n_learners": 1000, "n_target": 64, "rounds": 6, "n_devices": 1,
        "sharded": {"rounds_per_sec": rps_sharded},
        "unsharded": {"rounds_per_sec": rps_unsharded},
        "parity": parity,
    }]}


def test_participant_rows_are_row_matched(tmp_path):
    b, c = _write(tmp_path, "BENCH_engine.json",
                  _participant(100.0), _participant(30.0))
    (b / "BENCH_sweeps.json").write_text(json.dumps(_sweeps(1.0)))
    (c / "BENCH_sweeps.json").write_text(json.dumps(_sweeps(1.0)))
    assert check(b, c, 2.0) == 1          # sharded rps collapsed beyond 2x
    (c / "BENCH_engine.json").write_text(json.dumps(_participant(80.0)))
    assert check(b, c, 2.0) == 0          # within tolerance


def test_markdown_summary_emitted(tmp_path):
    b, c = _write(tmp_path, "BENCH_engine.json",
                  _participant(100.0), _participant(30.0, parity=False))
    (b / "BENCH_sweeps.json").write_text(json.dumps(_sweeps(1.0)))
    (c / "BENCH_sweeps.json").write_text(json.dumps(_sweeps(1.5)))
    summary = tmp_path / "step_summary.md"
    assert check(b, c, 2.0, summary_path=str(summary)) == 1
    md = summary.read_text()
    assert "| status | row | metric | baseline | current | ratio |" in md
    assert "Parity failures" in md
    assert ":x: FAIL" in md and ":white_check_mark: OK" in md
    # a second run appends (GITHUB_STEP_SUMMARY semantics)
    check(b, c, 2.0, summary_path=str(summary))
    assert summary.read_text().count("Benchmark regression guard") == 2
