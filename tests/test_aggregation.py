"""Aggregation / server-optimizer tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.aggregation import (aggregate_updates, fedavg_apply,
                                    flatten_update, stale_synchronous_aggregate,
                                    unflatten_update, yogi_apply, yogi_init)


def _tree(seed, shapes=((3, 4), (7,), (2, 2, 2))):
    rng = np.random.default_rng(seed)
    return {f"p{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
            for i, s in enumerate(shapes)}


def test_flatten_roundtrip():
    t = _tree(0)
    flat, spec = flatten_update(t)
    back = unflatten_update(flat, spec)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flatten_roundtrip_bf16():
    t = {"a": jnp.ones((3, 3), jnp.bfloat16), "b": jnp.zeros((2,), jnp.float32)}
    flat, spec = flatten_update(t)
    back = unflatten_update(flat, spec)
    assert back["a"].dtype == jnp.bfloat16 and back["b"].dtype == jnp.float32


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 8), seed=st.integers(0, 20))
def test_aggregate_is_convex_combination(n, seed):
    rng = np.random.default_rng(seed)
    trees = [_tree(seed + i) for i in range(n)]
    fresh = [True] * max(1, n // 2) + [False] * (n - max(1, n // 2))
    tau = [0] * max(1, n // 2) + [2] * (n - max(1, n // 2))
    agg, w = stale_synchronous_aggregate(trees, fresh, tau, rule="relay")
    # aggregate lies within the per-coordinate min/max envelope
    for key in trees[0]:
        stack = np.stack([np.asarray(t[key]) for t in trees])
        a = np.asarray(agg[key])
        assert (a <= stack.max(0) + 1e-5).all()
        assert (a >= stack.min(0) - 1e-5).all()


def test_fedavg_apply():
    params = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    delta = {"w": jnp.full((2, 2), 0.5, jnp.float32)}
    new = fedavg_apply(params, delta, server_lr=1.0)
    np.testing.assert_allclose(np.asarray(new["w"], np.float32), 1.5)
    assert new["w"].dtype == jnp.bfloat16


def test_yogi_moves_toward_delta_direction():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = yogi_init(params)
    delta = {"w": jnp.asarray([1.0, -1.0, 2.0, 0.0])}
    p = params
    for _ in range(10):
        p, state = yogi_apply(p, delta, state, lr=0.1)
    w = np.asarray(p["w"])
    assert w[0] > 0 and w[1] < 0 and w[2] > 0 and abs(w[3]) < 1e-6


def test_kernel_path_matches_jnp_path():
    trees = [_tree(i) for i in range(5)]
    fresh = [True, True, True, False, False]
    tau = [0, 0, 0, 1, 4]
    agg1, w1 = stale_synchronous_aggregate(trees, fresh, tau, rule="relay",
                                           use_kernel=False)
    agg2, w2 = stale_synchronous_aggregate(trees, fresh, tau, rule="relay",
                                           use_kernel=True)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(agg1), jax.tree.leaves(agg2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
