"""Chaos harness: deterministic fault injection + guarded aggregation.

The contracts under test (ISSUE PR-6):

  * guards on + no faults  =>  bit-identical to an unguarded run, on every
    substrate (fused / chunked / flat per-stage / legacy) — screening is a
    bit-exact no-op when nothing is rejected;
  * injected NaN/Inf/byzantine rows are rejected and *counted*, the guarded
    run finishes with finite metrics, and the identical fault plan produces
    the identical rejections on the legacy and fused substrates;
  * an unguarded run under the same NaN faults demonstrably diverges;
  * post-training drops and replay duplicates reproduce bit-identically
    across the fused and per-stage substrates (host-shared schedule logic).
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.faults import CORRUPTION_KINDS, FaultPlan, FaultSpec
from repro.sim.engine import SimConfig, Simulator
from repro.sweeps.runner import summaries_equal

BASE = dict(n_learners=30, rounds=8, eval_every=4, n_target=4,
            saa=True, selector="priority")


def _cfg(**kw):
    return SimConfig(**{**BASE, **kw})


def _plan(specs=(), **kw):
    return FaultPlan(n_learners=BASE["n_learners"], rounds=BASE["rounds"],
                     specs=specs, **kw)


# ---------------------------------------------------------------------------
# FaultPlan determinism
# ---------------------------------------------------------------------------


def test_fault_plan_is_deterministic():
    mk = lambda: _plan((FaultSpec("nan", prob=0.3),
                        FaultSpec("post_drop", prob=0.2)), seed=11)
    a, b = mk(), mk()
    np.testing.assert_array_equal(a.corrupt, b.corrupt)
    assert a.counts() == b.counts()
    assert a.has_corruption


def test_fault_plan_scoping_and_kinds():
    for kind in CORRUPTION_KINDS:
        p = _plan((FaultSpec(kind, prob=1.0, rounds=(2, 3), learners=(5,)),))
        hit = p.scale_for(2, [5])[0]
        assert hit != 1.0 or hit != hit          # NaN compares unequal
        assert p.scale_for(1, [5])[0] == 1.0     # outside the round window
        assert p.scale_for(2, [6])[0] == 1.0     # other learners untouched
    with pytest.raises(ValueError):
        FaultSpec("bogus")


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_sparse_plan_replays_dense_bit_exactly(seed):
    """COO storage is a pure layout change: both modes consume the RNG
    stream identically, so every per-round query — corruption multipliers,
    drops, replays, counts — matches the dense plan bit-exactly."""
    specs = (FaultSpec("nan", prob=0.1),
             FaultSpec("scale", prob=0.15, scale=1e4),
             FaultSpec("signflip", prob=0.05, rounds=(2, 6)),
             FaultSpec("post_drop", prob=0.1, learners=(1, 5, 9)),
             FaultSpec("replay", prob=0.2))
    dense = _plan(specs, seed=seed, sparse=False)
    sparse = _plan(specs, seed=seed, sparse=True)
    assert dense.counts() == sparse.counts()
    assert dense.has_corruption == sparse.has_corruption
    lids = np.arange(BASE["n_learners"])
    for r in range(BASE["rounds"] + 1):          # +1: beyond the horizon
        np.testing.assert_array_equal(dense.scale_for(r, lids),
                                      sparse.scale_for(r, lids))
        for lid in lids:
            assert dense.post_drop(r, lid) == sparse.post_drop(r, lid)
            assert dense.replay(r, lid) == sparse.replay(r, lid)


def test_sparse_plan_auto_switch_and_run_parity():
    """Auto-sparse plans drive a guarded run to the identical summary as
    the dense plan (the engine only sees the query API)."""
    mk = lambda sparse: _plan(NAN_PLAN, seed=7, sparse=sparse)
    assert not _plan(NAN_PLAN, seed=7).sparse      # small plan stays dense
    a = Simulator(_cfg(guard=True), fault_plan=mk(False)).run().summary()
    b = Simulator(_cfg(guard=True), fault_plan=mk(True)).run().summary()
    assert summaries_equal(dict(a), dict(b))
    assert a["rejected_nonfinite"] > 0


def test_without_crash_preserves_corruption():
    p = _plan((FaultSpec("inf", prob=0.5),), crash_after=3)
    q = p.without_crash()
    assert q.crash_after is None and p.crash_after == 3
    np.testing.assert_array_equal(p.corrupt, q.corrupt)


# ---------------------------------------------------------------------------
# screen_rows unit behaviour
# ---------------------------------------------------------------------------


def test_screen_rows_rejects_and_counts():
    u = np.ones((4, 8), np.float32)
    u[1, 3] = np.nan
    u[2] *= 100.0                    # byzantine-scale outlier
    valid = np.array([True, True, True, False])
    u2, v2, n_nf, n_out, n_clip = agg.screen_rows(
        jnp.asarray(u), jnp.asarray(valid), reject_mult=5.0)
    assert int(n_nf) == 1 and int(n_out) == 1 and int(n_clip) == 0
    assert list(np.asarray(v2)) == [True, False, False, False]
    assert np.all(np.isfinite(np.asarray(u2)))   # poison rows zeroed
    np.testing.assert_array_equal(np.asarray(u2)[1], 0.0)


def test_screen_rows_clip_rescales_survivors():
    u = np.ones((2, 4), np.float32) * 3.0        # norm 6
    valid = np.array([True, True])
    u2, v2, _, _, n_clip = agg.screen_rows(jnp.asarray(u),
                                           jnp.asarray(valid), clip=1.0)
    assert int(n_clip) == 2
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(u2), axis=1), 1.0, rtol=1e-6)


def test_screen_rows_clean_is_bit_exact():
    rng = np.random.default_rng(0)
    u = rng.normal(size=(5, 16)).astype(np.float32)
    valid = np.array([True] * 4 + [False])
    u[4] = 0.0
    u2, v2, n_nf, n_out, _ = agg.screen_rows(jnp.asarray(u),
                                             jnp.asarray(valid))
    assert int(n_nf) == 0 and int(n_out) == 0
    np.testing.assert_array_equal(np.asarray(u2), u)
    np.testing.assert_array_equal(np.asarray(v2), valid)


# ---------------------------------------------------------------------------
# guards on + no faults == unguarded, bitwise, on every substrate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sub", ["fused", "chunked", "flat", "legacy",
                                 "kernel", "yogi"])
def test_guard_without_faults_is_bit_identical(sub):
    extra = {"fused": {},
             "chunked": {"rounds_per_dispatch": 4},
             "flat": {"fused_rounds": False},
             "legacy": {"fast_path": False, "fused_rounds": False},
             "kernel": {"use_agg_kernel": True},
             "yogi": {"aggregator": "yogi"}}[sub]
    ref = Simulator(_cfg(**extra)).run().summary()
    grd = Simulator(_cfg(guard=True, quorum=1, **extra)).run().summary()
    for k in ref:
        assert grd[k] == ref[k] or (grd[k] != grd[k] and ref[k] != ref[k]), \
            (sub, k, ref[k], grd[k])
    assert grd["rejected_nonfinite"] == 0 and grd["quorum_skips"] == 0


# ---------------------------------------------------------------------------
# NaN/Inf-emitting learners: rejected, counted, cross-substrate identical
# ---------------------------------------------------------------------------


NAN_PLAN = (FaultSpec("nan", prob=0.2), FaultSpec("scale", prob=0.1,
                                                  scale=1e4))


def test_nan_learners_rejected_and_run_stays_finite():
    s = Simulator(_cfg(guard=True), fault_plan=_plan(NAN_PLAN, seed=7)) \
        .run().summary()
    assert s["rejected_nonfinite"] > 0
    assert math.isfinite(s["final_accuracy"])


def test_nan_faults_legacy_and_fused_converge_identically():
    """Property from the ISSUE: the legacy and fused pipelines under the
    identical fault plan reject the identical rows (schedule logic is
    shared host code) and land within the substrates' accuracy parity."""
    fused = Simulator(_cfg(guard=True),
                      fault_plan=_plan(NAN_PLAN, seed=7)).run().summary()
    flat = Simulator(_cfg(guard=True, fused_rounds=False),
                     fault_plan=_plan(NAN_PLAN, seed=7)).run().summary()
    legacy = Simulator(_cfg(guard=True, fast_path=False, fused_rounds=False),
                       fault_plan=_plan(NAN_PLAN, seed=7)).run().summary()
    assert summaries_equal(dict(fused), dict(flat))      # bitwise
    for k in ("rounds", "rejected_nonfinite", "rejected_norm",
              "quorum_skips", "unique_participants"):
        assert legacy[k] == fused[k], k
    assert abs(legacy["final_accuracy"] - fused["final_accuracy"]) < 1e-3


def test_unguarded_run_diverges_under_nan_faults():
    grd = Simulator(_cfg(guard=True),
                    fault_plan=_plan(NAN_PLAN, seed=7)).run().summary()
    raw = Simulator(_cfg(),
                    fault_plan=_plan(NAN_PLAN, seed=7)).run().summary()
    assert grd["rejected_nonfinite"] > 0
    assert not math.isfinite(raw["final_accuracy"]) or \
        raw["final_accuracy"] != grd["final_accuracy"]


def test_byzantine_scale_rows_rejected_by_norm_rule():
    plan = _plan((FaultSpec("scale", prob=0.25, scale=1e4),), seed=1)
    s = Simulator(_cfg(guard=True, guard_reject_mult=5.0),
                  fault_plan=plan).run().summary()
    assert s["rejected_norm"] > 0
    assert math.isfinite(s["final_accuracy"])


def test_quorum_skips_round_and_carries_params():
    """Every row poisoned => zero survivors => the apply is skipped and
    counted; the run still completes finite (params simply never move on
    poisoned rounds)."""
    plan = _plan((FaultSpec("nan", prob=1.0, rounds=(0, 3)),), seed=0)
    s = Simulator(_cfg(guard=True, quorum=1), fault_plan=plan).run().summary()
    assert s["quorum_skips"] >= 1
    assert math.isfinite(s["final_accuracy"])


# ---------------------------------------------------------------------------
# post-training drops + replay duplicates: substrate parity + accounting
# ---------------------------------------------------------------------------


def test_post_drop_wastes_work_identically_across_substrates():
    plan = lambda: _plan((FaultSpec("post_drop", prob=0.3),), seed=5)
    fused = Simulator(_cfg(), fault_plan=plan()).run().summary()
    flat = Simulator(_cfg(fused_rounds=False),
                     fault_plan=plan()).run().summary()
    clean = Simulator(_cfg()).run().summary()
    assert summaries_equal(dict(fused), dict(flat))
    assert fused["resource_wasted"] > clean["resource_wasted"]


def test_replay_duplicates_land_identically_across_substrates():
    plan = lambda: _plan((FaultSpec("replay", prob=0.5),), seed=9)
    fused = Simulator(_cfg(), fault_plan=plan()).run().summary()
    flat = Simulator(_cfg(fused_rounds=False),
                     fault_plan=plan()).run().summary()
    assert summaries_equal(dict(fused), dict(flat))


def test_chunked_guarded_faulted_matches_single_dispatch():
    mk = lambda: _plan((FaultSpec("inf", prob=0.15),
                        FaultSpec("replay", prob=0.3)), seed=3)
    k1 = Simulator(_cfg(guard=True, guard_reject_mult=5.0),
                   fault_plan=mk()).run().summary()
    k4 = Simulator(_cfg(guard=True, guard_reject_mult=5.0,
                        rounds_per_dispatch=4),
                   fault_plan=mk()).run().summary()
    assert summaries_equal(dict(k1), dict(k4))


# ---------------------------------------------------------------------------
# transfer-guard + program-structure invariants survive the guard
# ---------------------------------------------------------------------------


def test_guarded_faulted_pipeline_clean_under_transfer_guard():
    from repro.sim.pipeline import RoundPipeline
    sim = Simulator(_cfg(guard=True, guard_reject_mult=5.0),
                    fault_plan=_plan(NAN_PLAN, seed=7))
    accts = RoundPipeline([sim]).run(transfer_guard=True)
    s = accts[0].summary()
    assert s["rounds"] > 0 and math.isfinite(s["final_accuracy"])


def test_guarded_round_program_has_no_collectives_unsharded():
    import re
    from repro.sim.pipeline import RoundPipeline
    pipe = RoundPipeline([Simulator(_cfg(guard=True))])
    orig, captured = pipe._prog, []

    def wrapper(*args):
        if not captured:
            captured.append(orig.lower(*args).compile().as_text())
        return orig(*args)

    pipe._prog = wrapper
    pipe.run()
    txt = captured[0]
    for op in ("all-reduce", "all-gather", "all-to-all",
               "collective-permute", "reduce-scatter"):
        assert not re.search(rf"{op}(?:-start)?\(", txt), op
