"""Minimal npz pytree checkpointing (no orbax offline).

Leaves are keyed by their flattened key-path; restore requires a template tree
(the usual init_params output) so structure round-trips exactly. Device arrays
are gathered to host; bf16 is stored via uint16 view (npz has no bf16).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(ValueError):
    """The stored checkpoint does not match the template tree."""


def _keystr(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def save_pytree(path: str, tree) -> None:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        key = _keystr(kp)
        if arr.dtype == jnp.bfloat16:
            flat[key + "::bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)


def load_pytree(path: str, template):
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    stored = {f.removesuffix("::bf16") for f in data.files}
    expected = {_keystr(kp) for kp, _ in leaves_with_paths}
    if stored != expected:
        missing = sorted(expected - stored)
        extra = sorted(stored - expected)
        raise CheckpointError(
            f"checkpoint {path!r} does not match template tree: "
            f"missing keys {missing}, unexpected keys {extra}")
    out = []
    for kp, leaf in leaves_with_paths:
        key = _keystr(kp)
        if key + "::bf16" in data:
            arr = data[key + "::bf16"].view(jnp.bfloat16)
        else:
            arr = data[key]
        if arr.shape != leaf.shape:
            raise CheckpointError(
                f"checkpoint {path!r}: leaf {key!r} has shape {arr.shape}, "
                f"template expects {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
