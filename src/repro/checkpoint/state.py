"""Crash-safe full-run snapshots (chaos harness).

``repro.checkpoint.checkpoint`` stores a single model pytree; this module
stores everything a *run* needs to resume bit-exactly: per-sim host state
(RNG stream, selector/APT/accounting, forecaster banks, busy clocks),
model + optimizer vectors, the stale-cache rows in their insertion order,
the round counter and — for sweeps — the completed cells' results.

Exactness contract (tests/test_crash_resume.py): snapshots are taken only
at round/chunk boundaries, so a resumed run re-enters the identical
decision sequence — run(2R) == run(R) -> snapshot -> resume(R) bitwise,
for the fused pipeline (any ``rounds_per_dispatch``), the flat per-stage
path and the legacy engine.  Snapshots taken from a *sharded* pipeline
resume on the unsharded one: per-cell results are bit-identical across
meshes (the PR-4/PR-5 invariants), so the resumed half matches the sharded
uninterrupted run too.

Fault plans ride along in the snapshot but are restored **without** their
scheduled crash (``FaultPlan.without_crash``) — resuming a run whose whole
point was to crash would just crash again; corruption faults, which are
part of the compiled program's semantics, are preserved exactly.

Format: one pickle file, written atomically (tmp + ``os.replace``) so a
crash mid-write never corrupts the previous snapshot.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

SNAPSHOT_VERSION = 1


class SnapshotError(ValueError):
    """The snapshot file is missing, unreadable, or from another format."""


def save_snapshot(path: str, payload: dict) -> None:
    """Atomic pickle write: the previous snapshot survives a crash here."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        pickle.dump(payload, fh, protocol=pickle.HIGHEST_PROTOCOL)
    os.replace(tmp, path)


def load_snapshot(path: str) -> dict:
    if not os.path.exists(path):
        raise SnapshotError(f"no snapshot at {path!r}")
    with open(path, "rb") as fh:
        payload = pickle.load(fh)
    if not isinstance(payload, dict) or "version" not in payload:
        raise SnapshotError(f"{path!r} is not a run snapshot")
    if payload["version"] != SNAPSHOT_VERSION:
        raise SnapshotError(
            f"{path!r}: snapshot version {payload['version']} "
            f"(this build reads {SNAPSHOT_VERSION})")
    return payload


# ---------------------------------------------------------------------------
# Serial engine snapshots (per-stage / legacy round loop)
# ---------------------------------------------------------------------------


def engine_snapshot(sim, next_round: int) -> dict:
    """Snapshot a (non-fused) Simulator between rounds; ``next_round`` is
    the first round the resumed loop will run."""
    cfg = sim.cfg
    ps = {
        "cfg": dataclasses.asdict(cfg),
        "state": sim.capture_state(),
        "fault_plan": sim.fault_plan,
    }
    if cfg.fast_path:
        ps["flat_params"] = np.asarray(jax.device_get(sim.flat_params))
        ps["flat_opt_state"] = (
            jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                         sim.flat_opt_state)
            if sim.flat_opt_state is not None else None)
    else:
        ps["params"] = jax.tree.map(np.asarray, sim.params)
        ps["opt_state"] = (jax.tree.map(np.asarray, sim.opt_state)
                           if sim.opt_state is not None else None)
    return {"version": SNAPSHOT_VERSION, "kind": "engine",
            "next_round": int(next_round), "sim": ps}


def save_engine_snapshot(path: str, sim, next_round: int) -> None:
    save_snapshot(path, engine_snapshot(sim, next_round))


def _restore_sim(ps: dict, substrate_cache: Optional[dict] = None):
    """Rebuild one Simulator from its snapshot payload.  The substrate is
    reconstructed deterministically from the config seed (it is never
    stored — it is pure function of ``substrate_key``), then the captured
    mutable state is restored on top."""
    from repro.sim.engine import Simulator, SimConfig, Substrate, substrate_key

    cfg = SimConfig(**ps["cfg"])
    key = substrate_key(cfg)
    if substrate_cache is not None and key in substrate_cache:
        sub = substrate_cache[key]
    else:
        sub = Substrate.build(cfg)
        if substrate_cache is not None:
            substrate_cache[key] = sub
    fp = ps.get("fault_plan")
    if fp is not None:
        fp = fp.without_crash()
    sim = Simulator(cfg, substrate=sub, fault_plan=fp)
    sim.restore_state(ps["state"])
    if cfg.fast_path:
        sim.flat_params = jnp.asarray(ps["flat_params"])
        if ps.get("flat_opt_state") is not None:
            sim.flat_opt_state = jax.tree.map(jnp.asarray,
                                              ps["flat_opt_state"])
    else:
        sim.params = jax.tree.map(jnp.asarray, ps["params"])
        if ps.get("opt_state") is not None:
            sim.opt_state = jax.tree.map(jnp.asarray, ps["opt_state"])
    return sim


# ---------------------------------------------------------------------------
# Fused-pipeline snapshots (built by RoundPipeline.snapshot)
# ---------------------------------------------------------------------------


def build_resumed_pipeline(payload: dict, progress: bool = False,
                           checkpoint_path: Optional[str] = None,
                           checkpoint_every: int = 0, checkpoint_wrap=None,
                           telemetry=None):
    """Reconstruct a RoundPipeline mid-run from a ``kind == "pipeline"``
    snapshot.  Resume always runs unsharded (bit-identical per cell to any
    mesh, so snapshots from sharded runs restore fine); stale rows are
    re-seated into a fresh device cache in their saved order — slot ids
    never affect values.  A ``telemetry`` session logging into the crashed
    run's directory is truncated back to the snapshot's round-log offset,
    so the resumed log byte-continues the uninterrupted run's."""
    from repro.sim.pipeline import RoundPipeline

    sub_cache: dict = {}
    sims = [_restore_sim(ps, sub_cache) for ps in payload["sims"]]
    for sim in sims:
        if sim.cfg.shard_participants:
            # participant-sharded resume would need the (s, p) slot layout
            # restored; clear the flag — results are bit-identical anyway
            sim.cfg = dataclasses.replace(sim.cfg, shard_participants=0)
    if telemetry is not None:
        telemetry.restore(payload.get("telemetry"))
    pipe = RoundPipeline(sims, progress=progress,
                         checkpoint_path=checkpoint_path,
                         checkpoint_every=checkpoint_every,
                         checkpoint_wrap=checkpoint_wrap,
                         start_round=int(payload["next_round"]),
                         telemetry=telemetry,
                         labels=payload.get("labels"))
    pipe.done = list(payload["done"])
    for sim in sims:
        if not sim.stale_cache:
            continue
        rows = np.stack([f.delta for f in sim.stale_cache])
        slots, _ = pipe.cache.alloc(len(sim.stale_cache))
        pipe.cache.put(slots, rows)
        for f, slot in zip(sim.stale_cache, slots):
            f.delta = int(slot)
    return pipe


def resume_run(path: str, progress: bool = False, *,
               checkpoint_path: Optional[str] = None,
               checkpoint_every: int = 0, telemetry=None):
    """Resume a single-simulation run from its snapshot.  Returns the
    finalized Accounting — the same object an uninterrupted
    ``Simulator.run`` yields, bit-identical to it."""
    payload = load_snapshot(path)
    if payload["kind"] == "engine":
        sim = _restore_sim(payload["sim"])
        return sim._run_loop(int(payload["next_round"]), progress,
                             checkpoint_path, checkpoint_every,
                             telemetry=telemetry)
    if payload["kind"] == "pipeline":
        pipe = build_resumed_pipeline(payload, progress=progress,
                                      checkpoint_path=checkpoint_path,
                                      checkpoint_every=checkpoint_every,
                                      telemetry=telemetry)
        return pipe.run()[0] if len(pipe.sims) == 1 else pipe.run()
    raise SnapshotError(f"{path!r}: unknown snapshot kind "
                        f"{payload['kind']!r}")
