from repro.checkpoint.checkpoint import (CheckpointError,  # noqa: F401
                                         load_pytree, save_pytree)
from repro.checkpoint.state import (SnapshotError,  # noqa: F401
                                    build_resumed_pipeline, load_snapshot,
                                    resume_run, save_engine_snapshot,
                                    save_snapshot)
