"""Device-resident stale-update cache (SAA straggler store).

The host-side cache in ``repro.sim.engine`` used to hold each straggler's
flat ``(D,)`` delta as a numpy copy, forcing a device->host copy when the
update was cached and a host->device copy when it landed.  Here the rows
stay on device: a ``(capacity + 1, D)`` fp32 tensor whose last row is a
scratch slot that in-program scatters can target for non-straggler rows,
plus host-side slot accounting (free list + insertion order).  The round
pipeline scatters a round's straggler deltas into their slots and gathers
landing slots straight into the aggregation operand — the delta never
leaves the device.

Slot discipline:

- ``alloc(k)`` reserves ``k`` slots.  With ``grow=True`` (the engine's
  setting) a full cache doubles its capacity — parity with the unbounded
  host-list cache is preserved because nothing is ever dropped.  With
  ``grow=False`` the oldest occupied slots are evicted in insertion order
  (bounded-memory deployments); the evicted slot ids are returned so the
  caller can drop its matching entries.
- ``free(slots)`` releases landed/expired slots for reuse.  Freed slots are
  handed out LIFO; the policy only has to be deterministic — slot choice
  never affects values, because a slot's row is always scatter-written in
  the round its entry is created, before any gather reads it.
- ``valid_mask()`` exposes the occupancy mask over data slots (the scratch
  row is never valid).

Rows are exact: ``put``/``gather`` (the host-facing IO used by tests and
by callers that keep a host cache) move bits unchanged, and the pipeline's
in-program scatter/gather are pure data movement — so aggregation over
cached rows is bit-identical to aggregation over host copies of the same
updates.
"""
from __future__ import annotations

from collections import OrderedDict

import jax.numpy as jnp
import numpy as np


class CacheOverflow(RuntimeError):
    """alloc() on a full, non-growing cache with nothing to evict."""


class DeviceStaleCache:
    def __init__(self, d: int, capacity: int = 64, grow: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.d = int(d)
        self.capacity = int(capacity)
        self.grow = grow
        self.rows = jnp.zeros((self.capacity + 1, self.d), jnp.float32)
        # pop() hands out ascending slot ids for a fresh cache
        self._free = list(range(self.capacity - 1, -1, -1))
        self._order: "OrderedDict[int, int]" = OrderedDict()   # slot -> seq
        self._seq = 0
        self.grow_events = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._order)

    @property
    def trash_slot(self) -> int:
        """The scratch row: scatters for rows that cache nothing land here."""
        return self.capacity

    def occupied(self) -> list:
        """Occupied slot ids in insertion (= eviction) order."""
        return list(self._order)

    def valid_mask(self) -> np.ndarray:
        m = np.zeros(self.capacity, bool)
        occ = list(self._order)
        if occ:
            m[occ] = True
        return m

    # ------------------------------------------------------------------
    def _grow(self):
        old_c = self.capacity
        # the old scratch row (index old_c) becomes data slot old_c; its
        # content is irrelevant because every allocated slot is written
        # before it is read
        self.rows = jnp.concatenate(
            [self.rows, jnp.zeros((old_c, self.d), self.rows.dtype)])
        self.capacity = 2 * old_c
        # existing free slots are consumed before the newly minted ones
        self._free = list(range(self.capacity - 1, old_c - 1, -1)) + self._free
        self.grow_events += 1

    def alloc(self, k: int) -> tuple:
        """Reserve ``k`` slots; returns (slots, evicted_slots).

        ``slots`` are in allocation order.  ``evicted_slots`` is non-empty
        only for a full ``grow=False`` cache: the oldest occupied slots, in
        insertion order, whose entries the caller must drop.
        """
        evicted = []
        while len(self._free) < k:
            if self.grow:
                self._grow()
            elif self._order:
                old, _ = self._order.popitem(last=False)
                evicted.append(old)
                self._free.append(old)
            else:
                raise CacheOverflow(
                    f"need {k} slots, capacity {self.capacity}, nothing to evict")
        slots = []
        for _ in range(k):
            s = self._free.pop()
            self._order[s] = self._seq
            self._seq += 1
            slots.append(s)
        return slots, evicted

    def free(self, slots) -> None:
        for s in slots:
            del self._order[s]          # KeyError on double-free: a real bug
            self._free.append(s)

    # ------------------------------------------------------------------
    # Host-facing row IO (tests, host-cache interop; the round pipeline
    # scatters/gathers in-program instead)
    # ------------------------------------------------------------------
    def put(self, slots, rows) -> None:
        idx = np.asarray(slots, np.int32)
        self.rows = self.rows.at[idx].set(jnp.asarray(rows, jnp.float32))

    def gather(self, slots) -> np.ndarray:
        idx = np.asarray(slots, np.int32)
        return np.asarray(self.rows[idx])
