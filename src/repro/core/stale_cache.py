"""Device-resident stale-update cache (SAA straggler store).

The host-side cache in ``repro.sim.engine`` used to hold each straggler's
flat ``(D,)`` delta as a numpy copy, forcing a device->host copy when the
update was cached and a host->device copy when it landed.  Here the rows
stay on device: a ``(capacity + 1, D)`` fp32 tensor whose last row is a
scratch slot that in-program scatters can target for non-straggler rows,
plus host-side slot accounting (free list + insertion order).  The round
pipeline scatters a round's straggler deltas into their slots and gathers
landing slots straight into the aggregation operand — the delta never
leaves the device.

Slot discipline (one implementation, ``_SlotSpace``, shared by the
single-tensor cache below and the sharded per-shard accounting):

- ``alloc(k)`` reserves ``k`` slots.  With ``grow=True`` (the engine's
  setting) a full cache doubles its capacity — parity with the unbounded
  host-list cache is preserved because nothing is ever dropped.  With
  ``grow=False`` the oldest occupied slots are evicted in insertion order
  (bounded-memory deployments); the evicted slot ids are returned so the
  caller can drop its matching entries.
- ``free(slots)`` releases landed/expired slots for reuse.  Freed slots are
  handed out LIFO; the policy only has to be deterministic — slot choice
  never affects values, because a slot's row is always scatter-written in
  the round its entry is created, before any gather reads it.
- growth appends slots: existing ids stay valid, and the old scratch/trash
  row (index ``old capacity``) becomes a data slot whose stale content is
  irrelevant — every allocated slot is written before it is read.
- ``valid_mask()`` exposes the occupancy mask over data slots (the scratch
  row is never valid).

Rows are exact: ``put``/``gather`` (the host-facing IO used by tests and
by callers that keep a host cache) move bits unchanged, and the pipeline's
in-program scatter/gather are pure data movement — so aggregation over
cached rows is bit-identical to aggregation over host copies of the same
updates.
"""
from __future__ import annotations

from collections import OrderedDict

import jax.numpy as jnp
import numpy as np


class CacheOverflow(RuntimeError):
    """alloc() on a full, non-growing cache with nothing to evict."""


class _SlotSpace:
    """Free-list + insertion-order accounting for one slot space
    ``[0, capacity)`` — the single home of the slot-discipline invariants
    documented in the module docstring."""

    def __init__(self, capacity: int):
        # pop() hands out ascending slot ids for a fresh space
        self.free = list(range(capacity - 1, -1, -1))
        self.order: "OrderedDict[int, int]" = OrderedDict()   # slot -> seq

    def __len__(self) -> int:
        return len(self.order)

    def extend(self, old_capacity: int, new_capacity: int) -> None:
        """Append the minted slot ids; existing free slots are consumed
        before the new ones (they sit deeper in the LIFO free list)."""
        self.free[:0] = range(new_capacity - 1, old_capacity - 1, -1)

    def take(self, seq: int) -> int:
        s = self.free.pop()
        self.order[s] = seq
        return s

    def release(self, slot: int) -> None:
        del self.order[slot]          # KeyError on double-free: a real bug
        self.free.append(slot)

    def pop_oldest(self) -> int:
        old, _ = self.order.popitem(last=False)
        self.free.append(old)
        return old


class DeviceStaleCache:
    def __init__(self, d: int, capacity: int = 64, grow: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.d = int(d)
        self.capacity = int(capacity)
        self.grow = grow
        self.rows = jnp.zeros((self.capacity + 1, self.d), jnp.float32)
        self._space = _SlotSpace(self.capacity)
        self._seq = 0
        self.grow_events = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._space)

    @property
    def trash_slot(self) -> int:
        """The scratch row: scatters for rows that cache nothing land here."""
        return self.capacity

    def occupied(self) -> list:
        """Occupied slot ids in insertion (= eviction) order."""
        return list(self._space.order)

    def valid_mask(self) -> np.ndarray:
        m = np.zeros(self.capacity, bool)
        occ = list(self._space.order)
        if occ:
            m[occ] = True
        return m

    # ------------------------------------------------------------------
    def _grow(self):
        old_c = self.capacity
        # the old scratch row (index old_c) becomes data slot old_c (see
        # the module docstring's growth invariant)
        self.rows = jnp.concatenate(
            [self.rows, jnp.zeros((old_c, self.d), self.rows.dtype)])
        self.capacity = 2 * old_c
        self._space.extend(old_c, self.capacity)
        self.grow_events += 1

    def alloc(self, k: int) -> tuple:
        """Reserve ``k`` slots; returns (slots, evicted_slots).

        ``slots`` are in allocation order.  ``evicted_slots`` is non-empty
        only for a full ``grow=False`` cache: the oldest occupied slots, in
        insertion order, whose entries the caller must drop.
        """
        evicted = []
        while len(self._space.free) < k:
            if self.grow:
                self._grow()
            elif self._space.order:
                evicted.append(self._space.pop_oldest())
            else:
                raise CacheOverflow(
                    f"need {k} slots, capacity {self.capacity}, nothing to evict")
        slots = []
        for _ in range(k):
            slots.append(self._space.take(self._seq))
            self._seq += 1
        return slots, evicted

    def free(self, slots) -> None:
        for s in slots:
            self._space.release(s)

    # ------------------------------------------------------------------
    # Host-facing row IO (tests, host-cache interop; the round pipeline
    # scatters/gathers in-program instead)
    # ------------------------------------------------------------------
    def put(self, slots, rows) -> None:
        idx = np.asarray(slots, np.int32)
        self.rows = self.rows.at[idx].set(jnp.asarray(rows, jnp.float32))

    def gather(self, slots) -> np.ndarray:
        idx = np.asarray(slots, np.int32)
        return np.asarray(self.rows[idx])


class ShardedSlotAccounts:
    """Host-side slot accounting for a *sharded* stale cache.

    The sharded round pipeline keeps the cache rows as one
    ``(n_shards, capacity + 1, D)`` tensor sharded over the leading mesh
    axis; each shard's local slot space ``[0, capacity)`` (plus the local
    scratch row at index ``capacity``) is an independent ``_SlotSpace``.
    ``n_shards`` counts *device* shards: under the 2-D ``("s", "p")``
    round mesh the pipeline runs one slot space per flat ``(s, p)`` shard
    (``n_shards = n_s * n_p``, s-major) — a cell's stragglers live on its
    own sweep shard, partitioned over the participant shards that trained
    them, and the in-program scatter/gather stays shard-local (landings
    rejoin their cell through the aggregation psum).

    Capacity is uniform across shards (the device tensor is rectangular):
    when any shard's allocation outgrows its free list, ``alloc`` doubles
    ``capacity`` for *every* shard and reports it via the returned ``grew``
    flag — the pipeline then rebuilds the device tensor (growth appends
    slots, so existing local slot ids stay valid).  Per-shard discipline
    is ``DeviceStaleCache``'s ``grow=True`` mode: same ``_SlotSpace``,
    nothing evicted.
    """

    def __init__(self, n_shards: int, capacity: int = 64):
        if n_shards < 1 or capacity < 1:
            raise ValueError("n_shards and capacity must be >= 1")
        self.n_shards = int(n_shards)
        self.capacity = int(capacity)
        self._spaces = [_SlotSpace(self.capacity)
                        for _ in range(self.n_shards)]
        self._seq = 0
        self.grow_events = 0

    def __len__(self) -> int:
        return sum(len(sp) for sp in self._spaces)

    @property
    def trash_slot(self) -> int:
        """Each shard's local scratch row index."""
        return self.capacity

    def shard_len(self, shard: int) -> int:
        return len(self._spaces[shard])

    def _grow(self) -> None:
        old_c = self.capacity
        self.capacity = 2 * old_c
        for sp in self._spaces:
            sp.extend(old_c, self.capacity)
        self.grow_events += 1

    def alloc(self, shard: int, k: int) -> tuple:
        """Reserve ``k`` local slots on ``shard``; returns (slots, grew)."""
        grew = False
        while len(self._spaces[shard].free) < k:
            self._grow()
            grew = True
        slots = []
        for _ in range(k):
            slots.append(self._spaces[shard].take(self._seq))
            self._seq += 1
        return slots, grew

    def free(self, shard: int, slots) -> None:
        for s in slots:
            self._spaces[shard].release(s)

    def occupied(self, shard: int) -> list:
        """Occupied local slot ids on ``shard`` in insertion order."""
        return list(self._spaces[shard].order)

    def flat_index(self, shard: int, slot: int) -> int:
        """Row index of (shard, local slot) in the flattened
        ``(n_shards * (capacity + 1), D)`` view of the cache tensor."""
        return shard * (self.capacity + 1) + slot
