"""Adaptive Participant Target (paper §4.1).

mu_t = (1 - alpha) * D_{t-1} + alpha * mu_{t-1}          (EWMA of round duration)
B_t  = |{ s in stragglers : RT_s <= mu_t }|              (stragglers landing in-round)
N_t  = max(1, N_0 - B_t)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass
class AdaptiveParticipantTarget:
    n0: int                    # developer-set participant target
    alpha: float = 0.25        # paper's EWMA weight
    mu: float = 0.0            # running round-duration estimate

    def update_round_duration(self, last_duration: float) -> float:
        if self.mu == 0.0:
            self.mu = last_duration
        else:
            self.mu = (1.0 - self.alpha) * last_duration + self.alpha * self.mu
        return self.mu

    def target(self, straggler_remaining_times: Sequence[float]) -> int:
        b_t = sum(1 for rt in straggler_remaining_times if rt <= self.mu)
        return max(1, self.n0 - b_t)

    @property
    def next_slot(self):
        """The availability-query slot sent to learners at check-in (Alg. 1)."""
        return (self.mu, 2.0 * self.mu)
