"""Compatibility shim: participant selection moved to ``repro.selection``.

The selector zoo lives in ``src/repro/selection/`` (one strategy per
file, registered in ``SELECTOR_TABLE``; see ``docs/extending.md``).  This
module re-exports the pre-zoo names so existing imports — and pickled
checkpoints referencing the old classes — keep working.
"""
from repro.selection import (LearnerView, OortSelector,  # noqa: F401
                             PrioritySelector, RandomSelector, SafaSelector,
                             Selector, SELECTORS)
