"""Participant selection strategies.

- RandomSelector: uniform sampling (FedAvg default; Bonawitz et al., 2019)
- OortSelector: utility-guided selection (Lai et al., OSDI'21) — statistical
  utility (loss proxy) x system utility (completion-time penalty), with
  epsilon-greedy exploration and a pacer that trades round duration for
  statistical efficiency.
- PrioritySelector: RELAY's IPS (Alg. 1) — least-available-first with tie
  shuffling and a post-participation hold-off.
- SafaSelector: SAFA (Wu et al., 2021) — selects *all* available learners;
  the round ends when a target fraction reports (handled by the engine).

Selectors are host-side policy objects; they see per-learner metadata via a
``LearnerView`` and return participant id lists.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class LearnerView:
    """What the server may know about a checked-in learner."""
    learner_id: int
    availability_prob: float = 1.0   # learner-reported P(available in [mu, 2mu])
    last_stat_util: float = 0.0      # |B_i| * sqrt(mean loss^2) from last participation
    est_duration: float = 0.0        # estimated on-device round time (seconds)
    explored: bool = False           # has participated before


class Selector:
    name = "base"
    # Selectors that ignore availability forecasts / utilities set this False
    # and implement ``select_ids``; the engine then skips building LearnerViews
    # (and the forecaster window queries behind them) on the hot path.  The
    # queries are pure reads, so skipping them never changes forecaster state
    # or the RNG stream — selection is bit-identical either way.
    needs_views = True

    def select(self, round_idx: int, checked_in: Sequence[LearnerView],
               n_target: int, rng: np.random.Generator) -> List[int]:
        raise NotImplementedError

    def select_ids(self, round_idx: int, ids, n_target: int,
                   rng: np.random.Generator) -> List[int]:
        """View-free selection for ``needs_views = False`` selectors; ``ids``
        is the checked-in learner ids in ascending order."""
        raise NotImplementedError

    def update_feedback(self, learner_id: int, *, stat_util: float = None,
                        duration: float = None, round_idx: int = None):
        """Post-round feedback hook (Oort utilities, hold-offs...)."""


class RandomSelector(Selector):
    name = "random"
    needs_views = False

    def select_ids(self, round_idx, ids, n_target, rng):
        if len(ids) <= n_target:
            return list(ids)
        # rng.choice consumes the same stream for a list or an array of the
        # same length, so the two entry points draw identical cohorts
        return list(rng.choice(ids, size=n_target, replace=False))

    def select(self, round_idx, checked_in, n_target, rng):
        return self.select_ids(round_idx, [v.learner_id for v in checked_in],
                               n_target, rng)


class SafaSelector(Selector):
    """SAFA flips selection: every available learner trains every round."""
    name = "safa"
    needs_views = False

    def select_ids(self, round_idx, ids, n_target, rng):
        return list(ids)

    def select(self, round_idx, checked_in, n_target, rng):
        return [v.learner_id for v in checked_in]


class PrioritySelector(Selector):
    """RELAY IPS (Alg. 1): sort availability probabilities ascending, shuffle
    ties, take the top n_target. Participants then hold off from checking in
    for ``holdoff`` rounds (Bonawitz et al., 2019 pacing)."""
    name = "priority"

    def __init__(self, holdoff: int = 5):
        self.holdoff = holdoff
        self._held_until: Dict[int, int] = {}

    def select(self, round_idx, checked_in, n_target, rng):
        eligible = [v for v in checked_in
                    if self._held_until.get(v.learner_id, -1) < round_idx]
        if not eligible:
            eligible = list(checked_in)
        # ascending availability; random shuffle breaks ties (Alg. 1)
        jitter = rng.random(len(eligible))
        order = sorted(range(len(eligible)),
                       key=lambda i: (eligible[i].availability_prob, jitter[i]))
        chosen = [eligible[i].learner_id for i in order[:n_target]]
        for lid in chosen:
            self._held_until[lid] = round_idx + self.holdoff
        return chosen


class OortSelector(Selector):
    """Oort (Lai et al., OSDI'21), faithful to its core mechanics:

    util(i) = stat_util(i) * (T_pref / t_i)^alpha  if t_i > T_pref else stat_util(i)

    with epsilon-greedy exploration of never-selected learners (epsilon decays
    0.9 -> 0.2) and a pacer that raises T_pref by ``pacer_delta`` when the
    aggregate utility of selected participants stalls.
    """
    name = "oort"

    def __init__(self, alpha: float = 2.0, pacer_delta: float = 10.0,
                 pacer_window: int = 20, eps0: float = 0.9, eps_min: float = 0.2,
                 eps_decay: float = 0.98):
        self.alpha = alpha
        self.pacer_delta = pacer_delta
        self.pacer_window = pacer_window
        self.eps = eps0
        self.eps_min = eps_min
        self.eps_decay = eps_decay
        self.t_pref = None            # preferred round duration, set lazily
        self._util_history: List[float] = []
        self._stat_util: Dict[int, float] = {}
        self._duration: Dict[int, float] = {}

    def _utility(self, v: LearnerView) -> float:
        stat = self._stat_util.get(v.learner_id, v.last_stat_util)
        dur = self._duration.get(v.learner_id, v.est_duration) or 1.0
        if self.t_pref is not None and dur > self.t_pref:
            stat *= (self.t_pref / dur) ** self.alpha
        return stat

    def select(self, round_idx, checked_in, n_target, rng):
        if self.t_pref is None:
            durs = [v.est_duration for v in checked_in if v.est_duration > 0]
            self.t_pref = float(np.percentile(durs, 50)) if durs else 100.0
        explored = [v for v in checked_in if v.learner_id in self._stat_util]
        unexplored = [v for v in checked_in if v.learner_id not in self._stat_util]
        n_explore = int(round(self.eps * n_target))
        n_exploit = n_target - n_explore

        exploit_order = sorted(explored, key=self._utility, reverse=True)
        chosen = [v.learner_id for v in exploit_order[:n_exploit]]
        # exploration favors fast unexplored learners (Oort's speed heuristic)
        unexplored.sort(key=lambda v: v.est_duration or 1e9)
        chosen += [v.learner_id for v in unexplored[:n_target - len(chosen)]]
        if len(chosen) < n_target:  # backfill from remaining explored
            rest = [v.learner_id for v in exploit_order[n_exploit:]
                    if v.learner_id not in chosen]
            chosen += rest[:n_target - len(chosen)]
        self.eps = max(self.eps_min, self.eps * self.eps_decay)

        # pacer: if utility over the last window stalls, relax T_pref
        window_util = sum(self._utility(v) for v in checked_in
                          if v.learner_id in chosen)
        self._util_history.append(window_util)
        h = self._util_history
        if len(h) >= 2 * self.pacer_window:
            recent = sum(h[-self.pacer_window:])
            prev = sum(h[-2 * self.pacer_window:-self.pacer_window])
            if recent <= prev:
                self.t_pref += self.pacer_delta
                self._util_history = h[-self.pacer_window:]
        return chosen[:n_target]

    def update_feedback(self, learner_id, *, stat_util=None, duration=None,
                        round_idx=None):
        if stat_util is not None:
            self._stat_util[learner_id] = stat_util
        if duration is not None:
            self._duration[learner_id] = duration


SELECTORS = {
    "random": RandomSelector,
    "oort": OortSelector,
    "priority": PrioritySelector,
    "safa": SafaSelector,
}
