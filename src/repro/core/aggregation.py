"""Stale-Synchronous FedAvg aggregation (paper Alg. 2) over parameter pytrees.

The server receives participant deltas (possibly delayed by tau rounds),
computes SAA coefficients (``repro.core.staleness``), and produces the weighted
aggregate that the server optimizer applies to the global model.

Two code paths:
- pytree path (host-side FL simulation; arbitrary structures),
- stacked-flat path (on-mesh training; feeds the fused Pallas kernel).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.staleness import (RULE_ID, staleness_weights,
                                  staleness_weights_by_id)


# ---------------------------------------------------------------------------
# Flatten helpers
# ---------------------------------------------------------------------------


def flatten_update(tree):
    """Pytree -> (flat fp32 vector, treedef+shapes for unflatten)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes, [l.dtype for l in leaves])


def make_flat_spec(tree):
    """Flatten spec (treedef, shapes, dtypes, offsets) without moving data.

    Compute once per model; reuse for every ``unflatten_update`` of the run —
    the flat fast path's round loop never re-derives it.  All-tuple (and thus
    hashable), so jitted helpers can be cached per spec across instances.

    ``offsets`` holds each leaf's start position in the flat vector plus a
    final total-D sentinel: ``flat[offsets[i]:offsets[i+1]]`` is leaf ``i``'s
    segment — the layer-blocked view large-D models (the LM zoo) and the
    D-blocked aggregation layout slice by.  Consumers that predate the
    offsets unpack ``spec[:3]``; a flat vector longer than ``offsets[-1]``
    is treated as block-padded and the tail is ignored.
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(l.shape for l in leaves)
    offs, off = [], 0
    for s in shapes:
        offs.append(off)
        off += int(np.prod(s)) if s else 1
    return (treedef, shapes, tuple(l.dtype for l in leaves),
            tuple(offs) + (off,))


def flat_dim(spec) -> int:
    """Total flat vector length D for a spec from ``make_flat_spec``."""
    if len(spec) > 3:
        return int(spec[3][-1])
    _, shapes, _ = spec
    return int(sum(int(np.prod(s)) if s else 1 for s in shapes))


def unflatten_update(flat, spec):
    treedef, shapes, dtypes = spec[0], spec[1], spec[2]
    leaves, off = [], 0
    for shp, dt in zip(shapes, dtypes):
        n = int(np.prod(shp)) if shp else 1
        leaves.append(flat[off:off + n].reshape(shp).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------


def aggregate_updates(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """stacked: (n, D), weights: (n,) normalized -> (D,)."""
    return jnp.einsum("n,nd->d", weights, stacked)


def _waa(stacked, fresh, tau, valid, beta, *, rule):
    w = staleness_weights(stacked, fresh, tau, rule=rule, beta=beta, valid=valid)
    return aggregate_updates(stacked, w), w


_weights_and_aggregate = jax.jit(_waa, static_argnames=("rule",))


def _waa_by_id(stacked, fresh, tau, valid, beta, rule_id):
    w = staleness_weights_by_id(stacked, fresh, tau, rule_id, beta=beta,
                                valid=valid)
    return aggregate_updates(stacked, w), w


# the per-cell weights+aggregate unit the device-resident round pipeline
# vmaps inside its fused round program (repro.sim.pipeline); same code the
# batched sweep program below runs, so both paths share one set of numerics
weights_and_aggregate_by_id = _waa_by_id


@jax.jit
def _sweep_weights_and_aggregate(stacked, fresh, tau, valid, beta, rule_id):
    """vmap of the per-round weights+aggregate program over a leading sweep
    axis: stacked (S, n, D), masks (S, n), beta (S,), rule_id (S,) ->
    ((S, D), (S, n)).  The scaling rule is a traced per-cell operand
    (``lax.switch``), so cells mixing rules share this one compiled program;
    each cell's slice is bit-identical to the unbatched static-rule program
    on the same rows (rows are independent under vmap)."""
    return jax.vmap(_waa_by_id)(stacked, fresh, tau, valid, beta, rule_id)


def bucket_pow2(n: int) -> int:
    """Next power of two — the participant-axis padding bucket shared by the
    compiled aggregation path, the kernel path, and the engine's cohort
    padding (one compiled program per bucket, not per exact count)."""
    return 1 << (n - 1).bit_length()


def bucket_block(n: int, block: int) -> int:
    """Two-tier padding bucket: power-of-two up to ``block``, then multiples
    of ``block``.  Large axes (SAFA-style cohorts, sweep-packed rows) land
    within ``block - 1`` wasted slots instead of pow2's up-to-2x overshoot,
    while the number of distinct compiled shapes stays small.  Padding is
    masked/discarded everywhere, so bucket choice never affects results."""
    if n <= block:
        return bucket_pow2(n)
    return block * ((n + block - 1) // block)


def bucket_pad(updates, fresh, tau, *, bucketed: bool = True,
               lane_block: int = 0):
    """Host-side (numpy) padding of a round's updates for a compiled program.

    Pads the participant axis to ``bucket_pow2(n)`` zero rows (skipped when
    ``bucketed=False``) and, when ``lane_block`` > 0, the feature axis up to
    the next multiple of it.  Returns (updates, fresh, tau, valid) numpy
    arrays; ``valid`` masks the real rows.  Shared by the jnp fast path and
    the Pallas kernel wrappers so both pad identically.
    """
    n, D = np.shape(updates)
    m = bucket_pow2(n) if bucketed else n
    Dp = D + ((-D) % lane_block) if lane_block else D
    u = np.zeros((m, Dp), np.float32)
    u[:n, :D] = np.asarray(updates)
    fr = np.zeros(m, bool)
    fr[:n] = np.asarray(fresh)
    ta = np.zeros(m, np.int32)
    ta[:n] = np.asarray(tau)
    valid = np.arange(m) < n
    return u, fr, ta, valid


def stale_synchronous_aggregate_flat(stacked, fresh, tau, *, rule: str = "relay",
                                     beta: float = 0.35, use_kernel: bool = False,
                                     compiled: bool = True):
    """Aggregate already-stacked flat updates — the round engine's hot path.

    stacked: (n, D) fp32 rows (one per fresh/stale update); fresh: (n,) bool;
    tau: (n,) int staleness. Returns (aggregate (D,), weights (n,)).
    No per-update pytree traversal happens here: callers keep updates as flat
    rows from training to aggregation and unflatten once per round.

    ``compiled=True`` pads the participant axis to a power-of-two bucket
    (zero rows, masked out via ``staleness_weights``'s ``valid`` mask) and
    runs one jitted weights+aggregate program — without the bucketing, every
    new fresh+stale count would trigger a fresh XLA compile of the eager ops,
    which dominates the server step at scale.  ``compiled=False`` keeps the
    seed's unpadded eager evaluation (benchmark baseline).
    """
    n = np.shape(stacked)[0]
    if use_kernel:
        from repro.kernels.staleness_agg import ops as agg_ops
        return agg_ops.staleness_aggregate(stacked, fresh, tau, rule=rule,
                                           beta=beta, bucketed=compiled)
    if not compiled:
        stacked = jnp.asarray(stacked, jnp.float32)
        weights = staleness_weights(stacked, jnp.asarray(fresh, bool),
                                    jnp.asarray(tau, jnp.int32),
                                    rule=rule, beta=beta)
        return aggregate_updates(stacked, weights), weights
    # pad on host (numpy) — eager jnp.pad would itself compile per shape; the
    # single device transfer happens at the jit boundary below
    u, fr, ta, valid = bucket_pad(stacked, fresh, tau)
    agg, w = _weights_and_aggregate(u, fr, ta, valid, np.float32(beta),
                                    rule=rule)
    return agg, w[:n]


def sweep_bucket_pad(cell_updates, d: int):
    """Pad a sweep round's per-cell update stacks to one (S, n_b, D) tensor.

    cell_updates: length-S list; entry ``s`` is either ``None`` (no updates
    this round — the cell contributes all-invalid rows and a zero aggregate)
    or ``(rows, fresh, tau)`` with ``rows`` a list of (D,) fp32 vectors.
    The participant axis is padded to one shared ``bucket_block(n, 32)``
    bucket (power-of-two up to 32 slots, then multiples of 32) so the whole
    sweep reuses a compiled program per bucket; aggregation is
    padding-invariant (zero rows are masked by ``valid`` and contribute
    exact zeros to every reduction), so each cell's result is bit-identical
    to padding it to its own bucket.

    Returns numpy (U (S, n_b, d), fresh (S, n_b), tau (S, n_b),
    valid (S, n_b), has (S,)).
    """
    s_total = len(cell_updates)
    n_max = max([len(c[0]) for c in cell_updates if c is not None] + [1])
    n_b = bucket_block(n_max, 32)
    u = np.zeros((s_total, n_b, d), np.float32)
    fresh = np.zeros((s_total, n_b), bool)
    tau = np.zeros((s_total, n_b), np.int32)
    valid = np.zeros((s_total, n_b), bool)
    has = np.zeros(s_total, bool)
    for s, cell in enumerate(cell_updates):
        if cell is None:
            continue
        rows, fr, ta = cell
        n = len(rows)
        u[s, :n] = np.stack(rows)
        fresh[s, :n] = fr
        tau[s, :n] = ta
        valid[s, :n] = True
        has[s] = True
    return u, fresh, tau, valid, has


def sweep_aggregate_flat(stacked, fresh, tau, valid, beta, *,
                         rule="relay", use_kernel: bool = False):
    """SAA-aggregate S simulations' rounds in one batched program.

    stacked: (S, n, D) fp32 (typically from ``sweep_bucket_pad``); fresh/tau/
    valid: (S, n); beta: (S,) per-cell Eq. 2 averaging weights; ``rule`` is
    one rule name or a length-S sequence — mixed rules run in the same
    compiled program (per-cell ``lax.switch``).  Returns (aggregate (S, D),
    weights (S, n)).  ``use_kernel`` routes through the sweep-axis fused
    Pallas kernel (``kernels.staleness_agg``), which is compiled per rule
    and therefore requires a uniform one.  All-invalid cells produce an
    exactly-zero aggregate row (their weights normalize to 0).
    """
    s = np.shape(stacked)[0]
    rules = [rule] * s if isinstance(rule, str) else list(rule)
    if use_kernel:
        if len(set(rules)) != 1:
            raise ValueError("the sweep kernel is compiled per scaling rule; "
                             f"got mixed rules {sorted(set(rules))}")
        from repro.kernels.staleness_agg import ops as agg_ops
        return agg_ops.sweep_staleness_aggregate(stacked, fresh, tau,
                                                 valid=valid, rule=rules[0],
                                                 beta=beta)
    rule_id = np.array([RULE_ID[r] for r in rules], np.int32)
    return _sweep_weights_and_aggregate(
        stacked, np.asarray(fresh), np.asarray(tau), np.asarray(valid),
        np.asarray(beta, np.float32), rule_id)


# ---------------------------------------------------------------------------
# Guarded aggregation (chaos harness: screen rows before they are weighted)
# ---------------------------------------------------------------------------


def screen_rows(u, valid, *, clip=None, reject_mult=None, norm_d=None):
    """In-program screening of an update operand ``u`` (..., n, D).

    The one screening formula every guarded aggregation path runs — the
    engine's flat/legacy paths, the batched sweep program, and the fused
    round body — so rejection decisions are identical across substrates.
    Three screens, in order:

      1. non-finite reject: any NaN/Inf element invalidates the row;
      2. norm-outlier reject (``reject_mult``): rows whose squared L2 norm
         exceeds ``reject_mult**2`` times the median surviving squared norm;
      3. norm clip (``clip``): surviving rows are rescaled to L2 norm
         ``clip`` when they exceed it.

    Rejected rows are *zeroed*, not merely mask-flagged: deviation scores
    and the weighted einsum read every row downstream, and ``0 * NaN``
    would reintroduce the poison.  With all rows finite and ``clip`` /
    ``reject_mult`` inactive, the output is a bit-exact select of ``u``
    (``jnp.where`` under an all-true mask) — the guards-on/no-faults
    bit-parity guarantee rests on this.

    valid: (..., n) bool masking real rows (padding screens as invalid but
    is not counted).  Returns ``(u_screened, valid_out, n_nonfinite,
    n_norm_rejected, n_clipped)`` with int32 counts summed over the row
    axis.

    ``norm_d`` (D-blocked layouts): the finite test and the squared norms
    reduce over the leading ``norm_d`` columns only, so a block-padded
    operand screens bit-identically to its true-D slice (reducing across
    the appended zero columns would repartition the reduction and move
    bits); the clip rescale and the zeroing still apply to the full row.
    """
    u = jnp.asarray(u, jnp.float32)
    valid = jnp.asarray(valid, bool)
    u_t = u if norm_d is None else u[..., :norm_d]
    finite = jnp.isfinite(u_t).all(axis=-1)
    v1 = valid & finite
    n_nf = (valid & ~finite).sum(axis=-1).astype(jnp.int32)
    # rejected/padded rows get +inf norms: they sort last and never reach
    # the median index, which counts only surviving rows
    n2 = jnp.where(v1, jnp.sum(u_t * u_t, axis=-1), jnp.inf)
    if reject_mult is not None:
        srt = jnp.sort(n2, axis=-1)
        idx = jnp.maximum(v1.sum(axis=-1) - 1, 0) // 2
        med = jnp.take_along_axis(srt, idx[..., None], axis=-1)[..., 0]
        out = v1 & (n2 > (np.float32(reject_mult) ** 2) * med[..., None])
        v2 = v1 & ~out
        n_out = out.sum(axis=-1).astype(jnp.int32)
    else:
        v2 = v1
        n_out = jnp.zeros_like(n_nf)
    if clip is not None:
        c2 = np.float32(clip) * np.float32(clip)
        hit = v2 & (n2 > c2)
        scale = jnp.where(hit, np.float32(clip) / jnp.sqrt(n2),
                          jnp.float32(1.0))
        u = u * scale[..., None]
        n_clip = hit.sum(axis=-1).astype(jnp.int32)
    else:
        n_clip = jnp.zeros_like(n_nf)
    u = jnp.where(v2[..., None], u, 0.0)
    return u, v2, n_nf, n_out, n_clip


@functools.lru_cache(maxsize=16)
def _screen_fn(clip, reject_mult):
    return jax.jit(functools.partial(screen_rows, clip=clip,
                                     reject_mult=reject_mult))


def guarded_aggregate_flat(stacked, fresh, tau, *, rule: str = "relay",
                           beta: float = 0.35, use_kernel: bool = False,
                           compiled: bool = True, clip=None, reject_mult=None,
                           quorum: int = 1):
    """Screened, quorum-checked ``stale_synchronous_aggregate_flat``.

    Returns ``(agg (D,), weights (n,), info)`` where ``info`` holds the
    rejected-row counts (``nonfinite`` / ``norm``), ``clipped``,
    ``survivors``, and ``applied`` — False when survivors fall below
    ``quorum``, in which case the caller must carry params unchanged.

    When nothing is rejected or clipped, the call routes through the
    unguarded ``stale_synchronous_aggregate_flat`` with the caller's exact
    arguments, so guards-on/no-faults is bit-identical to guards-off on
    every route — including the Pallas kernel, which has no row-validity
    input and therefore only ever serves this clean case; screened
    aggregation always runs the jitted masked program.
    """
    n = int(np.shape(stacked)[0])
    u, fr, ta, valid = bucket_pad(stacked, fresh, tau, bucketed=compiled)
    u2, v2, n_nf, n_out, n_clip = _screen_fn(clip, reject_mult)(u, valid)
    n_nf = int(jax.device_get(n_nf))
    n_out = int(jax.device_get(n_out))
    n_clip = int(jax.device_get(n_clip))
    survivors = int(jax.device_get(v2.sum()))
    applied = survivors >= max(int(quorum), 1)
    info = {"nonfinite": n_nf, "norm": n_out, "clipped": n_clip,
            "survivors": survivors, "applied": applied}
    if n_nf == 0 and n_out == 0 and n_clip == 0:
        agg, w = stale_synchronous_aggregate_flat(
            stacked, fresh, tau, rule=rule, beta=beta,
            use_kernel=use_kernel, compiled=compiled)
        return agg, w, info
    agg, w = _weights_and_aggregate(u2, np.asarray(fr), np.asarray(ta),
                                    v2, np.float32(beta), rule=rule)
    return agg, w[:n], info


def stale_synchronous_aggregate(update_trees: Sequence, fresh: Sequence[bool],
                                tau: Sequence[int], *, rule: str = "relay",
                                beta: float = 0.35, use_kernel: bool = False,
                                compiled: bool = False):
    """Aggregate a round's fresh + stale update pytrees into a single delta tree.

    Thin wrapper over ``stale_synchronous_aggregate_flat`` for callers that
    still hold pytrees. Returns (aggregate_tree, weights).  Defaults to the
    eager (seed) evaluation: the stack lives on device here, and the compiled
    path's host-side bucket padding would force a device round trip — flat-row
    callers on the hot loop pass host arrays and default to ``compiled=True``.
    """
    assert len(update_trees) > 0
    flats, spec = [], None
    for t in update_trees:
        f, spec = flatten_update(t)
        flats.append(f)
    stacked = jnp.stack(flats)  # (n, D)
    agg, weights = stale_synchronous_aggregate_flat(
        stacked, fresh, tau, rule=rule, beta=beta, use_kernel=use_kernel,
        compiled=compiled)
    return unflatten_update(agg, spec), weights


# ---------------------------------------------------------------------------
# Server optimizers (operate on the aggregated delta)
# ---------------------------------------------------------------------------


def fedavg_apply(params, delta, server_lr: float = 1.0):
    """x_{t+1} = x_t + lr * Delta  (McMahan et al., 2017)."""
    return jax.tree.map(lambda p, d: (p.astype(jnp.float32)
                                      + server_lr * d.astype(jnp.float32)
                                      ).astype(p.dtype), params, delta)


def yogi_init(params):
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": z, "v": jax.tree.map(lambda p: jnp.full(p.shape, 1e-6, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32)}


def yogi_apply(params, delta, state, *, lr=1e-2, b1=0.9, b2=0.99, eps=1e-3):
    """Federated YoGi (Reddi et al. / Ramaswamy et al., 2020).

    v <- v - (1-b2) * d^2 * sign(v - d^2)   (YoGi's additive variant of Adam)
    """
    m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d.astype(jnp.float32),
                     state["m"], delta)
    v = jax.tree.map(
        lambda v_, d: v_ - (1 - b2) * jnp.square(d.astype(jnp.float32))
        * jnp.sign(v_ - jnp.square(d.astype(jnp.float32))), state["v"], delta)
    new_params = jax.tree.map(
        lambda p, m_, v_: (p.astype(jnp.float32)
                           + lr * m_ / (jnp.sqrt(v_) + eps)).astype(p.dtype),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": state["t"] + 1}


def yogi_init_flat(d: int):
    """YoGi state over a flat (D,) parameter vector (fast-path server)."""
    return {"m": jnp.zeros((d,), jnp.float32),
            "v": jnp.full((d,), 1e-6, jnp.float32),
            "t": jnp.zeros((), jnp.int32)}


def yogi_apply_flat(flat_params, delta, state, *, lr=1e-2, b1=0.9, b2=0.99,
                    eps=1e-3):
    """``yogi_apply`` on flat fp32 vectors — same elementwise formulas, so the
    values match the pytree version bit-for-bit; vmappable over a leading
    sweep axis."""
    m = b1 * state["m"] + (1 - b1) * delta
    d2 = jnp.square(delta)
    v = state["v"] - (1 - b2) * d2 * jnp.sign(state["v"] - d2)
    new = flat_params + lr * m / (jnp.sqrt(v) + eps)
    return new, {"m": m, "v": v, "t": state["t"] + 1}
