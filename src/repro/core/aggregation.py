"""Stale-Synchronous FedAvg aggregation (paper Alg. 2) over parameter pytrees.

The server receives participant deltas (possibly delayed by tau rounds),
computes SAA coefficients (``repro.core.staleness``), and produces the weighted
aggregate that the server optimizer applies to the global model.

Two code paths:
- pytree path (host-side FL simulation; arbitrary structures),
- stacked-flat path (on-mesh training; feeds the fused Pallas kernel).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.staleness import staleness_weights


# ---------------------------------------------------------------------------
# Flatten helpers
# ---------------------------------------------------------------------------


def flatten_update(tree):
    """Pytree -> (flat fp32 vector, treedef+shapes for unflatten)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes, [l.dtype for l in leaves])


def unflatten_update(flat, spec):
    treedef, shapes, dtypes = spec
    leaves, off = [], 0
    for shp, dt in zip(shapes, dtypes):
        n = int(np.prod(shp)) if shp else 1
        leaves.append(flat[off:off + n].reshape(shp).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------


def aggregate_updates(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """stacked: (n, D), weights: (n,) normalized -> (D,)."""
    return jnp.einsum("n,nd->d", weights, stacked)


def stale_synchronous_aggregate(update_trees: Sequence, fresh: Sequence[bool],
                                tau: Sequence[int], *, rule: str = "relay",
                                beta: float = 0.35, use_kernel: bool = False):
    """Aggregate a round's fresh + stale update pytrees into a single delta tree.

    Returns (aggregate_tree, weights) — weights exposed for accounting/tests.
    """
    assert len(update_trees) > 0
    flats, spec = [], None
    for t in update_trees:
        f, spec = flatten_update(t)
        flats.append(f)
    stacked = jnp.stack(flats)  # (n, D)
    fresh_arr = jnp.asarray(fresh, bool)
    tau_arr = jnp.asarray(tau, jnp.int32)
    if use_kernel:
        from repro.kernels.staleness_agg import ops as agg_ops
        agg, weights = agg_ops.staleness_aggregate(stacked, fresh_arr, tau_arr,
                                                   rule=rule, beta=beta)
    else:
        weights = staleness_weights(stacked, fresh_arr, tau_arr, rule=rule, beta=beta)
        agg = aggregate_updates(stacked, weights)
    return unflatten_update(agg, spec), weights


# ---------------------------------------------------------------------------
# Server optimizers (operate on the aggregated delta)
# ---------------------------------------------------------------------------


def fedavg_apply(params, delta, server_lr: float = 1.0):
    """x_{t+1} = x_t + lr * Delta  (McMahan et al., 2017)."""
    return jax.tree.map(lambda p, d: (p.astype(jnp.float32)
                                      + server_lr * d.astype(jnp.float32)
                                      ).astype(p.dtype), params, delta)


def yogi_init(params):
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": z, "v": jax.tree.map(lambda p: jnp.full(p.shape, 1e-6, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32)}


def yogi_apply(params, delta, state, *, lr=1e-2, b1=0.9, b2=0.99, eps=1e-3):
    """Federated YoGi (Reddi et al. / Ramaswamy et al., 2020).

    v <- v - (1-b2) * d^2 * sign(v - d^2)   (YoGi's additive variant of Adam)
    """
    m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d.astype(jnp.float32),
                     state["m"], delta)
    v = jax.tree.map(
        lambda v_, d: v_ - (1 - b2) * jnp.square(d.astype(jnp.float32))
        * jnp.sign(v_ - jnp.square(d.astype(jnp.float32))), state["v"], delta)
    new_params = jax.tree.map(
        lambda p, m_, v_: (p.astype(jnp.float32)
                           + lr * m_ / (jnp.sqrt(v_) + eps)).astype(p.dtype),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": state["t"] + 1}
