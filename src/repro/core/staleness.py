"""SAA weight-scaling rules (paper §4.2.4, Eq. 2).

Given a round's fresh updates F and stale updates S (delayed tau_s rounds):

  Equal :  w_s = 1
  DynSGD:  w_s = 1 / (tau_s + 1)                    (Jiang et al., 2017)
  AdaSGD:  w_s = exp(-(tau_s + 1))                  (Damaskinos et al., 2020)
  RELAY :  w_s = (1-beta)/(tau_s+1) + beta * (1 - exp(-Lam_s / Lam_max))   (Eq. 2)

with the privacy-preserving deviation score
  Lam_s = || u_hat_F - (u_s + n_F u_hat_F) / (n_F + 1) ||^2 / || u_hat_F ||^2.

Fresh updates always get w_f = 1; the final coefficients are w_i / sum_j w_j.

All functions are jittable over *stacked flat* updates ``U (n, D)`` with a
boolean ``fresh`` mask — this is the oracle for the fused Pallas kernel in
``repro.kernels.staleness_agg``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-12


def fresh_average(updates: jnp.ndarray, fresh: jnp.ndarray) -> jnp.ndarray:
    """updates: (n, D); fresh: (n,) bool. Returns u_hat_F (D,) (zeros if no fresh)."""
    n_f = fresh.sum()
    s = jnp.where(fresh[:, None], updates, 0.0).sum(axis=0)
    return s / jnp.maximum(n_f, 1)


def deviation_scores(updates: jnp.ndarray, fresh: jnp.ndarray) -> jnp.ndarray:
    """Lam_s per update (Eq. 2 numerator/denominator); 0 for fresh entries."""
    u_hat = fresh_average(updates, fresh)
    n_f = fresh.sum().astype(updates.dtype)
    mixed = (updates + n_f * u_hat[None, :]) / (n_f + 1.0)
    num = jnp.sum((u_hat[None, :] - mixed) ** 2, axis=-1)
    den = jnp.sum(u_hat ** 2) + EPS
    lam = num / den
    return jnp.where(fresh, 0.0, lam)


def _rule_equal(tau, lam, lam_max, beta):
    return jnp.ones_like(tau, dtype=jnp.float32)


def _rule_dynsgd(tau, lam, lam_max, beta):
    return 1.0 / (tau.astype(jnp.float32) + 1.0)


def _rule_adasgd(tau, lam, lam_max, beta):
    return jnp.exp(-(tau.astype(jnp.float32) + 1.0))


def _rule_relay(tau, lam, lam_max, beta):
    damp = 1.0 / (tau.astype(jnp.float32) + 1.0)
    boost = 1.0 - jnp.exp(-lam / jnp.maximum(lam_max, EPS))
    return (1.0 - beta) * damp + beta * boost


SCALING_RULES = {
    "equal": _rule_equal,
    "dynsgd": _rule_dynsgd,
    "adasgd": _rule_adasgd,
    "relay": _rule_relay,
}

# stable rule indexing for traced rule selection (``staleness_weights_by_id``)
RULE_ORDER = tuple(SCALING_RULES)
RULE_ID = {r: i for i, r in enumerate(RULE_ORDER)}


def staleness_weights(updates: jnp.ndarray, fresh: jnp.ndarray, tau: jnp.ndarray,
                      *, rule: str = "relay", beta: float = 0.35,
                      valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Normalized aggregation coefficients w_hat (n,).

    updates: (n, D) flat updates; fresh: (n,) bool; tau: (n,) staleness in rounds
    (0 for fresh); valid: optional (n,) mask for padded slots.
    """
    if valid is None:
        valid = jnp.ones_like(fresh)
    lam = deviation_scores(updates, fresh & valid)
    stale_mask = (~fresh) & valid
    lam_max = jnp.max(jnp.where(stale_mask, lam, 0.0))
    w_stale = SCALING_RULES[rule](tau, lam, lam_max, beta)
    w = jnp.where(fresh, 1.0, w_stale)
    w = jnp.where(valid, w, 0.0)
    return w / jnp.maximum(w.sum(), EPS)


def staleness_weights_by_id(updates, fresh, tau, rule_id, *, beta=0.35,
                            valid=None):
    """``staleness_weights`` with the scaling rule as a *traced* operand.

    ``rule_id`` indexes ``RULE_ORDER`` and selects the rule via
    ``lax.switch``, so a sweep can mix scaling rules across its cells inside
    one compiled program.  The selected branch is the same rule function the
    static path calls — per-cell results are bit-identical to
    ``staleness_weights(..., rule=RULE_ORDER[rule_id])``.
    """
    if valid is None:
        valid = jnp.ones_like(fresh)
    lam = deviation_scores(updates, fresh & valid)
    stale_mask = (~fresh) & valid
    lam_max = jnp.max(jnp.where(stale_mask, lam, 0.0))
    w_stale = jax.lax.switch(rule_id, [SCALING_RULES[r] for r in RULE_ORDER],
                             tau, lam, lam_max, beta)
    w = jnp.where(fresh, 1.0, w_stale)
    w = jnp.where(valid, w, 0.0)
    return w / jnp.maximum(w.sum(), EPS)
