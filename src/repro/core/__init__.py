"""RELAY core: the paper's contribution.

- ``selection``: Random / Oort / SAFA baselines + RELAY's IPS (Alg. 1)
- ``apt``: Adaptive Participant Target
- ``staleness``: SAA weight-scaling rules (Equal / DynSGD / AdaSGD / RELAY Eq. 2)
- ``aggregation``: stale-synchronous weighted aggregation (Alg. 2) over flat
  (n, D) update rows, with a thin pytree wrapper
- ``availability``: learner-side availability forecasting (scalar + bank)
"""
from repro.core.staleness import (  # noqa: F401
    staleness_weights,
    deviation_scores,
    SCALING_RULES,
)
from repro.core.aggregation import (  # noqa: F401
    flatten_update,
    unflatten_update,
    make_flat_spec,
    flat_dim,
    aggregate_updates,
    stale_synchronous_aggregate,
    stale_synchronous_aggregate_flat,
)
from repro.core.selection import (  # noqa: F401
    RandomSelector,
    OortSelector,
    PrioritySelector,
    SafaSelector,
)
from repro.core.apt import AdaptiveParticipantTarget  # noqa: F401
from repro.core.availability import (  # noqa: F401
    AvailabilityForecaster,
    ForecasterBank,
)
