"""RELAY core: the paper's contribution.

- ``selection``: Random / Oort / SAFA baselines + RELAY's IPS (Alg. 1)
- ``apt``: Adaptive Participant Target
- ``staleness``: SAA weight-scaling rules (Equal / DynSGD / AdaSGD / RELAY Eq. 2)
- ``aggregation``: stale-synchronous weighted aggregation (Alg. 2) over flat
  (n, D) update rows, with a thin pytree wrapper
- ``availability``: learner-side availability forecasting (scalar + bank)
"""
from repro.core.staleness import (  # noqa: F401
    staleness_weights,
    deviation_scores,
    SCALING_RULES,
)
from repro.core.aggregation import (  # noqa: F401
    flatten_update,
    unflatten_update,
    make_flat_spec,
    flat_dim,
    aggregate_updates,
    stale_synchronous_aggregate,
    stale_synchronous_aggregate_flat,
)
from repro.core.apt import AdaptiveParticipantTarget  # noqa: F401
from repro.core.availability import (  # noqa: F401
    AvailabilityForecaster,
    ForecasterBank,
)

# The selector classes moved to ``repro.selection`` (PR 9); the
# ``repro.core.selection`` shim re-imports them, which would cycle now
# that selection's base imports ``repro.core.registry`` — so the shim
# names resolve lazily here instead of at package-import time.
_SELECTION_NAMES = ("RandomSelector", "OortSelector", "PrioritySelector",
                    "SafaSelector")


def __getattr__(name):
    if name in _SELECTION_NAMES:
        from repro import selection as _selection
        return getattr(_selection, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
