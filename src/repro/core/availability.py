"""Learner-side availability forecasting (paper §4.1, App. A).

The paper trains a Prophet time-series model per device on its charging-state
trace (R^2 = 0.93 on the Stunner trace). Offline here, we implement a
seasonal-empirical forecaster with the same interface: each learner keeps its
own availability history, learns a periodic (hour-of-day x day-bucket) profile
online, and answers the server's query "P(available during [t+mu, t+2mu])?"
purely from local data — nothing about the learner's *training data* is shared
(the privacy argument of §4.2.4 / App. A).
"""
from __future__ import annotations

import numpy as np

HOUR = 3600.0
DAY = 24 * HOUR


class AvailabilityForecaster:
    """Online seasonal forecaster over hour-of-day bins with an EWMA residual."""

    def __init__(self, n_bins: int = 48, ewma_alpha: float = 0.05,
                 seasonal_weight: float = 0.9, prior: float = 0.5):
        self.n_bins = n_bins
        self.ewma_alpha = ewma_alpha
        self.seasonal_weight = seasonal_weight
        self.counts = np.ones(n_bins) * 2.0          # Beta(1,1)-ish smoothing
        self.avail_counts = np.ones(n_bins) * 2.0 * prior
        self.recent = prior

    def observe(self, t: float, available: bool):
        b = int((t % DAY) / DAY * self.n_bins) % self.n_bins
        self.counts[b] += 1.0
        self.avail_counts[b] += float(available)
        self.recent = ((1 - self.ewma_alpha) * self.recent
                       + self.ewma_alpha * float(available))

    def predict_window(self, t_start: float, t_end: float) -> float:
        """P(available throughout [t_start, t_end]) — the Alg. 1 p_l."""
        if t_end <= t_start:
            t_end = t_start + 1.0
        ts = np.linspace(t_start, t_end, 4)
        bins = ((ts % DAY) / DAY * self.n_bins).astype(int) % self.n_bins
        seasonal = float(np.mean(self.avail_counts[bins] / self.counts[bins]))
        return (self.seasonal_weight * seasonal
                + (1 - self.seasonal_weight) * self.recent)

    def score(self, trace_fn, t_eval: np.ndarray) -> dict:
        """Forecast-accuracy metrics against ground truth (paper §5.2 reports
        R^2 / MSE / MAE for Prophet on Stunner)."""
        preds = np.array([self.predict_window(t, t + HOUR / 2) for t in t_eval])
        truth = np.array([float(trace_fn(t)) for t in t_eval])
        mse = float(np.mean((preds - truth) ** 2))
        mae = float(np.mean(np.abs(preds - truth)))
        var = float(np.var(truth))
        # R^2 is undefined for a constant truth trace — report NaN rather
        # than a bogus score against an arbitrary denominator
        r2 = float("nan") if var == 0.0 else 1.0 - mse / var
        return {"r2": r2, "mse": mse, "mae": mae}


class ForecasterBank:
    """All learners' forecasters as (n, n_bins) count matrices.

    Same model as ``AvailabilityForecaster`` — hour-of-day seasonal profile
    plus an EWMA residual — but ``observe``/``predict`` are batched numpy
    operations over any subset of learners, removing the per-learner Python
    loop from the server's check-in and selection paths.  Matches the scalar
    forecaster bit-for-bit (same update formulas, evaluated elementwise).
    """

    def __init__(self, n: int, n_bins: int = 48, ewma_alpha: float = 0.05,
                 seasonal_weight: float = 0.9, prior: float = 0.5):
        self.n = n
        self.n_bins = n_bins
        self.ewma_alpha = ewma_alpha
        self.seasonal_weight = seasonal_weight
        self.counts = np.full((n, n_bins), 2.0)
        self.avail_counts = np.full((n, n_bins), 2.0 * prior)
        self.recent = np.full(n, prior)

    def _bin(self, t: float) -> int:
        return int((t % DAY) / DAY * self.n_bins) % self.n_bins

    def observe_batch(self, lids, t: float, available):
        """One observation at time ``t`` for each learner in ``lids``.

        ``available`` may be a scalar or an array aligned with ``lids``.
        ``lids`` must be unique within a call: the updates use fancy-index
        assignment, which applies only one step to a duplicated lid.
        """
        lids = np.asarray(lids)
        avail = np.broadcast_to(np.asarray(available, float), lids.shape)
        b = self._bin(t)
        self.counts[lids, b] += 1.0
        self.avail_counts[lids, b] += avail
        self.recent[lids] = ((1 - self.ewma_alpha) * self.recent[lids]
                             + self.ewma_alpha * avail)

    def observe_all(self, t: float, available):
        """Observation for every learner at once (warmup / census paths)."""
        avail = np.asarray(available, float)
        b = self._bin(t)
        self.counts[:, b] += 1.0
        self.avail_counts[:, b] += avail
        self.recent = (1 - self.ewma_alpha) * self.recent + self.ewma_alpha * avail

    def predict_window_batch(self, lids, t_start: float, t_end: float):
        """P(available throughout [t_start, t_end]) per queried learner."""
        lids = np.asarray(lids)
        if t_end <= t_start:
            t_end = t_start + 1.0
        ts = np.linspace(t_start, t_end, 4)
        bins = ((ts % DAY) / DAY * self.n_bins).astype(int) % self.n_bins
        ratios = (self.avail_counts[np.ix_(lids, bins)]
                  / self.counts[np.ix_(lids, bins)])
        seasonal = ratios.mean(axis=1)
        return (self.seasonal_weight * seasonal
                + (1 - self.seasonal_weight) * self.recent[lids])

    def view(self, lid: int) -> "ForecasterView":
        return ForecasterView(self, lid)


class ForecasterView:
    """Scalar ``AvailabilityForecaster``-compatible facade over one bank row."""

    __slots__ = ("bank", "lid", "_lid_arr")

    def __init__(self, bank: ForecasterBank, lid: int):
        self.bank = bank
        self.lid = lid
        self._lid_arr = np.array([lid])

    def observe(self, t: float, available: bool):
        self.bank.observe_batch(self._lid_arr, t, float(available))

    def predict_window(self, t_start: float, t_end: float) -> float:
        return float(self.bank.predict_window_batch(self._lid_arr,
                                                    t_start, t_end)[0])

    score = AvailabilityForecaster.score
