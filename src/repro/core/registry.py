"""Shared strategy-table machinery for the repo's plugin registries.

Three subsystems follow the same "a strategy is a file" pattern:
participant selection (:mod:`repro.selection`, PR 9), robust
aggregation (:mod:`repro.robust`, PR 8), and learner models
(:mod:`repro.learners`, this layer).  Each keeps a module-level table of
frozen spec dataclasses, registers one spec per file at import time,
folds a static key derived from the spec into ``pipeline_key`` so sweep
batches stay program-uniform, and renders a ``--list-*`` CLI table.

This module hosts the shared half: :class:`StrategyTable` (an ordered,
idempotent registry with knob-aware param normalization) and
:func:`describe_table` (the one column formatter behind
``--list-selectors`` / ``--list-aggregators`` / ``--list-models``).

Specs only need three attributes to live in a :class:`StrategyTable`:
``name`` (the registry key), ``doc`` (one line for the CLI table), and
``knobs`` (a tuple of :class:`Knob`).  Everything else — factories,
static-key policy, build contexts — stays subsystem-specific.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable parameter of a strategy: name, default, one-line doc."""

    name: str
    default: float
    doc: str = ""


class StrategyTable:
    """Ordered name → spec registry shared by the strategy subsystems.

    ``kind`` names the strategy family in error messages ("selector",
    "aggregator", "model").  Registration is idempotent for an identical
    spec (modules may be re-imported) and rejects a *different* spec
    under a taken name — silent strategy replacement would undermine the
    static-key caching everywhere downstream.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._specs: Dict[str, object] = {}

    # -- registration -------------------------------------------------
    def register(self, spec):
        existing = self._specs.get(spec.name)
        if existing is not None:
            if existing == spec:
                return spec
            raise ValueError(
                f"{self.kind} {spec.name!r} is already registered with a "
                f"different spec")
        self._specs[spec.name] = spec
        return spec

    # -- mapping surface ----------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __getitem__(self, name: str):
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r} "
                f"(choose from {self.names()})") from None

    def __iter__(self) -> Iterator[str]:
        return iter(self._specs)

    def __len__(self) -> int:
        return len(self._specs)

    def get(self, name: str, default=None):
        return self._specs.get(name, default)

    def names(self) -> Tuple[str, ...]:
        return tuple(self._specs)

    def values(self) -> Tuple[object, ...]:
        return tuple(self._specs.values())

    def items(self):
        return self._specs.items()

    # -- knob handling ------------------------------------------------
    def normalize_params(self, name: str,
                         params: Optional[Sequence[Tuple[str, object]]]
                         ) -> Tuple[Tuple[str, object], ...]:
        """Validate and canonicalize ``(knob, value)`` overrides.

        Returns a sorted tuple of ``(name, value)`` pairs — the hashable,
        order-independent form the static keys embed (later duplicates
        win, dict semantics).  Unknown knob names raise with the spec's
        knob list so CLI typos fail loudly at config-build time, not
        inside a compiled program.
        """
        spec = self[name]
        known = tuple(k.name for k in spec.knobs)
        items = sorted(dict(params or ()).items())
        unknown = [k for k, _ in items if k not in known]
        if unknown:
            raise ValueError(
                f"{self.kind} {name!r}: unknown knob(s) {unknown} "
                f"(accepted: {list(known) or 'none'})")
        return tuple(items)

    def knob_values(self, name: str,
                    params: Optional[Sequence[Tuple[str, object]]] = None
                    ) -> Dict[str, object]:
        """Spec defaults overlaid with normalized ``params`` overrides."""
        spec = self[name]
        values = {k.name: k.default for k in spec.knobs}
        for key, value in self.normalize_params(name, params):
            values[key] = value
        return values


def describe_table(title_row: Sequence[str],
                   rows: Sequence[Sequence[str]],
                   footnote: str = "") -> str:
    """Render a left-justified column table for the ``--list-*`` CLIs.

    All columns except the last are padded to their widest cell; the
    last column (by convention the doc string) is emitted ragged.  A
    non-empty ``footnote`` is appended as a trailing paragraph.
    """
    table = [tuple(title_row)] + [tuple(r) for r in rows]
    ncol = len(table[0])
    widths = [max(len(r[c]) for r in table) for c in range(ncol - 1)]
    lines = ["  ".join(v.ljust(w) for v, w in zip(r[:-1], widths))
             + f"  {r[-1]}" for r in table]
    text = "\n".join(lines)
    if footnote:
        text += "\n\n" + footnote
    return text
