"""HLO cost walker: trip-count-aware FLOP / byte / collective accounting.

XLA's ``compiled.cost_analysis()`` counts ``while`` (scan) bodies ONCE — for a
depth-scanned transformer that under-reports compute by ~n_layers x.  This
walker parses the post-optimization HLO text, expands every while body by its
``known_trip_count`` backend config (fallback: the loop condition's compare
constant), and accumulates:

  - flops: dot = 2 * prod(result) * K; elementwise/reduce = result elements;
  - bytes: operands + result per top-level op (fusion internals excluded,
    matching XLA's convention);
  - collective bytes per kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), also trip-count-scaled.

The walker is deliberately conservative and structural: it is used for the
roofline *terms*, where the dominant dots/collectives matter, not for exact
instruction counts.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->", re.M)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z]\w*\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "floor",
    "ceil", "sign", "cosine", "sine", "atan2", "expm1", "log1p", "logistic",
    "select", "compare", "and", "or", "xor", "not", "clamp", "remainder",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "copy", "broadcast", "iota", "transpose", "slice", "concatenate",
    "dynamic-slice", "dynamic-update-slice", "pad", "reverse", "convert",
    "gather", "scatter", "reduce", "rng", "rng-bit-generator", "map",
    "after-all", "partition-id", "replica-id", "custom-call", "infeed",
    "outfeed", "add-dependency", "optimization-barrier", "domain",
}


def _shape_elems_bytes(type_str):
    elems = byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations = self._split(hlo_text)
        self._memo = {}

    @staticmethod
    def _split(text):
        comps = {}
        cur, name = None, None
        for line in text.splitlines():
            stripped = line.strip()
            m = _COMP_HDR.match(line) if (line and not line[0].isspace()) else None
            if m and stripped.endswith("{"):
                name = m.group(1)
                cur = []
                comps[name] = cur
            elif stripped == "}":
                name, cur = None, None
            elif cur is not None and stripped:
                cur.append(stripped)
        return comps

    # ------------------------------------------------------------------
    def cost(self, comp_name: str):
        if comp_name in self._memo:
            return self._memo[comp_name]
        totals = defaultdict(float)
        lines = self.computations.get(comp_name, [])
        types = {}
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if m:
                types[m.group(1)] = m.group(2)

        for ln in lines:
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            name, rtype, opcode, rest = m.groups()
            r_elems, r_bytes = _shape_elems_bytes(rtype)

            if opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(ln)
                if tm:
                    trip = int(tm.group(1))
                body = _BODY_RE.search(ln)
                cond = _COND_RE.search(ln)
                for sub_m, factor in ((body, trip), (cond, trip + 1)):
                    if sub_m:
                        sub = self.cost(sub_m.group(1))
                        for k, v in sub.items():
                            totals[k] += v * factor
                continue

            if opcode in ("fusion", "call", "conditional", "reduce", "map",
                          "scatter", "select-and-scatter", "sort", "reduce-window"):
                cm = _CALLS_RE.search(ln)
                if cm:
                    sub = self.cost(cm.group(1))
                    for k, v in sub.items():
                        totals[k] += v
                # fusion/call IO bytes
                op_bytes = 0
                for op in _OPERAND_RE.findall(rest.split("),")[0]):
                    if op in types:
                        op_bytes += _shape_elems_bytes(types[op])[1]
                totals["bytes"] += op_bytes + r_bytes
                continue

            if opcode == "dot":
                k_size = 1
                cd = _CONTRACT_RE.search(ln)
                ops = _OPERAND_RE.findall(rest)
                if cd and ops and ops[0] in types:
                    lhs_dims = []
                    sm = _SHAPE_RE.search(types[ops[0]])
                    if sm:
                        lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
                    for idx in cd.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            k_size *= lhs_dims[int(idx)]
                totals["flops"] += 2.0 * r_elems * k_size
                op_bytes = sum(_shape_elems_bytes(types[o])[1]
                               for o in ops if o in types)
                totals["bytes"] += op_bytes + r_bytes
                continue

            if any(opcode.startswith(c) for c in COLLECTIVE_KINDS):
                if opcode.endswith("-done"):
                    continue
                kind = next(c for c in COLLECTIVE_KINDS if opcode.startswith(c))
                totals[f"coll_{kind}"] += r_bytes
                totals["coll_total"] += r_bytes
                totals["bytes"] += r_bytes
                continue

            if opcode in _ELEMENTWISE:
                totals["flops"] += r_elems
                op_bytes = sum(_shape_elems_bytes(types[o])[1]
                               for o in _OPERAND_RE.findall(rest) if o in types)
                totals["bytes"] += op_bytes + r_bytes
                continue

            if opcode in _FREE:
                if opcode in ("copy", "gather", "scatter", "dynamic-update-slice",
                              "dynamic-slice", "concatenate", "transpose", "pad",
                              "reshape", "broadcast", "convert"):
                    totals["bytes"] += 2.0 * r_bytes
                continue
            # unknown opcode: charge IO bytes only
            totals["bytes"] += r_bytes
        self._memo[comp_name] = dict(totals)
        return self._memo[comp_name]

    def entry_cost(self, entry_hint: str | None = None):
        # entry computation is the one named like main / or marked ENTRY (first)
        for cand in self.computations:
            if entry_hint and cand == entry_hint:
                return self.cost(cand)
        for cand in self.computations:
            if cand.startswith("main"):
                return self.cost(cand)
        # fallback: computation with max flops
        best = {}
        for cand in self.computations:
            c = self.cost(cand)
            if c.get("flops", 0) >= best.get("flops", 0):
                best = c
        return best


def analyze_hlo(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
    return model.entry_cost(m.group(1) if m else None)
