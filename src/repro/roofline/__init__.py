from repro.roofline.analysis import (  # noqa: F401
    collective_bytes_from_hlo,
    roofline_terms,
    model_flops,
    V5E,
)
