"""Roofline analysis from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips * peak_FLOPs)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes: ``compiled.cost_analysis()``.
collective_bytes: parsed from the post-SPMD HLO text — the summed result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (per-chip program, so already per-chip bytes).

Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

V5E = {
    "peak_flops_bf16": 197e12,   # FLOP/s per chip
    "hbm_bw": 819e9,             # B/s per chip
    "ici_bw": 50e9,              # B/s per link direction
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind. ``-done`` ops are skipped so
    async pairs aren't double counted."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        types, kind = m.group(1), m.group(2)
        if m.group(0).rstrip("(").endswith("-done("):
            continue
        b = _shape_bytes(types)
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    per_device_hbm: float = float("nan")

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},{self.chips},"
                f"{self.hlo_flops:.3e},{self.hlo_bytes:.3e},"
                f"{self.collective_bytes:.3e},{self.t_compute*1e3:.3f},"
                f"{self.t_memory*1e3:.3f},{self.t_collective*1e3:.3f},"
                f"{self.bottleneck},{self.model_flops:.3e},"
                f"{self.useful_ratio:.3f},{self.per_device_hbm:.3e}")

    HEADER = ("arch,shape,mesh,chips,hlo_flops,hlo_bytes,coll_bytes,"
              "t_compute_ms,t_memory_ms,t_collective_ms,bottleneck,"
              "model_flops,useful_ratio,per_device_hbm_bytes")


def roofline_terms(*, arch: str, shape: str, mesh_name: str, chips: int,
                   cost: dict, coll_bytes: float, model_flops_val: float,
                   per_device_hbm: float = float("nan"),
                   flops_are_per_chip: bool = True) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    # cost_analysis of an SPMD-partitioned module reports the per-chip program
    div = 1 if flops_are_per_chip else chips
    t_comp = flops / div / V5E["peak_flops_bf16"]
    t_mem = byts / div / V5E["hbm_bw"]
    t_coll = coll_bytes / V5E["ici_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_val / max(flops * (chips if flops_are_per_chip else 1), 1.0)
    return RooflineReport(arch, shape, mesh_name, chips, flops, byts, coll_bytes,
                          t_comp, t_mem, t_coll, bottleneck, model_flops_val,
                          useful, per_device_hbm)


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens


def active_params(cfg, params_shape) -> int:
    """Active parameters per token (MoE: routed experts counted top_k/E)."""
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        n = math.prod(leaf.shape)
        if cfg.moe and any(x in names for x in ("w_gate", "w_up", "w_down")) \
                and "ffn" in names and "shared" not in names \
                and len(leaf.shape) >= 3:
            n = n * cfg.top_k // max(cfg.n_experts, 1)
        if "embedding" in names or "w_out" in names and "head" in names:
            pass  # embeddings: gather ~O(d) per token, head counted fully
        total += n
    return int(total)
