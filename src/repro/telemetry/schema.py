"""Pinned telemetry schemas.

Every exported artifact (the in-program round-stats lane, the per-round
JSONL event log, the guard counters) has its field order pinned here so
downstream consumers — ``benchmarks/figures.py``, the CI smoke
validators, external dashboards — can rely on it.  Changing any tuple is
a schema break and must update ``tests/test_telemetry.py`` deliberately.
"""
from __future__ import annotations

# ---------------------------------------------------------------------------
# In-program round-stats lane (fused pipeline, ``SimConfig.telemetry >= 2``).
#
# One fp32 row per aggregation group per round, emitted as an extra
# ``lax.scan`` output alongside ``gstats`` and fetched only at chunk
# boundaries.  The first ``N_LANE_HOST`` fields are known on the host at
# pack time and ride through the floats buffer (the device echoes them so
# the lane is self-contained); the rest are computed in-program.
LANE_FIELDS = (
    # host pass-through (packed into the dispatch floats buffer)
    "round",                # simulated round index
    "sim_time",             # simulated clock at round end (hours)
    "cohort",               # learners selected this round
    "fresh",                # fresh (in-round) update rows aggregated
    "stale_landed",         # straggler rows landing this round (incl. replays)
    "cache_occupancy",      # stale-cache entries pending after scheduling
    # computed in-program, post-psum (no extra collective)
    "l2_min",               # update-row L2 norm, min over finite valid rows
    "l2_mean",              # ... mean
    "l2_max",               # ... max
    "nonfinite_rows",       # valid rows containing any non-finite entry
    # guard/robust columns (mirror gstats; zeros-but-survivors when
    # unguarded and non-robust)
    "rejected_nonfinite",   # rows rejected by the non-finite screen
    "rejected_norm",        # rows rejected by the norm-outlier screen
    "robust_rejected",      # rows the robust aggregator rejected (krum
                            # losers, norm_median_clip rejects)
    "robust_trimmed",       # rows trimmed per coordinate band (2*k_eff)
                            # or clipped by norm_median_clip
    "survivors",            # rows that entered the aggregate
    "applied",              # 1 if the update was applied (quorum met)
)
LANE_WIDTH = len(LANE_FIELDS)
# leading fields packed on the host into the widened floats buffer
N_LANE_HOST = 6

# lane fields serialized as ints in round events (the rest stay floats)
LANE_INT_FIELDS = frozenset((
    "round", "cohort", "fresh", "stale_landed", "cache_occupancy",
    "nonfinite_rows", "rejected_nonfinite", "rejected_norm",
    "robust_rejected", "robust_trimmed", "survivors", "applied",
))

# ---------------------------------------------------------------------------
# Per-round JSONL event log (``<telemetry-dir>/rounds.jsonl``).
#
# One event per (cell, recorded round), keys exactly in this order.  Only
# deterministic fields — no wall-clock — so the log joins the bitwise
# crash→resume contract: uninterrupted and crash→resume runs produce
# byte-identical files.  NaN accuracy/loss serialize as null.
ROUND_EVENT_KEYS = (
    "event",                # always "round"
    "cell",                 # cell / run label
    *LANE_FIELDS,
    # host-side accounting joined from the RoundRecord
    "resource_used",
    "resource_wasted",
    "unique_participants",
    "accuracy",             # null on non-eval rounds
    "loss",
)

# ---------------------------------------------------------------------------
# Registry counter names (single source of truth for guard accounting and
# the dispatch/transfer profile; ``PipelineStats`` is a view over these).
GUARD_COUNTERS = (
    "guard_rejected_nonfinite",
    "guard_rejected_norm",
    "guard_quorum_skips",
    "guard_robust_rejected",
    "guard_robust_trimmed",
)
PIPELINE_COUNTERS = (
    "pipeline_rounds",
    "pipeline_h2d_bytes",
    "pipeline_d2h_bytes",
    "pipeline_init_h2d_bytes",
    "pipeline_cross_shard_landings",
    "pipeline_feedback_fetches",
)
DISPATCH_KINDS = ("round", "eval", "cache_grow", "repack")

# ---------------------------------------------------------------------------
# Host-side tracer span names (Chrome trace-event JSON, Perfetto-loadable).
SPAN_NAMES = (
    "schedule",     # host prescheduling of a chunk of rounds
    "pack",         # packing dispatch int32/fp32 buffers
    "dispatch",     # device_put + the fused round program
    "fetch",        # device_get of gstats / lane / l2s + attribution
    "eval",         # deferred eval fill + early-stop bookkeeping
    "repack",       # early-stop sweep-bucket repacking
    "checkpoint",   # snapshot write
)
