"""Host-side tracer: nested spans exported as Chrome trace-event JSON.

``Tracer.span(name, **args)`` is a context manager instrumenting the
host stages of a run (schedule / pack / dispatch / fetch / eval / repack
/ checkpoint — see ``schema.SPAN_NAMES``).  The recorded timeline
exports as Chrome trace-event JSON, loadable in Perfetto
(https://ui.perfetto.dev — drag the file in) or ``chrome://tracing``.

A disabled tracer returns a shared null context: span call sites stay
unconditional in the hot loop at ~zero cost.  ``jax_profiler=True``
additionally wraps each span in ``jax.profiler.TraceAnnotation`` so host
spans line up with device events inside a ``jax.profiler.trace()``
capture.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Dict, List, Optional

_NULL_SPAN = contextlib.nullcontext()


class Tracer:
    """Records "X" (complete) trace events with µs timestamps."""

    def __init__(self, enabled: bool = True,
                 jax_profiler: bool = False) -> None:
        self.enabled = enabled
        self.jax_profiler = jax_profiler
        self.events: List[Dict[str, object]] = []
        self._t0 = time.perf_counter_ns()
        self._annotation = None
        if jax_profiler:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation = TraceAnnotation
            except Exception:  # pragma: no cover — old jax without profiler
                self._annotation = None

    def span(self, name: str, **args):
        if not self.enabled:
            return _NULL_SPAN
        return self._span(name, args)

    @contextlib.contextmanager
    def _span(self, name: str, args: Dict[str, object]):
        tid = threading.get_ident()
        start = time.perf_counter_ns()
        ann = self._annotation(name) if self._annotation else _NULL_SPAN
        try:
            with ann:
                yield
        finally:
            dur = time.perf_counter_ns() - start
            ev: Dict[str, object] = {
                "name": name, "ph": "X", "pid": os.getpid(),
                "tid": tid % 2**31,
                "ts": (start - self._t0) / 1e3,   # µs, run-relative
                "dur": dur / 1e3,
            }
            if args:
                ev["args"] = args
            self.events.append(ev)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker (crash/fault injections, etc.)."""
        if not self.enabled:
            return
        ev: Dict[str, object] = {
            "name": name, "ph": "i", "s": "g", "pid": os.getpid(),
            "tid": threading.get_ident() % 2**31,
            "ts": (time.perf_counter_ns() - self._t0) / 1e3,
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def chrome_trace(self) -> Dict[str, object]:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> Optional[str]:
        """Write Chrome trace-event JSON; returns the path (None if empty)."""
        if not self.events:
            return None
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)
        return path
