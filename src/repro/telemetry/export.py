"""Telemetry exporters: JSONL event logs and Prometheus text snapshots.

``JsonlWriter`` appends one JSON object per line, flushing every write so
the log survives a hard crash (SIGKILL) up to the last event — the
crash→resume contract truncates back to the snapshot's recorded offset
(`JsonlWriter.truncate_to`) and replays from there, making
uninterrupted and crash→resume round logs byte-identical.

Serialization is deterministic: keys keep insertion order (the pinned
schema order) and NaN/Inf floats are written as ``null`` — the files are
strict JSON, not the Python extension.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, Optional

from .registry import MetricsRegistry


def _clean(v):
    """NaN/Inf → None so every line is strict JSON."""
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v


def dumps_event(event: Dict[str, object]) -> str:
    return json.dumps({k: _clean(v) for k, v in event.items()},
                      separators=(", ", ": "))


class JsonlWriter:
    """Append-only JSONL sink with crash-safe flushing."""

    def __init__(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.path = path
        self._fh = open(path, "a")

    def write(self, event: Dict[str, object]) -> None:
        self._fh.write(dumps_event(event) + "\n")
        self._fh.flush()

    def tell(self) -> int:
        self._fh.flush()
        return self._fh.tell()

    def truncate_to(self, offset: int) -> None:
        """Drop events written past a snapshot boundary (resume path).

        No-op if the file is shorter than ``offset`` (resuming into a
        different directory than the crashed run logged to).
        """
        self._fh.flush()
        if 0 <= offset <= os.path.getsize(self.path):
            self._fh.truncate(offset)

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


def write_prometheus(registry: MetricsRegistry, path: str) -> str:
    """Write a Prometheus text-format (0.0.4) snapshot; returns the path."""
    with open(path, "w") as fh:
        fh.write(registry.prometheus_text())
    return path
