"""TelemetrySession: one run's telemetry sinks, tied together.

A session owns the metrics registry (single source of truth for guard
and dispatch counters), the host-side tracer, and the JSONL writers.
Every ``RoundPipeline`` has one — a directory-less default session costs
~nothing (null spans, no writers) but still backs ``PipelineStats``
with a live registry.

Exported artifacts (written under ``dir``):

  rounds.jsonl    per-round events, pinned schema, deterministic fields
                  only — joins the bitwise crash→resume contract
  events.jsonl    fault / crash / lifecycle events (wall-order, exempt
                  from the resume contract)
  trace.json      Chrome trace-event timeline (open in Perfetto)
  metrics.prom    Prometheus text-format counter snapshot

``state()`` / ``restore()`` carry the rounds.jsonl byte offset through
run snapshots: on resume into the same directory the log is truncated
back to the last checkpoint and replayed, so crash→resume produces the
byte-identical round log of an uninterrupted run.
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Dict, Optional

from .export import JsonlWriter, write_prometheus
from .registry import MetricsRegistry
from .schema import LANE_FIELDS, LANE_INT_FIELDS
from .trace import Tracer


class TelemetrySession:
    def __init__(self, dir: Optional[str] = None, *,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 jax_profiler: bool = False) -> None:
        self.dir = dir
        self.registry = registry if registry is not None else MetricsRegistry()
        if tracer is None:
            tracer = Tracer(enabled=dir is not None, jax_profiler=jax_profiler)
        self.tracer = tracer
        self._rounds: Optional[JsonlWriter] = None
        self._events: Optional[JsonlWriter] = None
        if dir is not None:
            os.makedirs(dir, exist_ok=True)
            self._rounds = JsonlWriter(os.path.join(dir, "rounds.jsonl"))
            self._events = JsonlWriter(os.path.join(dir, "events.jsonl"))
        self._closed = False

    # -- spans ---------------------------------------------------------------
    def span(self, name: str, **args):
        if not self.tracer.enabled:     # dir-less sessions: null span, no cost
            return self.tracer.span(name, **args)
        return self._timed_span(name, args)

    @contextlib.contextmanager
    def _timed_span(self, name: str, args: dict):
        """Trace span + wall-duration sample into the registry's
        ``span_seconds_<name>`` histogram (metrics.prom only — timings are
        wall-clock and stay out of the deterministic round log)."""
        t0 = time.perf_counter()
        with self.tracer.span(name, **args):
            yield
        self.registry.histogram(f"span_seconds_{name}").observe(
            time.perf_counter() - t0)

    # -- events --------------------------------------------------------------
    def round_event(self, cell: str, lane_row, rec) -> Dict[str, object]:
        """Build (and log) one per-round event from a lane row + RoundRecord.

        ``lane_row`` is the fp32 lane vector (``schema.LANE_FIELDS`` order);
        ``rec`` is the host-side ``RoundRecord`` for the same round.  The
        dict is returned for in-memory round logs regardless of whether a
        JSONL sink exists.  Deterministic fields only — no wall clock.
        """
        ev: Dict[str, object] = {"event": "round", "cell": cell}
        for name, v in zip(LANE_FIELDS, lane_row):
            ev[name] = int(v) if name in LANE_INT_FIELDS else float(v)
        ev["resource_used"] = float(rec.resource_used)
        ev["resource_wasted"] = float(rec.resource_wasted)
        ev["unique_participants"] = int(rec.unique_participants)
        ev["accuracy"] = None if rec.accuracy != rec.accuracy \
            else float(rec.accuracy)
        ev["loss"] = None if rec.loss != rec.loss else float(rec.loss)
        if self._rounds is not None:
            self._rounds.write(ev)
        return ev

    def event(self, kind: str, **fields) -> Dict[str, object]:
        """Log a non-round event (fault injection, crash, lifecycle)."""
        ev: Dict[str, object] = {"event": kind, **fields}
        self.registry.counter(f"events_{kind}").inc()
        if self._events is not None:
            self._events.write(ev)
        self.tracer.instant(kind, **fields)
        return ev

    # -- guard accounting (single writer) ------------------------------------
    def note_guard(self, acct, nonfinite: int, norm: int,
                   applied: bool) -> None:
        """The one call site that counts guard outcomes.

        Increments the registry counters (``PipelineStats.guard`` is a view
        over them) and forwards to the per-sim ``Accounting`` so summaries
        keep their pinned guard fields.
        """
        reg = self.registry
        if nonfinite:
            reg.counter("guard_rejected_nonfinite").inc(int(nonfinite))
        if norm:
            reg.counter("guard_rejected_norm").inc(int(norm))
        if not applied:
            reg.counter("guard_quorum_skips").inc()
        acct.note_guard(int(nonfinite), int(norm), applied)

    def note_robust(self, acct, rejected: int, trimmed: int) -> None:
        """The one call site that counts robust-aggregator outcomes
        (krum/norm-screen rejections, coordinate-band trims)."""
        reg = self.registry
        if rejected:
            reg.counter("guard_robust_rejected").inc(int(rejected))
        if trimmed:
            reg.counter("guard_robust_trimmed").inc(int(trimmed))
        acct.note_robust(int(rejected), int(trimmed))

    # -- lifecycle / resume --------------------------------------------------
    def flush(self) -> None:
        if self._rounds is not None:
            self._rounds.tell()
        if self._events is not None:
            self._events.tell()

    def state(self) -> Dict[str, int]:
        """Snapshot-carried state: the round-log byte offset."""
        return {"rounds_offset":
                self._rounds.tell() if self._rounds is not None else 0}

    def restore(self, state: Optional[Dict[str, int]]) -> None:
        """Re-enter the resume contract: truncate the round log back to the
        snapshot's offset so the resumed tail continues it exactly."""
        if state and self._rounds is not None:
            self._rounds.truncate_to(int(state.get("rounds_offset", 0)))

    def close(self) -> None:
        """Flush writers and export trace.json + metrics.prom (idempotent)."""
        if self._closed:
            return
        self._closed = True
        if self._rounds is not None:
            self._rounds.close()
        if self._events is not None:
            self._events.close()
        if self.dir is not None:
            self.tracer.export(os.path.join(self.dir, "trace.json"))
            write_prometheus(self.registry,
                             os.path.join(self.dir, "metrics.prom"))
