"""Telemetry subsystem: round-stats lane, trace spans, metrics, exporters.

Levels (``SimConfig.telemetry``):

  0  off — compiled round program identical to a telemetry-free build
  1  host — tracer spans + metrics registry / Prometheus snapshot
  2  full — additionally the in-program per-round stats lane and the
     per-round JSONL event log (``schema.LANE_FIELDS``)

The level is part of ``pipeline_key`` (program structure is static in
it); level 0 is bit-identical to not having telemetry at all, and the
lane at level 2 adds no collective — it is computed post-``psum`` and
fetched only at existing chunk boundaries.
"""
from .registry import Counter, CounterView, Gauge, Histogram, MetricsRegistry
from .schema import (DISPATCH_KINDS, GUARD_COUNTERS, LANE_FIELDS, LANE_WIDTH,
                     N_LANE_HOST, PIPELINE_COUNTERS, ROUND_EVENT_KEYS,
                     SPAN_NAMES)
from .session import TelemetrySession
from .trace import Tracer
from .export import JsonlWriter, dumps_event, write_prometheus

__all__ = [
    "Counter", "CounterView", "Gauge", "Histogram", "MetricsRegistry",
    "DISPATCH_KINDS", "GUARD_COUNTERS", "LANE_FIELDS", "LANE_WIDTH",
    "N_LANE_HOST", "PIPELINE_COUNTERS", "ROUND_EVENT_KEYS", "SPAN_NAMES",
    "TelemetrySession", "Tracer", "JsonlWriter", "dumps_event",
    "write_prometheus",
]
