"""Metrics registry: counters / gauges / histograms with a pinned schema.

One ``MetricsRegistry`` is the single source of truth for a run's
counters — ``PipelineStats`` and the ``Accounting`` guard fields are thin
views over it, so the ``--profile`` JSON, the Prometheus snapshot, and
the per-sim guard accounting can never disagree.

Stdlib-only and allocation-light: metric objects are created once
(get-or-create by name) and incremented in place on the host side of the
round loop.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Sequence, Tuple, Union

Number = Union[int, float]

# default histogram buckets: powers of ten around "seconds of host work"
_DEFAULT_BUCKETS = (1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)


class Counter:
    """Monotonic-by-convention counter (assignable for view semantics)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram (cumulative counts, Prometheus-style)."""

    __slots__ = ("name", "help", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = _DEFAULT_BUCKETS) -> None:
        self.name = name
        self.help = help
        self.buckets: Tuple[float, ...] = tuple(buckets)
        self.counts: List[int] = [0] * (len(self.buckets) + 1)  # +inf tail
        self.sum: float = 0.0
        self.count: int = 0

    def observe(self, v: Number) -> None:
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named metrics with get-or-create semantics and text exporters."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = _DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, help=help, buckets=buckets)

    def value(self, name: str) -> Number:
        m = self._metrics[name]
        if isinstance(m, Histogram):
            raise TypeError(f"{name!r} is a histogram; read .counts/.sum")
        return m.value

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self) -> Iterator[Metric]:
        return iter(self._metrics.values())

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict dump, stable-ordered by metric name."""
        out: Dict[str, object] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = {"buckets": list(m.buckets),
                             "counts": list(m.counts),
                             "sum": m.sum, "count": m.count}
            else:
                out[name] = m.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4) snapshot."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            kind = {"Counter": "counter", "Gauge": "gauge",
                    "Histogram": "histogram"}[type(m).__name__]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, Histogram):
                cum = 0
                for b, c in zip(m.buckets, m.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{b:g}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{name}_sum {m.sum:g}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {m.value:g}"
                             if isinstance(m.value, float)
                             else f"{name} {m.value}")
        return "\n".join(lines) + "\n"


class CounterView:
    """dict-like view over a fixed set of registry counters.

    Preserves the old ``PipelineStats.dispatches`` / ``.guard`` plain-dict
    API (``stats.guard["rejected_norm"] += 1``, ``dict(stats.dispatches)``)
    while the registry stays the single storage.
    """

    __slots__ = ("_reg", "_prefix", "_keys")

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 keys: Sequence[str]) -> None:
        self._reg = registry
        self._prefix = prefix
        self._keys = tuple(keys)
        for k in self._keys:
            registry.counter(prefix + k)

    def __getitem__(self, k: str) -> Number:
        if k not in self._keys:
            raise KeyError(k)
        return self._reg.counter(self._prefix + k).value

    def __setitem__(self, k: str, v: Number) -> None:
        if k not in self._keys:
            raise KeyError(k)
        self._reg.counter(self._prefix + k).value = v

    def __iter__(self) -> Iterator[str]:
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def __contains__(self, k: str) -> bool:
        return k in self._keys

    def keys(self) -> Tuple[str, ...]:
        return self._keys

    def values(self):
        return [self[k] for k in self._keys]

    def items(self):
        return [(k, self[k]) for k in self._keys]

    def as_dict(self) -> Dict[str, Number]:
        return {k: self[k] for k in self._keys}

    def __repr__(self) -> str:
        return f"CounterView({self.as_dict()!r})"

    def __eq__(self, other) -> bool:
        if isinstance(other, CounterView):
            other = other.as_dict()
        return self.as_dict() == other
