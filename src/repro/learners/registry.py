"""The model strategy table + the static key folded into pipeline_key.

Mirrors ``repro.selection.registry`` and ``repro.robust.aggregators``:
adding a model is a file-local change — implement the
:class:`~repro.learners.base.ModelFns` triple, register a
:class:`~repro.learners.base.ModelSpec` for it (one ``register_model``
call at import time), and it is sweepable by name everywhere a
``SimConfig.model`` goes.  See ``docs/extending.md`` for the worked
example.

``model_key`` is folded into both ``repro.sim.pipeline.pipeline_key``
(two cells sharing a fused program must train the same architecture —
sweep batches stay model-uniform) and ``repro.sim.engine.substrate_key``
(the initial parameter tree is part of the seed-built world state).
"""
from __future__ import annotations

import functools

from repro.core.registry import StrategyTable, describe_table
from repro.learners.base import DataMeta, ModelFns, ModelSpec

MODEL_TABLE: StrategyTable = StrategyTable("model")


def register_model(spec: ModelSpec) -> ModelSpec:
    """Register a learner model under ``spec.name`` (idempotent for an
    identical spec; a *different* spec under a taken name is an error)."""
    return MODEL_TABLE.register(spec)


def normalize_model_params(name: str, params) -> tuple:
    """Canonicalize ``SimConfig.model_params`` to a sorted, hashable
    ``((knob, value), ...)`` tuple, validating knob names against the
    spec so a typo'd knob fails at config time, not silently."""
    return MODEL_TABLE.normalize_params(name, params)


def model_key(cfg) -> tuple:
    """Static descriptor of the learner model for ``pipeline_key``.

    Two configs with equal ``model_key`` share one flat spec, one loss
    jaxpr, and therefore one fused round program — the full
    ``(name, params)`` pair is folded in (not just the name) so a
    ``d_model`` override compiles its own program variant instead of
    poisoning a shared cache entry.
    """
    return (cfg.model, tuple(cfg.model_params or ()))


@functools.lru_cache(maxsize=32)
def build_model(name: str, params: tuple, meta: DataMeta) -> ModelFns:
    """Resolve ``(model, model_params, meta)`` to its :class:`ModelFns`.

    ``lru_cache``-d so every Simulator of a sweep sharing a model cell
    receives the *identical* function objects — they key the jitted
    round-program caches downstream, so cache identity here is what
    keeps a 64-cell sweep at one compile per program shape.
    """
    spec = MODEL_TABLE[name]
    if spec.data_kind != meta.kind:
        raise ValueError(
            f"model {name!r} trains on {spec.data_kind!r} data but the "
            f"benchmark provides {meta.kind!r} samples")
    knobs = MODEL_TABLE.knob_values(name, params)
    fns = spec.build(knobs, meta)
    if not isinstance(fns, ModelFns):
        fns = ModelFns(*fns)
    return fns


def describe_models() -> str:
    """Human-readable strategy table (``--list-models``)."""
    rows = [(
        spec.name,
        spec.family,
        spec.data_kind,
        spec.kernel,
        ", ".join(f"{k.name}={k.default!r}" for k in spec.knobs) or "-",
        spec.doc,
    ) for spec in MODEL_TABLE.values()]
    return describe_table(
        ("model", "family", "data", "kernel", "knobs (model_params)", "doc"),
        rows,
        footnote="data = sample layout the model trains on; benchmarks "
                 "declare theirs (classifier: speech/cifar10/openimage, "
                 "tokens: tokens/tokens_skew) and the pair is validated "
                 "at substrate build time.")
