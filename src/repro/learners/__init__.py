"""Learner-model zoo behind the round pipeline ("a model is a file").

The third strategy table (after selection and robust aggregation): a
model file registers a :class:`~repro.learners.base.ModelSpec` into
``MODEL_TABLE`` and becomes sweepable via ``SimConfig.model`` /
``model_params`` on every substrate the flat fast path serves.  See
``docs/extending.md`` for the contributor guide.
"""
from repro.learners.base import (DataMeta, Knob, ModelFns,  # noqa: F401
                                 ModelSpec)
from repro.learners.registry import (MODEL_TABLE, build_model,  # noqa: F401
                                     describe_models, model_key,
                                     normalize_model_params, register_model)
from repro.learners import mlp as _mlp  # noqa: F401  (registers "mlp")
from repro.learners import lm as _lm    # noqa: F401  (registers the LM zoo)
