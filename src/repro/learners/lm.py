"""Language models from the model zoo, as federated learner plugins.

Each spec wraps :mod:`repro.models.transformer`'s composable decoder
(the zoo's GQA/SWA transformer, its MoE variant, and the RWKV6 hybrid)
into the :class:`~repro.learners.base.ModelFns` triple the round engine
consumes.  Federated specifics:

- ``param_dtype`` is forced to fp32: the aggregation substrate ships
  updates as flat fp32 rows (stale cache, SAA kernels, yogi state), and
  a bf16 parameter tree would round-trip through fp32 flatten/unflatten
  every round, changing the numerics the parity tests pin.
- ``loss`` returns *per-sequence* cross-entropy next to the mean so
  Oort's statistical utility (``sqrt(mean(loss^2))``) works unchanged
  on token workloads.
- ``evaluate`` reports (next-token accuracy, mean NLL) — the eval lane
  treats these exactly like the classifier's (accuracy, loss) pair.

These models train on ``data_kind="tokens"`` benchmarks (``tokens`` /
``tokens_skew``: ``repro.data.synthetic.federated_token_shards`` wired
through ``repro.sim.partition.make_token_dataset``), where a sample is
an ``(S,)`` int32 sequence and the label its next-token shift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.learners.base import Knob, ModelFns, ModelSpec
from repro.learners.registry import register_model
from repro.models import transformer as tf

_AUX_WEIGHT = 0.01   # MoE load-balance weight (matches transformer.lm_loss)


def _seq_xent(mcfg, params, x, y):
    """(per-sequence mean next-token cross-entropy, aux loss)."""
    h, aux, _ = tf.forward(mcfg, params, {"tokens": x})
    logits = tf._logits(mcfg, params, h).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return (logz - gold).mean(axis=-1), aux, logits


def _fns_for(mcfg: tf.ModelConfig) -> ModelFns:
    def init(key):
        return tf.init_params(mcfg, key)

    def loss(params, x, y):
        per_seq, aux, _ = _seq_xent(mcfg, params, x, y)
        return per_seq.mean() + _AUX_WEIGHT * aux, per_seq

    def evaluate(params, x, y):
        per_seq, _aux, logits = _seq_xent(mcfg, params, x, y)
        acc = (logits.argmax(-1) == y).mean()
        return acc, per_seq.mean()

    return ModelFns(init=init, loss=loss, evaluate=evaluate)


_BASE_KNOBS = (
    Knob("n_layers", 2, "decoder layers"),
    Knob("d_model", 64, "model width"),
    Knob("n_heads", 2, "attention / wkv heads"),
    Knob("d_ff", 128, "dense SwiGLU width"),
)


def _base_cfg(knobs: dict, meta, **over) -> tf.ModelConfig:
    return tf.ModelConfig(
        n_layers=int(knobs["n_layers"]),
        d_model=int(knobs["d_model"]),
        n_heads=int(knobs["n_heads"]),
        n_kv_heads=int(knobs["n_heads"]),
        d_ff=int(knobs["d_ff"]),
        vocab_size=int(meta.vocab),
        param_dtype=jnp.float32,
        **over)


def _build_transformer(knobs: dict, meta) -> ModelFns:
    window = int(knobs["window"])
    return _fns_for(_base_cfg(
        knobs, meta, arch_id="fl-transformer",
        window=window if window > 0 else None,
        use_kernels=bool(int(knobs["use_kernels"]))))


def _build_moe(knobs: dict, meta) -> ModelFns:
    return _fns_for(_base_cfg(
        knobs, meta, arch_id="fl-moe", family="moe", moe=True,
        n_experts=int(knobs["n_experts"]), top_k=int(knobs["top_k"]),
        moe_d_ff=int(knobs["moe_d_ff"])))


def _build_rwkv6(knobs: dict, meta) -> ModelFns:
    return _fns_for(_base_cfg(
        knobs, meta, arch_id="fl-rwkv6", family="hybrid",
        block_pattern=("rwkv6",),
        use_kernels=bool(int(knobs["use_kernels"]))))


register_model(ModelSpec(
    name="transformer",
    build=_build_transformer,
    doc="decoder-only GQA transformer LM (optional sliding-window attention)",
    data_kind="tokens",
    family="dense",
    kernel="swa attention (pallas, use_kernels=1)",
    knobs=_BASE_KNOBS + (
        Knob("window", 0, "sliding-window width (0 = full causal)"),
        Knob("use_kernels", 0, "route attention through the Pallas kernel"),
    ),
))

register_model(ModelSpec(
    name="moe",
    build=_build_moe,
    doc="mixture-of-experts transformer LM (top-k router + balance aux)",
    data_kind="tokens",
    family="moe",
    kernel="-",
    knobs=_BASE_KNOBS + (
        Knob("n_experts", 4, "routed experts"),
        Knob("top_k", 2, "experts per token"),
        Knob("moe_d_ff", 64, "per-expert SwiGLU width"),
    ),
))

register_model(ModelSpec(
    name="rwkv6",
    build=_build_rwkv6,
    doc="RWKV6 token/channel-mix LM (linear-attention wkv6 recurrence)",
    data_kind="tokens",
    family="rnn",
    kernel="wkv6 scan (pallas, use_kernels=1)",
    knobs=_BASE_KNOBS + (
        Knob("use_kernels", 0, "route the wkv6 recurrence through Pallas"),
    ),
))
