"""The default model: the engine's 2-layer MLP classifier, as a plugin.

This file *wraps* ``repro.sim.learner`` rather than reimplementing it:
``loss`` and ``evaluate`` are the exact function objects the pre-zoo
engine compiled against, so a ``SimConfig(model="mlp")`` run (the
default) produces bit-identical jaxprs — and therefore bit-identical
results — to the code before the model table existed.  Only ``init``
closes over the knobs (the hidden width), which is why the knob can
vary without touching the loss/eval cache identity.
"""
from __future__ import annotations

import functools

from repro.learners.base import Knob, ModelFns, ModelSpec
from repro.learners.registry import register_model
from repro.sim import learner as ln


def _build(knobs: dict, meta) -> ModelFns:
    hidden = int(knobs["hidden"])
    init = functools.partial(ln.mlp_init, dim=meta.feature_dim,
                             n_classes=meta.n_classes, hidden=hidden)
    return ModelFns(init=init, loss=ln._xent, evaluate=ln.evaluate)


register_model(ModelSpec(
    name="mlp",
    build=_build,
    doc="2-layer ReLU MLP classifier (the paper-scale statistical stand-in)",
    data_kind="classifier",
    family="dense",
    kernel="-",
    knobs=(Knob("hidden", 128, "hidden layer width"),),
))
