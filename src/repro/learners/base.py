"""Model plugin base: the learner-model interface + strategy spec.

A *model* is the third strategy family behind the fused round pipeline
(after the selectors of :mod:`repro.selection` and the robust
aggregators of :mod:`repro.robust`): a file registers one
:class:`ModelSpec` in ``repro.learners.MODEL_TABLE`` and the model is
sweepable by name everywhere a ``SimConfig.model`` goes — the engine,
the fused pipeline, the batched sweep runner, and the CLI.

What the engine actually consumes is a :class:`ModelFns` triple of pure
functions over parameter *pytrees*:

``init(key)``
    PRNG key -> parameter pytree.  Called once per substrate; the flat
    ``(D,)`` training row and its :func:`repro.core.aggregation.
    make_flat_spec` layout are derived from this tree, so everything
    downstream (stale cache, aggregation kernels, server optimizer)
    is model-agnostic.

``loss(params, x, y) -> (mean_loss, per_example_losses)``
    The local-training objective ``jax.value_and_grad`` differentiates.
    ``per_example_losses`` feeds Oort's statistical utility
    (``sqrt(mean(losses**2))``), so it must be a per-sample (or
    per-sequence) vector, not a scalar.

``evaluate(params, x, y) -> (accuracy, loss)``
    Held-out metric pair for the eval lane.

All three must be *hashable-stable*: ``repro.learners.build_model`` is
``lru_cache``-d per ``(model, model_params, meta)`` so the returned
function objects are identical across Simulators of a sweep — they are
part of the jit/lru cache keys of every compiled round program.

``data_kind`` declares the sample layout the model trains on
(``"classifier"``: ``x (N, dim) fp32 / y (N,) int``; ``"tokens"``:
``x (N, S) int32 tokens / y (N, S) int32 next-token labels``) and is
validated against the benchmark's :class:`DataMeta` at build time.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

from repro.core.registry import Knob  # noqa: F401  (re-export for model files)


@dataclasses.dataclass(frozen=True)
class DataMeta:
    """Static description of a benchmark's sample layout.

    Hashable (it is part of ``build_model``'s cache key); built once per
    :class:`repro.sim.engine.Substrate` from the seed-built dataset.
    """
    kind: str = "classifier"         # classifier | tokens
    feature_dim: int = 0             # classifier: x feature dimension
    n_classes: int = 0               # classifier: label cardinality
    vocab: int = 0                   # tokens: vocabulary size
    seq_len: int = 0                 # tokens: sequence length


class ModelFns(NamedTuple):
    """The three pure functions the round engine consumes (see module
    docstring for the exact contracts)."""
    init: Callable
    loss: Callable
    evaluate: Callable


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """One registered learner model (a row of ``MODEL_TABLE``).

    ``build(knobs, meta)`` receives the resolved knob dict (defaults
    overlaid with the cell's ``model_params``) and the benchmark's
    :class:`DataMeta`, and returns the :class:`ModelFns` triple;
    ``data_kind`` is the sample layout it requires; ``kernel`` names the
    accelerator kernel the forward path routes through (README table).
    """
    name: str
    build: Callable[[dict, DataMeta], ModelFns]
    doc: str = ""
    data_kind: str = "classifier"    # classifier | tokens
    family: str = "dense"            # dense | moe | rnn | ... (listing aid)
    kernel: str = "-"                # accelerator kernel used, if any
    knobs: tuple = ()                # Knob(...) entries (model_params)
