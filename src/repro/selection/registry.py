"""The selector strategy table + the static key folded into pipeline_key.

Mirrors ``repro.robust.aggregators`` and ``repro.learners``: adding a
selector is a file-local change — write a ``Selector`` subclass, register
a ``SelectorSpec`` for it (one ``register_selector`` call at import
time), and it is sweepable by name everywhere a ``SimConfig.selector``
goes.  See ``docs/extending.md`` for the worked example.

The registry mechanics (idempotent registration, knob validation, the
``--list-*`` column formatter) live in :mod:`repro.core.registry`'s
shared :class:`~repro.core.registry.StrategyTable`; this module keeps
the selection-specific surface: ``selector_key`` and ``build_selector``.
"""
from __future__ import annotations

from repro.core.registry import StrategyTable, describe_table
from repro.selection.base import SelectorSpec

SELECTOR_TABLE: StrategyTable = StrategyTable("selector")


def register_selector(spec: SelectorSpec) -> SelectorSpec:
    """Register a selection strategy under ``spec.name``.

    Idempotent re-registration of the identical spec is allowed (module
    reloads); a *different* spec under a taken name is an error.
    """
    return SELECTOR_TABLE.register(spec)


def normalize_selector_params(name: str, params) -> tuple:
    """Canonicalize ``SimConfig.selector_params`` to a sorted, hashable
    ``((knob, value), ...)`` tuple, validating knob names against the
    spec so a typo'd knob fails at config time, not silently."""
    return SELECTOR_TABLE.normalize_params(name, params)


def selector_key(cfg) -> tuple:
    """Static descriptor of the selection strategy for ``pipeline_key``.

    Two configs with equal ``selector_key`` impose identical structure on
    the fused round program: the same feedback-fetch path (and therefore
    the same ``rounds_per_dispatch`` cap) and the same cohort-shape
    regime.  Folding the full ``(name, params)`` pair — not just the
    structural bits — keeps sweep batches selector-uniform, so one Oort
    cell can no longer force K=1 on a whole mixed batch and each selector
    compiles to its own program variant.
    """
    spec = SELECTOR_TABLE[cfg.selector]
    return (spec.name, tuple(cfg.selector_params or ()),
            spec.needs_feedback, spec.select_all)


def build_selector(cfg, substrate=None, durations=None):
    """Construct the policy object for ``cfg.selector`` (engine entry)."""
    return SELECTOR_TABLE[cfg.selector].build(cfg, substrate=substrate,
                                              durations=durations)


def describe_selectors() -> str:
    """Human-readable strategy table (``--list-selectors``)."""
    rows = [(
        spec.name,
        "1" if spec.needs_feedback else "free",
        "all available" if spec.select_all else "n_target",
        ", ".join(f"{k.name}={k.default!r}" for k in spec.knobs) or "-",
        spec.doc,
    ) for spec in SELECTOR_TABLE.values()]
    return describe_table(
        ("selector", "K", "cohort", "knobs (selector_params)", "doc"), rows,
        footnote="K = rounds_per_dispatch cap: feedback selectors consume "
                 "the per-round device stat-utility vector, forcing K=1.")
