"""Uniform random selection (FedAvg default; Bonawitz et al., 2019).

Ported verbatim from the pre-zoo ``repro.core.selection`` — the RNG draw
order is part of the bit-parity contract (tests/test_selector_zoo.py).
"""
from __future__ import annotations

from repro.selection.base import Selector, SelectorSpec, class_factory
from repro.selection.registry import register_selector


class RandomSelector(Selector):
    name = "random"
    needs_views = False

    def select_ids(self, round_idx, ids, n_target, rng):
        if len(ids) <= n_target:
            return list(ids)
        # rng.choice consumes the same stream for a list or an array of the
        # same length, so the two entry points draw identical cohorts
        return list(rng.choice(ids, size=n_target, replace=False))

    def select(self, round_idx, checked_in, n_target, rng):
        return self.select_ids(round_idx, [v.learner_id for v in checked_in],
                               n_target, rng)


register_selector(SelectorSpec(
    name="random",
    factory=class_factory(RandomSelector),
    cls=RandomSelector,
    doc="uniform sampling without replacement (FedAvg baseline)",
))
