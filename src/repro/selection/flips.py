"""FLIPS-style label-distribution clustering selection (2308.03901).

FLIPS's core intuition: under non-IID label mappings, uniform sampling
over-represents the dominant label clusters; clustering learners by their
*label distribution* and guaranteeing every cluster a share of each
round's budget keeps minority data in the aggregate.

The clustering is a build-time artifact: label histograms come from the
substrate's dataset shards (server-visible metadata, not update values)
and a small deterministic k-means — seeded from the cell's config seed,
fixed iteration count — assigns every learner a cluster once, before
round 0.  Selection is then feedback-free and view-free: each round's
budget is split across the clusters present among the checked-in
learners (equal shares, largest-cluster-first remainder, overflow
redistributed), and members are drawn uniformly within each cluster.
Because no per-round device feedback is consumed, FLIPS cells chunk
freely (``rounds_per_dispatch`` > 1 stays legal).
"""
from __future__ import annotations

import numpy as np

from repro.selection.base import Knob, Selector, SelectorSpec
from repro.selection.registry import register_selector


def label_histograms(data) -> np.ndarray:
    """(n_learners, n_classes) row-normalized label distributions from a
    classifier ``repro.sim.partition.FederatedDataset``'s shards."""
    y = np.asarray(data.y_train)
    n_classes = int(data.n_classes)
    hists = np.zeros((len(data.shards), n_classes), np.float64)
    for i, shard in enumerate(data.shards):
        h = np.bincount(y[np.asarray(shard, int)], minlength=n_classes)
        hists[i] = h / max(h.sum(), 1)
    return hists


def token_histograms(data, top_k: int = 64) -> np.ndarray:
    """(n_learners, top_k) row-normalized unigram histograms for a token
    ``FederatedDataset`` — the LM analogue of the label distribution.

    The vocabulary is restricted to the ``top_k`` globally most frequent
    tokens (count desc, token id asc on ties): the skewed-unigram mappings
    concentrate their signal there, and a fixed small feature keeps the
    k-means distance geometry comparable to the classifier case instead of
    drowning it in thousands of near-zero tail frequencies."""
    x = np.asarray(data.x_train)
    vocab = int(data.vocab)
    top_k = max(1, min(int(top_k), vocab))
    glob = np.bincount(x.reshape(-1), minlength=vocab)
    top = np.lexsort((np.arange(vocab), -glob))[:top_k]
    hists = np.zeros((len(data.shards), top_k), np.float64)
    for i, shard in enumerate(data.shards):
        h = np.bincount(x[np.asarray(shard, int)].reshape(-1),
                        minlength=vocab)[top]
        hists[i] = h / max(h.sum(), 1)
    return hists


def learner_histograms(data, top_k: int = 64) -> np.ndarray:
    """Per-learner data-distribution features for clustering, dispatched on
    the dataset's sample layout (``FederatedDataset.kind``)."""
    if getattr(data, "kind", "classifier") == "tokens":
        return token_histograms(data, top_k=top_k)
    return label_histograms(data)


def kmeans_labels(hists: np.ndarray, k: int, seed: int,
                  iters: int = 8) -> np.ndarray:
    """Deterministic k-means over label distributions: seeded init, fixed
    iteration count, empty clusters re-seeded to the farthest point.
    Returns the (n_learners,) cluster assignment."""
    n = len(hists)
    k = max(1, min(k, n))
    rng = np.random.default_rng(seed)
    centers = hists[rng.choice(n, size=k, replace=False)].copy()
    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        d2 = ((hists[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        assign = d2.argmin(1)
        for c in range(k):
            m = assign == c
            if m.any():
                centers[c] = hists[m].mean(0)
            else:
                centers[c] = hists[d2.min(1).argmax()]
    return assign


class FlipsSelector(Selector):
    """Cluster-balanced uniform sampling over a fixed label clustering."""
    name = "flips"
    needs_views = False

    def __init__(self, cluster_of: np.ndarray):
        self.cluster_of = np.asarray(cluster_of, np.int64)

    def quotas(self, sizes, n_target: int) -> list:
        """Per-cluster budgets for cluster population ``sizes`` (in cluster
        order): equal split, remainder to the largest clusters first
        (cluster id breaks ties), overflow beyond a cluster's population
        redistributed to clusters with headroom.  Pure integer arithmetic —
        the closed-form oracle in tests/test_selector_zoo.py pins it."""
        sizes = [int(s) for s in sizes]
        g = len(sizes)
        q = [n_target // g] * g
        by_size = sorted(range(g), key=lambda c: (-sizes[c], c))
        for c in by_size[:n_target % g]:
            q[c] += 1
        # overflow: a cluster can't supply more than its population
        spill = 0
        for c in range(g):
            if q[c] > sizes[c]:
                spill += q[c] - sizes[c]
                q[c] = sizes[c]
        while spill > 0:
            room = [c for c in by_size if q[c] < sizes[c]]
            if not room:
                break
            for c in room:
                if spill == 0:
                    break
                q[c] += 1
                spill -= 1
        return q

    def select_ids(self, round_idx, ids, n_target, rng):
        ids = list(ids)
        if len(ids) <= n_target:
            return ids
        groups = {}
        for lid in ids:                       # ids ascending -> groups sorted
            groups.setdefault(int(self.cluster_of[lid]), []).append(lid)
        clusters = sorted(groups)
        q = self.quotas([len(groups[c]) for c in clusters], n_target)
        chosen = []
        for c, qc in zip(clusters, q):
            members = groups[c]
            if qc >= len(members):
                chosen += members
            elif qc > 0:
                chosen += list(rng.choice(members, size=qc, replace=False))
        return chosen

    def select(self, round_idx, checked_in, n_target, rng):
        return self.select_ids(round_idx, [v.learner_id for v in checked_in],
                               n_target, rng)


def _build(params, ctx):
    n_clusters = int(params.get("n_clusters", 4))
    iters = int(params.get("kmeans_iters", 8))
    top_k = int(params.get("token_top_k", 64))
    if ctx.substrate is None:
        raise ValueError("flips selector needs a substrate (label shards) "
                         "to cluster at build time")
    hists = learner_histograms(ctx.substrate.data, top_k=top_k)
    # seeded from the cell's config seed: cells sharing a seed share the
    # clustering (and the substrate build it reads), bit-identically on
    # every substrate/execution path
    assign = kmeans_labels(hists, n_clusters, seed=int(ctx.cfg.seed),
                           iters=iters)
    return FlipsSelector(assign)


register_selector(SelectorSpec(
    name="flips",
    factory=_build,
    cls=FlipsSelector,
    doc="FLIPS: label-distribution k-means, per-cluster budget shares",
    knobs=(Knob("n_clusters", 4, "label-distribution clusters"),
           Knob("kmeans_iters", 8, "fixed k-means iterations"),
           Knob("token_top_k", 64,
                "token workloads: unigram histogram width")),
))
