"""SAFA selection (Wu et al., 2021): every available learner trains.

The round-end rule — stop when ``safa_target_ratio`` of the cohort has
reported, capped by the deadline — lives in the engine's scheduler and is
switched by this spec's ``select_all`` flag (no engine special-casing on
the selector *name* remains).  Ported verbatim from the pre-zoo
``repro.core.selection``.
"""
from __future__ import annotations

from repro.selection.base import Selector, SelectorSpec, class_factory
from repro.selection.registry import register_selector


class SafaSelector(Selector):
    """SAFA flips selection: every available learner trains every round."""
    name = "safa"
    needs_views = False

    def select_ids(self, round_idx, ids, n_target, rng):
        return list(ids)

    def select(self, round_idx, checked_in, n_target, rng):
        return [v.learner_id for v in checked_in]


register_selector(SelectorSpec(
    name="safa",
    factory=class_factory(SafaSelector),
    cls=SafaSelector,
    select_all=True,
    doc="select all available; round ends at safa_target_ratio arrivals",
))
