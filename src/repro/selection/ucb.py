"""UCB1 bandit selection — the survey set's (2306.04862) bandit family
beyond Oort's epsilon-greedy heuristic.

Each learner is an arm; the reward of a pull is the statistical utility
the engine reports after the round (``update_feedback(stat_util=...)``,
the same per-row device loss stats Oort consumes — so this is a
``needs_feedback`` selector and forces ``rounds_per_dispatch=1``).
Selection scores are classic UCB1 on normalized rewards:

    score(i) = mean_reward(i) / max_mean  +  c * sqrt(2 ln t / n_i)

with never-pulled arms taking strict priority (uniformly shuffled among
themselves), and a shared per-round jitter draw breaking exploitation
ties deterministically.  Unlike Oort there is no completion-time penalty
or pacer: the bandit treats utility as the only signal, which makes it
the clean ablation partner for Oort's system-utility term.
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np

from repro.selection.base import Knob, Selector, SelectorSpec, class_factory
from repro.selection.registry import register_selector


class UcbSelector(Selector):
    name = "ucb"
    needs_views = False

    def __init__(self, c: float = 1.5):
        self.c = float(c)
        self.rounds = 0                       # t: completed selection rounds
        self._sum: Dict[int, float] = {}      # cumulative reward per arm
        self._n: Dict[int, int] = {}          # pulls per arm

    def _scores(self) -> Dict[int, float]:
        """UCB1 scores for every explored arm, computed in one pass."""
        means = {a: self._sum[a] / self._n[a] for a in self._n}
        max_mean = max(means.values(), default=0.0) or 1.0
        log_t = 2.0 * math.log(max(self.rounds, 2))
        return {a: means[a] / max_mean + self.c * math.sqrt(log_t / self._n[a])
                for a in self._n}

    def score(self, lid: int) -> float:
        """UCB1 score for an explored arm (``lid`` must have feedback)."""
        return self._scores()[lid]

    def select_ids(self, round_idx, ids, n_target, rng):
        ids = list(ids)
        self.rounds += 1
        # one jitter draw per call, shared by both branches below, so the
        # RNG stream advances identically whatever the explored split is
        jitter = rng.random(len(ids))
        if len(ids) <= n_target:
            return ids
        unexplored = [(jitter[k], lid) for k, lid in enumerate(ids)
                      if lid not in self._n]
        explored = [k for k, lid in enumerate(ids) if lid in self._n]
        unexplored.sort()
        chosen = [lid for _, lid in unexplored[:n_target]]
        want = n_target - len(chosen)
        if want > 0 and explored:
            scores = self._scores()
            order = sorted(explored,
                           key=lambda k: (-scores[ids[k]], jitter[k]))
            chosen += [ids[k] for k in order[:want]]
        return chosen

    def select(self, round_idx, checked_in, n_target, rng):
        return self.select_ids(round_idx, [v.learner_id for v in checked_in],
                               n_target, rng)

    def update_feedback(self, learner_id, *, stat_util=None, duration=None,
                        round_idx=None):
        if stat_util is not None:
            self._sum[learner_id] = self._sum.get(learner_id, 0.0) + stat_util
            self._n[learner_id] = self._n.get(learner_id, 0) + 1


register_selector(SelectorSpec(
    name="ucb",
    factory=class_factory(UcbSelector),
    cls=UcbSelector,
    needs_feedback=True,
    doc="UCB1 bandit on stat-utility rewards; unexplored arms first",
    knobs=(Knob("c", 1.5, "exploration-bonus coefficient"),),
))
