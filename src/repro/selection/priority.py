"""RELAY's IPS (paper Alg. 1): least-available-first priority selection.

Ported verbatim from the pre-zoo ``repro.core.selection`` — the jitter
draw (`rng.random(len(eligible))`) is part of the RNG-stream parity
contract.
"""
from __future__ import annotations

from typing import Dict

from repro.selection.base import Knob, Selector, SelectorSpec, class_factory
from repro.selection.registry import register_selector


class PrioritySelector(Selector):
    """RELAY IPS (Alg. 1): sort availability probabilities ascending, shuffle
    ties, take the top n_target. Participants then hold off from checking in
    for ``holdoff`` rounds (Bonawitz et al., 2019 pacing)."""
    name = "priority"

    def __init__(self, holdoff: int = 5):
        self.holdoff = holdoff
        self._held_until: Dict[int, int] = {}

    def select(self, round_idx, checked_in, n_target, rng):
        eligible = [v for v in checked_in
                    if self._held_until.get(v.learner_id, -1) < round_idx]
        if not eligible:
            eligible = list(checked_in)
        # ascending availability; random shuffle breaks ties (Alg. 1)
        jitter = rng.random(len(eligible))
        order = sorted(range(len(eligible)),
                       key=lambda i: (eligible[i].availability_prob, jitter[i]))
        chosen = [eligible[i].learner_id for i in order[:n_target]]
        for lid in chosen:
            self._held_until[lid] = round_idx + self.holdoff
        return chosen


register_selector(SelectorSpec(
    name="priority",
    factory=class_factory(PrioritySelector),
    cls=PrioritySelector,
    doc="RELAY IPS: least-available-first with tie shuffling + hold-off",
    knobs=(Knob("holdoff", 5, "rounds a participant holds off after "
                "selection"),),
))
