"""Selector plugin base: the host-side policy interface + strategy spec.

A participant selector is a *host-side* sequential decision process (it
consumes the engine's ``np.random.Generator`` stream and mutates its own
plain-attribute state), unlike the robust aggregators, which are pure jnp
cell functions.  What the two strategy tables share is the static-key
contract: every selector registers a ``SelectorSpec`` whose static
properties (``needs_feedback``, ``select_all``) describe how the fused
round program must be built around it, and ``repro.selection.selector_key``
folds those into ``repro.sim.pipeline.pipeline_key`` — so each selector
compiles to its own fused-program variant and sweep batches stay uniform.

The spec properties and the program structure they pin:

``needs_feedback``
    The selector consumes the per-row statistical-utility feedback
    (``update_feedback(stat_util=...)`` from the device's loss stats).
    The fused pipeline then fetches the per-round ``(R,)`` l2s vector
    (device->host) and defers feedback to post-dispatch; since the *next*
    round's selection depends on it, prescheduling is capped at K=1
    (``rounds_per_dispatch`` forced to 1).  Feedback-free selectors keep
    the round loop's device->host traffic at zero and chunk freely.

``select_all``
    SAFA semantics: the cohort is every available learner and the round
    ends when ``safa_target_ratio`` of them report (capped by the
    deadline).  Cohort sizes then vary wildly round to round, so the
    pipeline keeps padded shape buckets instead of exact shapes.

Selector state must deep-copy/pickle cleanly (plain attributes only):
``Simulator.capture_state`` snapshots the selector for crash-safe resume.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import registry as _registry


@dataclasses.dataclass
class LearnerView:
    """What the server may know about a checked-in learner."""
    learner_id: int
    availability_prob: float = 1.0   # learner-reported P(available in [mu, 2mu])
    last_stat_util: float = 0.0      # |B_i| * sqrt(mean loss^2) from last participation
    est_duration: float = 0.0        # estimated on-device round time (seconds)
    explored: bool = False           # has participated before


class Selector:
    name = "base"
    # Selectors that ignore availability forecasts / utilities set this False
    # and implement ``select_ids``; the engine then skips building LearnerViews
    # (and the forecaster window queries behind them) on the hot path.  The
    # queries are pure reads, so skipping them never changes forecaster state
    # or the RNG stream — selection is bit-identical either way.
    needs_views = True

    def select(self, round_idx: int, checked_in: Sequence[LearnerView],
               n_target: int, rng: np.random.Generator) -> List[int]:
        raise NotImplementedError

    def select_ids(self, round_idx: int, ids, n_target: int,
                   rng: np.random.Generator) -> List[int]:
        """View-free selection for ``needs_views = False`` selectors; ``ids``
        is the checked-in learner ids in ascending order."""
        raise NotImplementedError

    def update_feedback(self, learner_id: int, *, stat_util: float = None,
                        duration: float = None, round_idx: int = None):
        """Post-round feedback hook (Oort utilities, hold-offs...)."""


@dataclasses.dataclass(frozen=True)
class BuildContext:
    """Build-time world state a selector factory may consume.

    ``substrate`` is the seed-built ``repro.sim.engine.Substrate`` (dataset
    + shards, device profiles, traces); ``durations`` the per-learner
    config-determined round durations.  Factories must only *read* — the
    substrate is shared by every cell of a sweep seed.
    """
    cfg: object
    substrate: object = None
    durations: Optional[np.ndarray] = None


# One documented ``SimConfig.selector_params`` knob — the shared
# strategy-table dataclass (re-exported here for selector files).
Knob = _registry.Knob


@dataclasses.dataclass(frozen=True)
class SelectorSpec:
    """One registered selection strategy (a row of ``SELECTOR_TABLE``).

    ``factory(params, ctx)`` builds the per-run policy object from the
    cell's ``selector_params`` dict and a ``BuildContext``;
    ``needs_feedback`` / ``select_all`` are the static program-structure
    descriptors ``selector_key`` folds into ``pipeline_key`` (see module
    docstring); ``knobs`` documents the accepted ``selector_params`` and
    is enforced — an unknown knob is a config error, not a silent no-op.
    """
    name: str
    factory: Callable[[Dict, BuildContext], Selector]
    doc: str = ""
    needs_feedback: bool = False
    select_all: bool = False
    knobs: tuple = ()                 # Knob(...) entries
    cls: Optional[type] = None        # policy class, when 1:1 (listing aid)

    def knob_names(self) -> tuple:
        return tuple(k.name for k in self.knobs)

    def build(self, cfg, substrate=None, durations=None) -> Selector:
        params = dict(cfg.selector_params or ())
        unknown = set(params) - set(self.knob_names())
        if unknown:
            raise ValueError(
                f"selector {self.name!r}: unknown knob(s) {sorted(unknown)} "
                f"(accepted: {list(self.knob_names()) or 'none'})")
        return self.factory(params, BuildContext(cfg, substrate, durations))


def class_factory(cls: type) -> Callable[[Dict, BuildContext], Selector]:
    """Factory for selectors that are plain ``cls(**knobs)`` constructions."""
    return lambda params, ctx: cls(**params)
