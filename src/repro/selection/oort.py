"""Oort utility-guided selection (Lai et al., OSDI'21).

Ported verbatim from the pre-zoo ``repro.core.selection``.  Oort is the
archetypal ``needs_feedback`` selector: its statistical utility comes from
the per-row device loss stats, so the fused pipeline fetches the round's
l2s vector and caps ``rounds_per_dispatch`` at 1 (see
``repro.selection.base``).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.selection.base import (Knob, LearnerView, Selector, SelectorSpec,
                                  class_factory)
from repro.selection.registry import register_selector


class OortSelector(Selector):
    """Oort (Lai et al., OSDI'21), faithful to its core mechanics:

    util(i) = stat_util(i) * (T_pref / t_i)^alpha  if t_i > T_pref else stat_util(i)

    with epsilon-greedy exploration of never-selected learners (epsilon decays
    0.9 -> 0.2) and a pacer that raises T_pref by ``pacer_delta`` when the
    aggregate utility of selected participants stalls.
    """
    name = "oort"

    def __init__(self, alpha: float = 2.0, pacer_delta: float = 10.0,
                 pacer_window: int = 20, eps0: float = 0.9, eps_min: float = 0.2,
                 eps_decay: float = 0.98):
        self.alpha = alpha
        self.pacer_delta = pacer_delta
        self.pacer_window = pacer_window
        self.eps = eps0
        self.eps_min = eps_min
        self.eps_decay = eps_decay
        self.t_pref = None            # preferred round duration, set lazily
        self._util_history: List[float] = []
        self._stat_util: Dict[int, float] = {}
        self._duration: Dict[int, float] = {}

    def _utility(self, v: LearnerView) -> float:
        stat = self._stat_util.get(v.learner_id, v.last_stat_util)
        dur = self._duration.get(v.learner_id, v.est_duration) or 1.0
        if self.t_pref is not None and dur > self.t_pref:
            stat *= (self.t_pref / dur) ** self.alpha
        return stat

    def select(self, round_idx, checked_in, n_target, rng):
        if self.t_pref is None:
            durs = [v.est_duration for v in checked_in if v.est_duration > 0]
            self.t_pref = float(np.percentile(durs, 50)) if durs else 100.0
        explored = [v for v in checked_in if v.learner_id in self._stat_util]
        unexplored = [v for v in checked_in if v.learner_id not in self._stat_util]
        n_explore = int(round(self.eps * n_target))
        n_exploit = n_target - n_explore

        exploit_order = sorted(explored, key=self._utility, reverse=True)
        chosen = [v.learner_id for v in exploit_order[:n_exploit]]
        # exploration favors fast unexplored learners (Oort's speed heuristic)
        unexplored.sort(key=lambda v: v.est_duration or 1e9)
        chosen += [v.learner_id for v in unexplored[:n_target - len(chosen)]]
        if len(chosen) < n_target:  # backfill from remaining explored
            rest = [v.learner_id for v in exploit_order[n_exploit:]
                    if v.learner_id not in chosen]
            chosen += rest[:n_target - len(chosen)]
        self.eps = max(self.eps_min, self.eps * self.eps_decay)

        # pacer: if utility over the last window stalls, relax T_pref
        window_util = sum(self._utility(v) for v in checked_in
                          if v.learner_id in chosen)
        self._util_history.append(window_util)
        h = self._util_history
        if len(h) >= 2 * self.pacer_window:
            recent = sum(h[-self.pacer_window:])
            prev = sum(h[-2 * self.pacer_window:-self.pacer_window])
            if recent <= prev:
                self.t_pref += self.pacer_delta
                self._util_history = h[-self.pacer_window:]
        return chosen[:n_target]

    def update_feedback(self, learner_id, *, stat_util=None, duration=None,
                        round_idx=None):
        if stat_util is not None:
            self._stat_util[learner_id] = stat_util
        if duration is not None:
            self._duration[learner_id] = duration


register_selector(SelectorSpec(
    name="oort",
    factory=class_factory(OortSelector),
    cls=OortSelector,
    needs_feedback=True,
    doc="Oort: stat utility x completion-time penalty, eps-greedy + pacer",
    knobs=(Knob("alpha", 2.0, "completion-time penalty exponent"),
           Knob("pacer_delta", 10.0, "T_pref step when utility stalls"),
           Knob("pacer_window", 20, "pacer comparison window (rounds)"),
           Knob("eps0", 0.9, "initial exploration fraction"),
           Knob("eps_min", 0.2, "exploration floor"),
           Knob("eps_decay", 0.98, "per-round exploration decay")),
))
