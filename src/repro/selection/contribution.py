"""Contribution-weighted selection with a fairness floor (survey families
2207.03681 / 2311.06801: contribution/Shapley-weighted + fairness-
constrained selection, collapsed into one practical strategy).

Each learner carries an exponentially-decayed cumulative *contribution*
score fed by the post-round statistical utility (a cheap online stand-in
for Shapley value — so this is a ``needs_feedback`` selector, K=1).
Selection is greedy on contribution, but a fairness floor reserves
``ceil(fairness_frac * n_target)`` slots each round for the longest-
starved checked-in learners (never-selected first), preventing the
rich-get-richer lockout pure contribution ranking converges to.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.selection.base import Knob, Selector, SelectorSpec, class_factory
from repro.selection.registry import register_selector


class ContributionSelector(Selector):
    name = "contribution"
    needs_views = False

    def __init__(self, decay: float = 0.9, fairness_frac: float = 0.2):
        self.decay = float(decay)
        self.fairness_frac = float(fairness_frac)
        self._score: Dict[int, float] = {}
        self._last_sel: Dict[int, int] = {}   # round last selected

    def select_ids(self, round_idx, ids, n_target, rng):
        ids = list(ids)
        # one jitter draw per call (tie-breaks both rankings): the RNG
        # stream advances identically regardless of score state
        jitter = rng.random(len(ids))
        if len(ids) <= n_target:
            chosen = ids
        else:
            floor = min(int(math.ceil(self.fairness_frac * n_target)),
                        n_target)
            # fairness floor: longest-unselected first (never-selected at
            # the front), jitter breaks ties
            starved = sorted(range(len(ids)),
                             key=lambda k: (self._last_sel.get(ids[k], -1),
                                            jitter[k]))
            chosen = [ids[k] for k in starved[:floor]]
            taken = set(chosen)
            # remaining slots: contribution-ranked
            ranked = sorted((k for k in range(len(ids))
                             if ids[k] not in taken),
                            key=lambda k: (-self._score.get(ids[k], 0.0),
                                           jitter[k]))
            chosen += [ids[k] for k in ranked[:n_target - len(chosen)]]
        for lid in chosen:
            self._last_sel[lid] = round_idx
        return chosen

    def select(self, round_idx, checked_in, n_target, rng):
        return self.select_ids(round_idx, [v.learner_id for v in checked_in],
                               n_target, rng)

    def update_feedback(self, learner_id, *, stat_util=None, duration=None,
                        round_idx=None):
        if stat_util is not None:
            self._score[learner_id] = (self.decay
                                       * self._score.get(learner_id, 0.0)
                                       + stat_util)


register_selector(SelectorSpec(
    name="contribution",
    factory=class_factory(ContributionSelector),
    cls=ContributionSelector,
    needs_feedback=True,
    doc="decayed cumulative contribution ranking + fairness floor slots",
    knobs=(Knob("decay", 0.9, "per-update score decay"),
           Knob("fairness_frac", 0.2, "slot fraction reserved for the "
                "longest-starved learners")),
))
