"""Participant-selection strategy zoo (ROADMAP item 4).

A selector is a **file, not an engine change**: one module defining a
``Selector`` subclass plus one ``register_selector(SelectorSpec(...))``
call at import time.  The spec's static properties (``needs_feedback``,
``select_all``) describe the fused-program structure the strategy needs,
and ``selector_key`` folds them — with the strategy name and its
``selector_params`` knobs — into ``repro.sim.pipeline.pipeline_key``, so
every selector compiles to its own program variant and sweeps batch
selector-uniformly on shared seeds.  ``docs/extending.md`` is the
contributor guide; ``repro.robust.aggregators`` is the sibling table for
the device-side aggregation strategies.

Registered strategies (``python -m repro.sweeps --list-selectors``):

  random        uniform sampling (FedAvg baseline)
  oort          utility x speed, eps-greedy + pacer (Lai et al., OSDI'21)
  priority      RELAY IPS Alg. 1: least-available-first + hold-off
  safa          select-all, target-ratio round end (Wu et al., 2021)
  flips         label-distribution k-means, cluster-balanced budgets
  ucb           UCB1 bandit on stat-utility rewards
  contribution  decayed contribution ranking + fairness floor
"""
from repro.selection.base import (BuildContext, Knob, LearnerView,  # noqa: F401
                                  Selector, SelectorSpec, class_factory)
from repro.selection.registry import (SELECTOR_TABLE,  # noqa: F401
                                      build_selector, describe_selectors,
                                      normalize_selector_params,
                                      register_selector, selector_key)

# importing a strategy module registers it; table order = listing order
from repro.selection.uniform import RandomSelector  # noqa: F401,E402
from repro.selection.oort import OortSelector  # noqa: F401,E402
from repro.selection.priority import PrioritySelector  # noqa: F401,E402
from repro.selection.safa import SafaSelector  # noqa: F401,E402
from repro.selection.flips import FlipsSelector  # noqa: F401,E402
from repro.selection.ucb import UcbSelector  # noqa: F401,E402
from repro.selection.contribution import ContributionSelector  # noqa: F401,E402

# name -> class map kept for pre-zoo callers (`SELECTORS[name]()`); new
# code should go through SELECTOR_TABLE / build_selector, which honor
# selector_params and build-time context (FLIPS needs the substrate)
SELECTORS = {name: spec.cls for name, spec in SELECTOR_TABLE.items()
             if spec.cls is not None}

__all__ = [
    "BuildContext", "Knob", "LearnerView", "Selector", "SelectorSpec",
    "SELECTOR_TABLE", "SELECTORS", "build_selector", "class_factory",
    "describe_selectors", "normalize_selector_params", "register_selector",
    "selector_key",
    "RandomSelector", "OortSelector", "PrioritySelector", "SafaSelector",
    "FlipsSelector", "UcbSelector", "ContributionSelector",
]
