"""Paper-style reporting over sweep results.

Renders the resource-to-accuracy comparison (the paper's headline currency,
Figs. 2/6/7) for a whole grid the way ``examples/quickstart.py`` prints it
for two cells: one row per policy/scenario group (seeds aggregated), columns
for accuracy, resource usage, waste, and unique participation — as plain
text or a markdown table.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.sweeps.results import SweepResults

COLUMNS = (
    ("final_accuracy", "accuracy", "{:.3f}"),
    ("best_accuracy", "best", "{:.3f}"),
    ("resource_used", "resources(s)", "{:.0f}"),
    ("waste_fraction", "waste", "{:.1%}"),
    ("unique_participants", "unique", "{:.0f}"),
)


def _group_label(row: dict, by: Sequence[str]) -> str:
    return " ".join(f"{a}={row[a]}" for a in by)


def resource_to_accuracy_rows(results: SweepResults,
                              by: Optional[Sequence[str]] = None) -> list[dict]:
    by = ([a for a in results.axes if a != "seed"]
          if by is None else list(by))
    rows = results.group_stats(by=by)
    # best resource-to-accuracy first: highest accuracy per resource second
    rows.sort(key=lambda r: (-r["final_accuracy"], r["resource_used"]))
    for r in rows:
        r["_label"] = _group_label(r, by)
    return rows


def markdown_table(results: SweepResults,
                   by: Optional[Sequence[str]] = None) -> str:
    rows = resource_to_accuracy_rows(results, by)
    head = "| scenario | " + " | ".join(h for _, h, _ in COLUMNS) + " | seeds |"
    sep = "|" + "---|" * (len(COLUMNS) + 2)
    lines = [head, sep]
    for r in rows:
        cells = " | ".join(fmt.format(r[k]) for k, _, fmt in COLUMNS)
        lines.append(f"| {r['_label']} | {cells} | {r['n']} |")
    return "\n".join(lines)


def text_table(results: SweepResults,
               by: Optional[Sequence[str]] = None) -> str:
    rows = resource_to_accuracy_rows(results, by)
    label_w = max([len(r["_label"]) for r in rows] + [8]) + 2
    head = ("scenario".ljust(label_w)
            + "".join(h.rjust(14) for _, h, _ in COLUMNS) + "  seeds".rjust(7))
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(r["_label"].ljust(label_w)
                     + "".join(fmt.format(r[k]).rjust(14)
                               for k, _, fmt in COLUMNS)
                     + str(r["n"]).rjust(7))
    return "\n".join(lines)


def savings_line(results: SweepResults, best: dict, baseline: dict) -> str:
    """One-line takeaway comparing two coordinate selections, e.g.
    ``savings_line(res, {"policy": "relay"}, {"policy": "random"})``."""
    b = results.filter(**best).group_stats(by=list(best))
    r = results.filter(**baseline).group_stats(by=list(baseline))
    if not b or not r or not r[0]["resource_used"]:
        return "savings: n/a"
    save = 1 - b[0]["resource_used"] / r[0]["resource_used"]
    return (f"{_group_label(b[0], list(best))} used {save:.0%} fewer learner "
            f"resources than {_group_label(r[0], list(baseline))} "
            f"(accuracy {b[0]['final_accuracy']:.3f} vs "
            f"{r[0]['final_accuracy']:.3f})")
