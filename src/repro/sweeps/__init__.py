"""Vectorized scenario sweeps: declarative grids of FL simulations executed
as one batched program over the flat fast path.

  grid     — named axes (policy, SAA, hardware, availability, mapping, seeds)
             expanded to concrete ``SimConfig`` cells with shared-seed pairing
  runner   — lockstep batched executor: packed (S, n, D) training, vmapped /
             Pallas-kernel SAA aggregation, batched server step + eval;
             per-cell metrics bit-identical to serial ``Simulator.run``
  sharding — sweep-axis device mesh: cell placement over a 1-D
             ``jax.sharding.Mesh``, shard-aware repacking, row migration
             (``SweepRunner(cells, shard=True)`` / ``mesh=``)
  results  — struct-of-arrays metric accumulation per cell
  report   — paper-style resource-to-accuracy tables (text / markdown)

``python -m repro.sweeps [--smoke] [--sharded] [--rounds-per-dispatch K]``
runs a demo grid, verifies serial parity, and writes ``BENCH_sweeps.json``.
"""
from repro.sweeps.grid import (AXES, POLICIES, Cell, SweepSpec,  # noqa: F401
                               axis_updates, register_axis)
from repro.sweeps.results import CellResult, SweepResults  # noqa: F401
from repro.sweeps.runner import (SweepRunner, assert_parity,  # noqa: F401
                                 compat_key, resume_sweep, run_batched,
                                 run_serial)
from repro.sweeps.sharding import (Placement, local_capacity,  # noqa: F401
                                   sweep_mesh)
