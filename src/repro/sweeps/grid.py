"""Declarative scenario grids: named axes -> concrete ``SimConfig`` cells.

The paper's headline results are grids — selectors x SAA on/off x hardware
scenarios HS1-HS4 x availability settings x non-IID mappings, each over
multiple seeds.  A ``SweepSpec`` names those axes declaratively and expands
to ``Cell``s with **shared-seed pairing**: every axis combination is
instantiated once per seed with ``SimConfig.seed = seed``, so competing
policies see bit-identical datasets, device populations, and availability
traces (matched-condition comparisons; the substrate is also literally
shared in memory by ``repro.sweeps.runner``).

Axes resolve through a registry: an axis is either a registered named axis
(``policy``, ``hardware``, ``availability``, ...) mapping a value to a dict
of config-field updates, or any raw ``SimConfig`` field name.  New axes
register with ``register_axis``.

Accuracy-target early stop rides the raw-field mechanism: put
``target_accuracy`` in ``base`` (one bar for the whole grid) or use it as
an axis (``axes={"target_accuracy": [0.6, 0.7]}``) — cells that reach
their target leave the lockstep batch at that eval round (shrinking
bucket-padded repacking in the runner), and
``SweepResults.resource_to_target()`` tabulates the per-cell cost of
reaching the bar.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Mapping, Sequence

from repro.sim.engine import SimConfig

_SIMCONFIG_FIELDS = {f.name for f in dataclasses.fields(SimConfig)}

AxisFn = Callable[[object], dict]
AXES: Dict[str, AxisFn] = {}


def register_axis(name: str, fn: AxisFn) -> AxisFn:
    """Register a named axis: ``fn(value) -> dict`` of SimConfig updates."""
    AXES[name] = fn
    return fn


# End-to-end policy presets (paper §5 baselines); use the ``selector`` axis
# when only the selection strategy should vary (the whole
# ``repro.selection`` zoo, validated against SELECTOR_TABLE).
POLICIES = {
    "random": dict(selector="random"),
    "oort": dict(selector="oort"),
    "priority": dict(selector="priority"),
    "safa": dict(selector="safa", saa=True),
    "relay": dict(selector="priority", saa=True, apt=True,
                  scaling_rule="relay"),
}


def _selector_axis(v):
    from repro.selection import SELECTOR_TABLE
    return {"selector": _check(v, tuple(SELECTOR_TABLE), "selector")}


def _model_axis(v):
    from repro.learners import MODEL_TABLE
    return {"model": _check(v, tuple(MODEL_TABLE), "model")}


register_axis("policy", lambda v: dict(POLICIES[v]))
register_axis("selector", _selector_axis)
register_axis("model", _model_axis)
register_axis("saa", lambda v: {"saa": bool(v)})
register_axis("apt", lambda v: {"apt": bool(v)})
register_axis("hardware", lambda v: {"hardware_scenario": _check(
    v, ("HS1", "HS2", "HS3", "HS4"), "hardware")})
register_axis("availability", lambda v: {"dynamic_availability": (
    {"dynamic": True, "static": False}[v] if isinstance(v, str) else bool(v))})
register_axis("mapping", lambda v: {"mapping": v})
register_axis("scaling_rule", lambda v: {"scaling_rule": _check(
    v, ("equal", "dynsgd", "adasgd", "relay"), "scaling_rule")})


def _check(v, allowed, axis):
    if v not in allowed:
        raise ValueError(f"axis {axis!r}: {v!r} not in {allowed}")
    return v


def axis_updates(name: str, value) -> dict:
    """Config-field updates for one (axis, value) coordinate."""
    if name in AXES:
        return AXES[name](value)
    if name in _SIMCONFIG_FIELDS:
        return {name: value}
    raise KeyError(f"unknown sweep axis {name!r} "
                   f"(not registered, not a SimConfig field)")


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "on" if v else "off"
    return str(v)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One concrete simulation of a sweep: its grid coordinates + config."""
    name: str
    coords: tuple            # ((axis, value), ...), seed last
    config: SimConfig

    def coord(self, axis: str, default=None):
        return dict(self.coords).get(axis, default)


@dataclasses.dataclass
class SweepSpec:
    """Declarative scenario grid.

    axes: ordered {axis name: list of values}; base: fixed SimConfig
    overrides shared by every cell; seeds: shared-seed pairing — the full
    axis product is replicated per seed.

    Axes apply in order and later axes override earlier ones on shared
    config fields (e.g. a ``saa`` axis after a ``policy`` axis toggles SAA
    within each preset).  ``expand`` raises if an override collapses two
    differently-labeled cells onto the identical config — the symptom of
    axes ordered the wrong way around.
    """
    axes: Mapping[str, Sequence]
    base: Mapping[str, object] = dataclasses.field(default_factory=dict)
    seeds: Sequence[int] = (0,)

    def expand(self) -> list[Cell]:
        names = list(self.axes)
        cells, seen = [], {}
        for combo in itertools.product(*(self.axes[n] for n in names)):
            for seed in self.seeds:
                kw = dict(self.base)
                coords = []
                for n, v in zip(names, combo):
                    kw.update(axis_updates(n, v))
                    coords.append((n, v))
                kw["seed"] = int(seed)
                coords.append(("seed", int(seed)))
                name = "/".join(f"{n}={_fmt(v)}" for n, v in coords)
                cfg = SimConfig(**kw)
                dup = seen.setdefault(repr(cfg), name)
                if dup != name:
                    raise ValueError(
                        f"cells {dup!r} and {name!r} expand to the identical "
                        "config — an earlier axis's field is overridden by a "
                        "later axis; reorder the axes")
                cells.append(Cell(name, tuple(coords), cfg))
        return cells

    @property
    def size(self) -> int:
        n = len(self.seeds)
        for vals in self.axes.values():
            n *= len(vals)
        return n
