"""Sweep demo / smoke entry point.

  PYTHONPATH=src python -m repro.sweeps                  # demo grid
  PYTHONPATH=src python -m repro.sweeps --smoke          # small CI grid
  PYTHONPATH=src python -m repro.sweeps --list-selectors # strategy tables
  PYTHONPATH=src python -m repro.sweeps --selector random,oort,flips,ucb

Expands a policy x SAA x hardware grid (or, with ``--selector``, a
selector-zoo grid racing strategies from ``repro.selection`` under
matched seeds), runs it batched, re-runs every cell serially to assert
bit-identical metrics, prints the paper-style resource-to-accuracy table,
and writes ``BENCH_sweeps.json`` (batched vs serial wall-clock) at the
repo root.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

from repro.sweeps import (SweepSpec, assert_parity, resume_sweep, run_batched,
                          run_serial)
from repro.sweeps.report import savings_line, text_table


def demo_spec(smoke: bool) -> SweepSpec:
    if smoke:
        return SweepSpec(
            axes={"policy": ["random", "relay"], "saa": [False, True]},
            base=dict(n_learners=60, rounds=8, eval_every=4, n_target=5,
                      mapping="label_uniform"),
            seeds=(0,))
    return SweepSpec(
        axes={"policy": ["random", "oort", "safa", "relay"],
              "saa": [False, True],
              "hardware": ["HS1", "HS3"]},
        base=dict(n_learners=100, rounds=40, eval_every=10,
                  mapping="label_uniform"),
        seeds=(0, 1))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small CI grid")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the sweep axis over the local device mesh")
    ap.add_argument("--participant-shards", type=int, default=0,
                    help="shard each round's cohort rows over N participant "
                         "mesh shards (with --sharded: a (devices/N) x N "
                         "('s', 'p') mesh; alone: N of the local devices)")
    ap.add_argument("--rounds-per-dispatch", type=int, default=1,
                    help="K rounds per device dispatch (lax.scan chunking)")
    ap.add_argument("--out", default=None, help="BENCH_sweeps.json path")
    ap.add_argument("--checkpoint", default=None,
                    help="write crash-safe sweep snapshots to this path")
    ap.add_argument("--checkpoint-every", type=int, default=2,
                    help="rounds between snapshots (with --checkpoint)")
    ap.add_argument("--resume", default=None, metavar="CKPT",
                    help="resume a crashed sweep from its snapshot and "
                         "write the completed results (bit-identical to an "
                         "uninterrupted run)")
    ap.add_argument("--crash-after", type=int, default=None, metavar="R",
                    help="chaos: inject a crash once round R completes")
    ap.add_argument("--crash-hard", action="store_true",
                    help="chaos: crash via SIGKILL instead of an exception")
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="run at full telemetry (level 2) and export the run "
                         "timeline there: rounds.jsonl / events.jsonl, "
                         "trace.json (Perfetto), metrics.prom")
    ap.add_argument("--aggregator", default=None, metavar="A,B",
                    help="add a robust-aggregator sweep axis (comma list "
                         "from saa, coord_median, trimmed_mean, krum, "
                         "multi_krum, norm_median_clip)")
    ap.add_argument("--attack", default=None, metavar="X,Y",
                    help="add a coordinated-attack sweep axis (comma list "
                         "from none, collude_signflip, collude_same_value, "
                         "alie, adaptive); attacked and clean cells share "
                         "seeds, so every comparison is matched-condition")
    ap.add_argument("--attack-frac", type=float, default=0.25,
                    help="attacker fraction of the population (with --attack)")
    ap.add_argument("--selector", default=None, metavar="A,B",
                    help="race selection strategies: replaces the demo grid's "
                         "policy axis with a selector axis (comma list from "
                         "the repro.selection zoo; see --list-selectors)")
    ap.add_argument("--model", default=None, metavar="A,B",
                    help="add a learner-model sweep axis (comma list from "
                         "the repro.learners zoo; see --list-models; LM "
                         "models need --benchmark tokens)")
    ap.add_argument("--benchmark", default=None, metavar="B",
                    help="override the grid's benchmark (classifier: speech/"
                         "cifar10/openimage; LM: tokens/tokens_skew)")
    ap.add_argument("--list-selectors", action="store_true",
                    help="print the registered selector strategy table "
                         "(name, cadence, knobs) and exit")
    ap.add_argument("--list-aggregators", action="store_true",
                    help="print the registered robust-aggregator strategy "
                         "table and exit")
    ap.add_argument("--list-models", action="store_true",
                    help="print the registered learner-model strategy table "
                         "(name, family, data kind, kernel, knobs) and exit")
    args = ap.parse_args(argv)

    if args.list_selectors or args.list_aggregators or args.list_models:
        if args.list_selectors:
            from repro.selection import describe_selectors
            print(describe_selectors())
        if args.list_aggregators:
            from repro.robust.aggregators import describe_aggregators
            print(describe_aggregators())
        if args.list_models:
            from repro.learners import describe_models
            print(describe_models())
        return

    telemetry = None
    if args.telemetry_dir:
        from repro.telemetry import TelemetrySession
        telemetry = TelemetrySession(args.telemetry_dir)
    try:
        _run(args, telemetry)
    finally:
        if telemetry is not None:
            telemetry.close()
            print(f"# telemetry exported to {args.telemetry_dir}")


def _run(args, telemetry) -> None:
    if args.resume:
        results, wall = resume_sweep(args.resume, telemetry=telemetry)
        print(f"# resumed from {args.resume} in {wall:.2f}s "
              f"({len(results)} cells)")
        print(text_table(results))
        if args.out:
            payload = {"bench": "sweeps", "mode": "resume",
                       "resumed_from": args.resume, "cells": len(results),
                       "results": results.to_json_dict()}
            pathlib.Path(args.out).write_text(
                json.dumps(payload, indent=2) + "\n")
            print(f"\n# wrote {args.out}")
        return

    spec = demo_spec(args.smoke)
    if args.selector:
        # the selector axis REPLACES the policy axis: policy presets differ
        # (partly) by selector, so stacking both would collapse cells onto
        # identical configs (expand() rejects that); shared-seed pairing
        # makes the zoo race matched-condition
        axes = {k: v for k, v in spec.axes.items() if k != "policy"}
        spec.axes = {"selector": args.selector.split(","), **axes}
    # --aggregator / --attack extend the grid: both are raw SimConfig
    # fields, so they ride the grid's field-axis fallthrough and inherit
    # shared-seed pairing (attack x defense cells see identical cohorts)
    if args.aggregator:
        kinds = args.aggregator.split(",")
        spec.axes = dict(spec.axes, aggregator=kinds)
        if any(k in ("krum", "multi_krum") for k in kinds):
            spec.base = dict(spec.base, krum_f=max(
                int(dict(spec.base).get("krum_f", 0)), 1))
    if args.attack:
        spec.axes = dict(spec.axes, attack=args.attack.split(","))
        spec.base = dict(spec.base, attack_frac=args.attack_frac)
    if args.model:
        spec.axes = dict(spec.axes, model=args.model.split(","))
    if args.benchmark:
        base = dict(spec.base, benchmark=args.benchmark)
        # token benchmarks own their data-to-learner mapping (the shard
        # structure); drop a classifier-grid mapping axis value silently
        if args.benchmark in ("tokens", "tokens_skew"):
            base.pop("mapping", None)
        spec.base = base
    cells = spec.expand()
    if args.rounds_per_dispatch != 1:
        cells = [dataclasses.replace(c, config=dataclasses.replace(
            c.config, rounds_per_dispatch=args.rounds_per_dispatch))
            for c in cells]
    if telemetry is not None:
        cells = [dataclasses.replace(c, config=dataclasses.replace(
            c.config, telemetry=2)) for c in cells]
    if args.sharded or args.participant_shards:
        import jax
        axes = (["sweep"] if args.sharded else []) \
            + (["participant"] if args.participant_shards else [])
        print(f"# sharding the {'+'.join(axes)} axis over "
              f"{len(jax.devices())} device(s)")
    print(f"# sweep: {len(cells)} cells "
          f"({' x '.join(f'{a}[{len(v)}]' for a, v in spec.axes.items())}"
          f" x seeds[{len(spec.seeds)}])")

    fault_plan = None
    if args.crash_after is not None:
        from repro.faults import FaultPlan
        fault_plan = FaultPlan(
            n_learners=max(c.config.n_learners for c in cells),
            rounds=max(c.config.rounds for c in cells),
            crash_after=args.crash_after,
            crash_mode="hard" if args.crash_hard else "soft")
    results, batched_wall = run_batched(
        cells, shard=args.sharded,
        shard_participants=args.participant_shards,
        fault_plan=fault_plan,
        checkpoint_path=args.checkpoint,
        checkpoint_every=args.checkpoint_every if args.checkpoint else 0,
        telemetry=telemetry)
    # the serial reference stays at K=1 and telemetry off: an independent
    # ground truth, not the same machinery run twice (level-2 telemetry is
    # bit-transparent, so the parity assert below also proves that)
    serial_cells = ([dataclasses.replace(c, config=dataclasses.replace(
        c.config, rounds_per_dispatch=1, telemetry=0)) for c in cells]
        if args.rounds_per_dispatch != 1 or telemetry is not None else cells)
    serial_summaries, serial_wall = run_serial(serial_cells)
    assert_parity(results, serial_summaries)
    speedup = serial_wall / max(batched_wall, 1e-9)
    print(f"# batched {batched_wall:.2f}s vs serial {serial_wall:.2f}s "
          f"({speedup:.1f}x), per-cell metrics bit-identical\n")
    print(text_table(results))
    if "policy" in spec.axes:
        print()
        print(savings_line(results, {"policy": "relay", "saa": True},
                           {"policy": "random", "saa": False}))

    out = (pathlib.Path(args.out) if args.out else
           pathlib.Path(__file__).resolve().parents[3] / "BENCH_sweeps.json")
    payload = {
        "bench": "sweeps",
        "mode": "smoke" if args.smoke else "demo",
        "sharded": args.sharded,
        "participant_shards": args.participant_shards,
        "rounds_per_dispatch": args.rounds_per_dispatch,
        "cells": len(cells),
        "batched_wall_s": round(batched_wall, 3),
        "serial_wall_s": round(serial_wall, 3),
        "speedup": round(speedup, 2),
        "parity": True,
        "results": results.to_json_dict(),
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\n# wrote {out}")


if __name__ == "__main__":
    main()
