"""Sweep-axis device sharding: mesh, cell placement, and migration.

The sweep subsystem's `(S, ...)` leading axis is the natural device axis:
cells are independent simulations, so the fused round program partitions
over a 1-D ``jax.sharding.Mesh`` with axis ``"s"`` without any cross-cell
collectives — each shard runs the identical round body on its own slice of
cells, caches, and index arrays (``shard_map`` in ``repro.sim.pipeline``).

This module owns the host-side layout machinery:

``sweep_mesh``
    Build the 1-D mesh over the local devices.  On a single-device host the
    mesh degenerates to one shard (the sharded code path stays exercisable
    everywhere); CI forces a multi-device CPU host via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.  The pipeline
    normalizes this 1-D mesh into the 2-D ``("s", "p")`` round mesh
    (participant axis size 1); ``repro.sim.participant_sharding`` owns the
    2-D builders and the participant-axis row placement, and composes with
    the cell placement below via ``SweepRunner(shard_participants=)``.

``Placement``
    The cell -> (shard, local slot) assignment.  Cells are split into
    balanced contiguous blocks (ascending cell index), every shard's local
    arrays are padded to one shared power-of-two bucket ``s_loc`` plus one
    scratch row (the padding target for empty aggregation groups), so the
    global params/optimizer tensors are rectangular
    ``(n_shards, s_loc + 1, D)`` and shard cleanly.

Shard-aware repacking: when early-stopped cells shrink the live set enough
that the *bucketed* per-shard capacity drops, the pipeline rebuilds a
smaller ``Placement`` — live cells are compacted across shard boundaries
so stopped cells vacate their slots in whole per-shard bucket steps, and
every shard's padded work shrinks together (lockstep SPMD wall-time tracks
the busiest shard, so the shrink only pays off when all shards shed rows).
Migration is pure data movement (``reshard_rows``: one gather with the
target sharding), so repacking never changes any cell's bits.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.aggregation import bucket_pow2

SWEEP_AXIS = "s"


def sweep_mesh(devices=None) -> Mesh:
    """1-D device mesh over the sweep axis (all local devices by default).

    Placement specs for the round pipeline's device tensors live in
    ``repro.sim.participant_sharding`` (which normalizes this mesh into the
    2-D ``("s", "p")`` form) — they are mesh-shape-aware, so there are no
    1-D spec builders here to misuse on a 2-D mesh.
    """
    devs = jax.devices() if devices is None else list(devices)
    return Mesh(np.array(devs), (SWEEP_AXIS,))


def local_capacity(n_cells: int, n_shards: int) -> int:
    """Bucketed per-shard cell capacity: the power-of-two bucket of the
    balanced split's largest shard (>= 1 even for an empty live set)."""
    return bucket_pow2(max(-(-max(n_cells, 1) // n_shards), 1))


@dataclasses.dataclass(frozen=True)
class Placement:
    """Cell -> (shard, local slot) assignment over a 1-D sweep mesh.

    ``s_loc`` is the shared per-shard cell capacity (scratch row excluded);
    the global row of a cell in the flattened ``(n_shards * (s_loc+1), D)``
    view is ``shard * (s_loc + 1) + slot``, and each shard's scratch row
    (index ``s_loc`` locally) is the write target of padding aggregation
    groups — never a real cell.
    """
    n_shards: int
    s_loc: int
    shard_of: dict
    slot_of: dict
    shards: tuple           # shard -> tuple of its cells, ascending

    @staticmethod
    def build(cells, n_shards: int) -> "Placement":
        cells = sorted(cells)
        n = len(cells)
        s_loc = local_capacity(n, n_shards)
        sizes = [n // n_shards + (1 if j < n % n_shards else 0)
                 for j in range(n_shards)]
        shard_of, slot_of, shards, off = {}, {}, [], 0
        for j, size in enumerate(sizes):
            block = cells[off:off + size]
            off += size
            shards.append(tuple(block))
            for slot, c in enumerate(block):
                shard_of[c] = j
                slot_of[c] = slot
        return Placement(n_shards, s_loc, shard_of, slot_of, tuple(shards))

    @property
    def scratch_slot(self) -> int:
        return self.s_loc

    @property
    def rows_per_shard(self) -> int:
        return self.s_loc + 1

    @property
    def total_rows(self) -> int:
        return self.n_shards * (self.s_loc + 1)

    def flat_row(self, cell) -> int:
        return self.shard_of[cell] * (self.s_loc + 1) + self.slot_of[cell]

    def scratch_flat(self, shard: int) -> int:
        return shard * (self.s_loc + 1) + self.s_loc


# ---------------------------------------------------------------------------
# Migration: gather rows of a (n_shards, rows_loc, ...) tensor into a new
# layout under the same sharding.  Used for repacking (placement shrink) and
# for sharded stale-cache growth; both are pure data movement.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=16)
def _reshard_fn(sharding: NamedSharding):
    @functools.partial(jax.jit, out_shardings=sharding, static_argnums=(2,))
    def f(arr, new_to_old, head):
        flat = arr.reshape((-1,) + arr.shape[2:])
        return flat[new_to_old].reshape(head + arr.shape[2:])
    return f


def reshard_rows(arr, new_to_old: np.ndarray, head: tuple,
                 sharding: NamedSharding):
    """``out[shard, slot] = arr.flat_rows[new_to_old[shard * rows + slot]]``.

    arr: (n_shards, rows_loc, ...) device tensor; new_to_old: flat int32 map
    of length ``head[0] * head[1]`` into the *old* flattened row space;
    returns a (head[0], head[1], ...) tensor placed under ``sharding``.
    The map upload is an explicit ``device_put`` (transfer-guard clean).
    """
    idx = jax.device_put(np.asarray(new_to_old, np.int32),
                         NamedSharding(sharding.mesh, P()))
    return _reshard_fn(sharding)(arr, idx, tuple(head))
