"""Batched multi-simulation executor over the flat fast path.

``SweepRunner`` drives S compatible simulations in lockstep.  Each round,
every cell's host state machine (availability census, selection, batch
sampling, arrival schedule — the Simulator's own ``_begin_round`` /
``_schedule_round`` / ``_record_round`` methods, shared code with serial
runs) executes per cell, while the device side is batched across the sweep
axis.  Two executors:

  * fused device-resident pipeline (default, ``repro.sim.pipeline``): the
    whole round — packed cohort training with per-row parameters, straggler
    scatter into the shared device stale cache, gathered (G, n, D) SAA
    aggregation and the batched server apply — is ONE jitted dispatch with
    donated buffers.  Update rows never visit the host; per-round traffic
    is index arrays down and (for a ``needs_feedback`` selector batch —
    Oort, UCB, contribution) a stat-utility vector back.  Cells that hit their ``target_accuracy`` drop out of the
    lockstep batch entirely (shrinking bucket-padded repacking), so a
    sweep's cost tracks live cells rather than S x rounds;

  * per-stage batched path (``fused_rounds=False`` cells): the PR-2
    executor — packed train call, host-side update collection,
    ``sweep_bucket_pad`` + one vmapped SAA program (or the sweep-grid
    Pallas kernel), batched server step + eval — kept as the stage-by-stage
    parity/benchmark baseline.

Rows are independent under vmap and reductions are padding-invariant (zero
rows contribute exact zeros), so every cell's metrics are **bit-identical**
to a serial ``Simulator.run`` of the same config/seed — asserted by
``tests/test_sweep_parity.py`` and re-checked by the benchmarks.

Cells sharing a substrate key (benchmark, mapping, n_learners, seed,
availability) also share one ``Substrate`` build — the dominant cost of a
serial sweep — and the fused pipeline additionally shares one device copy
of each substrate's dataset across its cells.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import unflatten_update, yogi_apply_flat
from repro.core.staleness import RULE_ID
from repro.faults.attacks import attack_key
from repro.robust.aggregators import robust_key, robust_sweep_fn
from repro.sim import learner as ln
from repro.sim.engine import Simulator, Substrate, substrate_key
from repro.sim.pipeline import RoundPipeline, pipeline_key
from repro.sweeps.grid import Cell
from repro.sweeps.results import CellResult, SweepResults


ROW_BLOCK = 128   # packed-row padding bucket granularity (see bucket_block)


def compat_key(cfg) -> tuple:
    """Cells sharing this key run in one lockstep batch: fields that fix the
    compiled programs' shapes/static arguments or the lockstep cadence.
    Everything else (SAA, APT, setting, hardware, seeds, beta, server_lr,
    target_accuracy, and — on the jnp path — scaling_rule, which is a
    traced per-cell ``lax.switch`` operand) varies freely within a batch;
    the Pallas sweep kernel is compiled per rule, so kernel-backed cells
    split by rule.  Fused and per-stage cells never share a batch.  The
    selector (``selector_key`` inside ``pipeline_key``) splits batches
    too: batches are selector-uniform, so a feedback selector's K=1 cap
    and l2s fetch apply only to its own cells — and per-cell results stay
    bit-identical however the batches regroup (padding invariance)."""
    return pipeline_key(cfg) + (cfg.fused_rounds,)


@functools.lru_cache(maxsize=8)
def _packed_train_fn(spec, lr, prox_mu, loss=ln._xent):
    """One compiled program trains every cell's cohort: rows (R,) index the
    owning cell, whose flat parameters are gathered per row.  ``loss`` is
    the model objective off the MODEL_TABLE build (a stable object —
    ``build_model`` caches — so it is a sound lru key; ``model_key`` lives
    in ``compat_key``, keeping batches model-uniform)."""
    step = functools.partial(ln.local_train_flat, spec=spec, lr=lr,
                             prox_mu=prox_mu, loss=loss)

    def f(flat_params, cell_rows, bx, by):
        return jax.vmap(step)(flat_params[cell_rows], bx, by)

    return jax.jit(f)


@functools.lru_cache(maxsize=8)
def _sweep_eval_shared_fn(spec, evaluate=ln.evaluate):
    """Batched eval, one test set shared by every cell (the common
    shared-seed case): no per-cell gather or duplication at all."""
    def ev(flat, x, y):
        return evaluate(unflatten_update(flat, spec), x, y)

    return jax.jit(jax.vmap(ev, in_axes=(0, None, None)))


@functools.lru_cache(maxsize=8)
def _sweep_eval_fn(spec, evaluate=ln.evaluate):
    """Batched eval over mixed substrates; cells index into the batch's
    *unique* test sets (cells sharing a substrate share one host copy)."""
    def ev(flat, i, x_u, y_u):
        return evaluate(unflatten_update(flat, spec), x_u[i], y_u[i])

    return jax.jit(jax.vmap(ev, in_axes=(0, 0, None, None)))


@functools.lru_cache(maxsize=2)
def _sweep_apply_fn():
    """Batched FedAvg server step; cells without updates keep their exact
    parameter bits (``where`` selects the untouched row)."""
    return jax.jit(lambda fp, delta, lr, has: jnp.where(
        has[:, None], fp + lr[:, None] * delta, fp))


@functools.lru_cache(maxsize=2)
def _sweep_yogi_fn():
    def f(fp, delta, state, has):
        new_p, new_s = jax.vmap(yogi_apply_flat)(fp, delta, state)
        keep = lambda new, old: jnp.where(
            has.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
        return keep(new_p, fp), jax.tree.map(keep, new_s, state)

    return jax.jit(f)


@dataclasses.dataclass
class SweepRunner:
    """Expand cells (``SweepSpec.expand()``) and run them batched.

    ``shard=True`` places each compatibility batch's sweep axis on a device
    mesh axis "s" (all local devices by default; pass ``mesh=`` for an
    explicit one) — cells run shard-local round programs under ``shard_map``
    with bit-identical per-cell results.  ``shard_participants`` adds the
    participant mesh axis "p" (``repro.sim.participant_sharding``): each
    round's packed cohort rows split over it, so large cohorts train in
    parallel across devices.  ``True`` takes every local device (sweep-axis
    sharding off); an int N combines with ``shard=True`` as an
    ``(n_devices // N) x N`` 2-D ``("s", "p")`` mesh.  Multi-round chunking
    is per-cell config (``SimConfig.rounds_per_dispatch``).
    """
    cells: Sequence[Cell]
    progress: bool = False
    substrate_cache: Optional[dict] = None
    last_stats: Optional[dict] = None     # fused-pipeline transfer/dispatch stats
    shard: bool = False
    shard_participants: object = 0        # int p-shard count, or True = all devices
    mesh: Optional[object] = None         # jax.sharding.Mesh: ("s",) or ("s", "p")
    fault_plan: Optional[object] = None   # repro.faults.FaultPlan for every cell
    checkpoint_path: Optional[str] = None  # crash-safe sweep snapshots (fused)
    checkpoint_every: int = 0              # rounds between snapshots (0 = off)
    telemetry: Optional[object] = None     # TelemetrySession shared across
                                           # batches (one registry / trace /
                                           # round log for the whole sweep)

    def __post_init__(self):
        for c in self.cells:
            if not c.config.fast_path:
                raise ValueError(f"cell {c.name}: the batched sweep executor "
                                 "requires fast_path=True")
        if self.substrate_cache is None:
            self.substrate_cache = {}
        if self.telemetry is None:
            from repro.telemetry import TelemetrySession
            self.telemetry = TelemetrySession()
        if self.mesh is None and (self.shard or self.shard_participants):
            import jax
            from repro.sim.participant_sharding import (participant_mesh,
                                                        round_mesh)
            devs = jax.devices()
            if not self.shard:
                self.mesh = participant_mesh(self.shard_participants, devs)
            elif not self.shard_participants:
                self.mesh = round_mesh(len(devs), 1, devs)
            else:
                n_p = int(self.shard_participants)
                if (self.shard_participants is True or n_p < 1
                        or len(devs) % n_p):
                    raise ValueError(
                        "shard=True with shard_participants needs an integer "
                        f"participant shard count dividing the {len(devs)} "
                        "local devices")
                self.mesh = round_mesh(len(devs) // n_p, n_p, devs)
        if self.mesh is not None:
            for c in self.cells:
                if not c.config.fused_rounds:
                    raise ValueError(
                        f"cell {c.name}: device-mesh sharding requires the "
                        "fused pipeline (fused_rounds=True)")

    def substrate(self, cfg) -> Substrate:
        key = substrate_key(cfg)
        if key not in self.substrate_cache:
            self.substrate_cache[key] = Substrate.build(cfg)
        return self.substrate_cache[key]

    def run(self) -> SweepResults:
        groups: "OrderedDict[tuple, list[int]]" = OrderedDict()
        for i, c in enumerate(self.cells):
            groups.setdefault(compat_key(c.config), []).append(i)
        results: list[Optional[CellResult]] = [None] * len(self.cells)
        completed: dict = {}    # cell index -> finalized Accounting
        for idxs in groups.values():
            batch = [self.cells[i] for i in idxs]
            accts = self._run_batch(batch, idxs=idxs, completed=completed)
            for i, acct in zip(idxs, accts):
                completed[i] = acct
                results[i] = CellResult(cell=self.cells[i],
                                        summary=acct.summary(), acct=acct)
        return SweepResults(results)

    def _ckpt_wrap(self, idxs, completed):
        """Envelope hook for the in-flight batch's pipeline snapshots: wrap
        them into a resumable *sweep* snapshot carrying the grid and the
        already-finished cells' accountings (``resume_sweep`` consumes it).
        ``completed`` is read at snapshot time, so it holds exactly the
        batches finished before this one."""
        def wrap(pipeline_payload):
            return {"version": 1, "kind": "sweep",
                    "cells": list(self.cells),
                    "completed": dict(completed),
                    "group": list(idxs),
                    "fault_plan": self.fault_plan,
                    "checkpoint_every": self.checkpoint_every,
                    "pipeline": pipeline_payload}
        return wrap

    # ------------------------------------------------------------------
    def _run_batch(self, batch: Sequence[Cell], idxs=None, completed=None):
        cfgs = [c.config for c in batch]
        sims = [Simulator(cfg, substrate=self.substrate(cfg),
                          fault_plan=self.fault_plan) for cfg in cfgs]
        if cfgs[0].fused_rounds:        # uniform within a compat batch
            wrap = (self._ckpt_wrap(idxs, completed)
                    if self.checkpoint_path and self.checkpoint_every
                    and idxs is not None else None)
            with self.telemetry.span("batch", cells=len(batch)):
                pipe = RoundPipeline(sims, progress=self.progress,
                                     mesh=self.mesh,
                                     checkpoint_path=self.checkpoint_path,
                                     checkpoint_every=self.checkpoint_every,
                                     checkpoint_wrap=wrap,
                                     telemetry=self.telemetry,
                                     labels=[c.name for c in batch])
                accts = pipe.run()
            # the session registry is shared by every batch's pipeline, so
            # the newest snapshot already holds the sweep-wide totals —
            # no manual cross-batch merging
            self.last_stats = pipe.stats.as_dict()
            return accts
        return self._run_batch_stages(sims, cfgs)

    def _run_batch_stages(self, sims, cfgs):
        """The PR-2 per-stage batched executor (``fused_rounds=False``)."""
        cfg0 = cfgs[0]
        s_total = len(sims)
        spec = sims[0]._flat_spec
        d = len(np.asarray(sims[0].flat_params))
        fns = sims[0]._model_fns
        train = _packed_train_fn(spec, cfg0.local_lr, cfg0.prox_mu, fns.loss)
        eval_fn = _sweep_eval_shared_fn(spec, fns.evaluate)
        eval_fn_mixed = _sweep_eval_fn(spec, fns.evaluate)
        flat_params = jnp.stack([sim.flat_params for sim in sims])
        yogi = cfg0.server_opt == "yogi"
        opt_state = (jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[sim.flat_opt_state for sim in sims])
                     if yogi else None)
        datasets, te_idx = [], []
        for sim in sims:
            if not any(sim.data is ds for ds in datasets):
                datasets.append(sim.data)
            te_idx.append(next(i for i, ds in enumerate(datasets)
                               if ds is sim.data))
        x_te = np.stack([ds.x_test for ds in datasets])
        y_te = np.stack([ds.y_test for ds in datasets])
        te_idx = np.asarray(te_idx)
        beta = np.array([cfg.beta for cfg in cfgs], np.float32)
        lr_vec = np.array([cfg.server_lr for cfg in cfgs], np.float32)

        done = [False] * s_total
        for r in range(cfg0.rounds):
            if all(done):
                break
            plans = [None if done[i] else sim._begin_round(r)
                     for i, sim in enumerate(sims)]
            live = [i for i in range(s_total) if plans[i] is not None]
            if not live:
                continue

            # --- batched cohort training (packed rows) ----------------
            parts_x, parts_y, rows = [], [], []
            for i in live:
                p = plans[i]
                parts_x.append(p.bx)
                parts_y.append(p.by)
                rows.extend([i] * p.k)
            n_rows = len(rows)
            r_b = agg.bucket_block(n_rows, ROW_BLOCK)
            if r_b > n_rows:    # pad with copies of the first row (discarded)
                pad_x = np.broadcast_to(parts_x[0][:1],
                                        (r_b - n_rows,) + parts_x[0].shape[1:])
                pad_y = np.broadcast_to(parts_y[0][:1],
                                        (r_b - n_rows,) + parts_y[0].shape[1:])
                parts_x.append(pad_x)
                parts_y.append(pad_y)
                rows.extend([live[0]] * (r_b - n_rows))
            deltas, losses, l2s = train(flat_params, np.asarray(rows),
                                        np.concatenate(parts_x),
                                        np.concatenate(parts_y))
            deltas = np.asarray(deltas)
            losses = np.asarray(losses)
            l2s = np.asarray(l2s)

            # --- per-cell host logic + update collection --------------
            tails = {}
            cell_updates = [None] * s_total
            cell_lids = {}
            off = 0
            for i in live:
                p = plans[i]
                sl = slice(off, off + p.k)
                off += p.k
                d_i = sims[i]._corrupt_deltas(r, p, deltas[sl])
                t_end, fresh_up, stale_up, stale_taus, agg_lids = \
                    sims[i]._collect_updates(r, p, d_i, losses[sl],
                                             l2s[sl])
                tails[i] = (t_end, len(fresh_up), len(stale_up))
                if fresh_up or stale_up:
                    cell_updates[i] = (
                        fresh_up + stale_up,
                        [True] * len(fresh_up) + [False] * len(stale_up),
                        [0] * len(fresh_up) + stale_taus)
                    cell_lids[i] = agg_lids

            # --- batched aggregation + server step --------------------
            atk = attack_key(cfg0)          # uniform within a compat batch
            rob = robust_key(cfg0)
            if any(c is not None for c in cell_updates) and (
                    atk is not None or rob is not None):
                # attacked / robust route: the S=N slice of the same
                # compiled program the engine's flat path runs per cell
                # (repro.robust.aggregators — one set of numerics)
                u, fresh, tau, valid, has = agg.sweep_bucket_pad(
                    cell_updates, d)
                att = np.zeros(np.shape(valid), bool)
                for i, lids in cell_lids.items():
                    fp = sims[i].fault_plan
                    if fp is not None:
                        att[i, :len(lids)] = fp.attack_flags(r, lids)
                guard_desc = ((cfg0.guard_clip, cfg0.guard_reject_mult)
                              if cfg0.guard else None)
                fn = robust_sweep_fn(atk, guard_desc, rob,
                                     bool(cfg0.use_agg_kernel))
                rule_ids = np.asarray(
                    [RULE_ID[cfg.scaling_rule] for cfg in cfgs], np.int32)
                agg_out, st = fn(u, fresh, tau, valid, att, beta, rule_ids)
                st = np.asarray(jax.device_get(st))
                if cfg0.guard:
                    applied = has & (st[:, 2] >= max(int(cfg0.quorum), 1))
                else:
                    applied = has
                for i in np.nonzero(has)[0]:
                    if cfg0.guard:
                        sims[i].acct.note_guard(int(st[i, 0]), int(st[i, 1]),
                                                bool(applied[i]))
                    if rob is not None:
                        sims[i].acct.note_robust(int(st[i, 3]),
                                                 int(st[i, 4]))
                has = applied
                if yogi:
                    flat_params, opt_state = _sweep_yogi_fn()(
                        flat_params, agg_out, opt_state, has)
                else:
                    flat_params = _sweep_apply_fn()(flat_params, agg_out,
                                                    lr_vec, has)
            elif any(c is not None for c in cell_updates):
                u, fresh, tau, valid, has = agg.sweep_bucket_pad(cell_updates, d)
                if cfg0.guard:      # guard config is uniform (compat_key)
                    # same in-program screening the fused pipeline folds
                    # into its round body: survivors replace the valid
                    # mask, quorum failures keep their exact parameter
                    # bits via the has-gated apply below
                    screen = agg._screen_fn(cfg0.guard_clip,
                                            cfg0.guard_reject_mult)
                    u, v2, n_nf, n_out, _ = screen(u, valid)
                    valid = v2
                    surv = np.asarray(jax.device_get(v2.sum(axis=-1)))
                    n_nf = np.asarray(jax.device_get(n_nf))
                    n_out = np.asarray(jax.device_get(n_out))
                    applied = has & (surv >= max(int(cfg0.quorum), 1))
                    for i in np.nonzero(has)[0]:
                        sims[i].acct.note_guard(int(n_nf[i]), int(n_out[i]),
                                                bool(applied[i]))
                    has = applied
                agg_out, _ = agg.sweep_aggregate_flat(
                    u, fresh, tau, valid, beta,
                    rule=[cfg.scaling_rule for cfg in cfgs],
                    use_kernel=cfg0.use_agg_kernel)
                if yogi:
                    flat_params, opt_state = _sweep_yogi_fn()(
                        flat_params, agg_out, opt_state, has)
                else:
                    flat_params = _sweep_apply_fn()(flat_params, agg_out,
                                                    lr_vec, has)

            # --- batched evaluation + per-cell bookkeeping ------------
            acc = loss = None
            if sims[0].eval_due(r):
                a, lo = (eval_fn(flat_params, x_te[0], y_te[0])
                         if len(x_te) == 1 else
                         eval_fn_mixed(flat_params, te_idx, x_te, y_te))
                acc, loss = np.asarray(a), np.asarray(lo)
            for i in live:
                t_end, n_fresh, n_stale = tails[i]
                sims[i]._record_round(
                    r, plans[i].t_now, t_end, len(plans[i].chosen), n_fresh,
                    n_stale, acc_loss=(acc[i], loss[i]) if acc is not None else None,
                    progress=self.progress)
                if sims[i]._target_reached():
                    sims[i].acct.stopped_early = True
                    done[i] = True

        accts = []
        for i, sim in enumerate(sims):
            sim.flat_params = flat_params[i]
            if yogi:
                sim.flat_opt_state = jax.tree.map(lambda x: x[i], opt_state)
            accts.append(sim._finalize())
        return accts


# ---------------------------------------------------------------------------
# Batched-vs-serial harness (shared by `python -m repro.sweeps` and
# `benchmarks/bench_sweeps.py`)
# ---------------------------------------------------------------------------


def run_serial(cells: Sequence[Cell]):
    """The baseline a sweep replaces: one full ``Simulator(cfg).run()`` per
    cell (fresh substrate each).  Returns (summaries, wall seconds)."""
    t0 = time.time()
    summaries = [Simulator(c.config).run().summary() for c in cells]
    return summaries, time.time() - t0


def run_batched(cells: Sequence[Cell], shard: bool = False, mesh=None,
                shard_participants=0, fault_plan=None,
                checkpoint_path=None, checkpoint_every: int = 0,
                telemetry=None):
    """Returns (SweepResults, wall seconds) — wall includes substrate builds."""
    t0 = time.time()
    results = SweepRunner(cells, shard=shard, mesh=mesh,
                          shard_participants=shard_participants,
                          fault_plan=fault_plan,
                          checkpoint_path=checkpoint_path,
                          checkpoint_every=checkpoint_every,
                          telemetry=telemetry).run()
    return results, time.time() - t0


def resume_sweep(path: str, progress: bool = False, telemetry=None):
    """Resume a sweep from a crash-safe snapshot (``SweepRunner`` with
    ``checkpoint_path``): already-finished batches come back from their
    stored accountings, the in-flight batch resumes its pipeline mid-run,
    and batches that never started run fresh.  Per-cell results are
    bit-identical to the uninterrupted sweep (tests/test_crash_resume.py).
    Returns (SweepResults, wall seconds)."""
    from repro.checkpoint.state import build_resumed_pipeline, load_snapshot

    t0 = time.time()
    payload = load_snapshot(path)
    if payload["kind"] != "sweep":
        raise ValueError(f"{path!r} is a {payload['kind']!r} snapshot, not a "
                         "sweep snapshot (use repro.checkpoint.resume_run)")
    cells = payload["cells"]
    completed: dict = dict(payload["completed"])
    pipe = build_resumed_pipeline(payload["pipeline"], progress=progress,
                                  telemetry=telemetry)
    for i, acct in zip(payload["group"], pipe.run()):
        completed[i] = acct
    fp = payload.get("fault_plan")
    runner = SweepRunner(cells, progress=progress,
                         fault_plan=fp.without_crash() if fp is not None
                         else None, telemetry=telemetry)
    groups: "OrderedDict[tuple, list[int]]" = OrderedDict()
    for i, c in enumerate(cells):
        groups.setdefault(compat_key(c.config), []).append(i)
    for idxs in groups.values():
        if idxs[0] in completed:    # finished before the crash, or resumed
            continue
        accts = runner._run_batch([cells[i] for i in idxs])
        for i, acct in zip(idxs, accts):
            completed[i] = acct
    results = [CellResult(cell=c, summary=completed[i].summary(),
                          acct=completed[i]) for i, c in enumerate(cells)]
    return SweepResults(results), time.time() - t0


def summaries_equal(a: dict, b: dict) -> bool:
    """Exact summary comparison (NaN-tolerant for the accuracy fields)."""
    if set(a) != set(b):
        return False
    return all(a[k] == b[k] or (a[k] != a[k] and b[k] != b[k]) for k in a)


def assert_parity(results: SweepResults, serial_summaries) -> None:
    for res, ser in zip(results, serial_summaries):
        if not summaries_equal(dict(res.summary), dict(ser)):
            raise AssertionError(
                f"sweep parity violation at cell {res.cell.name}:\n"
                f"  batched: {res.summary}\n  serial : {ser}")
