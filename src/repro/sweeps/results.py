"""Struct-of-arrays accumulation of per-cell sweep metrics.

``SweepResults`` holds one ``CellResult`` per grid cell (its ``Cell``
coordinates plus the engine's fixed-key ``SimSummary``) and exposes the
columnar views the reporting layer consumes: ``soa()`` (one numpy array per
summary key + one object array per axis), coordinate filtering, and
seed-aggregated group statistics for paper-style resource-to-accuracy
tables.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.sim.metrics import SUMMARY_KEYS, Accounting, SimSummary
from repro.sweeps.grid import Cell


@dataclasses.dataclass
class CellResult:
    cell: Cell
    summary: SimSummary
    acct: Optional[Accounting] = None      # full round records when retained

    @property
    def round_log(self) -> list[dict]:
        """Pinned-schema telemetry round events (``SimConfig.telemetry >= 2``;
        empty when the run logged at a lower level or acct was dropped)."""
        return self.acct.round_events if self.acct is not None else []


class SweepResults:
    def __init__(self, results: Sequence[CellResult]):
        self.results = list(results)

    def __len__(self):
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def __getitem__(self, i):
        return self.results[i]

    @property
    def axes(self) -> list:
        """Axis names in grid order (seed last), from the first cell."""
        return [a for a, _ in self.results[0].cell.coords] if self.results else []

    def soa(self) -> dict:
        """Columnar view: {summary key: float/int array} plus
        {axis name: object array of coordinate values}."""
        out = {k: np.array([r.summary[k] for r in self.results])
               for k in SUMMARY_KEYS}
        for axis in self.axes:
            out[axis] = np.array([r.cell.coord(axis) for r in self.results],
                                 dtype=object)
        return out

    def filter(self, **coords) -> "SweepResults":
        keep = [r for r in self.results
                if all(r.cell.coord(a) == v for a, v in coords.items())]
        return SweepResults(keep)

    def group_stats(self, by: Optional[Sequence[str]] = None) -> list[dict]:
        """Per-group mean/min/max over the remaining axes (typically seeds).

        ``by`` defaults to every axis except ``seed``.  Each row carries the
        group coordinates, ``n`` runs, and ``<key>`` (mean) plus
        ``<key>_min``/``<key>_max`` for every summary key.
        """
        by = [a for a in self.axes if a != "seed"] if by is None else list(by)
        groups: dict = {}
        for r in self.results:
            gk = tuple((a, r.cell.coord(a)) for a in by)
            groups.setdefault(gk, []).append(r)
        rows = []
        for gk, members in groups.items():
            row = dict(gk)
            row["n"] = len(members)
            for k in SUMMARY_KEYS:
                vals = np.array([m.summary[k] for m in members], float)
                row[k] = float(np.nanmean(vals)) if len(vals) else float("nan")
                row[f"{k}_min"] = float(np.nanmin(vals))
                row[f"{k}_max"] = float(np.nanmax(vals))
            rows.append(row)
        return rows

    def resource_to_target(self) -> list[dict]:
        """Per-cell resource-to-target rows for accuracy-target sweeps
        (``SimConfig.target_accuracy`` / ``SweepSpec`` base or axis).

        For cells that stopped early, ``rounds``/``resource_used``/
        ``sim_time`` are the cost of *reaching* the target (the engine
        freezes accrual at the stop round); cells that ran out of rounds
        report their full cost with ``reached = False`` — the paper-style
        "resources to a fixed quality bar" comparison, one row per cell.
        """
        rows = []
        for r in self.results:
            s = r.summary
            rows.append({
                "cell": r.cell.name,
                **{a: r.cell.coord(a) for a in self.axes},
                "reached": bool(s["stopped_early"]),
                "rounds": s["rounds"],
                "sim_time": s["sim_time"],
                "resource_used": s["resource_used"],
                "final_accuracy": s["final_accuracy"],
            })
        return rows

    def guard_totals(self) -> dict:
        """Sweep-wide guard / robust-aggregation counters (chaos harness).

        Keys come from ``PipelineStats.GUARD_KEYS`` (itself derived from the
        telemetry schema, so a counter added there shows up here too).  A
        key is present only when some cell actually enables the feature —
        the guard for the screen/quorum counters, a robust aggregator for
        the ``robust_*`` counters.  A sweep with the feature off reports
        the key *absent* rather than a silent 0, so "0 rejections" can
        never be confused with "nothing was ever screened".
        """
        from repro.robust.aggregators import robust_key
        from repro.sim.pipeline import PipelineStats
        out = {}
        for k in PipelineStats.GUARD_KEYS:
            if k.startswith("robust_"):
                on = any(robust_key(r.cell.config) is not None
                         for r in self.results)
            else:
                on = any(r.cell.config.guard for r in self.results)
            if on:
                out[k] = int(sum(r.summary[k] for r in self.results))
        return out

    def round_logs(self) -> dict:
        """{cell name: telemetry round-event list} for cells that carried a
        level-2 round log.  Kept out of ``to_json_dict`` — the per-round log
        belongs in the telemetry directory's ``rounds.jsonl``, not in the
        summary payload."""
        return {r.cell.name: r.round_log for r in self.results
                if r.round_log}

    def to_json_dict(self) -> dict:
        return {"cells": [{"name": r.cell.name,
                           "coords": dict(r.cell.coords),
                           "summary": {k: r.summary[k] for k in SUMMARY_KEYS}}
                          for r in self.results]}
