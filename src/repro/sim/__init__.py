"""FL simulation substrate: device profiles, availability traces, data
partitioning, learner local training, resource accounting, and the
event-driven round engine that reproduces the paper's methodology."""
from repro.sim.engine import Simulator, SimConfig  # noqa: F401
from repro.sim.participant_sharding import (participant_mesh,  # noqa: F401
                                            round_mesh)
