"""Resource & quality accounting (the paper's evaluation currency).

- resource usage: cumulative compute+comm time spent by participants,
  *including* work that is never aggregated (paper footnote 3);
- resource wastage: the subset of that time whose updates were never
  incorporated into the global model;
- unique-participant rate (Fig. 3's right axis);
- accuracy/time/round timelines.
"""
from __future__ import annotations

import dataclasses
from typing import List, TypedDict


class SimSummary(TypedDict):
    """Fixed-key summary of one simulation run.

    This is the stable contract consumed by downstream layers (the sweep
    results accumulator in ``repro.sweeps.results``, benchmarks, examples):
    keys are exactly ``SUMMARY_KEYS``, values are plain Python scalars, and
    two runs of the same config/seed produce equal summaries.  Pinned by
    ``tests/test_metrics_schema.py``.
    """
    rounds: int                  # recorded rounds (skipped rounds excluded)
    sim_time: float              # simulated seconds at the last recorded round
    resource_used: float         # cumulative participant compute+comm seconds
    resource_wasted: float       # subset never incorporated into the model
    waste_fraction: float        # resource_wasted / resource_used (0 if unused)
    unique_participants: int     # distinct learners ever aggregated
    final_accuracy: float        # last evaluation (NaN if never evaluated)
    best_accuracy: float         # best evaluation (NaN if never evaluated)
    stopped_early: bool          # hit SimConfig.target_accuracy before rounds ran out
    rejected_nonfinite: int      # guard: update rows rejected for NaN/Inf
    rejected_norm: int           # guard: rows rejected as norm outliers
    quorum_skips: int            # rounds whose server apply was skipped (quorum)
    robust_rejected: int         # robust aggregator: rows rejected (krum /
                                 # multi_krum losers, norm_median_clip rejects)
    robust_trimmed: int          # robust aggregator: rows trimmed per
                                 # coordinate band (trimmed_mean/coord_median
                                 # 2*k_eff per round) or clipped
                                 # (norm_median_clip)


SUMMARY_KEYS = tuple(SimSummary.__annotations__)


@dataclasses.dataclass
class RoundRecord:
    round_idx: int
    sim_time: float
    n_selected: int
    n_fresh: int
    n_stale: int
    resource_used: float       # cumulative seconds
    resource_wasted: float     # cumulative seconds
    unique_participants: int
    accuracy: float = float("nan")
    loss: float = float("nan")


@dataclasses.dataclass
class Accounting:
    records: List[RoundRecord] = dataclasses.field(default_factory=list)
    resource_used: float = 0.0
    resource_wasted: float = 0.0
    unique: set = dataclasses.field(default_factory=set)
    stopped_early: bool = False   # accuracy-target early stop fired
    rejected_nonfinite: int = 0   # guard: rows rejected for NaN/Inf values
    rejected_norm: int = 0        # guard: rows rejected as norm outliers
    quorum_skips: int = 0         # rounds where the apply was quorum-skipped
    robust_rejected: int = 0      # robust aggregator: rows rejected
    robust_trimmed: int = 0       # robust aggregator: rows trimmed/clipped
    round_events: List[dict] = dataclasses.field(default_factory=list)
    # ^ telemetry round log (SimConfig.telemetry >= 2): one pinned-schema
    #   event dict per recorded round (repro.telemetry.schema
    #   .ROUND_EVENT_KEYS).  Lives here so snapshots carry it and a resumed
    #   run's in-memory log continues the crashed one's exactly.

    def note_guard(self, nonfinite: int, norm: int, applied: bool):
        """Record one aggregation's guard outcome (per round with updates)."""
        self.rejected_nonfinite += int(nonfinite)
        self.rejected_norm += int(norm)
        if not applied:
            self.quorum_skips += 1

    def note_robust(self, rejected: int, trimmed: int):
        """Record one aggregation's robust-strategy outcome."""
        self.robust_rejected += int(rejected)
        self.robust_trimmed += int(trimmed)

    def charge(self, seconds: float, wasted: bool):
        self.resource_used += seconds
        if wasted:
            self.resource_wasted += seconds

    def mark_wasted(self, seconds: float):
        """Work already charged as used turned out never to be aggregated."""
        self.resource_wasted += seconds

    def csv(self) -> str:
        hdr = ("round,sim_time,n_selected,n_fresh,n_stale,resource_used,"
               "resource_wasted,unique_participants,accuracy,loss")
        rows = [hdr]
        for r in self.records:
            rows.append(f"{r.round_idx},{r.sim_time:.1f},{r.n_selected},{r.n_fresh},"
                        f"{r.n_stale},{r.resource_used:.1f},{r.resource_wasted:.1f},"
                        f"{r.unique_participants},{r.accuracy:.4f},{r.loss:.4f}")
        return "\n".join(rows)

    def summary(self) -> SimSummary:
        last = self.records[-1] if self.records else None
        accs = [r.accuracy for r in self.records if r.accuracy == r.accuracy]
        return SimSummary(
            rounds=len(self.records),
            sim_time=last.sim_time if last else 0.0,
            resource_used=self.resource_used,
            resource_wasted=self.resource_wasted,
            waste_fraction=(self.resource_wasted / self.resource_used
                            if self.resource_used else 0.0),
            unique_participants=len(self.unique),
            final_accuracy=accs[-1] if accs else float("nan"),
            best_accuracy=max(accs) if accs else float("nan"),
            stopped_early=self.stopped_early,
            rejected_nonfinite=self.rejected_nonfinite,
            rejected_norm=self.rejected_norm,
            quorum_skips=self.quorum_skips,
            robust_rejected=self.robust_rejected,
            robust_trimmed=self.robust_trimmed,
        )
