"""Synthetic federated datasets + the paper's data-to-learner mappings (§5.1).

Datasets are Gaussian-cluster classification problems with the label
cardinalities of the paper's benchmarks (speech=35, cifar=10, openimage=600).
Mappings:
  D1 "uniform"     — IID uniform random split
  D2 "fedscale"    — realistic per-source mapping: learner sizes ~ power law,
                      labels drawn from the global marginal (close to IID, as
                      the paper observes in §E.1)
  D3 "label_<L>"   — label-limited: each learner holds ~10% of labels with
                      per-label sample counts L1 balanced / L2 uniform /
                      L3 zipf(alpha=1.95)
"""
from __future__ import annotations

import dataclasses

import numpy as np

BENCHMARKS = {
    # name: (n_classes, feature_dim, n_train, n_test)
    "speech": (35, 64, 7000, 1400),
    "cifar10": (10, 64, 5000, 1000),
    "openimage": (60, 64, 9000, 1500),
}

TOKEN_BENCHMARKS = {
    # name: (vocab, seq_len, samples_per_learner, n_test, unigram skew)
    "tokens": (1024, 64, 48, 256, 0.0),
    "tokens_skew": (1024, 64, 48, 256, 0.5),
}


def benchmark_kind(name: str) -> str:
    """The sample layout a benchmark provides: ``"classifier"`` (x (N, dim)
    fp32 / y (N,) int labels) or ``"tokens"`` (x (N, S) int32 sequences /
    y (N, S) next-token labels)."""
    if name in TOKEN_BENCHMARKS:
        return "tokens"
    if name in BENCHMARKS:
        return "classifier"
    raise ValueError(f"unknown benchmark {name!r} (choose from "
                     f"{tuple(BENCHMARKS) + tuple(TOKEN_BENCHMARKS)})")


@dataclasses.dataclass
class FederatedDataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    shards: list            # shards[i] = np.ndarray of sample indices for learner i
    kind: str = "classifier"    # sample layout (see ``benchmark_kind``)
    vocab: int = 0              # tokens: vocabulary size

    @property
    def n_classes(self):
        if self.kind == "tokens":
            return int(self.vocab)
        return int(self.y_train.max()) + 1


def make_dataset(name: str, rng: np.random.Generator, class_sep: float = 2.2):
    n_classes, dim, n_train, n_test = BENCHMARKS[name]
    centers = rng.standard_normal((n_classes, dim)) * class_sep / np.sqrt(dim) * np.sqrt(dim)
    centers = rng.standard_normal((n_classes, dim))
    centers *= class_sep / np.linalg.norm(centers, axis=1, keepdims=True) * np.sqrt(dim) ** 0.5

    def sample(n):
        y = rng.integers(0, n_classes, size=n)
        x = centers[y] + rng.standard_normal((n, dim))
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te


def make_token_dataset(name: str, n_learners: int, seed: int) -> FederatedDataset:
    """Federated token-shard dataset for the LM benchmarks.

    Each learner owns a contiguous index block over the concatenated
    per-learner corpora of ``repro.data.synthetic.federated_token_shards``
    (so the data-to-learner mapping *is* the shard structure — token
    benchmarks ignore ``SimConfig.mapping``); the held-out split is an
    unskewed corpus drawn from an independent seed offset.  Everything is
    derived from ``seed`` alone, keeping the substrate-cache contract:
    cells sharing a seed share bit-identical data.
    """
    from repro.data.synthetic import federated_token_shards
    vocab, seq_len, spl, n_test, skew = TOKEN_BENCHMARKS[name]
    per = federated_token_shards(vocab, n_learners, spl, seq_len,
                                 seed=seed, skew=skew)
    x_tr = np.concatenate([s["tokens"] for s in per])
    y_tr = np.concatenate([s["labels"] for s in per])
    shards = [np.arange(i * spl, (i + 1) * spl) for i in range(n_learners)]
    test = federated_token_shards(vocab, 1, n_test, seq_len,
                                  seed=seed + 104729, skew=0.0)[0]
    return FederatedDataset(name, x_tr, y_tr, test["tokens"], test["labels"],
                            shards, kind="tokens", vocab=vocab)


def partition(y: np.ndarray, n_learners: int, mapping: str,
              rng: np.random.Generator, label_fraction: float = 0.10,
              zipf_alpha: float = 1.95) -> list:
    n = len(y)
    n_classes = int(y.max()) + 1
    idx_by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    for a in idx_by_class:
        rng.shuffle(a)

    if mapping == "uniform":  # D1
        perm = rng.permutation(n)
        return [perm[i::n_learners] for i in range(n_learners)]

    if mapping == "fedscale":  # D2: power-law sizes, near-IID labels
        sizes = rng.zipf(1.6, size=n_learners).astype(float)
        sizes = np.maximum(sizes / sizes.sum() * n, 2).astype(int)
        perm = rng.permutation(n)
        shards, off = [], 0
        for s in sizes:
            shards.append(perm[off:off + s] if off < n else perm[-s:])
            off += s
        return shards

    if mapping.startswith("label"):  # D3: label-limited
        style = mapping.split("_", 1)[1] if "_" in mapping else "uniform"
        k = max(1, int(round(label_fraction * n_classes)))
        per_learner = max(2, n // n_learners)
        cursors = np.zeros(n_classes, dtype=int)
        shards = []
        for i in range(n_learners):
            labels = rng.choice(n_classes, size=k, replace=False)
            if style == "balanced":      # L1
                counts = np.full(k, per_learner // k)
            elif style == "zipf":        # L3
                w = (np.arange(1, k + 1, dtype=float) ** -zipf_alpha)
                w = w[rng.permutation(k)]
                counts = np.maximum((w / w.sum() * per_learner), 1).astype(int)
            else:                        # L2 uniform
                w = rng.random(k)
                counts = np.maximum((w / w.sum() * per_learner), 1).astype(int)
            take = []
            for lab, cnt in zip(labels, counts):
                pool = idx_by_class[lab]
                start = cursors[lab] % len(pool)
                sel = np.take(pool, np.arange(start, start + cnt), mode="wrap")
                cursors[lab] += cnt
                take.append(sel)
            shards.append(np.concatenate(take))
        return shards

    raise ValueError(f"unknown mapping {mapping}")


def label_coverage(shards, y, n_classes) -> np.ndarray:
    """Fraction of learners holding each label (paper §E.1 analysis)."""
    cov = np.zeros(n_classes)
    for sh in shards:
        labs = np.unique(y[sh])
        cov[labs] += 1
    return cov / len(shards)
