"""Learner-side local training for the FL simulation.

The simulation model is a 2-layer MLP classifier (the statistical role the
paper's ResNet/ShuffleNet/Albert play, scaled to CPU).  All selected
participants of a round train in one ``vmap``-ed jitted call — the TPU-pod
analogue of FedScale's time-multiplexed GPU workers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def mlp_init(key, dim: int, n_classes: int, hidden: int = 128):
    k1, k2 = jax.random.split(key)
    s1, s2 = dim ** -0.5, hidden ** -0.5
    return {
        "w1": jax.random.normal(k1, (dim, hidden), jnp.float32) * s1,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, n_classes), jnp.float32) * s2,
        "b2": jnp.zeros((n_classes,), jnp.float32),
    }


def mlp_apply(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def _xent(params, x, y):
    logits = mlp_apply(params, x)
    logp = jax.nn.log_softmax(logits)
    losses = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return losses.mean(), losses


@functools.partial(jax.jit, static_argnames=("lr", "prox_mu", "loss"))
def local_train(params, xs, ys, lr: float, prox_mu: float = 0.0, *,
                loss=_xent):
    """K local SGD steps (Alg. 2 participant update).

    xs: (n_steps, batch, ...); ys: (n_steps, batch, ...).
    ``prox_mu > 0`` adds FedProx's proximal term mu/2 ||w - w_global||^2
    (Li et al., MLSys'20) to each local step.  ``loss`` is the model's
    objective ``(params, x, y) -> (mean, per_example)`` — a static arg
    (the default is the MLP's cross-entropy), so each model compiles its
    own program and the default keeps the pre-model-zoo cache key.
    Returns (delta pytree, mean loss, sqrt(mean loss^2) for Oort stat-util).
    """
    p0 = params
    loss_fn = loss

    def step(p, xy):
        x, y = xy
        (loss, losses), g = jax.value_and_grad(loss_fn, has_aux=True)(p, x, y)
        if prox_mu > 0.0:
            g = jax.tree.map(lambda gw, w, w0: gw + prox_mu * (w - w0), g, p, p0)
        p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
        return p, (loss, jnp.sqrt(jnp.mean(losses ** 2)))

    final, (losses, l2s) = jax.lax.scan(step, params, (xs, ys))
    delta = jax.tree.map(lambda a, b: a - b, final, params)
    return delta, losses.mean(), l2s.mean()


# vmap over the participant axis — one compiled program trains the whole cohort
local_train_cohort = jax.jit(
    jax.vmap(local_train, in_axes=(None, 0, 0, None, None)),
    static_argnames=("lr", "prox_mu"))


def local_train_flat(flat_params, xs, ys, *, spec, lr, prox_mu,
                     loss=_xent, out_dim=None):
    """One learner's local round as a pure flat-vector function.

    flat_params: (D,) fp32 in ``spec`` leaf order; xs: (n_steps, batch, ...);
    returns (flat delta, mean loss, Oort l2 stat).  The unflatten and
    per-leaf flatten are pure reshapes, so the delta rows are bit-identical
    to ``local_train``'s pytree output — this is the unit the engine's
    ``flat_cohort_step`` vmaps over a cohort and the sweep runner vmaps over
    packed (cell, participant) rows with per-row parameters.

    ``out_dim`` (block-padded pipelines): when it exceeds the spec's D the
    delta is zero-padded to ``(out_dim,)`` so the caller's persistent
    D-blocked buffers need no per-round repadding; a ``flat_params`` row
    wider than D is likewise accepted (``unflatten_update`` consumes
    exactly D leading elements, the padded tail is ignored).
    """
    from repro.core.aggregation import unflatten_update
    delta, loss_v, l2 = local_train(unflatten_update(flat_params, spec),
                                    xs, ys, lr, prox_mu, loss=loss)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                            for l in jax.tree.leaves(delta)])
    if out_dim is not None and int(out_dim) > flat.shape[0]:
        flat = jnp.concatenate(
            [flat, jnp.zeros((int(out_dim) - flat.shape[0],), jnp.float32)])
    return flat, loss_v, l2


@jax.jit
def evaluate(params, x, y):
    logits = mlp_apply(params, x)
    acc = (logits.argmax(-1) == y).mean()
    loss, _ = _xent(params, x, y)
    return acc, loss


def sample_batch_indices(shard_idx: np.ndarray, n_steps: int, batch: int,
                         rng: np.random.Generator) -> np.ndarray:
    """Sample indices for one learner's fixed-shape local batches (with
    replacement when the shard is small).  The single RNG draw shared by the
    host-materialized and device-gather paths, so both consume the identical
    stream and pick the identical samples."""
    return rng.choice(shard_idx, size=n_steps * batch,
                      replace=len(shard_idx) < n_steps * batch)


def sample_local_batches(shard_idx: np.ndarray, x: np.ndarray, y: np.ndarray,
                         n_steps: int, batch: int, rng: np.random.Generator):
    """Fixed-shape local batches, materialized on host.  The device-resident
    round pipeline keeps only ``sample_batch_indices``' output and gathers
    the rows in-program from the device copy of the dataset."""
    take = sample_batch_indices(shard_idx, n_steps, batch, rng)
    return (x[take].reshape((n_steps, batch) + x.shape[1:]),
            y[take].reshape((n_steps, batch) + y.shape[1:]))
