"""Device heterogeneity profiles (paper §5.1 / App. C).

The paper assigns learner hardware from the AI Benchmark (inference time) and
MobiPerf (network) measurement corpora, clustered into 6 device classes with a
long-tail distribution.  We regenerate profiles with the same shape: 6
lognormal compute clusters spanning ~30x, and WiFi-class network speeds.

Hardware scenarios HS1-HS4 (paper §5.4): HS1 = current; HS2/HS3/HS4 = halve
completion time (compute + network) for the top 25% / 75% / 100% fastest.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (cluster weight, median per-sample train time [s], sigma) — long tail, ~30x spread
DEVICE_CLUSTERS = [
    (0.10, 0.015, 0.20),   # flagship
    (0.20, 0.030, 0.25),
    (0.25, 0.060, 0.25),
    (0.20, 0.120, 0.30),
    (0.15, 0.250, 0.30),
    (0.10, 0.500, 0.40),   # low-end / IoT
]

# MobiPerf-like WiFi Mbps (down, up) lognormal medians
NET_DOWN_MED, NET_UP_MED = 40.0, 12.0


@dataclasses.dataclass
class DeviceProfile:
    cluster: int
    per_sample_time: float      # seconds of compute per trained sample
    down_mbps: float
    up_mbps: float

    def round_duration(self, n_samples: int, epochs: int, model_mbits: float) -> float:
        """Compute + communication time for one FL round on this device."""
        compute = self.per_sample_time * n_samples * epochs
        comm = model_mbits / self.down_mbps + model_mbits / self.up_mbps
        return compute + comm


def sample_profiles(n: int, rng: np.random.Generator,
                    hardware_scenario: str = "HS1") -> list[DeviceProfile]:
    weights = np.array([c[0] for c in DEVICE_CLUSTERS])
    clusters = rng.choice(len(DEVICE_CLUSTERS), size=n, p=weights / weights.sum())
    profiles = []
    for c in clusters:
        _, med, sigma = DEVICE_CLUSTERS[c]
        t = float(np.exp(np.log(med) + sigma * rng.standard_normal()))
        down = float(np.exp(np.log(NET_DOWN_MED) + 0.5 * rng.standard_normal()))
        up = float(np.exp(np.log(NET_UP_MED) + 0.5 * rng.standard_normal()))
        profiles.append(DeviceProfile(int(c), t, down, up))
    return apply_hardware_scenario(profiles, hardware_scenario)


def apply_hardware_scenario(profiles: list[DeviceProfile],
                            hardware_scenario: str) -> list[DeviceProfile]:
    """HS2-HS4 speedups on an HS1 base population (paper §5.4).

    The base draws are scenario-independent, so one sampled population can be
    shared across a sweep's hardware axis; transformed profiles are new
    objects (the HS1 base is never mutated), HS1 returns the input list.
    """
    if hardware_scenario == "HS1":
        return profiles
    frac = {"HS2": 0.25, "HS3": 0.75, "HS4": 1.00}[hardware_scenario]
    speeds = np.array([p.per_sample_time for p in profiles])
    cutoff = np.quantile(speeds, frac)  # fastest `frac` portion
    return [dataclasses.replace(p, per_sample_time=p.per_sample_time / 2.0,
                                down_mbps=p.down_mbps * 2.0,
                                up_mbps=p.up_mbps * 2.0)
            if p.per_sample_time <= cutoff else p
            for p in profiles]
