"""Synthetic availability traces matched to the paper's §C analysis of the
136k-user behavior trace (Yang et al., 2020):

- diurnal cycles: most devices are available (charging) at night, few by day;
- long-tail session lengths: ~70% of availability sessions last < 10 minutes;
- cyclic weekly behavior.

Each learner gets a deterministic alternating (gap, session) renewal process
whose gap intensity is modulated by a per-learner diurnal phase.  ``available(t)``
is O(log n) via binary search; sessions are generated lazily.
"""
from __future__ import annotations

import bisect

import numpy as np

HOUR = 3600.0
DAY = 24 * HOUR


class LearnerTrace:
    def __init__(self, seed: int, phase_hours: float, night_owl: float,
                 horizon: float = 14 * DAY):
        rng = np.random.default_rng(seed)
        self.boundaries = [0.0]
        self.states = []       # states[i] applies in [boundaries[i], boundaries[i+1])
        t, avail = 0.0, False
        while t < horizon:
            hod = ((t / HOUR + phase_hours) % 24.0)
            night = 1.0 if (hod >= 22 or hod < 7) else 0.0
            if avail:
                # daytime sessions: lognormal median ~4 min (70% < 10 min,
                # paper §C); night sessions: overnight charging, median ~1 h
                if night * night_owl > 0.5:
                    dur = float(np.exp(np.log(60 * 60) + 1.2 * rng.standard_normal()))
                    dur = min(max(dur, 5 * 60), 9 * HOUR)
                else:
                    dur = float(np.exp(np.log(4 * 60) + 1.0 * rng.standard_normal()))
                    dur = min(max(dur, 30.0), 2 * HOUR)
            else:
                # gap short at night (plugging back in), long by day
                mean_gap = (25 * 60) * (1 - night * night_owl) \
                    + (6 * 60) * night * night_owl
                dur = float(rng.exponential(mean_gap) + 30.0)
            self.states.append(avail)
            t += dur
            self.boundaries.append(t)
            avail = not avail
        self.states.append(avail)

    def available(self, t: float) -> bool:
        i = bisect.bisect_right(self.boundaries, t) - 1
        return self.states[min(i, len(self.states) - 1)]

    def available_through(self, t0: float, t1: float) -> bool:
        """True if available for the whole window (no dropout mid-round)."""
        i0 = bisect.bisect_right(self.boundaries, t0) - 1
        i1 = bisect.bisect_right(self.boundaries, t1) - 1
        return i0 == i1 and self.states[min(i0, len(self.states) - 1)]

    def next_unavailable_after(self, t: float) -> float:
        i = bisect.bisect_right(self.boundaries, t) - 1
        if not self.states[min(i, len(self.states) - 1)]:
            return t
        return self.boundaries[i + 1] if i + 1 < len(self.boundaries) else float("inf")


class AlwaysAvailable:
    def available(self, t):  # noqa: D102
        return True

    def available_through(self, t0, t1):
        return True

    def next_unavailable_after(self, t):
        return float("inf")


class TraceBank:
    """Struct-of-arrays view over n learner traces for batched queries.

    All per-learner segment boundaries are packed into one globally sorted
    array by offsetting learner ``i``'s boundaries by ``i * stride`` (stride
    exceeds every boundary and every clipped query time), so a single
    ``np.searchsorted`` resolves the active segment of *all* queried learners
    at once — the vectorized counterpart of ``LearnerTrace.available``'s
    per-learner ``bisect``.  Semantics match the scalar classes bit-for-bit.
    """

    def __init__(self, traces):
        self.n = len(traces)
        rows_b = [np.asarray(getattr(t, "boundaries", [0.0]), np.float64)
                  for t in traces]
        rows_s = [np.asarray(getattr(t, "states", [True]), bool)
                  for t in traces]
        self.lens = np.array([len(b) for b in rows_b], np.int64)
        self.offsets = np.concatenate([[0], np.cumsum(self.lens)[:-1]])
        self.boundaries = (np.concatenate(rows_b) if rows_b
                           else np.zeros(0))
        self.states = (np.concatenate(rows_s) if rows_s
                       else np.zeros(0, bool))
        self.stride = float(self.boundaries.max(initial=0.0)) + 2.0
        self._packed = (self.boundaries
                        + np.repeat(np.arange(self.n), self.lens) * self.stride)
        self._all = np.arange(self.n)

    def _segment(self, lids, t):
        """Active segment index per queried learner (clipped to the last)."""
        tq = np.minimum(t, self.stride - 1.0)
        q = lids * self.stride + tq
        idx = np.searchsorted(self._packed, q, side="right") - 1 - self.offsets[lids]
        return np.clip(idx, 0, self.lens[lids] - 1)

    def available_batch(self, lids, t):
        lids = np.asarray(lids)
        return self.states[self.offsets[lids] + self._segment(lids, t)]

    def available_all(self, t):
        return self.available_batch(self._all, t)

    def available_through_batch(self, lids, t0, t1):
        lids = np.asarray(lids)
        s0 = self._segment(lids, t0)
        s1 = self._segment(lids, t1)
        return (s0 == s1) & self.states[self.offsets[lids] + s0]

    def next_unavailable_after_batch(self, lids, t):
        """Per-learner next dropout time; ``t`` where already unavailable,
        +inf when available beyond the trace horizon."""
        lids = np.asarray(lids)
        seg = self._segment(lids, t)
        avail = self.states[self.offsets[lids] + seg]
        has_next = seg + 1 < self.lens[lids]
        nxt_idx = self.offsets[lids] + np.minimum(seg + 1, self.lens[lids] - 1)
        nxt = np.where(has_next, self.boundaries[nxt_idx], np.inf)
        return np.where(avail, nxt, t)

    def view(self, lid: int) -> "TraceView":
        return TraceView(self, lid)


class TraceView:
    """Scalar ``LearnerTrace``-compatible facade over one TraceBank row."""

    __slots__ = ("bank", "lid", "_lid_arr")

    def __init__(self, bank: TraceBank, lid: int):
        self.bank = bank
        self.lid = lid
        self._lid_arr = np.array([lid])

    def available(self, t: float) -> bool:
        return bool(self.bank.available_batch(self._lid_arr, t)[0])

    def available_through(self, t0: float, t1: float) -> bool:
        return bool(self.bank.available_through_batch(self._lid_arr, t0, t1)[0])

    def next_unavailable_after(self, t: float) -> float:
        return float(self.bank.next_unavailable_after_batch(self._lid_arr, t)[0])


def make_traces(n: int, rng: np.random.Generator, dynamic: bool = True,
                horizon: float = 14 * DAY):
    if not dynamic:
        return [AlwaysAvailable() for _ in range(n)]
    seeds = rng.integers(0, 2 ** 31, size=n)
    phases = rng.uniform(0, 24, size=n)              # timezone / habit offset
    owls = np.clip(rng.beta(4, 2, size=n), 0.2, 1.0)  # strength of diurnality
    return [LearnerTrace(int(s), float(p), float(o), horizon)
            for s, p, o in zip(seeds, phases, owls)]
