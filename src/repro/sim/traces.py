"""Synthetic availability traces matched to the paper's §C analysis of the
136k-user behavior trace (Yang et al., 2020):

- diurnal cycles: most devices are available (charging) at night, few by day;
- long-tail session lengths: ~70% of availability sessions last < 10 minutes;
- cyclic weekly behavior.

Each learner gets a deterministic alternating (gap, session) renewal process
whose gap intensity is modulated by a per-learner diurnal phase.  ``available(t)``
is O(log n) via binary search; sessions are generated lazily.
"""
from __future__ import annotations

import bisect

import numpy as np

HOUR = 3600.0
DAY = 24 * HOUR


class LearnerTrace:
    def __init__(self, seed: int, phase_hours: float, night_owl: float,
                 horizon: float = 14 * DAY):
        rng = np.random.default_rng(seed)
        self.boundaries = [0.0]
        self.states = []       # states[i] applies in [boundaries[i], boundaries[i+1])
        t, avail = 0.0, False
        while t < horizon:
            hod = ((t / HOUR + phase_hours) % 24.0)
            night = 1.0 if (hod >= 22 or hod < 7) else 0.0
            if avail:
                # daytime sessions: lognormal median ~4 min (70% < 10 min,
                # paper §C); night sessions: overnight charging, median ~1 h
                if night * night_owl > 0.5:
                    dur = float(np.exp(np.log(60 * 60) + 1.2 * rng.standard_normal()))
                    dur = min(max(dur, 5 * 60), 9 * HOUR)
                else:
                    dur = float(np.exp(np.log(4 * 60) + 1.0 * rng.standard_normal()))
                    dur = min(max(dur, 30.0), 2 * HOUR)
            else:
                # gap short at night (plugging back in), long by day
                mean_gap = (25 * 60) * (1 - night * night_owl) \
                    + (6 * 60) * night * night_owl
                dur = float(rng.exponential(mean_gap) + 30.0)
            self.states.append(avail)
            t += dur
            self.boundaries.append(t)
            avail = not avail
        self.states.append(avail)

    def available(self, t: float) -> bool:
        i = bisect.bisect_right(self.boundaries, t) - 1
        return self.states[min(i, len(self.states) - 1)]

    def available_through(self, t0: float, t1: float) -> bool:
        """True if available for the whole window (no dropout mid-round)."""
        i0 = bisect.bisect_right(self.boundaries, t0) - 1
        i1 = bisect.bisect_right(self.boundaries, t1) - 1
        return i0 == i1 and self.states[min(i0, len(self.states) - 1)]

    def next_unavailable_after(self, t: float) -> float:
        i = bisect.bisect_right(self.boundaries, t) - 1
        if not self.states[min(i, len(self.states) - 1)]:
            return t
        return self.boundaries[i + 1] if i + 1 < len(self.boundaries) else float("inf")


class AlwaysAvailable:
    def available(self, t):  # noqa: D102
        return True

    def available_through(self, t0, t1):
        return True

    def next_unavailable_after(self, t):
        return float("inf")


def make_traces(n: int, rng: np.random.Generator, dynamic: bool = True,
                horizon: float = 14 * DAY):
    if not dynamic:
        return [AlwaysAvailable() for _ in range(n)]
    seeds = rng.integers(0, 2 ** 31, size=n)
    phases = rng.uniform(0, 24, size=n)              # timezone / habit offset
    owls = np.clip(rng.beta(4, 2, size=n), 0.2, 1.0)  # strength of diurnality
    return [LearnerTrace(int(s), float(p), float(o), horizon)
            for s, p, o in zip(seeds, phases, owls)]
