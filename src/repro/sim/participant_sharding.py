"""Participant-axis device sharding: the 2-D round mesh and row placement.

The fused round pipeline's device work is dominated by the packed cohort
training rows — independent per-participant local-SGD programs — so the
participant axis, unlike the sweep axis, parallelizes the hot matmuls
themselves across devices.  This module owns the host-side layout machinery
for placing those rows on a mesh; ``repro.sim.pipeline`` runs the sharded
round program.

Mesh composition
----------------

The round mesh is always 2-D with axes ``("s", "p")``:

  ``"s"`` — the sweep axis (cells / simulations; PR 4's mesh).  Cell state
      (params, optimizer rows) is partitioned over it and each shard runs
      its own cells' rounds with **no** cross-cell communication;
  ``"p"`` — the participant axis.  Each round's packed cohort rows are
      split into balanced contiguous blocks over it: every p-shard trains
      its block of rows shard-locally and holds the straggler-cache slots
      of the rows it trained.

Either axis may have size 1, so the same program covers sweep-only sharding
(PR 4, ``n_p = 1``), participant-only sharding of a single simulation
(``n_s = 1``), and the full 2-D composition.  ``as_round_mesh`` normalizes
a legacy 1-D ``("s",)`` mesh (``repro.sweeps.sharding.sweep_mesh``) into
the 2-D form.

Collective-per-round invariant
------------------------------

Cell parameters are **replicated** along ``"p"`` (placed ``P("s")``): every
p-shard applies the identical post-aggregation server step, so the replicas
stay bitwise equal without communication.  The only cross-shard data
dependency of a round is the SAA aggregation operand — each cell's fresh
rows and landing cache slots live on whichever p-shards trained them — and
it is reduced with a single ``jax.lax.psum`` over ``"p"``: each shard
contributes the columns it owns and exact zeros elsewhere, so the summed
operand is bit-identical to the unsharded gather (every element has exactly
one non-zero contributor) and the psum is the ONE collective in the hot
loop (asserted against the lowered HLO by tests/test_participant_sharding).

Dataset/test tensors are replicated over the whole mesh (read-only: each
p-shard gathers its own rows' local batches in-program); the per-round
index arrays are sharded like the cache, one block per (s, p) shard.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

SWEEP_AXIS = "s"
PART_AXIS = "p"


def round_mesh(n_sweep: int = 1, n_participant: int = 1,
               devices=None) -> Mesh:
    """2-D ``("s", "p")`` mesh over ``n_sweep * n_participant`` devices."""
    devs = list(jax.devices() if devices is None else devices)
    need = n_sweep * n_participant
    if need > len(devs):
        raise ValueError(f"round_mesh needs {n_sweep} x {n_participant} = "
                         f"{need} devices, have {len(devs)}")
    return Mesh(np.array(devs[:need]).reshape(n_sweep, n_participant),
                (SWEEP_AXIS, PART_AXIS))


def participant_mesh(n_participant=True, devices=None) -> Mesh:
    """Participant-only round mesh (``n_s = 1``) for single simulations.

    ``n_participant=True`` takes every local device; an int takes that many
    (clamped to the local device count, so a config asking for 4-way
    sharding still runs — trivially — on a 1-device host).
    """
    devs = list(jax.devices() if devices is None else devices)
    n_p = len(devs) if n_participant is True else min(int(n_participant),
                                                     len(devs))
    return round_mesh(1, max(n_p, 1), devs)


def as_round_mesh(mesh: Mesh) -> Mesh:
    """Normalize any accepted mesh into the 2-D ``("s", "p")`` form.

    Accepts the legacy 1-D ``("s",)`` sweep mesh (becomes ``n_p = 1``), a
    1-D ``("p",)`` mesh (becomes ``n_s = 1``), or a 2-D ``("s", "p")`` mesh
    (returned as-is).
    """
    names = tuple(mesh.axis_names)
    if names == (SWEEP_AXIS, PART_AXIS):
        return mesh
    devs = mesh.devices
    if names == (SWEEP_AXIS,):
        return Mesh(devs.reshape(-1, 1), (SWEEP_AXIS, PART_AXIS))
    if names == (PART_AXIS,):
        return Mesh(devs.reshape(1, -1), (SWEEP_AXIS, PART_AXIS))
    raise ValueError(f"expected a ('s',), ('p',) or ('s', 'p') mesh, "
                     f"got axes {names}")


def split_balanced(n: int, parts: int) -> list:
    """Balanced contiguous split sizes: ``parts`` blocks covering ``n`` rows,
    sizes differing by at most one (larger blocks first) — the participant
    analogue of ``Placement.build``'s cell split."""
    return [n // parts + (1 if j < n % parts else 0) for j in range(parts)]


# ---------------------------------------------------------------------------
# Placement specs for the round pipeline's device tensors
# ---------------------------------------------------------------------------


def param_spec(mesh: Mesh) -> NamedSharding:
    """(n_s, s_loc + 1, D) cell params/optimizer rows: partitioned over "s",
    replicated over "p" (every p-shard applies the identical server step)."""
    return NamedSharding(mesh, P(SWEEP_AXIS))


def cache_spec(mesh: Mesh) -> NamedSharding:
    """(n_s * n_p, C + 1, D) stale-cache rows: the leading axis is the flat
    (s, p) shard id (s-major), matching ``ShardedSlotAccounts`` run with
    ``n_shards = n_s * n_p`` — a straggler's slot lives on the p-shard that
    trained it."""
    return NamedSharding(mesh, P((SWEEP_AXIS, PART_AXIS)))


def chunk_spec(mesh: Mesh) -> NamedSharding:
    """(K, n_s * n_p, L) per-round packed index arrays: one block per flat
    (s, p) shard."""
    return NamedSharding(mesh, P(None, (SWEEP_AXIS, PART_AXIS)))


def replicated_spec(mesh: Mesh) -> NamedSharding:
    """Full replication (datasets / test sets / eval index maps)."""
    return NamedSharding(mesh, P())
