"""Device-resident round pipeline: ONE jitted dispatch per simulation round.

``RoundPipeline`` drives S >= 1 Simulators (the serial engine passes
``[self]``; ``repro.sweeps.runner`` passes a compatibility batch) through a
round loop whose entire device side — cohort local training, straggler
scatter into the device stale cache, SAA weights + aggregation, and the
server apply — is one compiled program with **donated** parameter / cache /
optimizer buffers.  Host<->device traffic per round:

  host -> device: the round's index arrays (sample indices, row->cell
      ownership, cache scatter slots, aggregation gather/mask arrays) via
      explicit ``jax.device_put`` — a few KB of int32/bool, never update
      rows or batch data (the dataset lives on device for the whole run);
  device -> host: nothing, unless an Oort selector needs its per-row
      stat-utility feedback (a (R,) fp32 vector), plus accuracy/loss on
      ``eval_every`` boundaries.

Because every *decision* of a round (arrival order, round end, fresh vs
straggler split, cache landings) depends only on durations/dropouts — never
on update values — ``Simulator._schedule_round`` runs before the dispatch
and the whole round becomes data-independent index plumbing around one
launch.  All heavy intermediates (the (R, D) delta rows, the stale rows,
the (G, n, D) aggregation operand) exist only inside the program.

Parity: gathers/scatters are pure data movement, padding rows are masked to
exact zeros before aggregation (``bucket_pad``'s layout, bit-for-bit), the
weights+aggregate unit is the same ``weights_and_aggregate_by_id`` the
batched sweep path has always vmapped, and the server apply is the same
formula — so per-cell metrics are bit-identical to the per-stage flat path
and to serial runs (asserted by tests/test_pipeline_parity.py and the
benchmarks).

Donation invariants: the stacked params tensor, the cache rows and the
optimizer state are donated into every round program — after a ``step`` the
previous round's buffers are dead and must not be touched; the pipeline is
their only owner and always replaces its references with the returned
arrays.  ``Simulator.flat_params`` is stale while a pipeline run is in
flight and is rewritten at ``finalize``.  Dataset/test tensors are *not*
donated (read-only, reused every round).

Early stop: cells whose latest evaluation reached ``target_accuracy`` leave
the lockstep batch entirely — no host round logic, no packed rows, no
aggregation group, no eval slot — so a sweep's per-round cost tracks the
*live* cells (bucket-padded repacking shrinks every axis), not S x rounds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import (aggregate_updates, unflatten_update,
                                    weights_and_aggregate_by_id,
                                    yogi_apply_flat)
from repro.core.stale_cache import DeviceStaleCache
from repro.core.staleness import EPS, RULE_ID
from repro.sim import learner as ln

ROW_BLOCK = 128   # packed participant-row padding bucket (bucket_block)
UPD_BLOCK = 32    # per-cell aggregation-row padding bucket (sweep_bucket_pad's)


def pipeline_key(cfg) -> tuple:
    """Config fields every Simulator in one pipeline must share: they fix
    the compiled round program's static structure or the lockstep cadence.
    ``repro.sweeps.runner.compat_key`` groups cells by (a superset of) this."""
    return (cfg.benchmark, cfg.local_steps, cfg.local_batch, cfg.local_lr,
            cfg.prox_mu, cfg.rounds, cfg.eval_every, cfg.aggregator,
            cfg.use_agg_kernel,
            cfg.scaling_rule if cfg.use_agg_kernel else None)


@dataclasses.dataclass
class PipelineStats:
    """Dispatch / transfer accounting for the hot loop (``--profile``)."""
    rounds: int = 0
    dispatches: dict = dataclasses.field(
        default_factory=lambda: {"round": 0, "eval": 0, "cache_grow": 0})
    h2d_bytes: int = 0          # per-round index arrays (explicit device_put)
    d2h_bytes: int = 0          # stat-util + eval fetches
    init_h2d_bytes: int = 0     # one-time dataset/params uploads

    def as_dict(self) -> dict:
        per_round = max(self.rounds, 1)
        return {
            "rounds": self.rounds,
            "dispatches": dict(self.dispatches),
            "dispatches_per_round": round(
                sum(self.dispatches.values()) / per_round, 3),
            "h2d_bytes": self.h2d_bytes,
            "d2h_bytes": self.d2h_bytes,
            "h2d_bytes_per_round": round(self.h2d_bytes / per_round),
            "d2h_bytes_per_round": round(self.d2h_bytes / per_round),
            "init_h2d_bytes": self.init_h2d_bytes,
        }


# ---------------------------------------------------------------------------
# The fused round program
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=16)
def _round_program(spec, lr, prox_mu, steps, batch, yogi, use_kernel,
                   kernel_rule, single):
    """Build + jit the single-dispatch round program.

    Static over (model spec, local hyperparameters, server optimizer,
    kernel routing, S==1); the round-varying index arrays arrive packed in
    TWO device buffers (one int32, one fp32) whose layout is described by
    the static ``shapes`` tuple — so one explicit ``jax.device_put`` pair
    covers a round, and XLA recompiles only when a padding bucket first
    appears.  ``single`` broadcasts the parameters instead of gathering
    them (the serial engine's S == 1 case; bit-identical either way).
    """
    train_unit = functools.partial(ln.local_train_flat, spec=spec, lr=lr,
                                   prox_mu=prox_mu)

    def prog(params, cache, opt_state, x_tr, y_tr, ints, floats, shapes):
        r_b, tb, g_b, nf_b, ns_b, all_valid = shapes
        n_b = nf_b + ns_b
        o = [0]

        def take(n, shape=None, dtype=None):
            a = ints[o[0]:o[0] + n]
            o[0] += n
            if dtype is not None:
                a = a.astype(dtype)
            return a.reshape(shape) if shape is not None else a

        batch_idx = take(r_b * tb, (r_b, tb))
        row_cell = take(r_b)
        row_sub = take(r_b)
        scat_slot = take(r_b)
        agg_cell = take(g_b)
        fr_idx = take(g_b * nf_b, (g_b, nf_b))
        sl_idx = take(g_b * ns_b, (g_b, ns_b))
        agg_tau = take(g_b * n_b, (g_b, n_b))
        rule_id = take(g_b)
        agg_fresh = take(g_b * n_b, (g_b, n_b), bool)
        agg_valid = take(g_b * n_b, (g_b, n_b), bool)
        has_g = take(g_b, None, bool)
        beta_g, lr_g = floats[:g_b], floats[g_b:2 * g_b]

        # --- train: gather batches + per-row params, one vmapped call ---
        bx = x_tr[row_sub[:, None], batch_idx]            # (R, steps*batch, dim)
        bx = bx.reshape(r_b, steps, batch, bx.shape[-1])
        by = y_tr[row_sub[:, None], batch_idx].reshape(r_b, steps, batch)
        if single:
            deltas, losses, l2s = jax.vmap(
                train_unit, in_axes=(None, 0, 0))(params[0], bx, by)
        else:
            deltas, losses, l2s = jax.vmap(train_unit)(params[row_cell], bx, by)

        # --- straggler scatter into the cache, then gather ---------------
        # scatter FIRST so the donated cache updates in place (a gather
        # before the scatter would force XLA to copy the whole buffer);
        # this round's scatter slots are disjoint from this round's landing
        # slots because the pipeline quarantines freed slots for one round
        cache = cache.at[scat_slot].set(deltas)

        # fresh columns from this round's delta rows, stale columns from
        # the cache slots; same per-cell row multiset as the per-stage
        # path's (fresh + stale, zero-padded) stack
        uf, us = deltas[fr_idx], cache[sl_idx]
        if not all_valid:
            # bucket_pad's exact zeros in the padding columns
            uf = jnp.where(agg_valid[:, :nf_b, None], uf, 0.0)
            us = jnp.where(agg_valid[:, nf_b:, None], us, 0.0)
        u = jnp.concatenate([uf, us], axis=1)

        # --- SAA weights + aggregate + server apply ----------------------
        rows_old = params[agg_cell]                       # (G, D)
        if use_kernel:
            from repro.kernels.staleness_agg.staleness_agg import (
                D_BLK, sweep_fused_staleness_apply,
                sweep_fused_staleness_aggregate)
            d = u.shape[-1]
            pad = (-d) % D_BLK
            up = jnp.pad(u, ((0, 0), (0, 0), (0, pad)))
            if yogi:
                agg_out, _ = sweep_fused_staleness_aggregate(
                    up, agg_fresh, agg_tau, beta_g, agg_valid,
                    rule=kernel_rule)
                agg_out = agg_out[:, :d]
            else:
                scal = jnp.stack([beta_g, lr_g], axis=1)
                new_rows, _ = sweep_fused_staleness_apply(
                    jnp.pad(rows_old, ((0, 0), (0, pad))), up, agg_fresh,
                    agg_tau, agg_valid, scal, rule=kernel_rule)
                new_rows = new_rows[:, :d]
        elif ns_b == 0:
            # no stale rows anywhere this round: Eq. 2 degenerates to the
            # fresh average, so skip the deviation pass entirely.  The
            # weight vector is bit-identical to the general path's (fresh
            # rows weigh 1, padding weighs 0, same normalization).
            w = agg_fresh.astype(jnp.float32)
            w = w / jnp.maximum(w.sum(axis=1, keepdims=True), EPS)
            agg_out = jax.vmap(aggregate_updates)(u, w)
        else:
            agg_out, _ = jax.vmap(weights_and_aggregate_by_id)(
                u, agg_fresh, agg_tau, agg_valid, beta_g, rule_id)
        if yogi:
            state_rows = jax.tree.map(lambda s: s[agg_cell], opt_state)
            new_rows, new_state = jax.vmap(yogi_apply_flat)(
                rows_old, agg_out, state_rows)
            keep = lambda new, old: jnp.where(
                has_g.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
            opt_state = jax.tree.map(
                lambda s, ns, os: s.at[agg_cell].set(keep(ns, os)),
                opt_state, new_state, state_rows)
        elif not use_kernel:
            new_rows = rows_old + lr_g[:, None] * agg_out
        new_rows = jnp.where(has_g[:, None], new_rows, rows_old)
        params = params.at[agg_cell].set(new_rows)
        return params, cache, opt_state, losses, l2s

    return jax.jit(prog, donate_argnums=(0, 1, 2), static_argnums=(7,))


@functools.lru_cache(maxsize=8)
def _eval_program(spec):
    """Batched eval over the live cells: gather their parameter rows and
    each cell's (possibly shared) test set."""
    def ev(flat, ti, x_u, y_u):
        return ln.evaluate(unflatten_update(flat, spec), x_u[ti], y_u[ti])

    def f(params, packed, x_u, y_u):
        l_b = packed.shape[0] // 2
        eval_idx, te_idx = packed[:l_b], packed[l_b:]
        return jax.vmap(ev, in_axes=(0, 0, None, None))(
            params[eval_idx], te_idx, x_u, y_u)

    return jax.jit(f)


# ---------------------------------------------------------------------------
# Pipeline driver
# ---------------------------------------------------------------------------


class RoundPipeline:
    def __init__(self, sims: Sequence, progress: bool = False):
        assert len(sims) >= 1
        self.sims = list(sims)
        self.progress = progress
        cfg0 = sims[0].cfg
        for sim in sims:
            assert sim.cfg.fast_path and sim.cfg.fused_rounds, \
                "RoundPipeline drives the fused fast path only"
            assert pipeline_key(sim.cfg) == pipeline_key(cfg0), \
                "incompatible Simulators in one pipeline batch"
        self.cfg0 = cfg0
        self.spec = sims[0]._flat_spec
        self.d = agg.flat_dim(self.spec)
        self.yogi = cfg0.aggregator == "yogi"
        self.stats = PipelineStats()

        s = len(sims)
        # stacked (S+1, D) params; the extra row is scratch that padding
        # aggregation groups read and write (never a real cell)
        self.params = jnp.concatenate(
            [jnp.stack([sim.flat_params for sim in sims]),
             jnp.zeros((1, self.d), jnp.float32)])
        if self.yogi:
            self.opt_state = jax.tree.map(
                lambda *xs: jnp.stack(xs + (jnp.zeros_like(xs[0]),)),
                *[sim.flat_opt_state for sim in sims])
        else:
            self.opt_state = None
        self.cache = DeviceStaleCache(
            self.d, capacity=max(c.cfg.stale_cache_capacity for c in sims),
            grow=True)

        # one device copy of each distinct substrate's dataset
        subs = []
        self.sub_idx = np.zeros(s, np.int32)
        for i, sim in enumerate(sims):
            if not any(sim.substrate is sb for sb in subs):
                subs.append(sim.substrate)
            self.sub_idx[i] = next(j for j, sb in enumerate(subs)
                                   if sb is sim.substrate)
        host = (np.stack([sb.data.x_train for sb in subs]),
                np.stack([sb.data.y_train for sb in subs]),
                np.stack([sb.data.x_test for sb in subs]),
                np.stack([sb.data.y_test for sb in subs]))
        self.x_tr, self.y_tr, self.x_te, self.y_te = jax.device_put(host)
        self.stats.init_h2d_bytes = (sum(a.nbytes for a in host)
                                     + (s + 1) * self.d * 4)
        # Oort is the only selector that consumes the per-row stat-utility
        # feedback; without one the round loop fetches nothing per round
        self._fetch_l2s = any(sim.cfg.selector == "oort" for sim in sims)
        self._prog = _round_program(
            self.spec, cfg0.local_lr, cfg0.prox_mu, cfg0.local_steps,
            cfg0.local_batch, self.yogi, cfg0.use_agg_kernel,
            cfg0.scaling_rule if cfg0.use_agg_kernel else None,
            len(sims) == 1)
        # single-sim non-SAFA cohorts have a near-constant size, so exact
        # (unpadded) shapes cost at most a handful of compiles and remove
        # the pow2 bucket's up-to-2x wasted training rows — but only long
        # runs amortize those compiles; short runs, SAFA cohorts (sizes all
        # over the place) and sweep batches keep the shared padding buckets.
        # Padding is masked/discarded everywhere, so the choice never
        # affects results (bucket_block's contract).
        self._exact = (len(sims) == 1 and cfg0.selector != "safa"
                       and cfg0.rounds >= 24)
        self._eval = _eval_program(self.spec)
        self.done = [False] * s
        self._pending_free = []   # freed slots quarantined for one round

    # ------------------------------------------------------------------
    def run(self, transfer_guard: bool = False):
        """Drive every round, then finalize.  ``transfer_guard=True`` wraps
        the round loop in ``jax.transfer_guard("disallow")``: every upload
        the pipeline performs is an explicit ``device_put``, so any
        *implicit* host transfer sneaking into the hot path raises — the
        CI smoke (and ``--profile`` benches) run in this mode."""
        for sim in self.sims:
            sim._t_now = 0.0
        if transfer_guard:
            with jax.transfer_guard("disallow"):
                self._run_rounds()
        else:
            self._run_rounds()
        return self.finalize()

    def _run_rounds(self):
        for r in range(self.cfg0.rounds):
            if all(self.done):
                break
            self.step(r)

    # ------------------------------------------------------------------
    def step(self, r: int) -> None:
        """One lockstep round across the live cells: host logic + ONE
        device dispatch (plus the batched eval on eval rounds)."""
        sims = self.sims
        cfg0 = self.cfg0
        plans = {}
        for i, sim in enumerate(sims):
            if self.done[i]:
                continue
            p = sim._begin_round(r)
            if p is not None:
                plans[i] = p
        if not plans:
            return
        order = list(plans)
        scheds = {i: sims[i]._schedule_round(r, plans[i]) for i in order}

        # --- slot management ---------------------------------------------
        # slots freed by landings/expiries are quarantined for one round
        # (released here, before this round's allocs): a slot gathered this
        # round is therefore never a scatter target this round, which lets
        # the program scatter before it gathers and keep the donated cache
        # update fully in place
        grow0 = self.cache.grow_events
        if self._pending_free:
            self.cache.free(self._pending_free)
        self._pending_free = [
            f.delta for i in order
            for f in scheds[i].landing + scheds[i].expired]
        for i in order:
            sc = scheds[i]
            if sc.new_stale:
                sc.slots, _ = self.cache.alloc(len(sc.new_stale))
        self.stats.dispatches["cache_grow"] += self.cache.grow_events - grow0

        # --- pack this round's cohort rows (survivors only) --------------
        # mid-round dropouts never deliver an update and never feed the
        # selector, so their rows are excluded from the packed training
        # call — the per-stage paths train them and discard the result
        tb = cfg0.local_steps * cfg0.local_batch
        surv = {i: np.nonzero(~np.isfinite(plans[i].drop_at))[0]
                for i in order}
        n_rows = sum(len(surv[i]) for i in order)
        r_b = (max(n_rows, 1) if self._exact
               else agg.bucket_block(max(n_rows, 1), ROW_BLOCK))
        batch_idx = np.zeros((r_b, tb), np.int32)
        row_cell = np.zeros(r_b, np.int32)
        row_sub = np.zeros(r_b, np.int32)
        scat_slot = np.full(r_b, self.cache.trash_slot, np.int32)
        pos = {}            # (sim, plan row) -> packed row
        offs = {}           # sim -> start of its packed block
        off = 0
        for i in order:
            p, sc = plans[i], scheds[i]
            sv = surv[i]
            offs[i] = off
            batch_idx[off:off + len(sv)] = p.bidx[sv]
            row_cell[off:off + len(sv)] = i
            row_sub[off:off + len(sv)] = self.sub_idx[i]
            for local, row_i in enumerate(sv):
                pos[(i, int(row_i))] = off + local
            for (row_i, _lid, _arr, _dur), slot in zip(sc.new_stale, sc.slots):
                scat_slot[pos[(i, row_i)]] = slot
            off += len(sv)
        if off < r_b:               # padding rows replicate the first real row
            batch_idx[off:] = batch_idx[0]
            row_cell[off:] = row_cell[0]
            row_sub[off:] = row_sub[0]

        # --- aggregation groups: one per cell with updates ---------------
        # column layout per group: fresh rows in [0, nf_b) (delta gathers),
        # stale rows in [nf_b, nf_b + ns_b) (cache-slot gathers); padding
        # columns are invalid and zeroed in-program, so each cell's operand
        # holds the same row multiset as the per-stage path's padded stack
        groups = [i for i in order
                  if scheds[i].fresh_rows or scheds[i].landing]
        g_b = (max(len(groups), 1) if self._exact
               else agg.bucket_pow2(max(len(groups), 1)))
        nf_max = max([len(scheds[i].fresh_rows) for i in groups] + [1])
        ns_max = max([len(scheds[i].landing) for i in groups] + [0])
        nf_b = (nf_max if self._exact
                else agg.bucket_block(nf_max, UPD_BLOCK))
        ns_b = (ns_max if self._exact
                else (agg.bucket_pow2(ns_max) if ns_max else 0))
        n_b = nf_b + ns_b
        all_valid = bool(
            groups and g_b == len(groups)
            and all(len(scheds[i].fresh_rows) == nf_b
                    and len(scheds[i].landing) == ns_b for i in groups))
        s_total = len(sims)
        agg_cell = np.full(g_b, s_total, np.int32)     # scratch params row
        fr_idx = np.zeros((g_b, nf_b), np.int32)
        sl_idx = np.zeros((g_b, ns_b), np.int32)
        agg_fresh = np.zeros((g_b, n_b), np.int32)
        agg_tau = np.zeros((g_b, n_b), np.int32)
        agg_valid = np.zeros((g_b, n_b), np.int32)
        rule_id = np.zeros(g_b, np.int32)
        has_g = np.zeros(g_b, np.int32)
        beta_g = np.zeros(g_b, np.float32)
        lr_g = np.zeros(g_b, np.float32)
        for g, i in enumerate(groups):
            sc, cfg = scheds[i], sims[i].cfg
            for col, row_i in enumerate(sc.fresh_rows):       # arrival order
                fr_idx[g, col] = pos[(i, row_i)]
                agg_fresh[g, col] = 1
                agg_valid[g, col] = 1
            for col, (f, tau) in enumerate(zip(sc.landing,
                                               sc.landing_taus)):  # cache order
                sl_idx[g, col] = f.delta           # cache slot
                agg_tau[g, nf_b + col] = tau
                agg_valid[g, nf_b + col] = 1
            agg_cell[g] = i
            rule_id[g] = RULE_ID[cfg.scaling_rule]
            beta_g[g] = cfg.beta
            lr_g[g] = cfg.server_lr
            has_g[g] = 1

        # --- ONE dispatch for the whole round ----------------------------
        ints = np.concatenate([batch_idx.ravel(), row_cell, row_sub,
                               scat_slot, agg_cell, fr_idx.ravel(),
                               sl_idx.ravel(), agg_tau.ravel(), rule_id,
                               agg_fresh.ravel(), agg_valid.ravel(), has_g])
        floats = np.concatenate([beta_g, lr_g])
        dev_ints, dev_floats = jax.device_put((ints, floats))
        self.stats.h2d_bytes += ints.nbytes + floats.nbytes
        self.stats.dispatches["round"] += 1
        self.stats.rounds += 1
        (self.params, self.cache.rows, self.opt_state, _losses, l2s) = \
            self._prog(self.params, self.cache.rows, self.opt_state,
                       self.x_tr, self.y_tr, dev_ints, dev_floats,
                       (r_b, tb, g_b, nf_b, ns_b, all_valid))

        l2s_np = None
        if self._fetch_l2s:
            l2s_np = np.asarray(jax.device_get(l2s))
            self.stats.d2h_bytes += l2s_np.nbytes

        # --- host bookkeeping: feedback, cache entries, records ----------
        from repro.sim.engine import _InFlight
        for i in order:
            sim, sc = sims[i], scheds[i]
            if l2s_np is None:
                l2s_i = None
            else:
                # re-index the packed survivor rows back to plan rows (the
                # feedback loop addresses plan rows; dropouts never feed back)
                l2s_i = np.zeros(plans[i].k, np.float32)
                l2s_i[surv[i]] = l2s_np[offs[i]:offs[i] + len(surv[i])]
            sim._apply_feedback(r, sc, l2s_i)
            for (row_i, lid, arr, dur), slot in zip(sc.new_stale, sc.slots):
                sim.stale_cache.append(_InFlight(
                    lid, r, arr, dur, slot, sim._stat_util(row_i, l2s_i)))

        acc = loss = None
        if sims[order[0]].eval_due(r):
            l_b = agg.bucket_pow2(len(order))
            eidx = np.asarray(order + [order[0]] * (l_b - len(order)), np.int32)
            packed = jax.device_put(np.concatenate([eidx, self.sub_idx[eidx]]))
            self.stats.dispatches["eval"] += 1
            a, lo = self._eval(self.params, packed, self.x_te, self.y_te)
            acc, loss = np.asarray(jax.device_get(a)), np.asarray(jax.device_get(lo))
            self.stats.h2d_bytes += 2 * eidx.nbytes
            self.stats.d2h_bytes += acc.nbytes + loss.nbytes
        for ei, i in enumerate(order):
            sc = scheds[i]
            sims[i]._record_round(
                r, plans[i].t_now, sc.t_end, len(plans[i].chosen),
                len(sc.fresh_rows), len(sc.landing),
                acc_loss=(acc[ei], loss[ei]) if acc is not None else None,
                progress=self.progress)
            if sims[i]._target_reached():
                sims[i].acct.stopped_early = True
                self.done[i] = True

    # ------------------------------------------------------------------
    def finalize(self):
        """Write the device state back to the Simulators and finalize each.
        After this the pipeline's donated-buffer chain ends; the returned
        Accountings are the same objects ``Simulator.run`` yields."""
        accts = []
        for i, sim in enumerate(self.sims):
            sim.flat_params = self.params[i]
            if self.yogi:
                sim.flat_opt_state = jax.tree.map(lambda x: x[i],
                                                  self.opt_state)
            accts.append(sim._finalize())
        return accts
