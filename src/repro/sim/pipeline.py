"""Device-resident round pipeline: ONE jitted dispatch per simulation round
(or per K-round chunk), optionally sharded over a sweep-axis device mesh.

``RoundPipeline`` drives S >= 1 Simulators (the serial engine passes
``[self]``; ``repro.sweeps.runner`` passes a compatibility batch) through a
round loop whose entire device side — cohort local training, straggler
scatter into the device stale cache, SAA weights + aggregation, and the
server apply — is one compiled program with **donated** parameter / cache /
optimizer buffers.  Host<->device traffic per round:

  host -> device: the round's index arrays (sample indices, row->cell
      ownership, cache scatter slots, aggregation gather/mask arrays) via
      explicit ``jax.device_put`` — a few KB of int32/bool, never update
      rows or batch data (the dataset lives on device for the whole run);
  device -> host: nothing, unless a ``needs_feedback`` selector (Oort,
      UCB, contribution — see ``repro.selection``) needs its per-row
      stat-utility feedback (a (R,) fp32 vector), plus accuracy/loss on
      ``eval_every`` boundaries.

Because every *decision* of a round (arrival order, round end, fresh vs
straggler split, cache landings) depends only on durations/dropouts — never
on update values — ``Simulator._schedule_round`` runs before the dispatch
and the whole round becomes data-independent index plumbing around one
launch.  All heavy intermediates (the (R, D) delta rows, the stale rows,
the (G, n, D) aggregation operand) exist only inside the program.

Multi-round chunking (``SimConfig.rounds_per_dispatch`` = K > 1): the host
state machine is *prescheduled* K rounds ahead — legal because nothing it
decides reads update values — and the K rounds run as one ``lax.scan`` over
the round body with the donated params/cache/optimizer buffers threaded
through the scan carry.  Chunks always break at ``eval_every`` boundaries,
so evaluation, accuracy-target early stop and the stat-utility feedback
keep their exact round semantics; per-cell results are bit-identical to
K=1 (asserted by tests/test_chunked_sharded.py).  A ``needs_feedback``
selector (``repro.selection``: Oort, UCB, contribution) needs its
per-round device feedback before the *next* round's selection, so it
forces K=1 — and because ``selector_key`` is part of ``pipeline_key``,
only *its own* batch: a feedback cell no longer caps prescheduling for
feedback-free cells sharing a sweep.

Device sharding (``mesh=``): the round program runs under ``shard_map``
over a 2-D ``("s", "p")`` mesh (``repro.sim.participant_sharding``; a
legacy 1-D "s" mesh from ``repro.sweeps.sharding`` is normalized, either
axis may be size 1):

  sweep axis "s" — cells are placed in balanced contiguous blocks of a
  ``(n_shards, s_loc + 1, D)`` params tensor (one scratch row per shard);
  each shard executes the identical round body on its own cells' packed
  rows, with no cross-cell communication.  Early-stop repacking is
  shard-aware: when the live set shrinks enough that the bucketed
  per-shard capacity drops, live cells are compacted across shard
  boundaries (stopped cells vacate whole per-shard bucket steps) and the
  state tensors are rebuilt by a resharding gather — pure data movement,
  bit-identical per cell to the unsharded run;

  participant axis "p" — each round's packed cohort rows are split into
  balanced contiguous blocks over the p-shards
  (``participant_sharding.split_balanced``), so the local-training
  matmuls — the CPU-bound hot path — run in parallel across devices and
  cohorts of tens of thousands of learners fit the round budget.  Cell
  params/optimizer rows are **replicated** along "p" (every p-shard
  applies the identical server step, so replicas stay bitwise equal with
  no communication); the stale cache is partitioned per (s, p) shard — a
  straggler's slot lives on the p-shard that trained it, wherever its
  cell's rows land in later rounds.  The only cross-shard data dependency
  is the SAA aggregation operand (a cell's fresh rows and landing slots
  live on whichever p-shards trained them): each shard zero-masks the
  columns it does not own and ONE ``psum`` over "p" reconstructs the full
  operand — bit-identical to the unsharded gather because every element
  has exactly one non-zero contributor, and the single collective in the
  hot loop (tests/test_participant_sharding.py asserts both).

Parity: gathers/scatters are pure data movement, padding rows are masked to
exact zeros before aggregation (``bucket_pad``'s layout, bit-for-bit), the
weights+aggregate unit is the same ``weights_and_aggregate_by_id`` the
batched sweep path has always vmapped, and the server apply is the same
formula — so per-cell metrics are bit-identical to the per-stage flat path
and to serial runs (asserted by tests/test_pipeline_parity.py and the
benchmarks), for every (mesh, K) combination.

Donation invariants: the stacked params tensor, the cache rows and the
optimizer state are donated into every round/chunk program — after a
dispatch the previous buffers are dead and must not be touched; the
pipeline is their only owner and always replaces its references with the
returned arrays.  Inside a chunk the same invariant holds step-to-step:
the scan carry owns the buffers, and host code never observes the
intermediate rounds' states.  ``Simulator.flat_params`` is stale while a
pipeline run is in flight and is rewritten at ``finalize``.  Dataset/test
tensors are *not* donated (read-only, reused every round; replicated
across the mesh when sharded).

Early stop: cells whose latest evaluation reached ``target_accuracy`` leave
the lockstep batch entirely — no host round logic, no packed rows, no
aggregation group, no eval slot — so a sweep's per-round cost tracks the
*live* cells (bucket-padded repacking shrinks every axis), not S x rounds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core.aggregation import (aggregate_updates, unflatten_update,
                                    weights_and_aggregate_by_id,
                                    yogi_apply_flat)
from repro.core.stale_cache import DeviceStaleCache, ShardedSlotAccounts
from repro.core.staleness import EPS, RULE_ID
from repro.faults.attacks import apply_attack, attack_key
from repro.learners import model_key
from repro.robust.aggregators import (COORD_KINDS, krum_select, robust_key,
                                      trimmed_weighted_aggregate,
                                      weighted_rows)
from repro.selection import SELECTOR_TABLE, selector_key
from repro.sim import learner as ln
from repro.sim.participant_sharding import PART_AXIS, split_balanced
from repro.telemetry import TelemetrySession
from repro.telemetry.registry import CounterView, MetricsRegistry
from repro.telemetry.schema import (DISPATCH_KINDS, GUARD_COUNTERS,
                                    LANE_WIDTH, N_LANE_HOST,
                                    PIPELINE_COUNTERS)

ROW_BLOCK = 128   # packed participant-row padding bucket (bucket_block)
UPD_BLOCK = 32    # per-cell aggregation-row padding bucket (sweep_bucket_pad's)


def pipeline_key(cfg) -> tuple:
    """Config fields every Simulator in one pipeline must share: they fix
    the compiled round program's static structure or the lockstep cadence.
    ``repro.sweeps.runner.compat_key`` groups cells by (a superset of) this."""
    return (cfg.benchmark, cfg.local_steps, cfg.local_batch, cfg.local_lr,
            cfg.prox_mu, cfg.rounds, cfg.eval_every, cfg.server_opt,
            robust_key(cfg), attack_key(cfg), selector_key(cfg),
            cfg.use_agg_kernel,
            cfg.scaling_rule if cfg.use_agg_kernel else None,
            cfg.rounds_per_dispatch, cfg.shard_participants,
            cfg.guard, cfg.guard_clip, cfg.guard_reject_mult, cfg.quorum,
            cfg.telemetry, model_key(cfg))


class PipelineStats:
    """Dispatch / transfer accounting for the hot loop (``--profile``).

    Backed by a telemetry ``MetricsRegistry`` — the registry is the single
    storage for every counter (including the guard counters, written once
    by ``TelemetrySession.note_guard``); this class is an attribute-style
    view over it, so the ``--profile`` JSON, the Prometheus snapshot and
    per-sim guard accounting can never disagree.  The attribute API is
    unchanged: ``stats.rounds += k``, ``stats.dispatches["eval"] += 1``,
    ``stats.as_dict()``.  When pipelines share one session (a sweep), the
    counters accumulate across batches and ``as_dict()`` is already the
    sweep-wide total.
    """

    # derived from the telemetry schema so a counter added there (e.g. the
    # robust-aggregator rejections) can never be silently dropped here
    GUARD_KEYS = tuple(k[len("guard_"):] for k in GUARD_COUNTERS)

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 n_shards: int = 1, n_pshards: int = 1):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.n_shards = n_shards
        self.n_pshards = n_pshards
        self.rounds_per_dispatch = 1
        for name in PIPELINE_COUNTERS:
            self.registry.counter(name)
        self.dispatches = CounterView(self.registry, "pipeline_dispatches_",
                                      DISPATCH_KINDS)
        self.guard = CounterView(self.registry, "guard_", self.GUARD_KEYS)

    def _counter(self, name):
        return self.registry.counter("pipeline_" + name)

    # per-round index arrays (explicit device_put) / stat-util + eval +
    # repack-eviction + lane fetches / one-time dataset uploads — all
    # plain registry counters behind attribute accessors
    rounds = property(lambda s: s._counter("rounds").value,
                      lambda s, v: setattr(s._counter("rounds"), "value", v))
    h2d_bytes = property(
        lambda s: s._counter("h2d_bytes").value,
        lambda s, v: setattr(s._counter("h2d_bytes"), "value", v))
    d2h_bytes = property(
        lambda s: s._counter("d2h_bytes").value,
        lambda s, v: setattr(s._counter("d2h_bytes"), "value", v))
    init_h2d_bytes = property(
        lambda s: s._counter("init_h2d_bytes").value,
        lambda s, v: setattr(s._counter("init_h2d_bytes"), "value", v))
    cross_shard_landings = property(
        lambda s: s._counter("cross_shard_landings").value,
        lambda s, v: setattr(s._counter("cross_shard_landings"), "value", v))
    feedback_fetches = property(
        lambda s: s._counter("feedback_fetches").value,
        lambda s, v: setattr(s._counter("feedback_fetches"), "value", v))

    def as_dict(self) -> dict:
        per_round = max(self.rounds, 1)
        return {
            "rounds": self.rounds,
            "dispatches": dict(self.dispatches),
            "dispatches_per_round": round(
                sum(self.dispatches.values()) / per_round, 3),
            "h2d_bytes": self.h2d_bytes,
            "d2h_bytes": self.d2h_bytes,
            "h2d_bytes_per_round": round(self.h2d_bytes / per_round),
            "d2h_bytes_per_round": round(self.d2h_bytes / per_round),
            "init_h2d_bytes": self.init_h2d_bytes,
            "n_shards": self.n_shards,
            "n_pshards": self.n_pshards,
            "rounds_per_dispatch": self.rounds_per_dispatch,
            "cross_shard_landings": self.cross_shard_landings,
            "feedback_fetches": self.feedback_fetches,
            "guard": dict(self.guard),
        }


# ---------------------------------------------------------------------------
# The fused round body (shared by the unsharded and sharded chunk programs
# — one set of numerics, two launch wrappers)
# ---------------------------------------------------------------------------


def _round_body(params, cache, opt_state, x_tr, y_tr, ints, floats, shapes,
                *, train_unit, steps, batch, yogi, use_kernel, kernel_rule,
                single, p_axis=None, guard=None, faulty=False, lane=False,
                attack=None, robust=None, norm_d=None):
    """One round's device work on one (local) params/cache block.

    params: (rows, D) — cell rows plus one scratch row; cache: (C + 1, D)
    slot rows plus the trash row; ints/floats: the round's packed index
    arrays whose layout is described by the static ``shapes`` tuple.
    ``single`` broadcasts the parameters instead of gathering them (the
    serial engine's S == 1 case; bit-identical either way).

    ``p_axis`` names the participant mesh axis when the body runs as one
    p-shard of a sharded round: the packed rows are this shard's block of
    the cohort, the cache is this shard's slot partition, and the
    aggregation operand is reconstructed from the per-shard ownership-
    masked partials with ONE ``psum`` — the hot loop's only collective.
    Everything after the psum (weights, aggregate, server apply) is
    computed identically on every p-shard, which is what keeps the
    p-replicated params/optimizer rows bitwise in sync.

    ``guard`` (static) is ``(clip, reject_mult, quorum)`` when guarded
    aggregation is on: the operand is screened in-program
    (``aggregation.screen_rows`` — the same formula every host path runs),
    the survivor mask replaces ``agg_valid``, and the server apply is
    gated on ``survivors >= quorum``.  ``faulty`` (static) appends a
    per-row fp32 corruption multiplier to the floats buffer, applied to
    the delta rows between training and the cache scatter — fault
    injection without any extra transfer or collective.  The last two
    outputs are a (G, 6) int32 stats block [rejected_nonfinite,
    rejected_norm, survivors, applied, robust_rejected, robust_trimmed]
    (zeros when unguarded/non-robust) and the telemetry round-stats lane;
    both are p-replicated like everything after the psum.

    ``attack`` (static, ``repro.faults.attacks.attack_key``) appends a
    per-group attacker mask to the ints buffer and rewrites the attacker
    rows of the post-psum operand *before* the lane stats and the guard
    screen (``apply_attack`` — the same formula every host path runs); a
    round with no scheduled attackers passes through bit-exactly.
    ``robust`` (static, ``repro.robust.aggregators.robust_key``) runs the
    robust aggregator: mask-style kinds shrink ``agg_valid`` before the
    SAA weights pass, coordinate-wise kinds replace it with the trimmed
    mean of the SAA-weighted rows (robust-of-weighted; the numerics of
    ``repro.robust.aggregators._robust_cell``, vmapped over groups).
    When either is active the staleness-agg Pallas kernel is bypassed —
    ``use_kernel`` then only routes the coordinate-wise statistic through
    the ``trimmed_agg`` kernel.  Both default to None, leaving the
    compiled program untouched (the static bit-parity half).

    ``lane`` (static, ``SimConfig.telemetry >= 2``) emits a per-group
    fp32 stats row (``telemetry.schema.LANE_FIELDS``): the host-known
    head fields ride through the floats buffer and are echoed back, the
    update-row L2-norm min/mean/max and non-finite count are computed on
    the *post-psum, pre-screen* operand (so corruption the guard later
    rejects is still visible), and the guard tail mirrors ``gstats``.
    Computed after the psum → no extra collective; lane off returns a
    zero-width block, so the program's outputs and numerics are untouched.
    """
    r_b, tb, g_b, nf_b, ns_b, all_valid = shapes
    n_b = nf_b + ns_b
    o = [0]

    def take(n, shape=None, dtype=None):
        a = ints[o[0]:o[0] + n]
        o[0] += n
        if dtype is not None:
            a = a.astype(dtype)
        return a.reshape(shape) if shape is not None else a

    batch_idx = take(r_b * tb, (r_b, tb))
    row_cell = take(r_b)
    row_sub = take(r_b)
    scat_slot = take(r_b)
    agg_cell = take(g_b)
    fr_idx = take(g_b * nf_b, (g_b, nf_b))
    sl_idx = take(g_b * ns_b, (g_b, ns_b))
    agg_tau = take(g_b * n_b, (g_b, n_b))
    rule_id = take(g_b)
    agg_fresh = take(g_b * n_b, (g_b, n_b), bool)
    agg_valid = take(g_b * n_b, (g_b, n_b), bool)
    agg_mask = take(g_b * n_b, (g_b, n_b), bool)
    has_g = take(g_b, None, bool)
    agg_att = (take(g_b * n_b, (g_b, n_b), bool) if attack is not None
               else None)
    beta_g, lr_g = floats[:g_b], floats[g_b:2 * g_b]

    # --- train: gather batches + per-row params, one vmapped call ---
    # trailing sample dims ride along untouched: (dim,) features for the
    # classifier benchmarks, (S,) token sequences (x AND y) for the LM ones
    bx = x_tr[row_sub[:, None], batch_idx]            # (R, steps*batch, ...)
    bx = bx.reshape((r_b, steps, batch) + bx.shape[2:])
    by = y_tr[row_sub[:, None], batch_idx]
    by = by.reshape((r_b, steps, batch) + by.shape[2:])
    if single:
        deltas, losses, l2s = jax.vmap(
            train_unit, in_axes=(None, 0, 0))(params[0], bx, by)
    else:
        deltas, losses, l2s = jax.vmap(train_unit)(params[row_cell], bx, by)

    # --- straggler scatter into the cache, then gather ---------------
    if faulty:
        # injected corruption: one IEEE fp32 multiply per delta row —
        # before the scatter, so cached straggler rows carry the fault too
        fscale = floats[2 * g_b:2 * g_b + r_b]
        deltas = deltas * fscale[:, None]
    # scatter FIRST so the donated cache updates in place (a gather
    # before the scatter would force XLA to copy the whole buffer);
    # this round's scatter slots are disjoint from this round's landing
    # slots because the pipeline quarantines freed slots for one round
    cache = cache.at[scat_slot].set(deltas)

    # fresh columns from this round's delta rows, stale columns from
    # the cache slots; same per-cell row multiset as the per-stage
    # path's (fresh + stale, zero-padded) stack
    uf, us = deltas[fr_idx], cache[sl_idx]
    if p_axis is not None:
        # every operand column is owned by exactly one p-shard (the one
        # holding its delta row / cache slot): zero the rest and let one
        # psum reconstruct the full operand — bit-identical to the
        # unsharded gather, since each element sums one non-zero
        # contributor with exact zeros
        uf = jnp.where(agg_mask[:, :nf_b, None], uf, 0.0)
        us = jnp.where(agg_mask[:, nf_b:, None], us, 0.0)
        u = jax.lax.psum(jnp.concatenate([uf, us], axis=1), p_axis)
    else:
        if not all_valid:
            # bucket_pad's exact zeros in the padding columns
            uf = jnp.where(agg_valid[:, :nf_b, None], uf, 0.0)
            us = jnp.where(agg_valid[:, nf_b:, None], us, 0.0)
        u = jnp.concatenate([uf, us], axis=1)

    if attack is not None:
        # coordinated attack: rewrite the attacker rows of the post-psum
        # operand (pre-lane, pre-screen — the lane and the guard both see
        # what the server would see)
        atk_kind, atk_scale, atk_z = attack
        u = apply_attack(u, agg_att, agg_valid, kind=atk_kind,
                         scale=atk_scale, z=atk_z)

    if lane:
        # telemetry lane, device half: row-norm stats over the *pre-screen*
        # operand, post-psum (p-replicated, no extra collective).  Finite
        # rows are selected with where() — never multiplied — so one NaN
        # row cannot poison the finite rows' stats.  Under the persistent
        # D-blocked layout (``norm_d``) the stats reduce over the true-D
        # slice: slice-then-reduce is bit-identical to the unpadded layout,
        # whereas reducing across appended zero columns is not (the SIMD
        # lane partition of the reduction changes).
        u_t = u if norm_d is None else u[..., :norm_d]
        row_fin = jnp.isfinite(u_t).all(axis=-1)
        norms = jnp.sqrt(jnp.sum(u_t * u_t, axis=-1))
        ok = agg_valid & row_fin
        cnt = ok.sum(axis=-1)
        nonzero = cnt > 0
        l2_min = jnp.where(nonzero,
                           jnp.min(jnp.where(ok, norms, jnp.inf), axis=-1),
                           0.0)
        l2_max = jnp.where(nonzero,
                           jnp.max(jnp.where(ok, norms, -jnp.inf), axis=-1),
                           0.0)
        l2_mean = jnp.where(
            nonzero,
            jnp.sum(jnp.where(ok, norms, 0.0), axis=-1)
            / jnp.maximum(cnt, 1).astype(jnp.float32), 0.0)
        lane_nonfin = (agg_valid & ~row_fin).sum(axis=-1)

    # --- guard screening + robust mask step (static: plain programs
    # are untouched) --------------------------------------------------
    zeros_g = jnp.zeros(g_b, jnp.int32)
    n_nf = n_out = rrej = rtrim = zeros_g
    if guard is not None:
        clip_g, mult_g, quorum_g = guard
        u, v2, n_nf, n_out, _ = agg.screen_rows(u, agg_valid, clip=clip_g,
                                                reject_mult=mult_g,
                                                norm_d=norm_d)
        agg_valid = v2
    robust_coord = robust is not None and robust[0] in COORD_KINDS
    if robust is not None and not robust_coord:
        # mask-style robust kinds shrink the survivor mask before the
        # SAA weights pass (repro.robust.aggregators._robust_cell order:
        # attack -> guard screen -> robust mask -> weights)
        if robust[0] in ("krum", "multi_krum"):
            sel = jax.vmap(functools.partial(
                krum_select, f=robust[1], m=robust[2]))(u, agg_valid)
            rrej = (agg_valid & ~sel).sum(axis=-1).astype(jnp.int32)
            agg_valid = sel
        else:                                        # norm_median_clip
            _, clip_r, mult_r = robust
            u, v2, nf2, out2, ncl2 = agg.screen_rows(
                u, agg_valid, clip=clip_r, reject_mult=mult_r)
            rrej, rtrim, agg_valid = nf2 + out2, ncl2, v2
    survivors = agg_valid.sum(axis=-1).astype(jnp.int32)
    has_eff = (has_g & (survivors >= quorum_g) if guard is not None
               else has_g)

    # --- SAA weights + aggregate + server apply ----------------------
    rows_old = params[agg_cell]                       # (G, D)
    # robust/attacked programs always take the jnp weights path for the
    # SAA part; use_kernel then only routes the coordinate-wise trim
    # through the trimmed_agg kernel (one cross-substrate story)
    saa_kernel = use_kernel and attack is None and robust is None
    if saa_kernel:
        from repro.kernels.staleness_agg.staleness_agg import (
            D_BLK, sweep_fused_staleness_apply,
            sweep_fused_staleness_aggregate)
        d = u.shape[-1]
        pad = (-d) % D_BLK
        up = jnp.pad(u, ((0, 0), (0, 0), (0, pad)))
        if yogi:
            agg_out, _ = sweep_fused_staleness_aggregate(
                up, agg_fresh, agg_tau, beta_g, agg_valid,
                rule=kernel_rule)
            agg_out = agg_out[:, :d]
        else:
            scal = jnp.stack([beta_g, lr_g], axis=1)
            new_rows, _ = sweep_fused_staleness_apply(
                jnp.pad(rows_old, ((0, 0), (0, pad))), up, agg_fresh,
                agg_tau, agg_valid, scal, rule=kernel_rule)
            new_rows = new_rows[:, :d]
    elif robust_coord:
        # robust-of-weighted: per-coordinate trimmed mean of the SAA-
        # weighted rows (trimmed_weighted_aggregate's formula, vmapped)
        median = robust[0] == "coord_median"
        tk = 0 if median else robust[1]
        if use_kernel:
            from repro.kernels.trimmed_agg import ops as tops
            y, cc = jax.vmap(weighted_rows)(u, agg_fresh, agg_tau,
                                            agg_valid, beta_g, rule_id)
            k_half = jnp.maximum((cc - 1) // 2, 0)
            k_eff = (k_half if median
                     else jnp.minimum(jnp.int32(tk), k_half))
            agg_out = tops.sweep_trimmed_aggregate(y, k_eff, cc)
            agg_out = jnp.where((cc > 0)[:, None], agg_out, 0.0)
            rtrim = jnp.where(cc > 0, 2 * k_eff, 0)
        else:
            agg_out, rtrim = jax.vmap(functools.partial(
                trimmed_weighted_aggregate, trim_k=tk, median=median))(
                u, agg_fresh, agg_tau, agg_valid, beta_g, rule_id)
    elif ns_b == 0:
        # no stale rows anywhere this round: Eq. 2 degenerates to the
        # fresh average, so skip the deviation pass entirely.  The
        # weight vector is bit-identical to the general path's (fresh
        # rows weigh 1, padding weighs 0, same normalization).  Under a
        # guard or a mask-style robust kind, rejected fresh rows must
        # weigh 0 too (agg_valid is the post-screen survivor mask;
        # without faults it covers every fresh column, so the bits are
        # unchanged).
        w = ((agg_fresh & agg_valid).astype(jnp.float32)
             if guard is not None or robust is not None
             else agg_fresh.astype(jnp.float32))
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), EPS)
        agg_out = jax.vmap(aggregate_updates)(u, w)
    else:
        agg_out, _ = jax.vmap(weights_and_aggregate_by_id)(
            u, agg_fresh, agg_tau, agg_valid, beta_g, rule_id)

    # --- stats block + lane assembly ---------------------------------
    if guard is not None or robust is not None:
        gstats = jnp.stack([n_nf, n_out, survivors,
                            has_eff.astype(jnp.int32), rrej, rtrim], axis=1)
    else:
        gstats = jnp.zeros((g_b, 6), jnp.int32)
    if lane:
        # assemble the lane row: host pass-through head (echoed from the
        # floats buffer), device norm stats, guard + robust tail
        # (agg_valid is the post-screen/post-mask survivor mask here;
        # plain programs leave it unchanged)
        host_off = 2 * g_b + (r_b if faulty else 0)
        lane_host = floats[host_off:host_off + g_b * N_LANE_HOST] \
            .reshape(g_b, N_LANE_HOST)
        lanes = jnp.concatenate([
            lane_host,
            jnp.stack([l2_min, l2_mean, l2_max,
                       lane_nonfin.astype(jnp.float32)], axis=1),
            jnp.stack([n_nf, n_out, rrej, rtrim],
                      axis=1).astype(jnp.float32),
            jnp.stack([agg_valid.sum(axis=-1).astype(jnp.float32),
                       has_eff.astype(jnp.float32)], axis=1),
        ], axis=1)
    else:
        # zero-width block keeps the program signature uniform at no cost
        lanes = jnp.zeros((g_b, 0), jnp.float32)
    if yogi:
        state_rows = jax.tree.map(lambda s: s[agg_cell], opt_state)
        new_rows, new_state = jax.vmap(yogi_apply_flat)(
            rows_old, agg_out, state_rows)
        keep = lambda new, old: jnp.where(
            has_eff.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
        opt_state = jax.tree.map(
            lambda s, ns, os: s.at[agg_cell].set(keep(ns, os)),
            opt_state, new_state, state_rows)
    elif not saa_kernel:
        new_rows = rows_old + lr_g[:, None] * agg_out
    # quorum failures (has_eff < has_g) carry the old rows unchanged
    new_rows = jnp.where(has_eff[:, None], new_rows, rows_old)
    params = params.at[agg_cell].set(new_rows)
    return params, cache, opt_state, losses, l2s, gstats, lanes


@functools.lru_cache(maxsize=16)
def _chunk_program(spec, lr, prox_mu, steps, batch, yogi, use_kernel,
                   kernel_rule, guard, faulty, lane, attack, robust,
                   loss, norm_d, out_dim, single):
    """K-round chunk program (unsharded): ``lax.scan`` of the round body
    with the donated params/cache/optimizer buffers as the scan carry and
    the K prescheduled rounds' index arrays as the scanned inputs.  One
    dispatch covers K rounds; the per-step math is the op-for-op round
    body, so results are bitwise those of K single dispatches — K=1 (the
    default) is simply a scan of length one, the only round driver.

    Static over (model spec, local hyperparameters, server optimizer,
    kernel routing, S==1); the round-varying index arrays arrive packed in
    TWO device buffers (one int32, one fp32) whose layout is described by
    the static ``shapes`` tuple — so one explicit ``jax.device_put`` pair
    covers a chunk, and XLA recompiles only when a padding bucket first
    appears.

    ``loss`` is the model's objective (``MODEL_TABLE``; stable per
    ``build_model``'s cache, so it is a sound lru key), ``norm_d`` /
    ``out_dim`` the persistent D-blocked layout's true and padded row
    widths (both ``None`` on the unpadded layout — the HEAD program).
    """
    train_unit = functools.partial(ln.local_train_flat, spec=spec, lr=lr,
                                   prox_mu=prox_mu, loss=loss,
                                   out_dim=out_dim)
    body = functools.partial(_round_body, train_unit=train_unit, steps=steps,
                             batch=batch, yogi=yogi, use_kernel=use_kernel,
                             kernel_rule=kernel_rule, guard=guard,
                             faulty=faulty, lane=lane, attack=attack,
                             robust=robust, single=single, norm_d=norm_d)

    def prog(params, cache, opt_state, x_tr, y_tr, ints_k, floats_k, shapes):
        def step(carry, xs):
            p, c, o = carry
            p, c, o, losses, l2s, gst, lns = body(p, c, o, x_tr, y_tr,
                                                  xs[0], xs[1], shapes)
            return (p, c, o), (losses, l2s, gst, lns)

        (params, cache, opt_state), (losses, l2s, gst, lns) = jax.lax.scan(
            step, (params, cache, opt_state), (ints_k, floats_k))
        return params, cache, opt_state, losses, l2s, gst, lns

    return jax.jit(prog, donate_argnums=(0, 1, 2), static_argnums=(7,))


@functools.lru_cache(maxsize=16)
def _sharded_chunk_program(spec, lr, prox_mu, steps, batch, yogi, use_kernel,
                           kernel_rule, guard, faulty, lane, attack, robust,
                           loss, norm_d, out_dim, mesh):
    """K-round chunk program sharded over the 2-D ``("s", "p")`` round
    mesh: ``shard_map`` with the chunk scan inside.  Each (s, p) device
    owns its s-block's ``(s_loc + 1, D)`` params rows (replicated along
    "p"), a ``(c_loc + 1, D)`` block of the flat per-(s, p)-shard cache,
    and its own packed index arrays covering the cohort rows it trains.
    The round body is shard-local except for the single aggregation-
    operand ``psum`` over "p" (a no-op reduction when ``n_p == 1``, the
    PR-4 sweep-only case) — every cell's math is op-for-op the unsharded
    body's and the sweep-axis Pallas kernels simply see a grid over the
    local S.  Datasets are replicated; losses/l2s come back concatenated
    along the row axis (flat shard ``f = j * n_p + q`` owns rows
    ``[f * r_b, (f+1) * r_b)``)."""
    train_unit = functools.partial(ln.local_train_flat, spec=spec, lr=lr,
                                   prox_mu=prox_mu, loss=loss,
                                   out_dim=out_dim)
    body = functools.partial(_round_body, train_unit=train_unit, steps=steps,
                             batch=batch, yogi=yogi, use_kernel=use_kernel,
                             kernel_rule=kernel_rule, guard=guard,
                             faulty=faulty, lane=lane, attack=attack,
                             robust=robust, single=False, p_axis=PART_AXIS,
                             norm_d=norm_d)
    opt_spec = ({"m": P("s"), "v": P("s"), "t": P("s")} if yogi else None)

    def prog(params3, cache3, opt_state, x_tr, y_tr, ints3, floats3, shapes):
        def per_shard(p3, c3, o3, x_tr, y_tr, i3, f3):
            p, c = p3[0], c3[0]
            o = jax.tree.map(lambda a: a[0], o3)

            def step(carry, xs):
                p, c, o = carry
                p, c, o, losses, l2s, gst, lns = body(p, c, o, x_tr, y_tr,
                                                      xs[0], xs[1], shapes)
                return (p, c, o), (losses, l2s, gst, lns)

            (p, c, o), (losses, l2s, gst, lns) = jax.lax.scan(
                step, (p, c, o), (i3[:, 0], f3[:, 0]))
            return (p[None], c[None], jax.tree.map(lambda a: a[None], o),
                    losses, l2s, gst, lns)

        return shard_map(
            per_shard, mesh=mesh,
            in_specs=(P("s"), P(("s", "p")), opt_spec, P(), P(),
                      P(None, ("s", "p")), P(None, ("s", "p"))),
            out_specs=(P("s"), P(("s", "p")), opt_spec,
                       P(None, ("s", "p")), P(None, ("s", "p")),
                       P(None, ("s", "p")), P(None, ("s", "p"))),
            check_rep=False,
        )(params3, cache3, opt_state, x_tr, y_tr, ints3, floats3)

    return jax.jit(prog, donate_argnums=(0, 1, 2), static_argnums=(7,))


@functools.lru_cache(maxsize=2)
def _row_fetch_program():
    """Jitted row gather from a (n_shards, rows_loc, ...) tensor's flattened
    row space — eager advanced indexing would sneak implicit scalar uploads
    past the transfer guard; inside jit the constants live in the program."""
    @jax.jit
    def f(arr, idx):
        return arr.reshape((-1,) + arr.shape[2:])[idx]
    return f


@functools.lru_cache(maxsize=8)
def _eval_program(spec, evaluate=ln.evaluate):
    """Batched eval over the live cells: gather their parameter rows and
    each cell's (possibly shared) test set.  ``evaluate`` is the model's
    metric fn (``MODEL_TABLE``); a block-padded parameter row is accepted
    as-is — ``unflatten_update`` consumes exactly D leading elements."""
    def ev(flat, ti, x_u, y_u):
        return evaluate(unflatten_update(flat, spec), x_u[ti], y_u[ti])

    def f(params, packed, x_u, y_u):
        l_b = packed.shape[0] // 2
        eval_idx, te_idx = packed[:l_b], packed[l_b:]
        return jax.vmap(ev, in_axes=(0, 0, None, None))(
            params[eval_idx], te_idx, x_u, y_u)

    return jax.jit(f)


# ---------------------------------------------------------------------------
# Pipeline driver
# ---------------------------------------------------------------------------


def _quarantine_frees(order, scheds) -> list:
    """Slots released by this round's landings/expiries, deduplicated by
    in-flight entry: a replay fault lands the same entry twice, but its
    slot must be freed exactly once."""
    out, seen = [], set()
    for i in order:
        for f in scheds[i].landing + scheds[i].expired:
            if id(f) not in seen:
                seen.add(id(f))
                out.append(f.delta)
    return out


@dataclasses.dataclass
class _RoundWork:
    """One prescheduled round of a chunk: the host state machine has already
    advanced past it (plans drawn, schedules fixed, slots allocated,
    records appended); only the device dispatch and the eval fill remain."""
    r: int
    order: list
    plans: dict
    scheds: dict
    surv: dict
    recs: dict
    rowq: dict      # (cell, plan row) -> (p-shard, local slot) row placement
    occ: dict       # cell -> stale-cache occupancy after this round's
                    # scheduling (captured at preschedule time — the cache
                    # mutates across a chunk's later rounds)


class RoundPipeline:
    def __init__(self, sims: Sequence, progress: bool = False, mesh=None,
                 checkpoint_path: Optional[str] = None,
                 checkpoint_every: int = 0, checkpoint_wrap=None,
                 start_round: int = 0, telemetry=None,
                 labels: Optional[Sequence[str]] = None):
        assert len(sims) >= 1
        self.sims = list(sims)
        self.progress = progress
        cfg0 = sims[0].cfg
        self.checkpoint_path = checkpoint_path
        self.checkpoint_every = int(checkpoint_every or 0)
        self.checkpoint_wrap = checkpoint_wrap  # envelope hook (sweep resume)
        self._start_round = int(start_round)
        self._next_ckpt = self._start_round + self.checkpoint_every
        for sim in sims:
            assert sim.cfg.fast_path and sim.cfg.fused_rounds, \
                "RoundPipeline drives the fused fast path only"
            assert pipeline_key(sim.cfg) == pipeline_key(cfg0), \
                "incompatible Simulators in one pipeline batch"
        self.cfg0 = cfg0
        # every pipeline has a telemetry session; the directory-less
        # default costs ~nothing (null spans, no writers) but still backs
        # PipelineStats with a live registry
        self.telemetry = (telemetry if telemetry is not None
                          else TelemetrySession())
        self._labels = (list(labels) if labels is not None
                        else [f"sim{i}" for i in range(len(sims))])
        # level >= 2 turns on the in-program round-stats lane (static in
        # pipeline_key, so every sim of a batch agrees)
        self._lane = int(cfg0.telemetry) >= 2
        self.spec = sims[0]._flat_spec
        self.d = agg.flat_dim(self.spec)
        # model objective/metric come off the MODEL_TABLE build (stable
        # objects: build_model caches, and model_key(cfg) ∈ pipeline_key
        # keeps the batch model-uniform, so sims[0]'s fns serve every cell)
        self._model_fns = sims[0]._model_fns
        # persistent D-blocked layout: when every round runs the staleness-
        # agg Pallas kernel (no attack/robust rewrite bypassing it), the
        # params/cache/opt buffers are allocated ONCE at the kernel's
        # D_BLK-padded width instead of jnp.pad-ing the operand each round.
        # For paper-scale D the per-round pad was cheap; for model-zoo D
        # (1e5+) it is an O(G·N·D) copy in the hot loop.  Pad columns hold
        # exact zeros for the life of the run (train deltas are zero-padded
        # at the source, every server op is columnwise), and every true-D
        # reduction (lane norms, guard screen) slices before reducing, so
        # results are bit-identical to the per-round-pad layout.
        saa = (cfg0.use_agg_kernel and attack_key(cfg0) is None
               and robust_key(cfg0) is None)
        if saa:
            from repro.kernels.staleness_agg.staleness_agg import D_BLK
            self.d_pad = self.d + ((-self.d) % D_BLK)
        else:
            self.d_pad = self.d
        pad_w = self.d_pad - self.d

        def _pad_rows(a):
            # widen the trailing D axis with zero columns (jnp/np alike);
            # identity on the unpadded layout and on non-D leaves (yogi "t")
            if pad_w and np.ndim(a) and np.shape(a)[-1] == self.d:
                width = [(0, 0)] * (np.ndim(a) - 1) + [(0, pad_w)]
                return (np.pad(a, width) if isinstance(a, np.ndarray)
                        else jnp.pad(a, width))
            return a

        self._pad_rows = _pad_rows
        self.yogi = cfg0.server_opt == "yogi"
        if mesh is None and cfg0.shard_participants:
            from repro.sim.participant_sharding import participant_mesh
            mesh = participant_mesh(cfg0.shard_participants)
        elif mesh is not None:
            if cfg0.shard_participants:
                raise ValueError(
                    "ambiguous participant sharding: an explicit mesh was "
                    "passed while SimConfig.shard_participants is set — "
                    "configure one or the other (SweepRunner callers: use "
                    "SweepRunner(shard_participants=))")
            from repro.sim.participant_sharding import as_round_mesh
            mesh = as_round_mesh(mesh)
        self.mesh = mesh
        self.n_shards = int(mesh.shape["s"]) if mesh is not None else 1
        self.n_pshards = int(mesh.shape["p"]) if mesh is not None else 1
        self.stats = PipelineStats(registry=self.telemetry.registry,
                                   n_shards=self.n_shards,
                                   n_pshards=self.n_pshards)

        s = len(sims)
        # ``needs_feedback`` selectors (Oort, UCB, contribution, ...)
        # consume the per-row stat-utility feedback; without one the round
        # loop fetches nothing per round.  selector_key is part of
        # pipeline_key, so the batch is selector-uniform and one spec lookup
        # decides for every cell.
        sel_spec = SELECTOR_TABLE[cfg0.selector]
        self._fetch_l2s = sel_spec.needs_feedback
        # A feedback selector's signal is device data needed before the
        # next round's host decisions, so it caps prescheduling at one round
        self.k_rounds = (1 if self._fetch_l2s
                         else max(1, int(cfg0.rounds_per_dispatch)))
        self.stats.rounds_per_dispatch = self.k_rounds

        if self.mesh is None:
            # stacked (S+1, D) params; the extra row is scratch that padding
            # aggregation groups read and write (never a real cell)
            self.placement = None
            self.params = jnp.concatenate(
                [_pad_rows(jnp.stack([sim.flat_params for sim in sims])),
                 jnp.zeros((1, self.d_pad), jnp.float32)])
            if self.yogi:
                self.opt_state = jax.tree.map(
                    lambda *xs: _pad_rows(
                        jnp.stack(xs + (jnp.zeros_like(xs[0]),))),
                    *[sim.flat_opt_state for sim in sims])
            else:
                self.opt_state = None
            self.cache = DeviceStaleCache(
                self.d_pad,
                capacity=max(c.cfg.stale_cache_capacity for c in sims),
                grow=True)
            self.accounts = None
        else:
            from repro.sim.participant_sharding import (cache_spec,
                                                        chunk_spec,
                                                        param_spec,
                                                        replicated_spec)
            from repro.sweeps.sharding import Placement
            self.placement = Placement.build(range(s), self.n_shards)
            self._shard_spec = param_spec(mesh)
            self._cache_spec = cache_spec(mesh)
            self._rep_spec = replicated_spec(mesh)
            self._chunk_spec = chunk_spec(mesh)
            self.params = jax.device_put(
                self._stack_rows([_pad_rows(np.asarray(sim.flat_params))
                                  for sim in sims], (self.d_pad,), np.float32),
                self._shard_spec)
            if self.yogi:
                leaves = [sim.flat_opt_state for sim in sims]
                self.opt_state = jax.tree.map(
                    lambda *xs: jax.device_put(
                        self._stack_rows(
                            [_pad_rows(np.asarray(x)) for x in xs],
                            np.shape(_pad_rows(np.asarray(xs[0]))),
                            np.asarray(xs[0]).dtype),
                        self._shard_spec),
                    *leaves)
            else:
                self.opt_state = None
            self.cache = None
            # one slot space per (s, p) shard, flat s-major — a straggler's
            # slot lives on the p-shard that trained its row
            nflat = self.n_shards * self.n_pshards
            self.accounts = ShardedSlotAccounts(
                nflat, capacity=max(c.cfg.stale_cache_capacity for c in sims))
            self.cache_rows = jax.device_put(
                jnp.zeros((nflat, self.accounts.capacity + 1, self.d_pad),
                          jnp.float32), self._cache_spec)
            self._saved = {}      # evicted done cells' final rows (host)

        # one device copy of each distinct substrate's dataset (replicated
        # across the mesh when sharded: shard-local batch gathers)
        subs = []
        self.sub_idx = np.zeros(s, np.int32)
        for i, sim in enumerate(sims):
            if not any(sim.substrate is sb for sb in subs):
                subs.append(sim.substrate)
            self.sub_idx[i] = next(j for j, sb in enumerate(subs)
                                   if sb is sim.substrate)
        host = (np.stack([sb.data.x_train for sb in subs]),
                np.stack([sb.data.y_train for sb in subs]),
                np.stack([sb.data.x_test for sb in subs]),
                np.stack([sb.data.y_test for sb in subs]))
        if self.mesh is None:
            self.x_tr, self.y_tr, self.x_te, self.y_te = jax.device_put(host)
        else:
            self.x_tr, self.y_tr, self.x_te, self.y_te = (
                jax.device_put(a, self._rep_spec) for a in host)
        self.stats.init_h2d_bytes += (sum(a.nbytes for a in host)
                                      + (s + self.n_shards) * self.d_pad * 4)
        # guard/fault routing is static program structure: all cells of a
        # batch share the guard config (pipeline_key) and the floats-buffer
        # layout (any faulted cell widens it for the whole batch)
        self._guard = ((cfg0.guard_clip, cfg0.guard_reject_mult,
                        max(int(cfg0.quorum), 1)) if cfg0.guard else None)
        self._faulty = any(
            sim.fault_plan is not None and sim.fault_plan.has_corruption
            for sim in sims)
        # robust aggregation / coordinated attacks are static program
        # structure like the guard (pipeline_key keeps batches uniform)
        self._attack = attack_key(cfg0)
        self._robust = robust_key(cfg0)
        norm_d = self.d if pad_w else None
        out_dim = self.d_pad if pad_w else None
        prog_args = (self.spec, cfg0.local_lr, cfg0.prox_mu, cfg0.local_steps,
                     cfg0.local_batch, self.yogi, cfg0.use_agg_kernel,
                     cfg0.scaling_rule if cfg0.use_agg_kernel else None,
                     self._guard, self._faulty, self._lane,
                     self._attack, self._robust,
                     self._model_fns.loss, norm_d, out_dim)
        if self.mesh is not None:
            self._prog = _sharded_chunk_program(*prog_args, mesh)
        else:
            self._prog = _chunk_program(*prog_args, len(sims) == 1)
        # single-sim non-SAFA cohorts have a near-constant size, so exact
        # (unpadded) shapes cost at most a handful of compiles and remove
        # the pow2 bucket's up-to-2x wasted training rows — but only long
        # runs amortize those compiles; short runs, SAFA cohorts (sizes all
        # over the place), sweep batches and chunked/sharded dispatches
        # keep the shared padding buckets.  Padding is masked/discarded
        # everywhere, so the choice never affects results (bucket_block's
        # contract).
        self._exact = (self.mesh is None and self.k_rounds == 1
                       and len(sims) == 1 and not sel_spec.select_all
                       and cfg0.rounds >= 24)
        self._eval = _eval_program(self.spec, self._model_fns.evaluate)
        self.done = [False] * s
        self._pending_free = []   # freed slots quarantined for one round

    def _stack_rows(self, rows: list, trailing: tuple, dtype) -> np.ndarray:
        """Place per-cell host rows into the (n_shards, s_loc + 1, ...)
        layout of the current placement (scratch/padding rows zero)."""
        pl = self.placement
        out = np.zeros((pl.n_shards, pl.s_loc + 1) + tuple(trailing), dtype)
        for i, row in enumerate(rows):
            out[pl.shard_of[i], pl.slot_of[i]] = row
        return out

    def _unpad_leaf(self, a):
        """Slice a D-blocked leaf back to the engine's true-D width
        (identity on the unpadded layout and on non-D leaves like the
        yogi step counter)."""
        if (self.d_pad != self.d and np.ndim(a)
                and np.shape(a)[-1] == self.d_pad):
            return a[..., :self.d]
        return a

    # ------------------------------------------------------------------
    def run(self, transfer_guard: bool = False):
        """Drive every round, then finalize.  ``transfer_guard=True`` wraps
        the round loop in ``jax.transfer_guard("disallow")``: every upload
        the pipeline performs is an explicit ``device_put``, so any
        *implicit* host transfer sneaking into the hot path raises — the
        CI smoke (and ``--profile`` benches) run in this mode."""
        if self._start_round == 0:
            for sim in self.sims:
                sim._t_now = 0.0
        if transfer_guard:
            with jax.transfer_guard("disallow"):
                self._run_rounds()
        else:
            self._run_rounds()
        return self.finalize()

    def _run_rounds(self):
        r = self._start_round
        fps = [sim.fault_plan for sim in self.sims
               if sim.fault_plan is not None]
        while r < self.cfg0.rounds and not all(self.done):
            # a chunk is K prescheduled rounds, broken early at eval
            # boundaries so evaluation / early stop / Oort feedback keep
            # their exact round semantics
            rounds = []
            while len(rounds) < self.k_rounds:
                rounds.append(r)
                if self.sims[0].eval_due(r):
                    break
                r += 1
            r = rounds[-1] + 1
            self._run_chunk(rounds)
            # checkpoint / crash hooks at chunk boundaries only, so a
            # resumed run re-enters at a boundary of the same chunk
            # sequence the uninterrupted run walks
            r_done = rounds[-1]
            if (self.checkpoint_path and self.checkpoint_every
                    and r_done + 1 >= self._next_ckpt
                    and r_done + 1 < self.cfg0.rounds):
                with self.telemetry.span("checkpoint", round=r_done + 1):
                    self.checkpoint(r_done + 1)
                self._next_ckpt = r_done + 1 + self.checkpoint_every
            for fp in fps:
                if fp.crash_due(r_done):
                    # log + flush before the crash fires: a hard crash is a
                    # SIGKILL, so anything unflushed would be lost
                    self.telemetry.event("crash", round=int(r_done),
                                         mode=fp.crash_mode)
                    self.telemetry.flush()
                    fp.trigger_crash(r_done)

    # ------------------------------------------------------------------
    # The round driver: preschedule a K-round chunk (K=1 by default),
    # dispatch it as one program, run the post-dispatch tail
    # ------------------------------------------------------------------
    def _shard_of(self, i: int) -> int:
        return self.placement.shard_of[i] if self.mesh is not None else 0

    def _preschedule(self, r: int) -> Optional[_RoundWork]:
        """Run one round's host state machine to completion — plans,
        schedules, slot allocation, selector feedback, record append — so
        the next round's decisions can be taken before this round's device
        work has run.  (Oort feedback is deferred to post-dispatch; its
        presence forces K=1, so no later round preschedules before it.)"""
        sims = self.sims
        plans = {}
        for i, sim in enumerate(sims):
            if self.done[i]:
                continue
            p = sim._begin_round(r)
            if p is not None:
                plans[i] = p
        if not plans:
            return None
        order = list(plans)
        scheds = {i: sims[i]._schedule_round(r, plans[i]) for i in order}
        surv = {i: np.nonzero(~np.isfinite(plans[i].drop_at))[0]
                for i in order}

        # participant-row placement: each s-shard's packed survivor rows
        # (cells in order, rows in plan order — the exact row packing
        # _materialize emits) split into balanced contiguous blocks over
        # the p-shards.  The trivial 1x1 placement doubles as the
        # unsharded path's row->packed-position map.
        rowq = {}
        for j in range(self.n_shards):
            rows_j = [(i, int(ri)) for i in order
                      if self._shard_of(i) == j for ri in surv[i]]
            off = 0
            for q, size in enumerate(split_balanced(len(rows_j),
                                                    self.n_pshards)):
                for loc in range(size):
                    rowq[rows_j[off + loc]] = (q, loc)
                off += size

        # slot management: release the previous round's quarantined slots,
        # then this round's allocs — a slot gathered this round is never a
        # scatter target this round, so the in-program scatter-then-gather
        # stays collision-free (see the cache comment in _round_body)
        if self.mesh is None:
            grow0 = self.cache.grow_events
            if self._pending_free:
                self.cache.free(self._pending_free)
            self._pending_free = _quarantine_frees(order, scheds)
            for i in order:
                sc = scheds[i]
                if sc.new_stale:
                    sc.slots, _ = self.cache.alloc(len(sc.new_stale))
            self.stats.dispatches["cache_grow"] += \
                self.cache.grow_events - grow0
        else:
            grow0 = self.accounts.grow_events
            for shard, slot in self._pending_free:
                self.accounts.free(shard, [slot])
            self._pending_free = _quarantine_frees(order, scheds)
            for i in order:
                sc = scheds[i]
                if sc.new_stale:
                    # a straggler caches on the (s, p) shard that trains
                    # its row this round — later rounds read it from there
                    # via the aggregation psum, wherever the cell's rows
                    # land by then
                    j = self.placement.shard_of[i]
                    slots = []
                    for (ri, _l, _a, _d) in sc.new_stale:
                        flat = j * self.n_pshards + rowq[(i, int(ri))][0]
                        s_ids, _ = self.accounts.alloc(flat, 1)
                        slots.append((flat, s_ids[0]))
                    sc.slots = slots
            self.stats.dispatches["cache_grow"] += \
                self.accounts.grow_events - grow0

        if not self._fetch_l2s:
            from repro.sim.engine import _InFlight
            for i in order:
                sim, sc = sims[i], scheds[i]
                sim._apply_feedback(r, sc, None)
                for (row_i, lid, arr, dur), slot in zip(sc.new_stale,
                                                        sc.slots):
                    sim.stale_cache.append(
                        _InFlight(lid, r, arr, dur, slot, 0.0))

        recs = {i: sims[i]._advance_round_state(
            r, plans[i].t_now, scheds[i].t_end, len(plans[i].chosen),
            len(scheds[i].fresh_rows), len(scheds[i].landing))
            for i in order}
        # telemetry: stale-cache occupancy must be read NOW — later rounds
        # of the same chunk mutate it before the dispatch runs.  (A feedback
        # selector's new stragglers are appended post-dispatch, so count
        # them in.)
        occ = {}
        if self._lane:
            for i in order:
                occ[i] = len(sims[i].stale_cache) + (
                    len(scheds[i].new_stale) if self._fetch_l2s else 0)
        return _RoundWork(r, order, plans, scheds, surv, recs, rowq, occ)

    def _materialize(self, works):
        """Build the chunk's packed index arrays: per round and per flat
        (s, p) shard, the same layout the single-round driver packs,
        padded to one chunk-global bucket set so the scan's inputs are
        rectangular.  Returns (ints (K, n_s * n_p, L), floats
        (K, n_s * n_p, F), shapes, offs) where ``offs[(k, i)]`` holds cell
        ``i``'s survivor rows' positions (aligned with ``surv[i]``) in the
        round-k loss/l2s vector flattened over (flat shard, local row).

        Aggregation-group metadata (cells, taus, fresh/valid masks, rules,
        betas) is replicated across a cell's p-shards — the post-psum
        weights pass must compute identically on all of them — while the
        gather columns (``fr_idx``/``sl_idx``) and the ownership mask
        (``agg_mask``) are per p-shard: a shard contributes exactly the
        operand columns whose delta row or cache slot it owns."""
        cfg0 = self.cfg0
        sims = self.sims
        tb = cfg0.local_steps * cfg0.local_batch
        n_p = self.n_pshards
        nflat = self.n_shards * n_p
        mesh = self.mesh
        if mesh is None:
            scratch = len(sims)
            trash = self.cache.trash_slot
            slot_of = lambda i: i
        else:
            scratch = self.placement.scratch_slot
            trash = self.accounts.trash_slot
            slot_of = self.placement.slot_of.__getitem__

        # chunk-global padding buckets (uniform scan/shard shapes)
        max_rows, max_g, nf_max, ns_max = 1, 1, 1, 0
        for w in works:
            rows_f, g_js = [0] * nflat, [0] * self.n_shards
            for (i, _ri), (q, _loc) in w.rowq.items():
                rows_f[self._shard_of(i) * n_p + q] += 1
            for i in w.order:
                sc = w.scheds[i]
                if sc.fresh_rows or sc.landing:
                    g_js[self._shard_of(i)] += 1
                    nf_max = max(nf_max, len(sc.fresh_rows))
                    ns_max = max(ns_max, len(sc.landing))
            max_rows = max(max_rows, *rows_f)
            max_g = max(max_g, *g_js)
        if self._exact:     # long serial runs: unpadded shapes (see __init__)
            r_b, g_b, nf_b = max_rows, max_g, nf_max
            ns_b = ns_max if ns_max else 0
        else:
            r_b = agg.bucket_block(max_rows, ROW_BLOCK)
            g_b = agg.bucket_pow2(max_g)
            nf_b = agg.bucket_block(nf_max, UPD_BLOCK)
            ns_b = agg.bucket_pow2(ns_max) if ns_max else 0
        n_b = nf_b + ns_b
        # a fully-populated single-round unsharded dispatch skips the
        # in-program padding masks entirely (they would be identities)
        all_valid = False
        if mesh is None and len(works) == 1:
            w0 = works[0]
            groups0 = [i for i in w0.order
                       if w0.scheds[i].fresh_rows or w0.scheds[i].landing]
            all_valid = bool(
                groups0 and g_b == len(groups0)
                and all(len(w0.scheds[i].fresh_rows) == nf_b
                        and len(w0.scheds[i].landing) == ns_b
                        for i in groups0))
        shapes = (r_b, tb, g_b, nf_b, ns_b, all_valid)

        # a faulted batch appends the per-row corruption multipliers to the
        # floats buffer (static layout — pipeline_key keeps faulted and
        # clean cells in separate batches only via the guard config, so the
        # widening applies to the whole batch); the telemetry lane appends
        # its host-known per-group head fields after those
        nf_len = (2 * g_b + (r_b if self._faulty else 0)
                  + (N_LANE_HOST * g_b if self._lane else 0))
        floats_all = np.zeros((len(works), nflat, nf_len), np.float32)
        chunks = []
        offs = {}
        gmaps = {}      # (k_idx, shard j) -> that shard's group cell list
        for k_idx, w in enumerate(works):
            per_shard = []
            for j in range(self.n_shards):
                cells_j = [i for i in w.order if self._shard_of(i) == j]
                groups = [i for i in cells_j
                          if w.scheds[i].fresh_rows or w.scheds[i].landing]
                gmaps[(k_idx, j)] = groups
                # p-replicated aggregation-group metadata
                agg_cell = np.full(g_b, scratch, np.int32)
                agg_fresh = np.zeros((g_b, n_b), np.int32)
                agg_tau = np.zeros((g_b, n_b), np.int32)
                agg_valid = np.zeros((g_b, n_b), np.int32)
                rule_id = np.zeros(g_b, np.int32)
                has_g = np.zeros(g_b, np.int32)
                beta_g = np.zeros(g_b, np.float32)
                lr_g = np.zeros(g_b, np.float32)
                agg_att = (np.zeros((g_b, n_b), np.int32)
                           if self._attack is not None else None)
                for g, i in enumerate(groups):
                    sc, cfg = w.scheds[i], sims[i].cfg
                    for col in range(len(sc.fresh_rows)):
                        agg_fresh[g, col] = 1
                        agg_valid[g, col] = 1
                    for col, tau in enumerate(sc.landing_taus):
                        agg_tau[g, nf_b + col] = tau
                        agg_valid[g, nf_b + col] = 1
                    if agg_att is not None and sims[i].fault_plan is not None:
                        # per-column attacker flags by learner id: a stale
                        # column is flagged for the round the update LANDS
                        # (the server can only ever see landed rows)
                        n_fr = len(sc.fresh_rows)
                        lids = ([int(w.plans[i].chosen[ri])
                                 for ri in sc.fresh_rows]
                                + [f.learner_id for f in sc.landing])
                        fl = sims[i].fault_plan.attack_flags(w.r, lids)
                        agg_att[g, :n_fr] = fl[:n_fr]
                        agg_att[g, nf_b:nf_b + len(sc.landing)] = fl[n_fr:]
                    agg_cell[g] = slot_of(i)
                    rule_id[g] = RULE_ID[cfg.scaling_rule]
                    beta_g[g] = cfg.beta
                    lr_g[g] = cfg.server_lr
                    has_g[g] = 1
                    if mesh is not None and sc.landing:
                        # diagnostic: landings whose slot shard differs from
                        # some other column of the same group — operand rows
                        # the psum genuinely merges across shards
                        col_q = ([w.rowq[(i, int(ri))][0]
                                  for ri in sc.fresh_rows]
                                 + [f.delta[0] - j * n_p for f in sc.landing])
                        self.stats.cross_shard_landings += sum(
                            1 for f in sc.landing
                            if any(qc != f.delta[0] - j * n_p
                                   for qc in col_q))
                floats_j = np.concatenate([beta_g, lr_g])
                if self._lane:
                    # host half of the lane, p-replicated like the rest of
                    # the group metadata; the device echoes it back so the
                    # fetched lane row is self-contained
                    tele_j = np.zeros((g_b, N_LANE_HOST), np.float32)
                    for g, i in enumerate(groups):
                        sc = w.scheds[i]
                        tele_j[g] = (w.r, sc.t_end, len(w.plans[i].chosen),
                                     len(sc.fresh_rows), len(sc.landing),
                                     w.occ[i])

                # per-q buffers, filled in ONE pass over rows and columns
                # (a scan per shard would scale host packing with n_p)
                batch_q = [np.zeros((r_b, tb), np.int32) for _ in range(n_p)]
                rcell_q = [np.full(r_b, scratch, np.int32)
                           for _ in range(n_p)]
                rsub_q = [np.zeros(r_b, np.int32) for _ in range(n_p)]
                scat_q = [np.full(r_b, trash, np.int32) for _ in range(n_p)]
                fr_q = [np.zeros((g_b, nf_b), np.int32) for _ in range(n_p)]
                sl_q = [np.zeros((g_b, ns_b), np.int32) for _ in range(n_p)]
                mask_q = [np.zeros((g_b, n_b), np.int32) for _ in range(n_p)]
                fscale_q = ([np.ones(r_b, np.float32) for _ in range(n_p)]
                            if self._faulty else None)
                nloc_q = [0] * n_p
                for i in cells_j:
                    p, sc, sv = w.plans[i], w.scheds[i], w.surv[i]
                    fp_i = sims[i].fault_plan
                    fsc_i = (fp_i.scale_for(w.r, p.chosen)
                             if self._faulty and fp_i is not None
                             and fp_i.has_corruption else None)
                    if fsc_i is not None:
                        # surviving corrupt rows (NaN/Inf/scaled) this cell
                        # injects this round — logged to events.jsonl
                        bad = int(np.count_nonzero(fsc_i[sv] != 1.0))
                        if bad:
                            self.telemetry.event(
                                "fault", cell=self._labels[i],
                                round=int(w.r), corrupt_rows=bad)
                    cell_offs = offs.setdefault(
                        (k_idx, i), np.zeros(len(sv), np.int64))
                    for k_row, ri in enumerate(sv):
                        q, loc = w.rowq[(i, int(ri))]
                        batch_q[q][loc] = p.bidx[ri]
                        rcell_q[q][loc] = slot_of(i)
                        rsub_q[q][loc] = self.sub_idx[i]
                        if fsc_i is not None:
                            fscale_q[q][loc] = fsc_i[ri]
                        cell_offs[k_row] = (j * n_p + q) * r_b + loc
                        nloc_q[q] = max(nloc_q[q], loc + 1)
                    for (ri, _l, _a, _d), slot in zip(sc.new_stale,
                                                      sc.slots):
                        q, loc = w.rowq[(i, int(ri))]
                        scat_q[q][loc] = slot if mesh is None else slot[1]
                # operand gather columns land on their owner shard's arrays
                # (the ownership mask the psum reconstruction relies on)
                for g, i in enumerate(groups):
                    sc = w.scheds[i]
                    for col, ri in enumerate(sc.fresh_rows):
                        q, loc = w.rowq[(i, int(ri))]
                        fr_q[q][g, col] = loc
                        mask_q[q][g, col] = 1
                    for col, f in enumerate(sc.landing):
                        q = 0 if mesh is None else f.delta[0] - j * n_p
                        sl_q[q][g, col] = (f.delta if mesh is None
                                           else f.delta[1])
                        mask_q[q][g, nf_b + col] = 1
                for q in range(n_p):
                    if 0 < nloc_q[q] < r_b:   # padding replicates row 0
                        batch_q[q][nloc_q[q]:] = batch_q[q][0]
                        rcell_q[q][nloc_q[q]:] = rcell_q[q][0]
                        rsub_q[q][nloc_q[q]:] = rsub_q[q][0]
                    ints_parts = [batch_q[q].ravel(), rcell_q[q], rsub_q[q],
                                  scat_q[q], agg_cell, fr_q[q].ravel(),
                                  sl_q[q].ravel(), agg_tau.ravel(), rule_id,
                                  agg_fresh.ravel(), agg_valid.ravel(),
                                  mask_q[q].ravel(), has_g]
                    if agg_att is not None:
                        # attacker flags ride the ints buffer, p-replicated
                        # like the rest of the group metadata
                        ints_parts.append(agg_att.ravel())
                    per_shard.append(np.concatenate(ints_parts))
                    parts = [floats_j]
                    if self._faulty:
                        parts.append(fscale_q[q])
                    if self._lane:
                        parts.append(tele_j.ravel())
                    floats_all[k_idx, j * n_p + q] = (
                        np.concatenate(parts) if len(parts) > 1
                        else floats_j)
            chunks.append(np.stack(per_shard))
        ints_all = np.stack(chunks)        # already int32 throughout
        return ints_all, floats_all, shapes, offs, gmaps

    def _run_chunk(self, rounds) -> None:
        """Preschedule up to K rounds, dispatch them as one scan program,
        then run the post-dispatch tail (Oort feedback, eval fill, early
        stop, shard repack) for the chunk."""
        works = []
        with self.telemetry.span("schedule", rounds=len(rounds)):
            for r in rounds:
                w = self._preschedule(r)
                if w is not None:
                    works.append(w)
        if not works:
            return
        sims = self.sims
        with self.telemetry.span("pack", rounds=len(works)):
            ints, floats, shapes, offs, gmaps = self._materialize(works)

        if self.mesh is None:
            dev_ints, dev_floats = jax.device_put(
                (ints[:, 0], floats[:, 0]))
            cache_rows = self.cache.rows
        else:
            # the host accounting may have grown mid-chunk; bring the
            # device tensor to the final capacity before the dispatch
            # (appended slots only — existing local slot ids stay valid)
            if self.cache_rows.shape[1] != self.accounts.capacity + 1:
                from repro.sweeps.sharding import reshard_rows
                old_rows = self.cache_rows.shape[1]
                nflat = self.n_shards * self.n_pshards
                cmap = np.full(nflat * (self.accounts.capacity + 1),
                               old_rows - 1, np.int32)   # any defined row
                for j in range(nflat):
                    base_new = j * (self.accounts.capacity + 1)
                    base_old = j * old_rows
                    for sl in range(old_rows - 1):
                        cmap[base_new + sl] = base_old + sl
                self.cache_rows = reshard_rows(
                    self.cache_rows, cmap,
                    (nflat, self.accounts.capacity + 1),
                    self._cache_spec)
            dev_ints = jax.device_put(ints, self._chunk_spec)
            dev_floats = jax.device_put(floats, self._chunk_spec)
            cache_rows = self.cache_rows
        self.stats.h2d_bytes += ints.nbytes + floats.nbytes
        self.stats.dispatches["round"] += 1
        self.stats.rounds += len(works)
        with self.telemetry.span("dispatch", rounds=len(works)):
            (params, cache_rows, self.opt_state, _losses, l2s, gstats,
             lanes) = self._prog(self.params, cache_rows, self.opt_state,
                                 self.x_tr, self.y_tr, dev_ints, dev_floats,
                                 shapes)
        self.params = params
        if self.mesh is None:
            self.cache.rows = cache_rows
        else:
            self.cache_rows = cache_rows

        # --- guard/robust-stats attribution (active programs only) -------
        lane_np = None
        with self.telemetry.span("fetch"):
            if self._guard is not None or self._robust is not None:
                g_np = np.asarray(jax.device_get(gstats))
                self.stats.d2h_bytes += g_np.nbytes
                g_b = shapes[2]
                for k_idx, w in enumerate(works):
                    # unsharded: (g_b, 6); sharded: (nflat * g_b, 6) with
                    # flat shard f = j * n_p + q owning [f*g_b, (f+1)*g_b)
                    # — gstats are p-replicated: read each group's q=0 copy
                    flat = g_np[k_idx].reshape(-1, 6)
                    for j in range(self.n_shards):
                        for g, i in enumerate(gmaps[(k_idx, j)]):
                            nf, nnorm, _surv, applied, rrej, rtrim = (
                                int(x) for x in
                                flat[(j * self.n_pshards) * g_b + g])
                            # single writer for guard/robust accounting:
                            # the session increments the registry counters
                            # (stats.guard is a view) and forwards to the
                            # per-sim Accounting
                            if self._guard is not None:
                                self.telemetry.note_guard(
                                    sims[i].acct, nf, nnorm, bool(applied))
                            if self._robust is not None:
                                self.telemetry.note_robust(
                                    sims[i].acct, rrej, rtrim)

            if self._lane:
                lane_np = np.asarray(jax.device_get(lanes))
                self.stats.d2h_bytes += lane_np.nbytes

            # --- deferred Oort feedback (K forced to 1) -------------------
            if self._fetch_l2s:
                from repro.sim.engine import _InFlight
                l2s_np = np.asarray(jax.device_get(l2s))
                self.stats.d2h_bytes += l2s_np.nbytes
                self.stats.feedback_fetches += 1
                (w,) = works
                l2s_flat = l2s_np[0].ravel()  # (flat shard, local row) order
                for i in w.order:
                    sim, sc = sims[i], w.scheds[i]
                    l2s_i = np.zeros(w.plans[i].k, np.float32)
                    l2s_i[w.surv[i]] = l2s_flat[offs[(0, i)]]
                    sim._apply_feedback(w.r, sc, l2s_i)
                    for (row_i, lid, arr, dur), slot in zip(sc.new_stale,
                                                            sc.slots):
                        sim.stale_cache.append(_InFlight(
                            lid, w.r, arr, dur, slot,
                            sim._stat_util(row_i, l2s_i)))

        # --- eval fill + early stop at the chunk's eval boundary ----------
        wl = works[-1]
        if sims[wl.order[0]].eval_due(wl.r):
            with self.telemetry.span("eval", round=wl.r):
                self._eval_fill(wl)

        # --- per-round telemetry events (after eval, so the chunk's eval
        # round carries its accuracy/loss) ---------------------------------
        if self._lane:
            g_b = shapes[2]
            for k_idx, w in enumerate(works):
                flat = lane_np[k_idx].reshape(-1, LANE_WIDTH)
                rows = {}
                for j in range(self.n_shards):
                    for g, i in enumerate(gmaps[(k_idx, j)]):
                        rows[i] = flat[(j * self.n_pshards) * g_b + g]
                for i in w.order:
                    row = rows.get(i)
                    if row is None:
                        # nothing aggregated for this cell this round (no
                        # fresh rows, no landings): the host half is still
                        # known, the device stats are genuinely zero
                        sc = w.scheds[i]
                        row = np.zeros(LANE_WIDTH, np.float32)
                        row[:N_LANE_HOST] = (w.r, sc.t_end,
                                             len(w.plans[i].chosen),
                                             len(sc.fresh_rows),
                                             len(sc.landing), w.occ[i])
                    ev = self.telemetry.round_event(self._labels[i], row,
                                                    w.recs[i])
                    sims[i].acct.round_events.append(ev)
            self.telemetry.flush()
        if self.mesh is not None:
            self._maybe_repack()

    def _eval_fill(self, wl) -> None:
        """Deferred eval at the chunk's eval boundary: batched accuracy/loss
        for the live cells, round-record fill, accuracy-target early stop."""
        sims = self.sims
        l_b = agg.bucket_pow2(len(wl.order))
        cells = wl.order + [wl.order[0]] * (l_b - len(wl.order))
        if self.mesh is None:
            rows = np.asarray(cells, np.int32)
            eval_params = self.params
        else:
            rows = np.asarray([self.placement.flat_row(i)
                               for i in cells], np.int32)
            eval_params = self.params.reshape(-1, self.d_pad)
        packed = np.concatenate([rows,
                                 self.sub_idx[np.asarray(cells)]])
        packed = (jax.device_put(packed) if self.mesh is None
                  else jax.device_put(packed, self._rep_spec))
        self.stats.dispatches["eval"] += 1
        a, lo = self._eval(eval_params, packed, self.x_te, self.y_te)
        acc = np.asarray(jax.device_get(a))
        loss = np.asarray(jax.device_get(lo))
        self.stats.h2d_bytes += 2 * rows.nbytes
        self.stats.d2h_bytes += acc.nbytes + loss.nbytes
        for ei, i in enumerate(wl.order):
            sims[i]._fill_round_eval(wl.recs[i], acc[ei], loss[ei],
                                     progress=self.progress)
            if sims[i]._target_reached():
                sims[i].acct.stopped_early = True
                self.done[i] = True

    # ------------------------------------------------------------------
    # Crash-safe snapshots (chaos harness): the full batch state at a
    # chunk boundary, as plain host objects — resumable bit-exactly
    # ------------------------------------------------------------------
    def snapshot(self, r_next: int) -> dict:
        """Host snapshot of every sim's state with ``r_next`` the first
        round a resume will run.  Taken only at chunk boundaries, so a
        resumed pipeline re-enters the identical chunk sequence; stale
        rows are gathered off the device cache and re-seated on resume
        (slot ids never affect values, only placement)."""
        sims = self.sims
        # parameter / optimizer rows leave at the engine's true-D width
        # (the padded tail is derivable zero); stale rows stay at the
        # cache width — a resume rebuilds the pipeline from the same cfg,
        # so the re-seating cache has the identical d_pad
        unpad = lambda a: a[..., :self.d]
        if self.mesh is None:
            params_np = np.asarray(jax.device_get(self.params))
            cache_np = np.asarray(jax.device_get(self.cache.rows))
            opt_np = (jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                   self.opt_state) if self.yogi else None)
            row_of = lambda i: unpad(params_np[i])
            opt_of = ((lambda i: jax.tree.map(
                lambda a: self._unpad_leaf(a[i]), opt_np))
                if self.yogi else (lambda i: None))
            slot_row = lambda slot: cache_np[slot]
        else:
            flat = unpad(np.asarray(
                jax.device_get(self.params)).reshape(-1, self.d_pad))
            cache_np = np.asarray(
                jax.device_get(self.cache_rows)).reshape(-1, self.d_pad)
            rows_loc = self.accounts.capacity + 1
            opt_np = (jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                   self.opt_state) if self.yogi else None)

            def row_of(i):
                if i in self._saved:
                    return unpad(np.asarray(self._saved[i][0]))
                return flat[self.placement.flat_row(i)]

            def opt_of(i):
                if not self.yogi:
                    return None
                if i in self._saved:
                    return jax.tree.map(
                        lambda a: self._unpad_leaf(np.asarray(a)),
                        self._saved[i][1])
                fr = self.placement.flat_row(i)
                return jax.tree.map(
                    lambda a: self._unpad_leaf(
                        a.reshape((-1,) + a.shape[2:])[fr]), opt_np)

            slot_row = lambda sl: cache_np[sl[0] * rows_loc + sl[1]]
        payload_sims = []
        for i, sim in enumerate(sims):
            rows = [np.asarray(slot_row(f.delta)) for f in sim.stale_cache]
            payload_sims.append({
                "cfg": dataclasses.asdict(sim.cfg),
                "state": sim.capture_state(stale_rows=rows),
                "flat_params": np.asarray(row_of(i)),
                "flat_opt_state": opt_of(i),
                "fault_plan": sim.fault_plan,
            })
        return {"version": 1, "kind": "pipeline", "next_round": int(r_next),
                "done": list(self.done), "sims": payload_sims,
                "labels": list(self._labels),
                # rounds.jsonl byte offset at this boundary: a resume into
                # the same telemetry dir truncates back to it, keeping the
                # round log inside the bitwise-resume contract
                "telemetry": self.telemetry.state()}

    def checkpoint(self, r_next: int) -> None:
        from repro.checkpoint.state import save_snapshot
        payload = self.snapshot(r_next)
        if self.checkpoint_wrap is not None:
            payload = self.checkpoint_wrap(payload)
        save_snapshot(self.checkpoint_path, payload)

    # ------------------------------------------------------------------
    # Shard-aware repacking (early-stopped cells vacate whole shard
    # bucket steps; live cells compact across shard boundaries)
    # ------------------------------------------------------------------
    def _maybe_repack(self) -> None:
        from repro.sweeps.sharding import Placement
        live = [i for i in range(len(self.sims)) if not self.done[i]]
        if not live:
            return
        new_pl = Placement.build(live, self.n_shards)
        if new_pl.s_loc >= self.placement.s_loc:
            return
        with self.telemetry.span("repack", live=len(live)):
            self._repack(new_pl, live)

    def _repack(self, new_pl, live) -> None:
        from repro.sweeps.sharding import reshard_rows
        old_pl = self.placement
        self.stats.dispatches["repack"] += 1

        # 1. save the evicted (done) cells' final rows to host — their
        #    device rows disappear with the shrink; finalize reads these
        evict = [i for i in old_pl.shard_of
                 if self.done[i] and i not in self._saved]
        if evict:
            # replicated: the jitted gather reads sharded operands, so a
            # single-device index array would force an implicit reshard
            idx = jax.device_put(
                np.asarray([old_pl.flat_row(i) for i in evict], np.int32),
                self._rep_spec)
            fetch = _row_fetch_program()
            rows = np.asarray(jax.device_get(fetch(self.params, idx)))
            opt_rows = None
            if self.yogi:
                opt_rows = jax.tree.map(
                    lambda a: np.asarray(jax.device_get(fetch(a, idx))),
                    self.opt_state)
            self.stats.d2h_bytes += rows.nbytes
            for k, i in enumerate(evict):
                self._saved[i] = (
                    rows[k],
                    jax.tree.map(lambda a: a[k], opt_rows)
                    if self.yogi else None)

        # 2. migrate params / optimizer rows into the compacted layout
        head = (self.n_shards, new_pl.s_loc + 1)
        pmap = np.full(new_pl.total_rows, old_pl.scratch_flat(0), np.int32)
        for i in live:
            pmap[new_pl.flat_row(i)] = old_pl.flat_row(i)
        self.params = reshard_rows(self.params, pmap, head, self._shard_spec)
        if self.yogi:
            self.opt_state = jax.tree.map(
                lambda a: reshard_rows(a, pmap, head, self._shard_spec),
                self.opt_state)

        # 3. rebuild the sharded cache: every live in-flight entry gets a
        #    slot on its cell's new s-shard — staying on its p-shard, so
        #    the participant partition survives the compaction —
        #    (allocation may grow capacity), then one gather moves the rows
        n_p = self.n_pshards
        nflat = self.n_shards * n_p
        new_acc = ShardedSlotAccounts(nflat, capacity=self.accounts.capacity)
        moves = []                        # (in-flight entry, old flat row)
        old_rows_loc = self.accounts.capacity + 1
        for i in live:
            shard = new_pl.shard_of[i]
            for f in self.sims[i].stale_cache:
                old_flat, old_slot = f.delta
                new_flat = shard * n_p + old_flat % n_p
                slots, _ = new_acc.alloc(new_flat, 1)
                f.delta = (new_flat, slots[0])
                moves.append((f, old_flat * old_rows_loc + old_slot))
        new_rows_loc = new_acc.capacity + 1
        # default: shard 0's old trash row — any defined row does (padding
        # slots are always scatter-written before they are ever gathered)
        cmap = np.full(nflat * new_rows_loc, old_rows_loc - 1,
                       np.int32)
        for f, old_flat_row in moves:
            shard, slot = f.delta
            cmap[shard * new_rows_loc + slot] = old_flat_row
        self.cache_rows = reshard_rows(
            self.cache_rows, cmap, (nflat, new_rows_loc),
            self._cache_spec)
        self.accounts = new_acc
        self._pending_free = []   # old slot ids are meaningless now
        self.placement = new_pl

    # ------------------------------------------------------------------
    def finalize(self):
        """Write the device state back to the Simulators and finalize each.
        After this the pipeline's donated-buffer chain ends; the returned
        Accountings are the same objects ``Simulator.run`` yields."""
        accts = []
        if self.mesh is None:
            for i, sim in enumerate(self.sims):
                sim.flat_params = self.params[i, :self.d]
                if self.yogi:
                    sim.flat_opt_state = jax.tree.map(
                        lambda x: self._unpad_leaf(x[i]), self.opt_state)
                accts.append(sim._finalize())
            return accts
        flat = self.params.reshape(-1, self.d_pad)
        for i, sim in enumerate(self.sims):
            if i in self._saved:
                row, opt_row = self._saved[i]
                sim.flat_params = jnp.asarray(row)[:self.d]
                if self.yogi:
                    sim.flat_opt_state = jax.tree.map(
                        lambda a: self._unpad_leaf(jnp.asarray(a)), opt_row)
            else:
                fr = self.placement.flat_row(i)
                sim.flat_params = flat[fr, :self.d]
                if self.yogi:
                    sim.flat_opt_state = jax.tree.map(
                        lambda a: self._unpad_leaf(
                            a.reshape((-1,) + a.shape[2:])[fr]),
                        self.opt_state)
            accts.append(sim._finalize())
        return accts
