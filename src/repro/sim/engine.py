"""Event-driven FL round engine reproducing the paper's methodology (§5.1).

Supports the paper's experimental settings:
  OC — over-commit selection by 30% and wait for the first N_t updates;
  DL — fixed reporting deadline, aggregate whatever arrived.
SAFA semantics (select-all + target-ratio round end + bounded-staleness cache)
and RELAY semantics (IPS + APT + SAA with Eq. 2 weights) are both expressible.

Simulated time is decoupled from wall-clock: device durations come from the
heterogeneity profiles, availability from the trace substrate, and every
round's cohort trains in one vmapped JAX call.

Three substrates, same semantics (parity-tested in
tests/test_fastpath_parity.py and tests/test_pipeline_parity.py):

  fused device-resident pipeline (default) — the whole device side of a
  round (cohort training, stale-cache scatter, SAA weights + aggregation,
  server apply) runs as ONE jitted dispatch per round with donated
  parameter/cache buffers (``repro.sim.pipeline``); straggler updates live
  in a device-resident slot cache (``repro.core.stale_cache``), local
  batches are gathered in-program from a device copy of the dataset, and
  the only per-round device->host traffic is the stat-utility vector
  (when a ``needs_feedback`` selector — Oort, UCB, contribution — is
  configured; see ``repro.selection``) plus accuracy/loss every
  ``eval_every`` rounds.  ``SimConfig.shard_participants`` additionally
  splits the packed cohort rows over a participant device-mesh axis
  (``repro.sim.participant_sharding``) for 10k+ learner cohorts — the
  dataset/trace tensors are replicated across the mesh (each shard
  gathers its own rows' batches in-program) and per-round results stay
  bit-identical to the unsharded pipeline;

  flat fast path (``fused_rounds=False``) — the per-stage flat path: flat
  (n, D) fp32 update rows from the compiled cohort program
  (``flat_cohort_step``) through a host-side stale cache to the compiled
  aggregation and flat server step, with one device->host delta copy per
  round; kept as the stage-by-stage parity baseline;

  legacy path (``fast_path=False``) — the original per-learner scalar loops
  and pytree shuffling, kept as the seed-parity/benchmark baseline.

All paths share the struct-of-arrays ``TraceBank``/``ForecasterBank``
availability substrate (fast paths) and the same host-side round logic.

The round loop is decomposed into ``_begin_round`` (host: availability,
selection, batch sampling), ``_schedule_round`` (host: arrival schedule,
fresh/straggler split, stale-cache landings — all decidable *before*
training, which is what lets the fused pipeline dispatch one program per
round), the device stage(s), and ``_record_round`` (host bookkeeping +
optional eval).  ``run()`` chains them for one simulation;
``repro.sweeps.runner`` drives many Simulators through the same methods in
lockstep, batching the device stages across the sweep axis — the host logic
is shared code, so batched cells are bit-identical to serial runs of the
same config/seed.  ``target_accuracy`` arms accuracy-target early stop:
the run ends at the first evaluated round whose accuracy reaches the
target (checked only on ``eval_every`` boundaries, so serial, flat and
batched executions stop at the identical round).

Seed-determined world state (dataset, shards, device profiles, availability
traces, warmed forecasters, initial model) is factored into ``Substrate`` so
a sweep's shared-seed cells build it once and every policy sees identical
traces (matched-condition comparisons, Soltani et al. 2022).
"""
from __future__ import annotations

import copy
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import (fedavg_apply, stale_synchronous_aggregate,
                                    stale_synchronous_aggregate_flat,
                                    unflatten_update, yogi_apply,
                                    yogi_apply_flat, yogi_init, yogi_init_flat)
from repro.core.apt import AdaptiveParticipantTarget
from repro.core.availability import AvailabilityForecaster, ForecasterBank
from repro.selection import (SELECTOR_TABLE, LearnerView, build_selector,
                             normalize_selector_params)
from repro.faults.attacks import attack_key
from repro.robust.aggregators import robust_host_aggregate, robust_key
from repro.sim import devices as dev
from repro.sim import learner as ln
from repro.sim import partition as part
from repro.sim import traces as tr
from repro.sim.metrics import Accounting, RoundRecord

HOUR = 3600.0


# ---------------------------------------------------------------------------
# Pure flat-update round programs (vmappable: repro.sweeps stacks them)
# ---------------------------------------------------------------------------


def flat_cohort_step(flat_params, bx, by, *, spec, lr, prox_mu,
                     loss=ln._xent):
    """One round of local training as a pure function of the flat model.

    flat_params: (D,) fp32 in ``spec`` leaf order; bx: (m, steps, batch, ...);
    by: (m, steps, batch, ...).  Returns ((m, D) flat deltas, (m,) losses,
    (m,) Oort l2 stats).  ``loss`` is the model's objective from the model
    table (the default is the MLP's).  Rows are independent under vmap, so
    padding rows never perturb real rows, and the whole step can be vmapped
    along a leading sweep axis (or packed as per-row parameters) with
    bit-identical per-row results — the property ``repro.sweeps.runner``
    builds on.
    """
    step = functools.partial(ln.local_train_flat, spec=spec, lr=lr,
                             prox_mu=prox_mu, loss=loss)
    return jax.vmap(step, in_axes=(None, 0, 0))(flat_params, bx, by)


@functools.lru_cache(maxsize=8)
def _cohort_step_fn(spec, lr, prox_mu, loss=ln._xent):
    """Jitted ``flat_cohort_step``, cached per (spec, lr, prox_mu, loss) so
    every Simulator with the same model/hyperparameters shares one
    program (``repro.learners.build_model`` hands out stable function
    objects, so the loss is cache-key-safe)."""
    return jax.jit(functools.partial(flat_cohort_step, spec=spec, lr=lr,
                                     prox_mu=prox_mu, loss=loss))


@functools.lru_cache(maxsize=2)
def _flat_apply_fn():
    """FedAvg server step on the flat vector: x <- x + lr * Delta."""
    return jax.jit(lambda flat, delta, lr: flat + lr * delta)


@functools.lru_cache(maxsize=2)
def _yogi_flat_fn():
    return jax.jit(yogi_apply_flat)


@functools.lru_cache(maxsize=8)
def _flat_eval_fn(spec, evaluate=ln.evaluate):
    return jax.jit(lambda flat, x, y: evaluate(unflatten_update(flat, spec),
                                               x, y))


@functools.lru_cache(maxsize=8)
def _unflatten_fn(spec):
    return jax.jit(lambda flat: unflatten_update(flat, spec))


@dataclasses.dataclass
class SimConfig:
    benchmark: str = "speech"
    mapping: str = "uniform"          # uniform | fedscale | label_{balanced,uniform,zipf}
    n_learners: int = 200
    rounds: int = 200
    selector: str = "random"          # any repro.selection strategy: random |
                                      # oort | priority | safa | flips | ucb |
                                      # contribution (+ registered plugins)
    selector_params: tuple = ()       # ((knob, value), ...) strategy knobs —
                                      # validated against the SelectorSpec,
                                      # folded into selector_key/pipeline_key
    server_opt: str = "fedavg"        # fedavg | yogi server optimizer (named
                                      # `aggregator` before PR 8; old configs
                                      # migrate in __post_init__)
    aggregator: str = "saa"           # robust aggregation strategy: saa |
                                      # coord_median | trimmed_mean | krum |
                                      # multi_krum | norm_median_clip
                                      # (repro.robust; saa = plain weighted
                                      # path, the default and parity baseline)
    trim_k: int = 1                   # trimmed_mean: rows trimmed per tail,
                                      # per coordinate (0 = statically saa)
    krum_f: int = 0                   # krum/multi_krum byzantine allowance f
    multi_krum_m: Optional[int] = None  # multi_krum survivors (None = c - f)
    attack: str = "none"              # coordinated attack: none |
                                      # collude_signflip | collude_same_value
                                      # | alie | adaptive (repro.faults.attacks;
                                      # auto-attaches an AttackSpec to the
                                      # fault plan)
    attack_frac: float = 0.25         # attacker fraction of the population
    attack_scale: float = 10.0        # attack magnitude knob
    attack_z: float = 1.5             # alie sigma multiplier
    scaling_rule: str = "relay"       # equal | dynsgd | adasgd | relay
    beta: float = 0.35                # Eq. 2 averaging weight
    saa: bool = False                 # accept stale updates
    staleness_threshold: Optional[int] = None   # None = unbounded (RELAY default)
    setting: str = "OC"               # OC | DL
    deadline: float = 100.0           # DL reporting deadline (seconds)
    n_target: int = 10
    overcommit: float = 1.3           # OC over-commit factor
    safa_target_ratio: float = 0.1    # SAFA round-end fraction
    apt: bool = False
    dynamic_availability: bool = True
    hardware_scenario: str = "HS1"
    local_steps: int = 5
    local_batch: int = 16
    local_lr: float = 0.05
    prox_mu: float = 0.0              # FedProx proximal term (0 = plain FedAvg)
    server_lr: float = 1.0
    model_mbits: float = 50.0         # update size on the wire
    eval_every: int = 10
    selection_window: float = 5.0
    seed: int = 0
    use_agg_kernel: bool = False      # route aggregation through the Pallas kernel
    fast_path: bool = True            # flat (n, D) updates + TraceBank/ForecasterBank
    fused_rounds: bool = True         # single-dispatch device-resident round pipeline
    target_accuracy: Optional[float] = None   # accuracy-target early stop (eval rounds)
    stale_cache_capacity: int = 64    # initial device stale-cache slots (grows 2x)
    rounds_per_dispatch: int = 1      # K rounds per device dispatch (lax.scan chunk);
                                      # host decisions are prescheduled K ahead, chunks
                                      # break at eval rounds; bit-identical to K=1
    shard_participants: int = 0       # shard the packed cohort rows over a device
                                      # mesh axis "p": 0 = off, N = N shards (clamped
                                      # to the local device count), True = all local
                                      # devices.  Fused pipeline only; bit-identical
                                      # to the unsharded run (one psum per round)
    guard: bool = False               # screen update rows before aggregation
                                      # (non-finite reject + optional norm rules);
                                      # with no faults injected, guarded runs are
                                      # bit-identical to unguarded ones
    guard_clip: Optional[float] = None         # L2 clip for surviving rows
    guard_reject_mult: Optional[float] = None  # reject rows whose sq-norm exceeds
                                               # mult^2 x median surviving sq-norm
    quorum: int = 1                   # min surviving rows for a server apply;
                                      # below it the round's apply is skipped
                                      # (params carried unchanged)
    telemetry: int = 0                # 0 = off (program bit-identical to a
                                      # telemetry-free build), 1 = host-side
                                      # spans + metrics registry, 2 = also
                                      # the in-program round-stats lane +
                                      # per-round JSONL events.  Static in
                                      # pipeline_key (program structure)
    model: str = "mlp"                # learner model: any repro.learners
                                      # strategy — mlp | transformer | moe |
                                      # rwkv6 (+ registered plugins); folded
                                      # into pipeline_key and substrate_key
    model_params: tuple = ()          # ((knob, value), ...) model knobs —
                                      # validated against the ModelSpec

    def __post_init__(self):
        # pre-PR-8 configs (and their snapshots) used `aggregator` for the
        # server optimizer; migrate so old dicts keep working
        if self.aggregator in ("fedavg", "yogi"):
            self.server_opt = self.aggregator
            self.aggregator = "saa"
        from repro.faults.attacks import ATTACK_KINDS
        from repro.robust import ROBUST_AGGREGATORS
        if self.selector not in SELECTOR_TABLE:
            raise ValueError(f"unknown selector {self.selector!r} "
                             f"(choose from {tuple(SELECTOR_TABLE)})")
        # canonical sorted-tuple form: hashable (pipeline_key), picklable
        # (checkpoints), and knob-validated at config time
        self.selector_params = normalize_selector_params(
            self.selector, self.selector_params)
        if self.aggregator not in ROBUST_AGGREGATORS:
            raise ValueError(f"unknown aggregator {self.aggregator!r} "
                             f"(choose from {ROBUST_AGGREGATORS})")
        if self.attack not in ATTACK_KINDS:
            raise ValueError(f"unknown attack {self.attack!r} "
                             f"(choose from {ATTACK_KINDS})")
        # model-table validation (lazy import: repro.learners imports the
        # sim package for the MLP wrapper, so engine must not import it at
        # module level)
        from repro.learners import MODEL_TABLE, normalize_model_params
        if self.model not in MODEL_TABLE:
            raise ValueError(f"unknown model {self.model!r} "
                             f"(choose from {tuple(MODEL_TABLE)})")
        self.model_params = normalize_model_params(self.model,
                                                   self.model_params)
        if self.model != "mlp" and not self.fast_path:
            raise ValueError(
                f"model {self.model!r} requires the flat fast path "
                "(fast_path=True) — the legacy pytree round loop is "
                "MLP-only")


def substrate_key(cfg: SimConfig) -> tuple:
    """The config fields that determine the seed-built world state."""
    return (cfg.benchmark, cfg.mapping, cfg.n_learners, cfg.seed,
            cfg.dynamic_availability, cfg.model,
            tuple(cfg.model_params or ()))


@dataclasses.dataclass
class Substrate:
    """Everything the config seed determines before the first round.

    Built with the exact RNG draw order of the original Simulator
    constructor (dataset, partition, profiles, traces), then the generator
    state is captured so a Simulator resuming from a cached Substrate
    consumes the identical stream the uncached constructor would — sweep
    cells sharing a substrate are bit-identical to standalone runs.

    Device profiles are stored as the HS1 base population; hardware
    scenarios are pure transforms applied per Simulator
    (``devices.apply_hardware_scenario``), so the hardware axis of a sweep
    shares one substrate too.
    """
    key: tuple
    data: part.FederatedDataset
    base_profiles: list
    traces: list
    trace_bank: tr.TraceBank
    rng_state: dict
    params0: dict                      # initial model pytree (read-only, shared)
    flat_params0: np.ndarray           # same model, flat fp32 (D,)
    flat_spec: tuple
    meta: object = None                # repro.learners.DataMeta of the dataset
    model_fns: object = None           # repro.learners.ModelFns (init/loss/eval)
    _warmed: Optional[tuple] = None    # lazily-built fast-path forecaster warmup

    @staticmethod
    def build(cfg: SimConfig) -> "Substrate":
        from repro.learners import DataMeta, build_model
        rng = np.random.default_rng(cfg.seed)
        if part.benchmark_kind(cfg.benchmark) == "tokens":
            # token benchmarks carry their own shard structure; profiles and
            # traces still consume this generator, in the same order the
            # classifier branch draws them
            data = part.make_token_dataset(cfg.benchmark, cfg.n_learners,
                                           cfg.seed)
            meta = DataMeta(kind="tokens", vocab=data.vocab,
                            seq_len=int(data.x_train.shape[1]))
        else:
            x_tr, y_tr, x_te, y_te = part.make_dataset(cfg.benchmark, rng)
            shards = part.partition(y_tr, cfg.n_learners, cfg.mapping, rng)
            data = part.FederatedDataset(cfg.benchmark, x_tr, y_tr, x_te,
                                         y_te, shards)
            meta = DataMeta(kind="classifier",
                            feature_dim=int(x_tr.shape[1]),
                            n_classes=data.n_classes)
        base_profiles = dev.sample_profiles(cfg.n_learners, rng)   # HS1 base
        traces = tr.make_traces(cfg.n_learners, rng,
                                dynamic=cfg.dynamic_availability)
        model_fns = build_model(cfg.model, tuple(cfg.model_params), meta)
        params0 = model_fns.init(jax.random.PRNGKey(cfg.seed))
        flat_spec = agg.make_flat_spec(params0)
        flat0, _ = agg.flatten_update(params0)
        return Substrate(key=substrate_key(cfg), data=data,
                         base_profiles=base_profiles, traces=traces,
                         trace_bank=tr.TraceBank(traces),
                         rng_state=rng.bit_generator.state,
                         params0=params0, flat_params0=np.asarray(flat0),
                         flat_spec=flat_spec, meta=meta, model_fns=model_fns)

    def warmed_fbank(self) -> tuple:
        """Pre-deployment forecaster history (paper App. A step 2), computed
        once per substrate; returns (counts, avail_counts, recent) arrays
        that each Simulator copies into its own ForecasterBank."""
        if self._warmed is None:
            fb = ForecasterBank(len(self.traces))
            for tt in np.arange(0, 3 * 24 * HOUR, 1800.0):
                fb.observe_all(tt, self.trace_bank.available_all(tt))
            self._warmed = (fb.counts, fb.avail_counts, fb.recent)
        return self._warmed


@dataclasses.dataclass
class _InFlight:
    learner_id: int
    origin_round: int
    arrival: float
    duration: float
    delta: object                     # device-cache slot id (fused), flat (D,)
    stat_util: float                  # fp32 row (flat) or pytree (legacy)


@dataclasses.dataclass
class RoundPlan:
    """Host-side output of ``_begin_round``: everything the device stage
    needs for one round's cohort training.  The fused pipeline carries only
    sample *indices* (``bidx``) and gathers the batches in-program; the
    per-stage paths materialize ``bx``/``by`` on host.  Both consume the
    identical RNG draws, so the sampled batches match bit-for-bit."""
    t_now: float
    chosen: list
    n_t: int
    k: int                            # cohort size
    bx: Optional[np.ndarray]          # (k, steps, batch, dim) local batches
    by: Optional[np.ndarray]          # (k, steps, batch)
    durs: np.ndarray                  # (k,)
    drop_at: np.ndarray               # (k,) mid-round dropout offsets (inf = none)
    bidx: Optional[np.ndarray] = None  # (k, steps*batch) sample indices (fused)


@dataclasses.dataclass
class RoundSchedule:
    """Host-side round outcome, decided *before* the device dispatch.

    Everything here depends only on the plan (durations, dropouts, arrival
    order) and the stale-cache metadata — never on the update values — so
    the fused pipeline can build its gather/scatter index arrays and launch
    one program for train + cache + aggregate + apply.  Entries removed from
    ``Simulator.stale_cache`` (``landing``/``expired``) are returned so the
    caller can free their device slots or collect their host rows."""
    t_end: float
    fresh_rows: list                  # plan-row indices aggregated fresh, arrival order
    new_stale: list                   # (row, lid, arrival, duration) entering the cache
    landing: list                     # _InFlight entries landing this round, cache order
    landing_taus: list                # their staleness (rounds)
    expired: list                     # over-threshold entries (removed, marked wasted)
    feedback: list                    # (lid, row, duration) selector feedback, arrival order
    slots: list = dataclasses.field(default_factory=list)  # set by the pipeline


class Simulator:
    def __init__(self, cfg: SimConfig, substrate: Optional[Substrate] = None,
                 fault_plan=None):
        self.cfg = cfg
        self.fault_plan = fault_plan  # repro.faults.FaultPlan or None
        if cfg.attack != "none" and cfg.attack_frac > 0:
            # auto-attach the coordinated attack to the fault plan; a
            # restored plan already carries one (resume-safe), and the
            # attacker stream is independent of the fault draws, so two
            # cells differing only in aggregator share identical attacks
            from repro.faults import AttackSpec, FaultPlan
            plan = self.fault_plan
            if plan is None:
                plan = FaultPlan(cfg.n_learners, cfg.rounds, specs=(),
                                 seed=cfg.seed)
            if getattr(plan, "attack", None) is None:
                plan = plan.with_attack(AttackSpec(
                    cfg.attack, cfg.attack_frac, cfg.attack_scale,
                    cfg.attack_z))
            self.fault_plan = plan
        if substrate is None:
            substrate = Substrate.build(cfg)
        else:
            assert substrate.key == substrate_key(cfg), \
                "substrate built for a different config family"
        self.substrate = substrate
        self.rng = np.random.default_rng(cfg.seed)
        self.rng.bit_generator.state = substrate.rng_state
        self.data = substrate.data
        self.profiles = dev.apply_hardware_scenario(substrate.base_profiles,
                                                    cfg.hardware_scenario)
        self.traces = substrate.traces
        # per-learner round duration is config-determined: compute it once
        self.durations = np.array([
            p.round_duration(cfg.local_steps * cfg.local_batch, 1, cfg.model_mbits)
            for p in self.profiles])
        if cfg.fast_path:
            self.trace_bank = substrate.trace_bank
            self.fbank = ForecasterBank(cfg.n_learners)
            self.forecasters = None
        else:
            self.trace_bank = None
            self.fbank = None
            self.forecasters = [AvailabilityForecaster() for _ in range(cfg.n_learners)]
        self._warmup_forecasters()
        # strategy-table build: the spec's static flags drive the engine's
        # scheduling rules, the factory gets the build-time world state
        # (FLIPS clusters the substrate's label shards here)
        self._sel_spec = SELECTOR_TABLE[cfg.selector]
        self.selector = build_selector(cfg, substrate=substrate,
                                       durations=self.durations)
        self.apt = AdaptiveParticipantTarget(n0=cfg.n_target) if cfg.apt else None
        self.params = substrate.params0
        self._flat_spec = substrate.flat_spec
        self._model_fns = substrate.model_fns  # ModelFns(init, loss, evaluate)
        if cfg.fast_path:
            self.flat_params = jnp.asarray(substrate.flat_params0)
            self.flat_opt_state = (yogi_init_flat(len(substrate.flat_params0))
                                   if cfg.server_opt == "yogi" else None)
            self.opt_state = None
        else:
            self.flat_params = None
            self.flat_opt_state = None
            self.opt_state = yogi_init(self.params) if cfg.server_opt == "yogi" else None
        self.acct = Accounting()
        self.stale_cache: list[_InFlight] = []
        self.busy_until = np.zeros(cfg.n_learners)  # device busy training/uploading
        self.mu = cfg.deadline  # initial round-duration estimate
        self._t_now = 0.0

    # ------------------------------------------------------------------
    def _warmup_forecasters(self):
        """Learners have pre-deployment local history (paper App. A step 2)."""
        if self.cfg.fast_path:
            counts, avail_counts, recent = self.substrate.warmed_fbank()
            self.fbank.counts = counts.copy()
            self.fbank.avail_counts = avail_counts.copy()
            self.fbank.recent = recent.copy()
            return
        ts = np.arange(0, 3 * 24 * HOUR, 1800.0)
        for lid, (f, t) in enumerate(zip(self.forecasters, self.traces)):
            for tt in ts:
                f.observe(tt, t.available(tt))

    def _available_now(self, t_now: float):
        """Idle + available learner ids (ascending), forecasters updated."""
        if self.cfg.fast_path:
            mask = self.trace_bank.available_all(t_now) & (self.busy_until <= t_now)
            available = np.nonzero(mask)[0]
            if len(available):                  # devices log their own state
                self.fbank.observe_batch(available, t_now, 1.0)
            return available
        available = [lid for lid in range(self.cfg.n_learners)
                     if self.traces[lid].available(t_now)
                     and self.busy_until[lid] <= t_now]
        for lid in available:
            self.forecasters[lid].observe(t_now, True)
        return available

    def _views(self, t_now: float, available_ids):
        t0, t1 = t_now + self.mu, t_now + 2 * self.mu
        if self.cfg.fast_path:
            probs = self.fbank.predict_window_batch(available_ids, t0, t1)
            return [LearnerView(lid, availability_prob=float(p),
                                est_duration=self.durations[lid])
                    for lid, p in zip(available_ids, probs)]
        return [LearnerView(lid,
                            availability_prob=self.forecasters[lid].predict_window(t0, t1),
                            est_duration=self.durations[lid])
                for lid in available_ids]

    # ------------------------------------------------------------------
    # Round stages (run() chains them; repro.sweeps.runner drives them in
    # lockstep across many Simulators with batched device stages)
    # ------------------------------------------------------------------

    def eval_due(self, r: int) -> bool:
        return (r + 1) % self.cfg.eval_every == 0 or r == self.cfg.rounds - 1

    def _begin_round(self, r: int) -> Optional[RoundPlan]:
        """Host pre-step: advance time, census availability, pick the cohort,
        sample its local batches.  Returns None when the round is skipped
        (nobody available / nobody selected)."""
        cfg = self.cfg
        self._t_now += cfg.selection_window
        t_now = self._t_now
        available = self._available_now(t_now)
        if not len(available):
            self._t_now += 60.0
            return None

        n_t = cfg.n_target
        if self.apt is not None:
            rts = [f.arrival - t_now for f in self.stale_cache
                   if f.arrival > t_now]
            n_t = self.apt.target(rts)
        n_sel = (int(np.ceil(n_t * cfg.overcommit))
                 if cfg.setting == "OC" else n_t)
        if self.selector.needs_views:
            views = self._views(t_now, available)
            chosen = self.selector.select(r, views, n_sel, self.rng)
        else:
            # view-free selectors (random, safa) skip the forecaster window
            # queries — pure reads, so state and RNG streams are untouched
            chosen = self.selector.select_ids(r, available, n_sel, self.rng)
        if not chosen:
            self._t_now += 60.0
            return None
        return self._build_plan(chosen, t_now, n_t)

    def _build_plan(self, chosen, t_now, n_t) -> RoundPlan:
        cfg = self.cfg
        fused = cfg.fast_path and cfg.fused_rounds
        takes, xs, ys = [], [], []
        for lid in chosen:
            if fused:
                # indices only; the pipeline gathers the rows in-program
                takes.append(ln.sample_batch_indices(
                    self.data.shards[lid], cfg.local_steps, cfg.local_batch,
                    self.rng))
            else:
                bx, by = ln.sample_local_batches(
                    self.data.shards[lid], self.data.x_train,
                    self.data.y_train, cfg.local_steps, cfg.local_batch,
                    self.rng)
                xs.append(bx)
                ys.append(by)
        durs = self.durations[np.asarray(chosen)]
        k = len(chosen)
        if cfg.fast_path:
            nus = self.trace_bank.next_unavailable_after_batch(chosen, t_now)
            rel = nus - t_now
            drop_at = np.where(rel < durs, rel, np.inf)
        else:
            drop_at = []
            for lid, d in zip(chosen, durs):
                nu = self.traces[lid].next_unavailable_after(t_now)
                drop_at.append(nu - t_now if nu - t_now < d else np.inf)
            drop_at = np.array(drop_at)
        if fused:
            return RoundPlan(t_now, list(chosen), n_t, k, None, None, durs,
                             drop_at, bidx=np.asarray(takes, np.int32))
        return RoundPlan(t_now, list(chosen), n_t, k, np.stack(xs),
                         np.stack(ys), durs, drop_at)

    def _train(self, plan: RoundPlan):
        """Device stage: the cohort's local training (simulated durations,
        real gradients).  Fast path returns flat (k, D) fp32 host rows."""
        cfg = self.cfg
        if cfg.fast_path:
            # pad the cohort to a power-of-two bucket: one compiled program per
            # bucket instead of per distinct cohort size (rows independent
            # under vmap, so real rows are bit-identical; padding discarded).
            # Serial-only: the sweep runner packs unpadded plan rows itself.
            k, m = plan.k, agg.bucket_pow2(plan.k)
            bx = np.concatenate([plan.bx,
                                 np.broadcast_to(plan.bx[:1],
                                                 (m - k,) + plan.bx.shape[1:])])
            by = np.concatenate([plan.by,
                                 np.broadcast_to(plan.by[:1],
                                                 (m - k,) + plan.by.shape[1:])])
            step = _cohort_step_fn(self._flat_spec, cfg.local_lr, cfg.prox_mu,
                                   self._model_fns.loss)
            deltas, losses, l2s = step(self.flat_params, bx, by)
            # one device->host copy per round
            return np.asarray(deltas)[:k], np.asarray(losses)[:k], np.asarray(l2s)[:k]
        deltas, losses, l2s = ln.local_train_cohort(
            self.params, plan.bx, plan.by, cfg.local_lr, cfg.prox_mu)
        return deltas, np.asarray(losses), np.asarray(l2s)

    def _schedule_round(self, r: int, plan: RoundPlan) -> RoundSchedule:
        """Host post-plan step, decided *before* training: arrival schedule,
        round end time, fresh/straggler split, stale-cache landings, resource
        accounting.  None of it reads the update values, so the fused
        pipeline runs it first and dispatches one device program for the
        whole round.  Accounting/bookkeeping mutations happen here in the
        same order the pre-refactor ``_collect_updates`` performed them
        (float accumulation order is part of the parity contract)."""
        cfg = self.cfg
        t_now, chosen, durs, drop_at = plan.t_now, plan.chosen, plan.durs, plan.drop_at
        n_t = plan.n_t

        fp = self.fault_plan
        arrivals = []   # (arrival_time, idx into chosen) for non-dropouts
        for i, lid in enumerate(chosen):
            if np.isfinite(drop_at[i]):
                # device went away mid-round: partial work, always wasted
                self.acct.charge(float(drop_at[i]), wasted=True)
                self.busy_until[lid] = t_now + float(drop_at[i])
            elif fp is not None and fp.post_drop(r, lid):
                # injected fault: the learner finishes training but the
                # result is lost before upload — full duration charged and
                # wasted (paper §3), no arrival, no selector feedback
                self.acct.charge(float(durs[i]), wasted=True)
                self.busy_until[lid] = t_now + float(durs[i])
            else:
                arrivals.append((t_now + durs[i], i))
                self.acct.charge(float(durs[i]), wasted=False)
                self.busy_until[lid] = t_now + float(durs[i])
        arrivals.sort()

        # --- round end time ---------------------------------------
        if self._sel_spec.select_all:
            need = max(1, int(np.ceil(cfg.safa_target_ratio * len(chosen))))
            t_end = (arrivals[need - 1][0] if len(arrivals) >= need
                     else t_now + cfg.deadline)
            t_end = min(t_end, t_now + cfg.deadline)
        elif cfg.setting == "OC":
            t_end = (arrivals[n_t - 1][0] if len(arrivals) >= n_t
                     else (arrivals[-1][0] if arrivals else t_now + cfg.deadline))
        else:  # DL
            t_end = t_now + cfg.deadline

        # --- split fresh / straggler ------------------------------
        fresh_rows, new_stale, feedback = [], [], []
        for (arr, i) in arrivals:
            lid = chosen[i]
            feedback.append((lid, i, durs[i]))
            if arr <= t_end and (cfg.setting == "DL"
                                 or self._sel_spec.select_all
                                 or len(fresh_rows) < n_t):
                fresh_rows.append(i)
                self.acct.unique.add(lid)
            elif cfg.saa:
                new_stale.append((i, lid, arr, durs[i]))
            else:
                # already charged as used at dispatch; never aggregated
                self.acct.mark_wasted(float(durs[i]))

        # --- stale updates landing this round ---------------------
        landing, landing_taus, expired = [], [], []
        still_waiting = []
        for f in self.stale_cache:
            if f.arrival <= t_end:
                tau = r - f.origin_round
                if (cfg.staleness_threshold is None
                        or tau <= cfg.staleness_threshold):
                    landing.append(f)
                    landing_taus.append(tau)
                    self.acct.unique.add(f.learner_id)
                    if fp is not None and fp.replay(r, f.learner_id):
                        # injected fault: the same stale delivery lands
                        # twice — a duplicate row in the aggregation operand
                        landing.append(f)
                        landing_taus.append(tau)
                else:
                    expired.append(f)
                    self.acct.mark_wasted(f.duration)
            else:
                still_waiting.append(f)
        self.stale_cache = still_waiting
        return RoundSchedule(t_end, fresh_rows, new_stale, landing,
                             landing_taus, expired, feedback)

    def _apply_feedback(self, r: int, sched: RoundSchedule, l2s) -> None:
        """Selector feedback for every arrival, in arrival order.  ``l2s``
        holds the per-row loss stats consumed by ``needs_feedback``
        selectors (Oort, UCB, contribution); None when the fused pipeline
        skipped the fetch, in which case stat_util is reported as 0."""
        cfg = self.cfg
        for (lid, i, dur) in sched.feedback:
            stat_util = (float(cfg.local_steps * cfg.local_batch * l2s[i])
                         if l2s is not None else 0.0)
            self.selector.update_feedback(lid, stat_util=stat_util,
                                          duration=dur, round_idx=r)

    def _stat_util(self, row: int, l2s) -> float:
        return (float(self.cfg.local_steps * self.cfg.local_batch * l2s[row])
                if l2s is not None else 0.0)

    def _collect_updates(self, r: int, plan: RoundPlan, deltas, losses, l2s):
        """Host post-step for the per-stage paths: schedule the round, apply
        selector feedback, then materialize the scheduled rows from the
        round's update values.  Returns (t_end, fresh_updates, stale_updates,
        stale_taus, agg_lids) where ``agg_lids`` are the learner ids behind
        each aggregation-operand row, fresh first then landing stale (the
        attack paths map them to the round's attacker set)."""
        cfg = self.cfg
        sched = self._schedule_round(r, plan)
        self._apply_feedback(r, sched, l2s)

        def row(i):
            return (deltas[i] if cfg.fast_path
                    else jax.tree.map(lambda d: d[i], deltas))

        fresh_updates = [row(i) for i in sched.fresh_rows]
        for (i, lid, arr, dur) in sched.new_stale:
            delta_i = row(i)
            if cfg.fast_path:
                # copy: delta_i is a view into the round's padded (m, D)
                # cohort buffer; caching the view would pin the whole
                # buffer for the straggler's lifetime
                delta_i = np.array(delta_i)
            self.stale_cache.append(_InFlight(lid, r, arr, dur, delta_i,
                                              self._stat_util(i, l2s)))
        stale_updates = [f.delta for f in sched.landing]
        agg_lids = ([int(plan.chosen[i]) for i in sched.fresh_rows]
                    + [f.learner_id for f in sched.landing])
        return (sched.t_end, fresh_updates, stale_updates,
                sched.landing_taus, agg_lids)

    def _corrupt_deltas(self, r: int, plan: RoundPlan, deltas):
        """Apply the fault plan's per-row update corruption (chaos harness).

        A pure fp32 multiply after local training and before caching /
        aggregation — the identical IEEE operation the fused pipeline folds
        into its round program, so faulted runs stay parity-comparable
        across substrates.  Losses and Oort stats are computed pre-fault
        everywhere (corruption models the uplink, not the training)."""
        fp = self.fault_plan
        if fp is None or not fp.has_corruption:
            return deltas
        fscale = fp.scale_for(r, plan.chosen)
        if self.cfg.fast_path:
            return np.asarray(deltas) * fscale[:, None]
        k = len(plan.chosen)
        return jax.tree.map(
            lambda d: d * jnp.asarray(fscale).reshape((k,) + (1,) * (d.ndim - 1)),
            deltas)

    def _aggregate(self, r, agg_lids, fresh_updates, stale_updates,
                   stale_taus):
        """Returns the aggregated delta, or None when the guard's quorum
        check rejects the round (caller carries params unchanged)."""
        cfg = self.cfg
        fresh_mask = [True] * len(fresh_updates) + [False] * len(stale_updates)
        taus = [0] * len(fresh_updates) + stale_taus
        atk = attack_key(cfg)
        rob = robust_key(cfg)
        if atk is not None or rob is not None:
            # attacked / robust route: one shared composition program
            # (attack -> guard screen -> robust strategy -> SAA weights),
            # the same per-cell numerics the fused pipeline and the batched
            # sweep executor run.  Legacy trees flatten exactly as the
            # guarded path does.
            if cfg.fast_path:
                stacked = np.stack(fresh_updates + stale_updates)
                spec = None
            else:
                flats, spec = [], None
                for t in fresh_updates + stale_updates:
                    f, spec = agg.flatten_update(t)
                    flats.append(f)
                stacked = jnp.stack(flats)
            att = (self.fault_plan.attack_flags(r, agg_lids)
                   if atk is not None else np.zeros(len(fresh_mask), bool))
            guard_desc = ((cfg.guard_clip, cfg.guard_reject_mult)
                          if cfg.guard else None)
            agg_out, info = robust_host_aggregate(
                stacked, fresh_mask, taus, att, attack=atk, guard=guard_desc,
                robust=rob, use_kernel=cfg.use_agg_kernel, beta=cfg.beta,
                rule=cfg.scaling_rule, quorum=cfg.quorum,
                bucketed=cfg.fast_path)
            if cfg.guard:
                self.acct.note_guard(info["nonfinite"], info["norm"],
                                     info["applied"])
            if rob is not None:
                self.acct.note_robust(info["robust_rejected"],
                                      info["robust_trimmed"])
            if not info["applied"]:
                return None
            return agg_out if spec is None else unflatten_update(agg_out,
                                                                 spec)
        if not cfg.guard:
            if cfg.fast_path:
                stacked = np.stack(fresh_updates + stale_updates)
                agg_flat, _ = stale_synchronous_aggregate_flat(
                    stacked, fresh_mask, taus, rule=cfg.scaling_rule,
                    beta=cfg.beta, use_kernel=cfg.use_agg_kernel)
                return agg_flat
            agg_tree, _ = stale_synchronous_aggregate(
                fresh_updates + stale_updates, fresh_mask, taus,
                rule=cfg.scaling_rule, beta=cfg.beta,
                use_kernel=cfg.use_agg_kernel,
                compiled=False)  # seed-exact eager baseline
            return agg_tree
        # guarded route: one shared screening + masked-aggregation program.
        # Legacy trees are flattened exactly as the unguarded tree path
        # does, so the clean (nothing-rejected) case routes through the
        # identical unguarded computation bit-for-bit.
        if cfg.fast_path:
            stacked = np.stack(fresh_updates + stale_updates)
            spec = None
        else:
            flats, spec = [], None
            for t in fresh_updates + stale_updates:
                f, spec = agg.flatten_update(t)
                flats.append(f)
            stacked = jnp.stack(flats)
        agg_out, _, info = agg.guarded_aggregate_flat(
            stacked, fresh_mask, taus, rule=cfg.scaling_rule, beta=cfg.beta,
            use_kernel=cfg.use_agg_kernel, compiled=cfg.fast_path,
            clip=cfg.guard_clip, reject_mult=cfg.guard_reject_mult,
            quorum=cfg.quorum)
        self.acct.note_guard(info["nonfinite"], info["norm"], info["applied"])
        if not info["applied"]:
            return None
        return agg_out if spec is None else unflatten_update(agg_out, spec)

    def _apply_update(self, agg_out):
        """Server optimizer step on the aggregated delta."""
        cfg = self.cfg
        if cfg.fast_path:
            if cfg.server_opt == "yogi":
                self.flat_params, self.flat_opt_state = _yogi_flat_fn()(
                    self.flat_params, agg_out, self.flat_opt_state)
            else:
                self.flat_params = _flat_apply_fn()(self.flat_params, agg_out,
                                                    cfg.server_lr)
        elif cfg.server_opt == "yogi":
            self.params, self.opt_state = yogi_apply(self.params, agg_out,
                                                     self.opt_state)
        else:
            self.params = fedavg_apply(self.params, agg_out, cfg.server_lr)

    def _evaluate(self):
        if self.cfg.fast_path:
            return _flat_eval_fn(self._flat_spec,
                                 self._model_fns.evaluate)(self.flat_params,
                                                           self.data.x_test,
                                                           self.data.y_test)
        return ln.evaluate(self.params, self.data.x_test, self.data.y_test)

    def _advance_round_state(self, r: int, t_start: float, t_end: float,
                             n_selected: int, n_fresh: int, n_stale: int):
        """The host part of ``_record_round`` that the *next* round's
        ``_begin_round`` depends on: round-duration estimate, the appended
        RoundRecord (accuracy NaN until an evaluation fills it), and the
        clock.  The chunked pipeline calls this during prescheduling — K
        rounds ahead of the device dispatch — and fills the eval fields
        afterwards via ``_fill_round_eval``; values are identical to the
        unchunked sequence because nothing here reads update values."""
        duration = t_end - t_start
        self.mu = (self.apt.update_round_duration(duration)
                   if self.apt is not None else
                   0.75 * duration + 0.25 * self.mu)
        rec = RoundRecord(r, t_end, n_selected, n_fresh, n_stale,
                          self.acct.resource_used, self.acct.resource_wasted,
                          len(self.acct.unique))
        self.acct.records.append(rec)
        self._t_now = t_end
        return rec

    def _fill_round_eval(self, rec, acc, loss, progress: bool = False):
        """Write an evaluation's metrics into an already-appended record."""
        rec.accuracy, rec.loss = float(acc), float(loss)
        if progress:
            print(f"  round {rec.round_idx:4d} t={rec.sim_time/60:7.1f}min "
                  f"acc={rec.accuracy:.3f} "
                  f"used={self.acct.resource_used/60:.0f}min "
                  f"wasted={100*self.acct.resource_wasted/max(self.acct.resource_used,1e-9):.0f}%")

    def _record_round(self, r: int, t_start: float, t_end: float,
                      n_selected: int, n_fresh: int, n_stale: int,
                      acc_loss=None, progress: bool = False):
        """Bookkeeping tail of a round: round-duration estimate, RoundRecord,
        optional evaluation (``acc_loss`` supplies precomputed metrics when a
        sweep batch evaluated all cells in one call)."""
        rec = self._advance_round_state(r, t_start, t_end, n_selected,
                                        n_fresh, n_stale)
        if self.eval_due(r):
            acc, loss = self._evaluate() if acc_loss is None else acc_loss
            self._fill_round_eval(rec, acc, loss, progress=progress)
        return rec

    def _target_reached(self) -> bool:
        """Accuracy-target early stop: True once the latest recorded round's
        evaluation reached ``target_accuracy``.  Only eval rounds carry an
        accuracy (NaN otherwise), so every execution mode — serial, flat,
        batched sweep — tests the identical round boundaries and stops at
        the identical round."""
        target = self.cfg.target_accuracy
        if target is None or not self.acct.records:
            return False
        acc = self.acct.records[-1].accuracy
        return acc == acc and acc >= target

    def _finalize(self) -> Accounting:
        # updates still in flight at the end of training are wasted work
        for f in self.stale_cache:
            self.acct.mark_wasted(f.duration)
        if self.cfg.fast_path:
            self.params = _unflatten_fn(self._flat_spec)(self.flat_params)
        return self.acct

    # ------------------------------------------------------------------
    # Snapshot support (chaos harness: crash-safe bit-exact resume)
    # ------------------------------------------------------------------

    def capture_state(self, stale_rows=None):
        """Everything mutable the round loop reads, as plain host objects.

        ``stale_rows`` optionally supplies the stale-cache update rows
        (aligned with ``self.stale_cache``) — the fused pipeline passes the
        gathered device rows, since there ``_InFlight.delta`` is only a
        cache slot id.  The result round-trips through pickle; restoring it
        into a Simulator rebuilt from the same config + substrate resumes
        the identical RNG/selector/accounting streams."""
        cfg = self.cfg
        st = {
            "rng": self.rng.bit_generator.state,
            "selector": copy.deepcopy(self.selector),
            "apt": copy.deepcopy(self.apt),
            "busy_until": self.busy_until.copy(),
            "mu": self.mu,
            "t_now": self._t_now,
            "acct": copy.deepcopy(self.acct),
        }
        if cfg.fast_path:
            st["fbank"] = (self.fbank.counts.copy(),
                           self.fbank.avail_counts.copy(),
                           self.fbank.recent.copy())
        else:
            st["forecasters"] = copy.deepcopy(self.forecasters)
        entries = []
        for idx, f in enumerate(self.stale_cache):
            if stale_rows is not None:
                row = np.asarray(stale_rows[idx])
            elif cfg.fast_path:
                row = np.asarray(f.delta)
            else:
                row = jax.tree.map(np.asarray, f.delta)
            entries.append((f.learner_id, f.origin_round, f.arrival,
                            f.duration, f.stat_util, row))
        st["stale"] = entries
        return st

    def restore_state(self, st):
        """Inverse of ``capture_state``.  Stale entries come back with their
        host rows as ``delta``; a fused-pipeline resume re-seats them into
        the device cache afterwards (``repro.checkpoint.state``)."""
        self.rng.bit_generator.state = st["rng"]
        self.selector = copy.deepcopy(st["selector"])
        self.apt = copy.deepcopy(st["apt"])
        self.busy_until = np.array(st["busy_until"])
        self.mu = st["mu"]
        self._t_now = st["t_now"]
        self.acct = copy.deepcopy(st["acct"])
        if self.cfg.fast_path:
            counts, avail_counts, recent = st["fbank"]
            self.fbank.counts = np.array(counts)
            self.fbank.avail_counts = np.array(avail_counts)
            self.fbank.recent = np.array(recent)
        else:
            self.forecasters = copy.deepcopy(st["forecasters"])
        self.stale_cache = [
            _InFlight(lid, orig, arr, dur, row, su)
            for (lid, orig, arr, dur, su, row) in st["stale"]]

    # ------------------------------------------------------------------
    def run(self, progress: bool = False, *,
            checkpoint_path: Optional[str] = None, checkpoint_every: int = 0,
            telemetry=None):
        if self.cfg.shard_participants and not (self.cfg.fast_path
                                                and self.cfg.fused_rounds):
            raise ValueError(
                "shard_participants requires the fused fast path "
                "(fast_path=True, fused_rounds=True) — the per-stage and "
                "legacy substrates have no device-sharded round program")
        if self.cfg.fast_path and self.cfg.fused_rounds:
            from repro.sim.pipeline import RoundPipeline
            return RoundPipeline([self], progress=progress,
                                 checkpoint_path=checkpoint_path,
                                 checkpoint_every=checkpoint_every,
                                 telemetry=telemetry).run()[0]
        self._t_now = 0.0
        return self._run_loop(0, progress, checkpoint_path, checkpoint_every,
                              telemetry=telemetry)

    def _run_loop(self, start_round: int, progress: bool,
                  checkpoint_path: Optional[str], checkpoint_every: int,
                  telemetry=None):
        """The per-stage/legacy round loop from ``start_round`` — resume
        entry point: a restored Simulator continues here without resetting
        the clock."""
        cfg = self.cfg
        fp = self.fault_plan
        if telemetry is None:
            from repro.telemetry import TelemetrySession
            telemetry = TelemetrySession()
        for r in range(start_round, cfg.rounds):
            with telemetry.span("schedule", round=r):
                plan = self._begin_round(r)
            if plan is not None:
                with telemetry.span("dispatch", round=r):
                    deltas, losses, l2s = self._train(plan)
                    deltas = self._corrupt_deltas(r, plan, deltas)
                with telemetry.span("fetch", round=r):
                    t_end, fresh_updates, stale_updates, stale_taus, \
                        agg_lids = \
                        self._collect_updates(r, plan, deltas, losses, l2s)
                    if fresh_updates or stale_updates:
                        agg_out = self._aggregate(r, agg_lids, fresh_updates,
                                                  stale_updates, stale_taus)
                        if agg_out is not None:
                            self._apply_update(agg_out)
                with telemetry.span("eval", round=r):
                    self._record_round(r, plan.t_now, t_end,
                                       len(plan.chosen), len(fresh_updates),
                                       len(stale_updates), progress=progress)
                if self._target_reached():
                    self.acct.stopped_early = True
                    break
            if checkpoint_path and checkpoint_every and \
                    (r + 1) % checkpoint_every == 0 and r + 1 < cfg.rounds:
                from repro.checkpoint.state import save_engine_snapshot
                with telemetry.span("checkpoint", round=r + 1):
                    save_engine_snapshot(checkpoint_path, self, r + 1)
            if fp is not None and fp.crash_due(r):
                telemetry.event("crash", round=int(r), mode=fp.crash_mode)
                telemetry.flush()
                fp.trigger_crash(r)
        return self._finalize()
