"""Event-driven FL round engine reproducing the paper's methodology (§5.1).

Supports the paper's experimental settings:
  OC — over-commit selection by 30% and wait for the first N_t updates;
  DL — fixed reporting deadline, aggregate whatever arrived.
SAFA semantics (select-all + target-ratio round end + bounded-staleness cache)
and RELAY semantics (IPS + APT + SAA with Eq. 2 weights) are both expressible.

Simulated time is decoupled from wall-clock: device durations come from the
heterogeneity profiles, availability from the trace substrate, and every
round's cohort trains in one vmapped JAX call.

Two substrates, same semantics (parity-tested in tests/test_fastpath_parity.py):

  fast path (default) — participant updates are flat (n, D) fp32 rows from the
  compiled cohort-training program all the way to aggregation (unflattened
  once per round to apply the server step); availability queries go through
  the struct-of-arrays ``TraceBank``/``ForecasterBank`` with batched
  searchsorted/bincount math instead of per-learner Python objects;

  legacy path (``fast_path=False``) — the original per-learner scalar loops
  and pytree shuffling, kept as the parity/benchmark baseline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import numpy as np

from repro.core import aggregation as agg
from repro.core.aggregation import (fedavg_apply, stale_synchronous_aggregate,
                                    stale_synchronous_aggregate_flat,
                                    unflatten_update, yogi_apply, yogi_init)
from repro.core.apt import AdaptiveParticipantTarget
from repro.core.availability import AvailabilityForecaster, ForecasterBank
from repro.core.selection import SELECTORS, LearnerView, OortSelector, PrioritySelector
from repro.sim import devices as dev
from repro.sim import learner as ln
from repro.sim import partition as part
from repro.sim import traces as tr
from repro.sim.metrics import Accounting, RoundRecord

HOUR = 3600.0


@functools.lru_cache(maxsize=8)
def _fedavg_flat_fn(spec):
    """Jitted unflatten+FedAvg step, cached per flat spec so every Simulator
    instance with the same model shares one compiled program."""
    return jax.jit(lambda p, flat, lr: fedavg_apply(
        p, unflatten_update(flat, spec), lr))


@functools.lru_cache(maxsize=8)
def _unflatten_fn(spec):
    return jax.jit(lambda flat: unflatten_update(flat, spec))


@dataclasses.dataclass
class SimConfig:
    benchmark: str = "speech"
    mapping: str = "uniform"          # uniform | fedscale | label_{balanced,uniform,zipf}
    n_learners: int = 200
    rounds: int = 200
    selector: str = "random"          # random | oort | priority | safa
    aggregator: str = "fedavg"        # fedavg | yogi
    scaling_rule: str = "relay"       # equal | dynsgd | adasgd | relay
    beta: float = 0.35                # Eq. 2 averaging weight
    saa: bool = False                 # accept stale updates
    staleness_threshold: Optional[int] = None   # None = unbounded (RELAY default)
    setting: str = "OC"               # OC | DL
    deadline: float = 100.0           # DL reporting deadline (seconds)
    n_target: int = 10
    overcommit: float = 1.3           # OC over-commit factor
    safa_target_ratio: float = 0.1    # SAFA round-end fraction
    apt: bool = False
    dynamic_availability: bool = True
    hardware_scenario: str = "HS1"
    local_steps: int = 5
    local_batch: int = 16
    local_lr: float = 0.05
    prox_mu: float = 0.0              # FedProx proximal term (0 = plain FedAvg)
    server_lr: float = 1.0
    model_mbits: float = 50.0         # update size on the wire
    eval_every: int = 10
    selection_window: float = 5.0
    seed: int = 0
    use_agg_kernel: bool = False      # route aggregation through the Pallas kernel
    fast_path: bool = True            # flat (n, D) updates + TraceBank/ForecasterBank


@dataclasses.dataclass
class _InFlight:
    learner_id: int
    origin_round: int
    arrival: float
    duration: float
    delta: object                     # flat (D,) fp32 row (fast) or pytree (legacy)
    stat_util: float


class Simulator:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        x_tr, y_tr, x_te, y_te = part.make_dataset(cfg.benchmark, self.rng)
        shards = part.partition(y_tr, cfg.n_learners, cfg.mapping, self.rng)
        self.data = part.FederatedDataset(cfg.benchmark, x_tr, y_tr, x_te, y_te, shards)
        self.profiles = dev.sample_profiles(cfg.n_learners, self.rng,
                                            cfg.hardware_scenario)
        self.traces = tr.make_traces(cfg.n_learners, self.rng,
                                     dynamic=cfg.dynamic_availability)
        # per-learner round duration is config-determined: compute it once
        self.durations = np.array([
            p.round_duration(cfg.local_steps * cfg.local_batch, 1, cfg.model_mbits)
            for p in self.profiles])
        if cfg.fast_path:
            self.trace_bank = tr.TraceBank(self.traces)
            self.fbank = ForecasterBank(cfg.n_learners)
            self.forecasters = None
        else:
            self.trace_bank = None
            self.fbank = None
            self.forecasters = [AvailabilityForecaster() for _ in range(cfg.n_learners)]
        self._warmup_forecasters()
        sel_cls = SELECTORS[cfg.selector]
        self.selector = sel_cls()
        self.apt = AdaptiveParticipantTarget(n0=cfg.n_target) if cfg.apt else None
        key = jax.random.PRNGKey(cfg.seed)
        self.params = ln.mlp_init(key, self.data.x_train.shape[1], self.data.n_classes)
        self._flat_spec = agg.make_flat_spec(self.params)
        # one compiled unflatten+FedAvg step per round on the fast path (the
        # eager tree ops dispatch a dozen tiny programs per round otherwise)
        self._fedavg_flat = _fedavg_flat_fn(self._flat_spec)
        self._unflatten = _unflatten_fn(self._flat_spec)
        self.opt_state = yogi_init(self.params) if cfg.aggregator == "yogi" else None
        self.acct = Accounting()
        self.stale_cache: list[_InFlight] = []
        self.busy_until = np.zeros(cfg.n_learners)  # device busy training/uploading
        self.mu = cfg.deadline  # initial round-duration estimate

    # ------------------------------------------------------------------
    def _warmup_forecasters(self):
        """Learners have pre-deployment local history (paper App. A step 2)."""
        ts = np.arange(0, 3 * 24 * HOUR, 1800.0)
        if self.cfg.fast_path:
            for tt in ts:                       # one vectorized census per step
                self.fbank.observe_all(tt, self.trace_bank.available_all(tt))
        else:
            for lid, (f, t) in enumerate(zip(self.forecasters, self.traces)):
                for tt in ts:
                    f.observe(tt, t.available(tt))

    def _available_now(self, t_now: float):
        """Idle + available learner ids (ascending), forecasters updated."""
        if self.cfg.fast_path:
            mask = self.trace_bank.available_all(t_now) & (self.busy_until <= t_now)
            available = np.nonzero(mask)[0]
            if len(available):                  # devices log their own state
                self.fbank.observe_batch(available, t_now, 1.0)
            return available
        available = [lid for lid in range(self.cfg.n_learners)
                     if self.traces[lid].available(t_now)
                     and self.busy_until[lid] <= t_now]
        for lid in available:
            self.forecasters[lid].observe(t_now, True)
        return available

    def _views(self, t_now: float, available_ids):
        t0, t1 = t_now + self.mu, t_now + 2 * self.mu
        if self.cfg.fast_path:
            probs = self.fbank.predict_window_batch(available_ids, t0, t1)
            return [LearnerView(lid, availability_prob=float(p),
                                est_duration=self.durations[lid])
                    for lid, p in zip(available_ids, probs)]
        return [LearnerView(lid,
                            availability_prob=self.forecasters[lid].predict_window(t0, t1),
                            est_duration=self.durations[lid])
                for lid in available_ids]

    def _local_round(self, participant_ids, t_now):
        """Run the cohort's local training; returns per-participant results.

        Fast path: deltas come back as stacked flat (n, D) fp32 rows straight
        from the compiled program; legacy: a pytree of stacked leaves.
        """
        cfg = self.cfg
        xs, ys = [], []
        for lid in participant_ids:
            bx, by = ln.sample_local_batches(self.data.shards[lid],
                                             self.data.x_train, self.data.y_train,
                                             cfg.local_steps, cfg.local_batch, self.rng)
            xs.append(bx)
            ys.append(by)
        durs = self.durations[np.asarray(participant_ids)]
        if cfg.fast_path:
            nus = self.trace_bank.next_unavailable_after_batch(participant_ids, t_now)
            rel = nus - t_now
            drop_at = np.where(rel < durs, rel, np.inf)
            # pad the cohort to a power-of-two bucket: one compiled program per
            # bucket instead of per distinct cohort size (rows independent
            # under vmap, so real rows are bit-identical; padding discarded)
            k = len(xs)
            m = agg.bucket_pow2(k)
            bx = np.stack(xs + [xs[0]] * (m - k))
            by = np.stack(ys + [ys[0]] * (m - k))
            deltas, losses, l2s = ln.local_train_cohort_flat(
                self.params, bx, by, cfg.local_lr, cfg.prox_mu)
            deltas = np.asarray(deltas)[:k]     # one device->host copy per round
            return (deltas, np.asarray(losses)[:k], np.asarray(l2s)[:k],
                    durs, drop_at)
        drop_at = []
        for lid, d in zip(participant_ids, durs):
            nu = self.traces[lid].next_unavailable_after(t_now)
            drop_at.append(nu - t_now if nu - t_now < d else np.inf)
        drop_at = np.array(drop_at)
        deltas, losses, l2s = ln.local_train_cohort(
            self.params, np.stack(xs), np.stack(ys), cfg.local_lr, cfg.prox_mu)
        return deltas, np.asarray(losses), np.asarray(l2s), durs, drop_at

    def _aggregate(self, fresh_updates, stale_updates, stale_taus):
        cfg = self.cfg
        fresh_mask = [True] * len(fresh_updates) + [False] * len(stale_updates)
        taus = [0] * len(fresh_updates) + stale_taus
        if cfg.fast_path:
            stacked = np.stack(fresh_updates + stale_updates)
            agg_flat, _ = stale_synchronous_aggregate_flat(
                stacked, fresh_mask, taus, rule=cfg.scaling_rule,
                beta=cfg.beta, use_kernel=cfg.use_agg_kernel)
            return agg_flat
        agg_tree, _ = stale_synchronous_aggregate(
            fresh_updates + stale_updates, fresh_mask, taus,
            rule=cfg.scaling_rule, beta=cfg.beta, use_kernel=cfg.use_agg_kernel,
            compiled=False)  # seed-exact eager baseline
        return agg_tree

    # ------------------------------------------------------------------
    def run(self, progress: bool = False):
        cfg = self.cfg
        t_now = 0.0
        for r in range(cfg.rounds):
            t_now += cfg.selection_window
            available = self._available_now(t_now)
            if not len(available):
                t_now += 60.0
                continue

            # --- target & selection -----------------------------------
            n_t = cfg.n_target
            if self.apt is not None:
                rts = [f.arrival - t_now for f in self.stale_cache
                       if f.arrival > t_now]
                n_t = self.apt.target(rts)
            n_sel = (int(np.ceil(n_t * cfg.overcommit))
                     if cfg.setting == "OC" else n_t)
            views = self._views(t_now, available)
            chosen = self.selector.select(r, views, n_sel, self.rng)
            if not chosen:
                t_now += 60.0
                continue

            # --- local training (simulated durations, real gradients) --
            deltas, losses, l2s, durs, drop_at = self._local_round(chosen, t_now)

            arrivals = []   # (arrival_time, idx into chosen) for non-dropouts
            for i, lid in enumerate(chosen):
                if np.isfinite(drop_at[i]):
                    # device went away mid-round: partial work, always wasted
                    self.acct.charge(float(drop_at[i]), wasted=True)
                    self.busy_until[lid] = t_now + float(drop_at[i])
                else:
                    arrivals.append((t_now + durs[i], i))
                    self.acct.charge(float(durs[i]), wasted=False)
                    self.busy_until[lid] = t_now + float(durs[i])
            arrivals.sort()

            # --- round end time ---------------------------------------
            if cfg.selector == "safa":
                need = max(1, int(np.ceil(cfg.safa_target_ratio * len(chosen))))
                t_end = (arrivals[need - 1][0] if len(arrivals) >= need
                         else t_now + cfg.deadline)
                t_end = min(t_end, t_now + cfg.deadline)
            elif cfg.setting == "OC":
                t_end = (arrivals[n_t - 1][0] if len(arrivals) >= n_t
                         else (arrivals[-1][0] if arrivals else t_now + cfg.deadline))
            else:  # DL
                t_end = t_now + cfg.deadline

            # --- split fresh / straggler ------------------------------
            fresh_updates, fresh_ids = [], []
            for (arr, i) in arrivals:
                lid = chosen[i]
                delta_i = (deltas[i] if cfg.fast_path
                           else jax.tree.map(lambda d: d[i], deltas))
                stat_util = float(cfg.local_steps * cfg.local_batch * l2s[i])
                self.selector.update_feedback(lid, stat_util=stat_util,
                                              duration=durs[i], round_idx=r)
                if arr <= t_end and (cfg.setting == "DL" or cfg.selector == "safa"
                                     or len(fresh_updates) < n_t):
                    fresh_updates.append(delta_i)
                    fresh_ids.append(lid)
                    self.acct.unique.add(lid)
                elif cfg.saa:
                    if cfg.fast_path:
                        # copy: delta_i is a view into the round's padded
                        # (m, D) cohort buffer; caching the view would pin
                        # the whole buffer for the straggler's lifetime
                        delta_i = np.array(delta_i)
                    self.stale_cache.append(_InFlight(lid, r, arr, durs[i],
                                                      delta_i, stat_util))
                else:
                    # already charged as used at dispatch; never aggregated
                    self.acct.mark_wasted(float(durs[i]))

            # --- stale updates landing this round ---------------------
            stale_updates, stale_taus = [], []
            still_waiting = []
            for f in self.stale_cache:
                if f.arrival <= t_end:
                    tau = r - f.origin_round
                    if (cfg.staleness_threshold is None
                            or tau <= cfg.staleness_threshold):
                        stale_updates.append(f.delta)
                        stale_taus.append(tau)
                        self.acct.unique.add(f.learner_id)
                    else:
                        self.acct.mark_wasted(f.duration)
                else:
                    still_waiting.append(f)
            self.stale_cache = still_waiting

            # --- aggregate + server update ----------------------------
            if fresh_updates or stale_updates:
                agg_out = self._aggregate(fresh_updates, stale_updates, stale_taus)
                if cfg.fast_path and cfg.aggregator != "yogi":
                    self.params = self._fedavg_flat(self.params, agg_out,
                                                    cfg.server_lr)
                else:
                    agg_tree = (self._unflatten(agg_out) if cfg.fast_path
                                else agg_out)
                    if cfg.aggregator == "yogi":
                        self.params, self.opt_state = yogi_apply(
                            self.params, agg_tree, self.opt_state)
                    else:
                        self.params = fedavg_apply(self.params, agg_tree,
                                                   cfg.server_lr)

            # --- bookkeeping ------------------------------------------
            duration = t_end - t_now
            self.mu = (self.apt.update_round_duration(duration)
                       if self.apt is not None else
                       0.75 * duration + 0.25 * self.mu)
            rec = RoundRecord(r, t_end, len(chosen), len(fresh_updates),
                              len(stale_updates), self.acct.resource_used,
                              self.acct.resource_wasted, len(self.acct.unique))
            if (r + 1) % cfg.eval_every == 0 or r == cfg.rounds - 1:
                acc, loss = ln.evaluate(self.params, self.data.x_test,
                                        self.data.y_test)
                rec.accuracy, rec.loss = float(acc), float(loss)
                if progress:
                    print(f"  round {r:4d} t={t_end/60:7.1f}min acc={acc:.3f} "
                          f"used={self.acct.resource_used/60:.0f}min "
                          f"wasted={100*self.acct.resource_wasted/max(self.acct.resource_used,1e-9):.0f}%")
            self.acct.records.append(rec)
            t_now = t_end

        # updates still in flight at the end of training are wasted work
        for f in self.stale_cache:
            self.acct.mark_wasted(f.duration)
        return self.acct
