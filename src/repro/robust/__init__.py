"""Byzantine-resilient aggregation strategies (pluggable robust aggregators).

``SimConfig.aggregator`` selects a strategy from ``ROBUST_AGGREGATORS``;
``robust_key`` maps a config to the static program descriptor the fused
round pipeline, the per-stage sweep executor and the engine's flat/legacy
paths all share.  This is the repo's first strategy-plugin interface —
the selector zoo (ROADMAP item 4) is meant to follow the same shape.
"""
from repro.robust.aggregators import (COORD_KINDS, MASK_KINDS,
                                      ROBUST_AGGREGATORS, krum_select,
                                      robust_key, trimmed_weighted_aggregate)

__all__ = ["ROBUST_AGGREGATORS", "COORD_KINDS", "MASK_KINDS", "robust_key",
           "krum_select", "trimmed_weighted_aggregate"]
