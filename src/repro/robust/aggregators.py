"""Robust aggregation strategies over the flat ``(n, D)`` update operand.

Two strategy styles, both composed with SAA staleness weighting:

* **mask-style** (``krum``, ``multi_krum``, ``norm_median_clip``): the
  strategy computes a survivor mask over rows; the existing SAA
  weights-and-aggregate runs on the survivors.  When the mask keeps every
  valid row the result is bit-identical to plain SAA — that is the
  dynamic half of the bit-parity gate.
* **coordinate-wise** (``trimmed_mean``, ``coord_median``): SAA weights
  ``w`` are computed over the valid rows, each row is rescaled to
  ``y_i = c * w_i * u_i`` (``c`` = valid count, so the untrimmed mean of
  ``y`` equals the SAA weighted aggregate), and a per-coordinate k-trimmed
  mean of ``y`` is taken (robust-of-weighted).  ``coord_median`` is the
  maximal trim ``k = (c-1)//2``.

Every function here is a pure jnp formula shared verbatim by the fused
round program (vmapped over groups), the per-stage sweep executor, and
the engine's flat/legacy paths, so all substrates agree bitwise.

Padding convention: invalid rows are excluded via the ``valid`` mask;
for the coordinate-wise sort they are replaced by ``+inf`` so they land
past the inclusion band ``[k, c-k)`` (appending ``+inf`` rows never
changes which finite values the band selects).  ``NaN`` entries are
scrubbed to ``+inf`` before the sort so the sort-based formula and the
rank-based Pallas kernel agree on ordering.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import staleness as stale

ROBUST_AGGREGATORS = ("saa", "coord_median", "trimmed_mean", "krum",
                      "multi_krum", "norm_median_clip")
MASK_KINDS = ("krum", "multi_krum", "norm_median_clip")
COORD_KINDS = ("trimmed_mean", "coord_median")

# one-line docs + knob names for ``--list-aggregators`` (the knobs are the
# SimConfig fields the kind reads; ``robust_key`` above is the authority on
# when a knob setting changes the compiled program)
_AGG_DOCS = {
    "saa": ("plain SAA staleness-weighted aggregation (baseline)", ()),
    "coord_median": ("per-coordinate median of SAA-weighted rows", ()),
    "trimmed_mean": ("per-coordinate k-trimmed mean of SAA-weighted rows",
                     ("trim_k",)),
    "krum": ("Krum: keep the single closest-neighborhood row", ("krum_f",)),
    "multi_krum": ("Multi-Krum: keep the m best-scored rows",
                   ("krum_f", "multi_krum_m")),
    "norm_median_clip": ("median-norm clip + reject screen",
                         ("guard_clip", "guard_reject_mult")),
}


def describe_aggregators() -> str:
    """Formatted strategy table (``--list-aggregators``)."""
    from repro.core.registry import describe_table
    rows = []
    for kind in ROBUST_AGGREGATORS:
        style = ("mask" if kind in MASK_KINDS
                 else "coord" if kind in COORD_KINDS else "baseline")
        doc, knobs = _AGG_DOCS[kind]
        rows.append((kind, style, ", ".join(knobs) or "-", doc))
    return describe_table(("aggregator", "style", "knobs", "doc"), rows)


def robust_key(cfg) -> Optional[Tuple]:
    """Static robust-program descriptor for a ``SimConfig``.

    Returns ``None`` when the configured aggregator statically reduces to
    the plain SAA program (``saa`` itself, ``trimmed_mean`` with
    ``trim_k<=0``, ``norm_median_clip`` with both screen knobs unset) —
    those configs compile to *today's* program, which is the static half
    of the bit-parity gate.  Otherwise returns a hashable tuple of every
    static parameter the robust program variant needs.
    """
    kind = cfg.aggregator
    if kind == "saa":
        return None
    if kind == "trimmed_mean":
        return None if int(cfg.trim_k) <= 0 else ("trimmed_mean",
                                                  int(cfg.trim_k))
    if kind == "coord_median":
        return ("coord_median",)
    if kind in ("krum", "multi_krum"):
        if kind == "multi_krum" and int(cfg.krum_f) <= 0 \
                and cfg.multi_krum_m is None:
            return None       # m = c - 0 = c keeps every row: statically saa
        m = 1 if kind == "krum" else (
            None if cfg.multi_krum_m is None else int(cfg.multi_krum_m))
        return (kind, int(cfg.krum_f), m)
    if kind == "norm_median_clip":
        if cfg.guard_clip is None and cfg.guard_reject_mult is None:
            return None
        return ("norm_median_clip",
                None if cfg.guard_clip is None else float(cfg.guard_clip),
                None if cfg.guard_reject_mult is None
                else float(cfg.guard_reject_mult))
    raise ValueError(f"unknown aggregator {kind!r} "
                     f"(choose from {ROBUST_AGGREGATORS})")


# -- mask-style ---------------------------------------------------------------

def krum_select(u: jnp.ndarray, valid: jnp.ndarray, *, f: int,
                m: Optional[int]) -> jnp.ndarray:
    """(Multi-)Krum survivor mask for one cell.

    ``u``: ``(n, D)`` rows, ``valid``: ``(n,)`` bool.  Score each valid row
    by the sum of its ``max(c - f - 2, 1)`` smallest squared distances to
    other valid rows (``c`` = valid count); keep the ``m`` best-scored rows
    (``m=None`` → dynamic ``m = c - f``; ``m=1`` is classic Krum).  When
    ``m >= c`` the mask equals ``valid`` — dynamic bit-parity with SAA.
    """
    n = u.shape[0]
    sq = jnp.sum(u * u, axis=-1)
    gram = u @ u.T
    d = sq[:, None] + sq[None, :] - 2.0 * gram
    idx = jnp.arange(n, dtype=jnp.int32)
    pair = valid[:, None] & valid[None, :] & (idx[:, None] != idx[None, :])
    # NaN distances (from nonfinite rows) must not poison sort order.
    d = jnp.where(pair & jnp.isfinite(d), d, jnp.inf)
    ds = jnp.sort(d, axis=1)
    c = jnp.sum(valid.astype(jnp.int32))
    kk = jnp.clip(c - int(f) - 2, 1, n)
    col = jnp.arange(n, dtype=jnp.int32)[None, :]
    score = jnp.sum(jnp.where((col < kk) & jnp.isfinite(ds), ds, 0.0), axis=1)
    # Rows whose neighbour band ran past the finite distances score +inf.
    short = jnp.sum(jnp.isfinite(ds).astype(jnp.int32), axis=1) < kk
    score = jnp.where(valid & ~short, score, jnp.inf)
    m_eff = jnp.clip(c - int(f) if m is None else int(m), 1, n)
    # Rank with index tie-break; invalid rows tie-break behind every valid
    # row so an all-+inf column of scores still selects valid rows first.
    tie = jnp.where(valid, idx, idx + n)
    rank = jnp.sum(((score[None, :] < score[:, None])
                    | ((score[None, :] == score[:, None])
                       & (tie[None, :] < tie[:, None]))).astype(jnp.int32),
                   axis=1)
    return valid & (rank < m_eff)


# -- coordinate-wise ----------------------------------------------------------

def weighted_rows(u: jnp.ndarray, fresh: jnp.ndarray, tau: jnp.ndarray,
                  valid: jnp.ndarray, beta, rule_id):
    """Rescale rows to ``y_i = c * w_i * u_i`` with SAA weights ``w``.

    Invalid rows become ``+inf`` and NaNs are scrubbed to ``+inf`` so both
    the sort-based formula and the rank-based kernel see one ordering.
    Returns ``(y, c)`` with ``c`` the int32 valid count.
    """
    w = stale.staleness_weights_by_id(u, fresh, tau, rule_id,
                                      beta=beta, valid=valid)
    c = jnp.sum(valid.astype(jnp.int32))
    y = c.astype(u.dtype) * w[:, None] * u
    y = jnp.where(valid[:, None], y, jnp.inf)
    return jnp.where(jnp.isnan(y), jnp.inf, y), c


def trimmed_from_sorted(ys: jnp.ndarray, c, k_eff):
    """Mean of the sorted column band ``[k_eff, c - k_eff)`` (shared by the
    sort path and the kernel reference)."""
    n = ys.shape[0]
    ridx = jnp.arange(n, dtype=jnp.int32)[:, None]
    include = (ridx >= k_eff) & (ridx < c - k_eff)
    denom = jnp.maximum(c - 2 * k_eff, 1).astype(ys.dtype)
    return jnp.sum(jnp.where(include, ys, 0.0), axis=0) / denom


def trimmed_weighted_aggregate(u, fresh, tau, valid, beta, rule_id, *,
                               trim_k: int, median: bool):
    """Per-coordinate k-trimmed mean of the SAA-weighted rows for one cell.

    ``median=True`` ignores ``trim_k`` and trims maximally
    (``k = (c-1)//2``; even ``c`` averages the middle pair).  Returns
    ``(aggregate (D,), n_trimmed int32)`` where ``n_trimmed = 2*k_eff``
    counts rows excluded per coordinate band.
    """
    y, c = weighted_rows(u, fresh, tau, valid, beta, rule_id)
    k_half = jnp.maximum((c - 1) // 2, 0)
    k_eff = k_half if median else jnp.minimum(jnp.int32(trim_k), k_half)
    out = trimmed_from_sorted(jnp.sort(y, axis=0), c, k_eff)
    out = jnp.where(c > 0, out, 0.0)
    return out, jnp.where(c > 0, 2 * k_eff, 0)


# -- shared composition: attack -> guard -> robust -> aggregate ---------------
#
# One per-cell function every attacked/robust path runs: the fused round
# body vmaps it over groups, ``robust_sweep_fn`` vmaps it over sweep cells,
# and the engine's flat/legacy paths call the S=1 slice of the *same*
# compiled sweep program — so all substrates share one set of numerics.
# Robust/attacked configs always take the jnp weights path for the SAA
# part (``SimConfig.use_agg_kernel`` only routes the coordinate-wise
# statistic through the ``trimmed_agg`` Pallas kernel), keeping the
# cross-substrate story simple; statically-inactive configs
# (``robust_key``/``attack_key`` both None) never reach this code and
# compile to today's program unchanged.

def _robust_cell(u, fresh, tau, valid, att, beta, rule_id, *, attack, guard,
                 robust, want_y):
    """attack + screen + robust aggregate for one cell.

    Returns ``(out, stats)`` with ``stats`` int32 ``(5,)``:
    ``[n_nonfinite, n_norm_rejected, survivors, robust_rejected,
    robust_trimmed]``.  ``want_y`` (static) returns the kernel operand
    ``(y, k_eff, c)`` instead of the coordinate-wise aggregate so a caller
    can run the trimmed kernel outside the vmap.
    """
    from repro.core import aggregation as agg
    from repro.faults.attacks import apply_attack
    zero = jnp.int32(0)
    if attack is not None:
        kind, scale, z = attack
        u = apply_attack(u, att, valid, kind=kind, scale=scale, z=z)
    n_nf = n_out = zero
    if guard is not None:
        clip, rej = guard
        u, valid, n_nf, n_out, _ = agg.screen_rows(u, valid, clip=clip,
                                                   reject_mult=rej)
    rrej = rtrim = zero
    coord = robust is not None and robust[0] in COORD_KINDS
    if robust is not None and not coord:
        if robust[0] in ("krum", "multi_krum"):
            sel = krum_select(u, valid, f=robust[1], m=robust[2])
            rrej = jnp.sum((valid & ~sel).astype(jnp.int32))
            valid = sel
        else:                                        # norm_median_clip
            _, clip2, rej2 = robust
            u, v2, nf2, out2, ncl2 = agg.screen_rows(u, valid, clip=clip2,
                                                     reject_mult=rej2)
            rrej, rtrim, valid = nf2 + out2, ncl2, v2

    def stats(rt):
        return jnp.stack([n_nf, n_out, jnp.sum(valid.astype(jnp.int32)),
                          rrej, rt])

    if coord:
        median = robust[0] == "coord_median"
        trim_k = 0 if median else robust[1]
        if want_y:
            y, c = weighted_rows(u, fresh, tau, valid, beta, rule_id)
            k_half = jnp.maximum((c - 1) // 2, 0)
            k_eff = k_half if median else jnp.minimum(jnp.int32(trim_k),
                                                      k_half)
            return (y, k_eff, c), stats(jnp.where(c > 0, 2 * k_eff, 0))
        out, rt = trimmed_weighted_aggregate(u, fresh, tau, valid, beta,
                                             rule_id, trim_k=trim_k,
                                             median=median)
        return out, stats(rt)
    out, _ = agg.weights_and_aggregate_by_id(u, fresh, tau, valid, beta,
                                             rule_id)
    return out, stats(rtrim)


@functools.lru_cache(maxsize=64)
def robust_sweep_fn(attack, guard, robust, kernel: bool):
    """Jitted sweep-axis program: ``(u (S,n,D), fresh, tau, valid, att
    (S,n), beta (S,), rule_id (S,)) -> (agg (S,D), stats (S,5))``."""
    coord = robust is not None and robust[0] in COORD_KINDS
    base = functools.partial(_robust_cell, attack=attack, guard=guard,
                             robust=robust, want_y=coord and kernel)
    if not (coord and kernel):
        return jax.jit(jax.vmap(base))

    def f(u, fresh, tau, valid, att, beta, rule_id):
        (y, k_eff, c), st = jax.vmap(base)(u, fresh, tau, valid, att, beta,
                                           rule_id)
        from repro.kernels.trimmed_agg import ops as tops
        return tops.sweep_trimmed_aggregate(y, k_eff, c), st
    return jax.jit(f)


def robust_host_aggregate(stacked, fresh, tau, att, *, attack, guard, robust,
                          use_kernel: bool, beta: float, rule: str,
                          quorum: int = 1, bucketed: bool = True):
    """Engine flat/legacy entry for attacked/robust rounds.

    ``stacked``: (n, D) update rows; ``att``: (n,) attacker mask for this
    round's operand.  Pads like the guarded path and runs the S=1 slice of
    the shared sweep program.  Returns ``(agg (D,), info)``; ``applied`` is
    the guard's quorum verdict (always True when ``guard`` is None).
    """
    from repro.core import aggregation as agg
    n = int(np.shape(stacked)[0])
    u, fr, ta, valid = agg.bucket_pad(stacked, fresh, tau, bucketed=bucketed)
    am = np.zeros(len(valid), bool)
    am[:n] = np.asarray(att, bool)
    fn = robust_sweep_fn(attack, guard, robust, bool(use_kernel))
    out, st = fn(u[None], fr[None], ta[None], valid[None], am[None],
                 np.asarray([beta], np.float32),
                 np.asarray([stale.RULE_ID[rule]], np.int32))
    n_nf, n_out, survivors, rrej, rtrim = [int(x)
                                           for x in jax.device_get(st[0])]
    applied = guard is None or survivors >= max(int(quorum), 1)
    info = {"nonfinite": n_nf, "norm": n_out, "survivors": survivors,
            "applied": applied, "robust_rejected": rrej,
            "robust_trimmed": rtrim}
    return out[0], info
