"""Synthetic token pipeline for the LM training paths.

Deterministic, seedable, shardable.  Sequences follow a Zipf-ish unigram
distribution with short-range repetition structure so that a small model's
loss actually decreases (useful for the end-to-end examples/tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _unigram_logits(vocab: int) -> np.ndarray:
    return -1.1 * np.log(np.arange(1, vocab + 1))


def token_batches(vocab: int, batch: int, seq_len: int, *, seed: int = 0):
    """Infinite generator of {"tokens", "labels"} numpy batches."""
    rng = np.random.default_rng(seed)
    p = np.exp(_unigram_logits(vocab))
    p /= p.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=p)
        # inject copy structure: second half repeats first half with noise
        half = seq_len // 2
        toks[:, half:half * 2] = toks[:, :half]
        flips = rng.random((batch, half)) < 0.1
        toks[:, half:half * 2][flips] = rng.choice(vocab, size=int(flips.sum()), p=p)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


def federated_token_shards(vocab: int, n_learners: int, samples_per_learner: int,
                           seq_len: int, *, seed: int = 0, skew: float = 0.0):
    """Per-learner token corpora; ``skew`` biases each learner's unigram
    distribution (the LM analogue of label-limited mapping)."""
    rng = np.random.default_rng(seed)
    base = np.exp(_unigram_logits(vocab))
    shards = []
    for i in range(n_learners):
        p = base.copy()
        if skew > 0:
            boost = rng.choice(vocab, size=max(1, vocab // 10), replace=False)
            p[boost] *= 1 + 10 * skew
        p /= p.sum()
        toks = rng.choice(vocab, size=(samples_per_learner, seq_len + 1), p=p)
        shards.append({"tokens": toks[:, :-1].astype(np.int32),
                       "labels": toks[:, 1:].astype(np.int32)})
    return shards
