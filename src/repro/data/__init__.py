from repro.data.synthetic import token_batches, federated_token_shards  # noqa: F401
