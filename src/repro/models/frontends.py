"""Modality frontend stubs (harness carve-out).

The VLM vision encoder (InternViT) and audio codec (EnCodec) are NOT implemented;
``input_specs()`` supplies precomputed patch embeddings / discrete codec tokens of
the right shape.  This module only provides the projector that maps frontend
embeddings into the decoder's d_model.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import dense_init


def frontend_init(key, d_frontend: int, d_model: int, dtype):
    return {"proj": dense_init(key, (d_frontend, d_model), dtype)}


def project_frontend(params, embeds):
    """embeds: (B, P, d_frontend) -> (B, P, d_model)."""
    return embeds.astype(params["proj"].dtype) @ params["proj"]
