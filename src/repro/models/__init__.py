"""Model zoo: composable decoder-only stacks covering the assigned architectures.

All models are functional JAX: ``init(cfg, key) -> params`` pytrees and pure
``forward / prefill / decode`` functions.  Layer stacks are ``lax.scan``-ed over
stacked per-layer parameters so HLO size is depth-independent.
"""
from repro.models.transformer import (  # noqa: F401
    ModelConfig,
    init_params,
    forward,
    init_decode_state,
    decode_step,
    prefill,
)
