"""RWKV6 "Finch" block: attention-free time mixing with data-dependent decay.

Recurrence (per head, head dim N, state S in R^{N x N}):
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + lora_w(ddlerp(x)))) data-dependent per channel.

The sequential form here is the oracle for the chunked Pallas kernel in
``repro.kernels.wkv6``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

DDLERP_COMPONENTS = ("r", "k", "v", "w", "g")


def rwkv6_init(key, d_model: int, n_heads: int, *, lora_rank: int = 32,
               w_lora_rank: int = 64, dtype=jnp.bfloat16):
    N = d_model // n_heads
    ks = iter(jax.random.split(key, 24))
    p = {
        "mu_x": jnp.zeros((d_model,), dtype),
        "w0": jnp.full((d_model,), -6.0, jnp.float32),
        "u": jnp.zeros((n_heads, N), jnp.float32),
        "ln_x_scale": jnp.ones((d_model,), jnp.float32),
    }
    for c in DDLERP_COMPONENTS:
        p[f"mu_{c}"] = jnp.zeros((d_model,), dtype)
        rank = w_lora_rank if c == "w" else lora_rank
        p[f"lora_{c}_a"] = dense_init(next(ks), (d_model, rank), dtype)
        p[f"lora_{c}_b"] = dense_init(next(ks), (rank, d_model), dtype)
    for c in ("r", "k", "v", "g", "o"):
        p[f"w_{c}"] = dense_init(next(ks), (d_model, d_model), dtype)
    return p


def _ddlerp(params, x, x_prev):
    """Data-dependent lerp producing the 5 mixed inputs (r, k, v, w, g)."""
    xx = x_prev - x
    base = x + xx * params["mu_x"]
    outs = {}
    for c in DDLERP_COMPONENTS:
        lo = jnp.tanh(base @ params[f"lora_{c}_a"]) @ params[f"lora_{c}_b"]
        outs[c] = x + xx * (params[f"mu_{c}"] + lo)
    return outs


def _project(params, mixed, n_heads):
    d = mixed["r"].shape[-1]
    N = d // n_heads
    shp = mixed["r"].shape[:-1] + (n_heads, N)
    r = (mixed["r"] @ params["w_r"]).reshape(shp)
    k = (mixed["k"] @ params["w_k"]).reshape(shp)
    v = (mixed["v"] @ params["w_v"]).reshape(shp)
    g = jax.nn.silu(mixed["g"] @ params["w_g"])
    w_log = params["w0"] + (jnp.tanh(mixed["w"] @ params[f"lora_w_a"])
                            @ params["lora_w_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(shp)  # decay in (0, 1)
    return r, k, v, w, g


def _group_norm(x, scale, n_heads, eps=1e-5):
    # per-head LayerNorm on the flattened (H*N) output, as in RWKV6
    shp = x.shape
    xh = x.reshape(shp[:-1] + (n_heads, shp[-1] // n_heads)).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * scale).astype(x.dtype)


def wkv6_scan(r, k, v, w, u, state0=None):
    """Sequential WKV6 recurrence. r,k,v,w: (B, S, H, N); u: (H, N).

    Returns (y: (B, S, H, N), final_state: (B, H, N, N)).
    """
    B, S, H, N = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    s0 = jnp.zeros((B, H, N, N), jnp.float32) if state0 is None else state0

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, N)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B, H, N, N)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., :, None] * kv)
        s_new = w_t[..., :, None] * s + kv
        return s_new, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    from repro.models.mamba import _chunked_scan
    s_fin, ys = _chunked_scan(step, s0, xs, S)
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), s_fin


def rwkv6_forward(params, x, *, n_heads, state=None, use_kernel=False):
    """Full-sequence RWKV6 time mixing. x: (B, S, d).

    state (decode continuation): {"x_prev": (B, d), "wkv": (B, H, N, N)} or None.
    Returns (out, new_state).
    """
    B, S, d = x.shape
    x_prev_tok = x[:, :-1]
    first = state["x_prev"][:, None] if state is not None else jnp.zeros_like(x[:, :1])
    x_prev = jnp.concatenate([first, x_prev_tok], axis=1)
    mixed = _ddlerp(params, x, x_prev)
    r, k, v, w, g = _project(params, mixed, n_heads)
    u = params["u"]
    s0 = state["wkv"] if state is not None else None
    if use_kernel:
        from repro.kernels.wkv6 import ops as wkv_ops
        y, s_fin = wkv_ops.wkv6(r, k, v, w, u, state0=s0)
    else:
        y, s_fin = wkv6_scan(r, k, v, w, u, state0=s0)
    y = _group_norm(y.reshape(B, S, d), params["ln_x_scale"], n_heads)
    out = (y * g) @ params["w_o"]
    new_state = {"x_prev": x[:, -1], "wkv": s_fin}
    return out, new_state


def rwkv6_decode(params, x, state, *, n_heads):
    """Single-token step; x: (B, 1, d), state as above."""
    return rwkv6_forward(params, x, n_heads=n_heads, state=state)
