"""Primitive layers shared by every architecture: norms, RoPE, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun-style), the standard for LLM stacks."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    # Norm statistics in fp32 for stability regardless of activation dtype.
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh) rotated pairwise; positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    sin = jnp.sin(angles)[..., :, None, :]  # (..., S, 1, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (the universal dense FFN across the assigned archs)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(params, x):
    gate = jax.nn.silu(x @ params["w_gate"])
    up = x @ params["w_up"]
    return (gate * up) @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init_params(key, vocab: int, d_model: int, dtype):
    return {"embedding": embed_init(key, (vocab, d_model), dtype)}


def embed_lookup(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def lm_head(params, x, tie_embedding: bool):
    w = params["embedding"].T if tie_embedding else params["w_out"]
    return x @ w.astype(x.dtype)
