"""Mamba (S6) selective-state-space block, used by the Jamba hybrid layers.

h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D * x_t
with (dt, B, C) data-dependent.  Sequential scan form; O(1) decode state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def mamba_init(key, d_model: int, *, d_state: int = 16, expand: int = 2,
               dt_rank: int | None = None, conv_width: int = 4, dtype=jnp.bfloat16):
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = iter(jax.random.split(key, 8))
    A = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                         (d_inner, d_state))
    return {
        "w_in": dense_init(next(ks), (d_model, 2 * d_inner), dtype),
        "conv_w": dense_init(next(ks), (conv_width, d_inner), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_x": dense_init(next(ks), (d_inner, dt_rank + 2 * d_state), dtype),
        "w_dt": dense_init(next(ks), (dt_rank, d_inner), dtype),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(next(ks), (d_inner, d_model), dtype),
    }


SCAN_CHUNK = 256  # remat granularity of the time scan (bounds bwd residuals)


def _chunked_scan(step, h0, xs_t, S):
    """scan over time in rematerialized chunks: backward residuals are O(chunk)
    instead of O(S) — the recurrent-layer analogue of per-layer remat."""
    if S % SCAN_CHUNK != 0 or S <= SCAN_CHUNK:
        return jax.lax.scan(step, h0, xs_t)
    n_ch = S // SCAN_CHUNK

    def chunk_body(h, chunk_xs):
        return jax.lax.scan(step, h, chunk_xs)

    chunked = tuple(t.reshape((n_ch, SCAN_CHUNK) + t.shape[1:]) for t in xs_t)
    h_fin, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, chunked)
    return h_fin, ys.reshape((S,) + ys.shape[2:])


def _conv_step_weights(params):
    return params["conv_w"], params["conv_b"]


def _ssm_inputs(params, xs, dt_rank, d_state):
    """xs: (B, S, d_inner) post-conv activations -> (dt, Bmat, Cmat)."""
    xdb = xs @ params["w_x"]
    dt_low, Bm, Cm = jnp.split(xdb, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus((dt_low @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])  # (B,S,d_inner)
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def mamba_forward(params, x, *, d_state: int = 16, expand: int = 2,
                  dt_rank: int | None = None, conv_width: int = 4, state=None):
    """x: (B, S, d). state: {"conv": (B, W-1, d_inner), "ssm": (B, d_inner, N)} | None.

    Returns (out, new_state).
    """
    B, S, d = x.shape
    d_inner = expand * d
    dt_rank = dt_rank or max(1, d // 16)
    xz = x @ params["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)  # (B, S, d_inner) each

    # causal conv1d over time
    conv_prev = (state["conv"] if state is not None
                 else jnp.zeros((B, conv_width - 1, d_inner), xs.dtype))
    xpad = jnp.concatenate([conv_prev, xs], axis=1)  # (B, S+W-1, d_inner)
    cw, cb = _conv_step_weights(params)
    xc = sum(xpad[:, i:i + S] * cw[i] for i in range(conv_width)) + cb
    xc = jax.nn.silu(xc)
    new_conv = xpad[:, S:S + conv_width - 1] if S >= conv_width - 1 else xpad[:, -(conv_width - 1):]

    dt, Bm, Cm = _ssm_inputs(params, xc, dt_rank, d_state)
    A = -jnp.exp(params["A_log"])  # (d_inner, N)
    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, d_inner, d_state), jnp.float32))
    xcf = xc.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,d_inner), (B,d_inner), (B,N), (B,N)
        dA = jnp.exp(dt_t[..., None] * A)                       # (B,d_inner,N)
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h_new = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h_new, C_t)
        return h_new, y

    xs_t = (xcf.transpose(1, 0, 2), dt.transpose(1, 0, 2),
            Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h_fin, ys = _chunked_scan(step, h0, xs_t, S)
    y = ys.transpose(1, 0, 2) + params["D"] * xcf
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["w_out"]
    return out, {"conv": new_conv, "ssm": h_fin}


def mamba_decode(params, x, state, **kw):
    return mamba_forward(params, x, state=state, **kw)
