"""Composable decoder-only model covering all assigned architectures.

A model is a sequence of *layers*, each layer = (mixer, ffn) where
mixer in {gqa attention, MLA attention, mamba, rwkv6} and ffn in {dense SwiGLU,
MoE, none (rwkv6 uses its own channel-mix = dense here)}.

Layers are grouped into an optional unrolled *prefix* (e.g. DeepSeek's first
dense layer) followed by a periodic *super-block* that is ``lax.scan``-ed over
its repeats (Jamba: 8-layer super-block x 4; homogeneous stacks: 1-layer block
x n_layers).  HLO size is therefore depth-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import shard_hints
from repro.models import mamba as mb
from repro.models import moe as moe_lib
from repro.models import rwkv6 as rw
from repro.models import frontends as fr


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str = "custom"
    family: str = "dense"            # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # citation for the config
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    d_head: Optional[int] = None
    qkv_bias: bool = False
    attn_type: str = "gqa"           # gqa | mla
    window: Optional[int] = None     # sliding-window width (None = full causal)
    rope_theta: float = 1e4
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    first_k_dense: int = 0
    moe_every: int = 1               # MoE ffn on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    moe_group_size: int = 4096
    # --- hybrid / ssm ---
    block_pattern: Tuple[str, ...] = ("attn",)  # mixer per layer, tiled
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv_width: int = 4
    rwkv_lora_rank: int = 32
    rwkv_w_lora_rank: int = 64
    # --- frontend ---
    frontend: Optional[str] = None   # "vision" | None (audio uses plain tokens)
    d_frontend: int = 1024
    n_frontend_tokens: int = 256
    # --- misc ---
    tie_embeddings: bool = False
    vocab_pad_to: int = 0            # pad vocab rows so "model" axis divides
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.bfloat16
    use_kernels: bool = False
    mla_absorb: bool = False         # absorbed-matmul MLA decode (beyond-paper)
    loss_chunk: int = 0              # >0: chunk the LM loss over sequence
    remat: bool = False              # activation checkpointing on super-blocks

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to <= 0:
            return self.vocab_size
        m = self.vocab_pad_to
        return (self.vocab_size + m - 1) // m * m

    def mixer_of(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def ffn_of(self, layer_idx: int) -> str:
        if self.mixer_of(layer_idx) == "rwkv6":
            return "dense"  # channel-mix approximated by a dense SwiGLU
        if (self.moe and layer_idx >= self.first_k_dense
                and layer_idx % self.moe_every == self.moe_offset):
            return "moe"
        return "dense"

    def layer_spec(self, layer_idx: int) -> Tuple[str, str]:
        return (self.mixer_of(layer_idx), self.ffn_of(layer_idx))

    def segment_plan(self) -> Tuple[list, list, int]:
        """Returns (prefix_specs, period_specs, n_repeats)."""
        prefix = [self.layer_spec(i) for i in range(self.first_k_dense)]
        rest = self.n_layers - self.first_k_dense
        period = 1
        # the super-block period must tile both the mixer pattern and moe cadence
        for cand in (len(self.block_pattern), self.moe_every):
            period = _lcm(period, cand)
        assert rest % period == 0, (
            f"{self.arch_id}: {rest} layers not divisible by super-block {period}")
        specs = [self.layer_spec(self.first_k_dense + i) for i in range(period)]
        return prefix, specs, rest // period


def _lcm(a, b):
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------


def _layer_init(cfg: ModelConfig, key, spec):
    mixer, ffn = spec
    kmix, kffn, kn1, kn2 = jax.random.split(key, 4)
    p = {"norm1": L.rmsnorm_init(cfg.d_model), "norm2": L.rmsnorm_init(cfg.d_model)}
    if mixer == "attn":
        if cfg.attn_type == "mla":
            p["mixer"] = attn.mla_init(
                kmix, cfg.d_model, cfg.n_heads, kv_lora_rank=cfg.kv_lora_rank,
                qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
                v_head_dim=cfg.v_head_dim, dtype=cfg.param_dtype)
        else:
            p["mixer"] = attn.gqa_init(kmix, cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.head_dim,
                                       cfg.qkv_bias, cfg.param_dtype)
    elif mixer == "mamba":
        p["mixer"] = mb.mamba_init(kmix, cfg.d_model, d_state=cfg.mamba_d_state,
                                   expand=cfg.mamba_expand,
                                   conv_width=cfg.mamba_conv_width,
                                   dtype=cfg.param_dtype)
    elif mixer == "rwkv6":
        p["mixer"] = rw.rwkv6_init(kmix, cfg.d_model, cfg.n_heads,
                                   lora_rank=cfg.rwkv_lora_rank,
                                   w_lora_rank=cfg.rwkv_w_lora_rank,
                                   dtype=cfg.param_dtype)
    else:
        raise ValueError(mixer)
    if ffn == "dense":
        p["ffn"] = L.mlp_init(kffn, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    elif ffn == "moe":
        p["ffn"] = moe_lib.moe_init(kffn, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                                    cfg.n_shared_experts, cfg.shared_d_ff or cfg.moe_d_ff,
                                    cfg.param_dtype)
    return p


def _mixer_forward(cfg, spec, p, x, positions, state):
    """Full-sequence mixer. Returns (out, new_state_or_cache)."""
    mixer, _ = spec
    if mixer == "attn":
        if cfg.attn_type == "mla":
            out, kv = attn.mla_forward(
                p, x, positions, n_heads=cfg.n_heads, kv_lora_rank=cfg.kv_lora_rank,
                qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
                v_head_dim=cfg.v_head_dim, rope_theta=cfg.rope_theta, window=cfg.window)
            new_state = {"c_kv": kv[0], "k_rope": kv[1],
                         "pos": positions.astype(jnp.int32)}
        else:
            out, kv = attn.gqa_forward(
                p, x, positions, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.head_dim, rope_theta=cfg.rope_theta, window=cfg.window,
                use_kernel=cfg.use_kernels)
            new_state = {"k": kv[0], "v": kv[1], "pos": positions.astype(jnp.int32)}
        return out, new_state
    if mixer == "mamba":
        return mb.mamba_forward(p, x, d_state=cfg.mamba_d_state,
                                expand=cfg.mamba_expand,
                                conv_width=cfg.mamba_conv_width, state=state)
    if mixer == "rwkv6":
        return rw.rwkv6_forward(p, x, n_heads=cfg.n_heads, state=state,
                                use_kernel=cfg.use_kernels)
    raise ValueError(mixer)


def _mixer_decode(cfg, spec, p, x, position, state):
    mixer, _ = spec
    if mixer == "attn":
        if cfg.attn_type == "mla":
            return attn.mla_decode(
                p, x, position, state, n_heads=cfg.n_heads,
                kv_lora_rank=cfg.kv_lora_rank, qk_nope_dim=cfg.qk_nope_dim,
                qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
                rope_theta=cfg.rope_theta, window=cfg.window,
                absorbed=cfg.mla_absorb)
        return attn.gqa_decode(p, x, position, state, n_heads=cfg.n_heads,
                               n_kv_heads=cfg.n_kv_heads, d_head=cfg.head_dim,
                               rope_theta=cfg.rope_theta, window=cfg.window)
    if mixer == "mamba":
        return mb.mamba_decode(p, x, state, d_state=cfg.mamba_d_state,
                               expand=cfg.mamba_expand,
                               conv_width=cfg.mamba_conv_width)
    if mixer == "rwkv6":
        return rw.rwkv6_decode(p, x, state, n_heads=cfg.n_heads)
    raise ValueError(mixer)


def _ffn_forward(cfg, spec, p, x):
    """Returns (out, aux_loss)."""
    _, ffn = spec
    if ffn == "dense":
        return L.mlp(p["ffn"], x), jnp.zeros((), jnp.float32)
    return moe_lib.moe_forward(p["ffn"], x, n_experts=cfg.n_experts,
                               top_k=cfg.top_k, group_size=cfg.moe_group_size)


def _layer_forward(cfg, spec, p, x, positions, state):
    h, new_state = _mixer_forward(cfg, spec, p["mixer"],
                                  L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                                  positions, state)
    x = x + h
    h, aux = _ffn_forward(cfg, spec, p, L.rmsnorm(p["norm2"], x, cfg.norm_eps))
    return x + h, new_state, aux


def _layer_decode(cfg, spec, p, x, position, state):
    h, new_state = _mixer_decode(cfg, spec, p["mixer"],
                                 L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                                 position, state)
    x = x + h
    h, aux = _ffn_forward(cfg, spec, p, L.rmsnorm(p["norm2"], x, cfg.norm_eps))
    return x + h, new_state, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key):
    prefix, specs, n_rep = cfg.segment_plan()
    keys = jax.random.split(key, 4 + len(prefix))
    params = {"embed": L.embed_init_params(keys[0], cfg.padded_vocab, cfg.d_model,
                                           cfg.param_dtype),
              "final_norm": L.rmsnorm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["head"] = {"w_out": L.dense_init(keys[1],
                                                (cfg.d_model, cfg.padded_vocab),
                                                cfg.param_dtype)}
    if cfg.frontend == "vision":
        params["frontend"] = fr.frontend_init(keys[2], cfg.d_frontend, cfg.d_model,
                                              cfg.param_dtype)
    params["prefix"] = [
        _layer_init(cfg, keys[4 + i], spec) for i, spec in enumerate(prefix)]

    def superblock_init(k):
        ks = jax.random.split(k, len(specs))
        return {f"sub{i}": _layer_init(cfg, ks[i], spec)
                for i, spec in enumerate(specs)}

    rep_keys = jax.random.split(keys[3], n_rep)
    params["stack"] = jax.vmap(superblock_init)(rep_keys)
    return params


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed_inputs(cfg, params, batch):
    """batch: {"tokens": (B, S_text)[, "frontend_embeds": (B, P, d_frontend)]}"""
    x = L.embed_lookup(params["embed"], batch["tokens"])
    if cfg.frontend == "vision":
        fe = fr.project_frontend(params["frontend"], batch["frontend_embeds"])
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
    return x


def _logits(cfg, params, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].T.astype(x.dtype)
    else:
        logits = x @ params["head"]["w_out"].astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad rows out of the softmax support (sharded-safe: iota compare)
        pad_mask = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params, batch, *, return_states: bool = False):
    """Returns (logits or final hidden, aux_loss, states)."""
    prefix, specs, n_rep = cfg.segment_plan()
    x = shard_hints.constrain_activations(_embed_inputs(cfg, params, batch))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    aux_total = jnp.zeros((), jnp.float32)
    prefix_states = []
    for p, spec in zip(params["prefix"], prefix):
        x, st, aux = _layer_forward(cfg, spec, p, x, positions, None)
        aux_total += aux
        prefix_states.append(st if return_states else None)

    def superblock(carry, p_slice):
        x, aux_acc = carry
        states = {}
        for i, spec in enumerate(specs):
            x, st, aux = _layer_forward(cfg, spec, p_slice[f"sub{i}"], x,
                                        positions, None)
            aux_acc = aux_acc + aux
            states[f"sub{i}"] = st if return_states else 0
        return (shard_hints.constrain_activations(x), aux_acc), states

    block_fn = jax.checkpoint(superblock) if cfg.remat else superblock
    (x, aux_total), stack_states = jax.lax.scan(
        block_fn, (x, aux_total), params["stack"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    states = {"prefix": prefix_states, "stack": stack_states} if return_states else None
    return x, aux_total, states


def lm_loss(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01):
    """Cross-entropy next-token loss (labels = batch["labels"])."""
    x, aux, _ = forward(cfg, params, batch)
    labels = batch["labels"]
    # only score the token positions (frontend positions carry no labels)
    if cfg.frontend == "vision":
        x = x[:, -labels.shape[1]:]

    def chunk_loss(xc, yc):
        logits = _logits(cfg, params, xc).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return (logz - gold).sum()

    B, S, _ = x.shape
    if cfg.loss_chunk and S > cfg.loss_chunk and S % cfg.loss_chunk == 0:
        nch = S // cfg.loss_chunk
        xs = x.reshape(B, nch, cfg.loss_chunk, -1).transpose(1, 0, 2, 3)
        ys = labels.reshape(B, nch, cfg.loss_chunk).transpose(1, 0, 2)
        total = jax.lax.scan(
            lambda c, xy: (c + chunk_loss(*xy), None), jnp.zeros((), jnp.float32),
            (xs, ys))[0]
    else:
        total = chunk_loss(x, labels)
    return total / (B * S) + aux_weight * aux


def prefill(cfg: ModelConfig, params, batch):
    """Run the full prompt; returns (last-position logits, states for decode)."""
    x, aux, states = forward(cfg, params, batch, return_states=True)
    logits = _logits(cfg, params, x[:, -1:])[..., :cfg.vocab_size]
    return logits, states


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def _mixer_state_shape(cfg, spec, B, cache_len):
    mixer, _ = spec
    dt = cfg.param_dtype
    if mixer == "attn":
        if cfg.attn_type == "mla":
            return {"c_kv": jnp.zeros((B, cache_len, cfg.kv_lora_rank), dt),
                    "k_rope": jnp.zeros((B, cache_len, cfg.qk_rope_dim), dt),
                    "pos": jnp.full((B, cache_len), -1, jnp.int32)}
        return {"k": jnp.zeros((B, cache_len, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((B, cache_len, cfg.n_kv_heads, cfg.head_dim), dt),
                "pos": jnp.full((B, cache_len), -1, jnp.int32)}
    if mixer == "mamba":
        d_inner = cfg.mamba_expand * cfg.d_model
        return {"conv": jnp.zeros((B, cfg.mamba_conv_width - 1, d_inner), dt),
                "ssm": jnp.zeros((B, d_inner, cfg.mamba_d_state), jnp.float32)}
    if mixer == "rwkv6":
        N = cfg.d_model // cfg.n_heads
        return {"x_prev": jnp.zeros((B, cfg.d_model), dt),
                "wkv": jnp.zeros((B, cfg.n_heads, N, N), jnp.float32)}
    raise ValueError(mixer)


def init_decode_state(cfg: ModelConfig, B: int, max_seq: int):
    """Allocate the serve-time state. Attention caches are ring buffers of
    ``min(max_seq, window)`` slots when a sliding window is configured."""
    cache_len = max_seq if cfg.window is None else min(max_seq, cfg.window)
    prefix, specs, n_rep = cfg.segment_plan()
    state = {"prefix": [_mixer_state_shape(cfg, s, B, cache_len) for s in prefix]}

    def one(_):
        return {f"sub{i}": _mixer_state_shape(cfg, s, B, cache_len)
                for i, s in enumerate(specs)}

    state["stack"] = jax.vmap(one)(jnp.arange(n_rep))
    return state


def decode_step(cfg: ModelConfig, params, state, tokens, position):
    """One-token decode. tokens: (B,), position: (B,) absolute positions.

    Returns (logits (B, vocab), new_state).
    """
    prefix, specs, n_rep = cfg.segment_plan()
    x = L.embed_lookup(params["embed"], tokens[:, None])

    new_prefix = []
    for p, spec, st in zip(params["prefix"], prefix, state["prefix"]):
        x, st_new, _ = _layer_decode(cfg, spec, p, x, position, st)
        new_prefix.append(st_new)

    def superblock(x, slc):
        p_slice, st_slice = slc
        new_states = {}
        for i, spec in enumerate(specs):
            x, st_new, _ = _layer_decode(cfg, spec, p_slice[f"sub{i}"], x,
                                         position, st_slice[f"sub{i}"])
            new_states[f"sub{i}"] = st_new
        return x, new_states

    x, new_stack = jax.lax.scan(superblock, x, (params["stack"], state["stack"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(cfg, params, x)[:, 0, :cfg.vocab_size]
    return logits, {"prefix": new_prefix, "stack": new_stack}
