"""Attention variants: GQA (covers MHA), sliding-window, and MLA (DeepSeek-V2).

Full-sequence attention (training / prefill) is computed with a memory-bounded
double-blocked online-softmax (flash-attention structure in pure jnp) so that
``memory_analysis()`` of the dry-run reflects a deployable implementation rather
than an O(S^2) score materialization.  The Pallas SWA kernel in
``repro.kernels.swa_attention`` shares this function as its oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blocked online-softmax attention core
# ---------------------------------------------------------------------------


def blocked_attention(q, k, v, q_positions, kv_positions, *, window=None,
                      q_chunk: int = 1024, kv_chunk: int = 1024, softmax_scale=None):
    """Causal (optionally sliding-window) attention.

    q: (B, Sq, Hkv, G, Dk)   grouped query heads
    k: (B, Sk, Hkv, Dk); v: (B, Sk, Hkv, Dv)   (Dk may differ from Dv, e.g. MLA)
    q_positions: (B, Sq) absolute positions of queries
    kv_positions: (B, Sk) absolute positions of keys; negative = invalid slot
    Returns (B, Sq, Hkv, G, Dv).
    """
    B, Sq, Hkv, G, Dh = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # Pad to multiples of the chunk sizes; padded kv slots get position -1.
    pad_q = (-Sq) % q_chunk
    pad_k = (-Sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad_k)), constant_values=-1)
    Sq_p, Sk_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // q_chunk, Sk_p // kv_chunk

    q = q.reshape(B, nq, q_chunk, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    qpos = q_positions.reshape(B, nq, q_chunk).transpose(1, 0, 2)
    kc = k.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    kpos = kv_positions.reshape(B, nk, kv_chunk).transpose(1, 0, 2)

    def q_block(carry, q_in):
        qb, qp = q_in  # (B, Cq, Hkv, G, Dh), (B, Cq)

        def kv_block(state, kv_in):
            m, l, o = state
            kb, vb, kp = kv_in  # (B, Ck, Hkv, Dh), ..., (B, Ck)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            mask = qp[:, None, None, :, None] >= kp[:, None, None, None, :]
            mask &= kp[:, None, None, None, :] >= 0
            if window is not None:
                mask &= (qp[:, None, None, :, None] - kp[:, None, None, None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), (kc, vc, kpos))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.transpose(0, 3, 1, 2, 4)  # (B, Cq, Hkv, G, Dh)

    _, out = jax.lax.scan(q_block, None, (q, qpos))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, Hkv, G, Dv)
    return out[:, :Sq].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, q_position, kv_positions, *, window=None,
                     softmax_scale=None):
    """One-token attention against a (possibly ring-buffered) cache.

    q: (B, 1, Hkv, G, Dh); caches (B, Sc, Hkv, Dh); kv_positions (B, Sc) with -1
    marking unwritten slots.
    """
    B, _, Hkv, G, Dh = q.shape
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    mask = kv_positions[:, None, None, None, :] >= 0
    mask &= kv_positions[:, None, None, None, :] <= q_position[:, None, None, None, None]
    if window is not None:
        mask &= (q_position[:, None, None, None, None]
                 - kv_positions[:, None, None, None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (covers MHA when n_kv_heads == n_heads)
# ---------------------------------------------------------------------------


def gqa_init(key, d_model, n_heads, n_kv_heads, d_head, qkv_bias, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], (d_model, n_heads * d_head), dtype),
        "w_k": dense_init(ks[1], (d_model, n_kv_heads * d_head), dtype),
        "w_v": dense_init(ks[2], (d_model, n_kv_heads * d_head), dtype),
        "w_o": dense_init(ks[3], (n_heads * d_head, d_model), dtype),
    }
    if qkv_bias:
        p["b_q"] = jnp.zeros((n_heads * d_head,), dtype)
        p["b_k"] = jnp.zeros((n_kv_heads * d_head,), dtype)
        p["b_v"] = jnp.zeros((n_kv_heads * d_head,), dtype)
    return p


def gqa_project_qkv(params, x, n_heads, n_kv_heads, d_head, positions, rope_theta):
    B, S, _ = x.shape
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if "b_q" in params:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    q = q.reshape(B, S, n_heads, d_head)
    k = k.reshape(B, S, n_kv_heads, d_head)
    v = v.reshape(B, S, n_kv_heads, d_head)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def gqa_forward(params, x, positions, *, n_heads, n_kv_heads, d_head,
                rope_theta, window=None, use_kernel=False):
    """Full-sequence GQA (training / prefill). Returns (out, (k, v)).

    ``use_kernel`` routes sliding-window attention through the Pallas
    flash-SWA kernel (requires a window that is a multiple of its 128 tile and
    contiguous positions — i.e. the standard prefill layout).
    """
    B, S, _ = x.shape
    G = n_heads // n_kv_heads
    q, k, v = gqa_project_qkv(params, x, n_heads, n_kv_heads, d_head, positions, rope_theta)
    if use_kernel and window is not None and window % 128 == 0:
        from repro.kernels.swa_attention import ops as swa_ops
        out = swa_ops.swa_attention(q, k, v, window=window)
        out = out.reshape(B, S, n_heads * d_head)
    else:
        qg = q.reshape(B, S, n_kv_heads, G, d_head)
        out = blocked_attention(qg, k, v, positions, positions, window=window)
        out = out.reshape(B, S, n_heads * d_head)
    return out @ params["w_o"], (k, v)


def gqa_decode(params, x, position, cache, *, n_heads, n_kv_heads, d_head,
               rope_theta, window=None):
    """Single-token GQA against a cache dict {"k","v","pos"} (ring buffer).

    cache["k"/"v"]: (B, Sc, Hkv, Dh); cache["pos"]: (B, Sc) absolute positions,
    -1 for never-written slots.  ``position``: (B,) current absolute position.
    """
    B, S1, _ = x.shape
    G = n_heads // n_kv_heads
    q, k, v = gqa_project_qkv(params, x, n_heads, n_kv_heads, d_head,
                              position[:, None], rope_theta)
    Sc = cache["k"].shape[1]
    slot = (position % Sc).astype(jnp.int32)  # ring buffer (full cache: slot==pos)
    b_idx = jnp.arange(B)
    k_cache = cache["k"].at[b_idx, slot].set(k[:, 0])
    v_cache = cache["v"].at[b_idx, slot].set(v[:, 0])
    kv_pos = cache["pos"].at[b_idx, slot].set(position.astype(jnp.int32))
    qg = q.reshape(B, 1, n_kv_heads, G, d_head)
    out = decode_attention(qg, k_cache, v_cache, position, kv_pos, window=window)
    out = out.reshape(B, 1, n_heads * d_head)
    new_cache = {"k": k_cache, "v": v_cache, "pos": kv_pos}
    return out @ params["w_o"], new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2), compressed KV cache
# ---------------------------------------------------------------------------


def mla_init(key, d_model, n_heads, *, kv_lora_rank, qk_nope_dim, qk_rope_dim,
             v_head_dim, dtype):
    ks = jax.random.split(key, 6)
    return {
        "w_q": dense_init(ks[0], (d_model, n_heads * (qk_nope_dim + qk_rope_dim)), dtype),
        "w_dkv": dense_init(ks[1], (d_model, kv_lora_rank), dtype),
        "w_kr": dense_init(ks[2], (d_model, qk_rope_dim), dtype),
        "w_uk": dense_init(ks[3], (kv_lora_rank, n_heads * qk_nope_dim), dtype),
        "w_uv": dense_init(ks[4], (kv_lora_rank, n_heads * v_head_dim), dtype),
        "w_o": dense_init(ks[5], (n_heads * v_head_dim, d_model), dtype),
    }


def _mla_qkr(params, x, positions, n_heads, qk_nope_dim, qk_rope_dim, rope_theta):
    B, S, _ = x.shape
    q = (x @ params["w_q"]).reshape(B, S, n_heads, qk_nope_dim + qk_rope_dim)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    c_kv = x @ params["w_dkv"]  # (B, S, r)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions, rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def _mla_expand_kv(params, c_kv, n_heads, qk_nope_dim, v_head_dim):
    B, S, _ = c_kv.shape
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, n_heads, qk_nope_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, n_heads, v_head_dim)
    return k_nope, v


def mla_forward(params, x, positions, *, n_heads, kv_lora_rank, qk_nope_dim,
                qk_rope_dim, v_head_dim, rope_theta, window=None):
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(
        params, x, positions, n_heads, qk_nope_dim, qk_rope_dim, rope_theta)
    k_nope, v = _mla_expand_kv(params, c_kv, n_heads, qk_nope_dim, v_head_dim)
    # Assemble full-width q/k: rope part is shared across heads on the k side.
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,dn+dr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, n_heads, qk_rope_dim))],
        axis=-1)
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5
    qg = q_full[:, :, :, None, :]  # G = 1 per head (MHA over latent kv)
    out = blocked_attention(qg, k_full, v, positions, positions, window=window,
                            softmax_scale=scale)
    out = out.reshape(B, S, n_heads * v_head_dim)
    return out @ params["w_o"], (c_kv, k_rope)


def mla_decode(params, x, position, cache, *, n_heads, kv_lora_rank, qk_nope_dim,
               qk_rope_dim, v_head_dim, rope_theta, window=None, absorbed=False):
    """Decode with the compressed cache {"c_kv": (B,Sc,r), "k_rope": (B,Sc,dr), "pos"}.

    ``absorbed=False`` (paper-exact naive path) re-expands k/v for the whole cache.
    ``absorbed=True`` folds w_uk into the query and w_uv into the output so the
    attention runs directly in the latent space — a beyond-paper perf variant.
    """
    B, _, _ = x.shape
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkr(
        params, x, position[:, None], n_heads, qk_nope_dim, qk_rope_dim, rope_theta)
    Sc = cache["c_kv"].shape[1]
    slot = (position % Sc).astype(jnp.int32)
    b_idx = jnp.arange(B)
    c_kv = cache["c_kv"].at[b_idx, slot].set(c_kv_new[:, 0])
    k_rope = cache["k_rope"].at[b_idx, slot].set(k_rope_new[:, 0])
    kv_pos = cache["pos"].at[b_idx, slot].set(position.astype(jnp.int32))
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5

    if absorbed:
        # q_lat[b,h,r] = sum_d q_nope[b,h,d] * w_uk[r, h*dn+d]
        w_uk = params["w_uk"].reshape(kv_lora_rank, n_heads, qk_nope_dim)
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        s = jnp.einsum("bhr,bkr->bhk", q_lat, c_kv.astype(jnp.float32))
        s = s + jnp.einsum("bhd,bkd->bhk", q_rope[:, 0].astype(jnp.float32),
                           k_rope.astype(jnp.float32))
        s = s * scale
        mask = (kv_pos >= 0) & (kv_pos <= position[:, None])
        if window is not None:
            mask &= (position[:, None] - kv_pos) < window
        p = jax.nn.softmax(jnp.where(mask[:, None, :], s, NEG_INF), axis=-1)
        o_lat = jnp.einsum("bhk,bkr->bhr", p, c_kv.astype(jnp.float32))
        w_uv = params["w_uv"].reshape(kv_lora_rank, n_heads, v_head_dim)
        out = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
        out = out.reshape(B, 1, n_heads * v_head_dim).astype(x.dtype)
    else:
        k_nope, v = _mla_expand_kv(params, c_kv, n_heads, qk_nope_dim, v_head_dim)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, Sc, n_heads, qk_rope_dim))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
        out = decode_attention(q_full, k_full, v, position, kv_pos, window=window,
                               softmax_scale=scale)
        out = out.reshape(B, 1, n_heads * v_head_dim)
    new_cache = {"c_kv": c_kv, "k_rope": k_rope, "pos": kv_pos}
    return out @ params["w_o"], new_cache
