"""Mixture-of-Experts layer: shared + routed experts, top-k, capacity dispatch.

Dispatch is scatter/gather-based (sort-free GShard-style position assignment)
so HLO FLOPs stay proportional to *active* expert compute — important for the
roofline utility ratio.  Experts are sharded on the ``model`` mesh axis
(expert parallelism); the dispatch buffers carry explicit sharding constraints
(repro.models.shard_hints) so the partitioner routes tokens with an
all-to-all instead of gathering expert weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import shard_hints
from repro.models.layers import dense_init


def moe_init(key, d_model: int, moe_d_ff: int, n_experts: int,
             n_shared_experts: int, shared_d_ff: int, dtype):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (n_experts, d_model, moe_d_ff), dtype),
        "w_up": dense_init(ks[2], (n_experts, d_model, moe_d_ff), dtype),
        "w_down": dense_init(ks[3], (n_experts, moe_d_ff, d_model), dtype),
    }
    if n_shared_experts > 0:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d_model, shared_d_ff, dtype)
    return p


def router_topk(logits, top_k: int):
    """Top-k routing with softmax-renormalized gates. logits: (..., E) fp32."""
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, top_k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)
    return top_vals, top_idx


def load_balance_loss(logits, top_idx, n_experts: int):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    gates = jax.nn.softmax(logits, axis=-1)
    p_e = gates.mean(axis=0)
    assign = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32).sum(axis=1)
    f_e = assign.mean(axis=0) / max(top_idx.shape[-1], 1)
    return n_experts * jnp.sum(f_e * p_e)


def moe_forward(params, x, *, n_experts: int, top_k: int,
                capacity_factor: float = 1.25, group_size: int = 4096):
    """x: (B, S, d). Returns (out, aux_loss).

    Tokens are processed in G groups of g so per-expert capacity buffers stay
    small; one batched scatter dispatches all groups at once (no vmap — the
    buffer keeps an explicit (G, E, C, d) layout the partitioner can shard).
    """
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    N = B * S
    g = min(group_size, N)
    pad = (-N) % g
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    G = xf.shape[0] // g
    xg = xf.reshape(G, g, d)
    cap = int(max(top_k, g * top_k * capacity_factor / n_experts))

    logits = xg.astype(jnp.float32) @ params["router"]        # (G, g, E)
    gates, idx = router_topk(logits, top_k)                   # (G, g, k)

    k = top_k
    flat_idx = idx.reshape(G, g * k)                          # (G, g*k)
    onehot = jax.nn.one_hot(flat_idx, n_experts, dtype=jnp.int32)
    # log-depth prefix sum (TPU-idiomatic; a sequential cumsum lowers to a
    # g*k-trip while loop on some backends)
    pos = jax.lax.associative_scan(jnp.add, onehot, axis=1) - 1  # (G, g*k, E)
    pos_in_expert = jnp.take_along_axis(pos, flat_idx[..., None], axis=2)[..., 0]
    keep = pos_in_expert < cap
    slot = jnp.where(keep, pos_in_expert, cap)                # overflow row

    tok_rep = jnp.repeat(jnp.arange(g), k)                    # (g*k,)
    g_idx = jnp.arange(G)[:, None]                            # (G, 1)
    buf = jnp.zeros((G, n_experts, cap + 1, d), xg.dtype)
    buf = buf.at[g_idx, flat_idx, slot].add(xg[:, tok_rep])
    expert_in = shard_hints.constrain_expert_dim(buf[:, :, :cap], 1)  # (G,E,C,d)

    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]))
    up = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", gate * up, params["w_down"])
    expert_out = shard_hints.constrain_expert_dim(expert_out, 1)

    out_tok = expert_out[g_idx, flat_idx, jnp.minimum(slot, cap - 1)]  # (G,g*k,d)
    out_tok = out_tok * (keep[..., None] * gates.reshape(G, g * k, 1)
                         ).astype(expert_out.dtype)
    out = jnp.zeros((G, g, d), expert_out.dtype)
    out = out.at[g_idx, jnp.broadcast_to(tok_rep, (G, g * k))].add(out_tok)
    out = out.reshape(-1, d)[:N].reshape(B, S, d)

    aux = load_balance_loss(logits.reshape(-1, n_experts),
                            idx.reshape(-1, k), n_experts)
    if "shared" in params:
        from repro.models.layers import mlp
        out = out + mlp(params["shared"], x)
    return out, aux
