"""Sharding-constraint hints for model internals.

The SPMD partitioner propagates input shardings well through simple stacks but
loses them across deep scan+remat+vmap nests (observed: replicated activations
and fully-gathered expert weights).  The launch layer registers the mesh axes
here; model code drops ``with_sharding_constraint`` pins at the few places that
anchor the layout:

- activations after embedding and between super-blocks: batch dim -> batch axes
- MoE dispatch buffers: expert dim -> "model" (expert parallelism)

On hosts without a mesh (unit tests, simulation) hints are disabled and all
helpers are no-ops.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"batch_axes": None, "model_axis": None}


def configure(*, batch_axes: Optional[Tuple[str, ...]] = None,
              model_axis: Optional[str] = None):
    _STATE["batch_axes"] = batch_axes
    _STATE["model_axis"] = model_axis


def reset():
    configure()


@contextlib.contextmanager
def hints(*, batch_axes=None, model_axis=None):
    old = dict(_STATE)
    configure(batch_axes=batch_axes, model_axis=model_axis)
    try:
        yield
    finally:
        _STATE.update(old)


def constrain_activations(x):
    """x: (..., B, S, d) — pin the batch dim (3rd from the end)."""
    ba = _STATE["batch_axes"]
    if ba is None:
        return x
    spec = [None] * x.ndim
    spec[-3] = ba
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_expert_dim(t, expert_axis_index: int):
    """Pin dim ``expert_axis_index`` of t to the model axis (expert parallel)."""
    ma = _STATE["model_axis"]
    if ma is None:
        return t
    spec = [None] * t.ndim
    spec[expert_axis_index] = ma
    return jax.lax.with_sharding_constraint(t, P(*spec))
