"""Coordinated (colluding) attack models for the chaos harness.

PR-6 faults corrupt rows *independently*; an ``AttackSpec`` instead
drives a seeded per-round attacker set (drawn by ``FaultPlan.with_attack``
from its own RNG stream, so existing fault draws are untouched) whose
rows are rewritten *jointly* at aggregation time.  The rewrite is a pure
jnp formula applied to the post-psum ``(..., n, D)`` operand with the
attacker/valid masks, shared verbatim by the fused round program, the
per-stage sweep executor and the engine's flat/legacy paths — so an
attack replays bit-identically on every substrate, like existing faults.

Attack kinds (``SimConfig.attack``):

* ``collude_signflip``   — attackers submit ``-scale * u_i``.
* ``collude_same_value`` — attackers all submit one shared constant
  vector of L2 norm ``scale`` (maximal collusion; defeats per-row
  screens, shifts the mean together).
* ``alie``               — "A Little Is Enough"-style: attackers submit
  ``mu - z * sigma`` of the *honest* rows, a small coordinated nudge
  that sits inside the honest empirical spread.
* ``adaptive``           — under-the-norm-screen: attackers submit
  ``-u_i`` rescaled to ``scale * sqrt(median honest ||u||^2)`` (the same
  median convention the guard's norm screen uses), i.e. the largest
  reversed update that a median-norm reject with
  ``guard_reject_mult > scale`` will not flag.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

ATTACK_KINDS = ("none", "collude_signflip", "collude_same_value", "alie",
                "adaptive")
_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class AttackSpec:
    """Static description of a coordinated attack (hashable; part of the
    pipeline program key via ``attack_key``)."""
    kind: str
    frac: float = 0.25       # attacker fraction of the population, per round
    scale: float = 10.0      # magnitude knob (see kind docs above)
    z: float = 1.5           # alie sigma multiplier

    def __post_init__(self):
        if self.kind not in ATTACK_KINDS:
            raise ValueError(f"unknown attack kind {self.kind!r} "
                             f"(choose from {ATTACK_KINDS})")


def attack_key(cfg) -> Optional[Tuple[str, float, float]]:
    """Static attack descriptor for a ``SimConfig`` (None == no attack,
    i.e. today's program — the static half of the bit-parity gate)."""
    if cfg.attack == "none" or float(cfg.attack_frac) <= 0.0:
        return None
    if cfg.attack not in ATTACK_KINDS:
        raise ValueError(f"unknown attack kind {cfg.attack!r} "
                         f"(choose from {ATTACK_KINDS})")
    return (cfg.attack, float(cfg.attack_scale), float(cfg.attack_z))


def apply_attack(u: jnp.ndarray, att: jnp.ndarray, valid: jnp.ndarray, *,
                 kind: str, scale: float, z: float) -> jnp.ndarray:
    """Rewrite attacker rows of the aggregation operand.

    ``u``: ``(..., n, D)`` update rows; ``att`` / ``valid``: ``(..., n)``
    bool masks (``att`` marks columns whose learner is in this round's
    attacker set).  Rows with ``att`` False pass through via ``where``
    bit-exactly, so attack-free rounds of an attacked program stay
    bit-identical to the clean program (the dynamic parity half).
    """
    attc = (att & valid)[..., None]
    if kind == "collude_signflip":
        return jnp.where(attc, -scale * u, u)
    if kind == "collude_same_value":
        d = u.shape[-1]
        crafted = jnp.full(u.shape[-1:], scale / (d ** 0.5), u.dtype)
        return jnp.where(attc, crafted, u)
    honest = (valid & ~att)[..., None]
    hcnt = jnp.maximum(jnp.sum(honest, axis=-2, keepdims=True), 1)
    if kind == "alie":
        mu = jnp.sum(jnp.where(honest, u, 0.0), axis=-2, keepdims=True) / hcnt
        var = jnp.sum(jnp.where(honest, (u - mu) ** 2, 0.0), axis=-2,
                      keepdims=True) / hcnt
        crafted = mu - z * jnp.sqrt(var)
        return jnp.where(attc, crafted, u)
    if kind == "adaptive":
        n2 = jnp.sum(u * u, axis=-1)
        srt = jnp.sort(jnp.where(honest[..., 0], n2, jnp.inf), axis=-1)
        h1 = hcnt[..., 0, 0]
        med = jnp.take_along_axis(
            srt, (jnp.maximum(h1, 1) - 1)[..., None] // 2, axis=-1)
        target = scale * jnp.sqrt(jnp.maximum(med, 0.0))
        rn = jnp.sqrt(jnp.maximum(n2, _EPS))[..., None]
        return jnp.where(attc, -u * (target[..., None] / rn), u)
    raise ValueError(f"unknown attack kind {kind!r}")
