"""Deterministic, seeded fault injection for the round engine (chaos harness).

A ``FaultPlan`` is a *program* of faults, fully materialized at construction
from ``np.random.default_rng(seed)`` — dense per-(round, learner) arrays, so
the same plan replays the identical faults on every substrate (legacy,
per-stage flat, fused pipeline, batched sweeps) and across checkpoint/resume.
Four fault families:

  update corruption (``nan`` / ``inf`` / ``signflip`` / ``scale``) — a
      per-row fp32 multiplier applied to the learner's flat update delta
      right after local training.  The fused pipeline folds the multiplier
      into the round program (an extra fp32 lane in the packed floats
      buffer), so the transfer-guard and one-psum-per-round invariants
      survive; the host paths apply the identical IEEE multiply, keeping
      all substrates bit-identical under faults.

  ``post_drop`` — the learner finishes training but the result is lost
      before upload: full duration charged and wasted (the paper's §3
      wasted-work currency), device busy for the whole round, no arrival,
      no selector feedback.  Decided in ``Simulator._schedule_round``
      (host), hence identical across substrates.

  ``replay`` — a landing stale update is delivered twice in the same round
      (duplicate slot gather / duplicate cached row in the aggregation
      operand), exercising the slot cache's free-dedup discipline.

  host crash (``crash_after`` / ``crash_mode``) — after round
      ``crash_after`` completes: ``"soft"`` raises ``InjectedCrash`` (the
      in-process property tests), ``"hard"`` SIGKILLs the process (the CI
      chaos leg), leaving recovery to ``--resume`` from the last
      checkpoint.

Rounds beyond the plan's horizon and learners beyond ``n_learners`` are
fault-free, so a crash-only plan may be built with ``FaultPlan(0, 0, ...)``.
"""
from __future__ import annotations

import dataclasses
import os
import signal
from typing import Optional, Sequence, Tuple

import numpy as np

CORRUPTION_KINDS = ("nan", "inf", "signflip", "scale")
KINDS = CORRUPTION_KINDS + ("post_drop", "replay")


class InjectedCrash(RuntimeError):
    """A FaultPlan's scheduled soft host crash (``crash_mode="soft"``)."""

    def __init__(self, round_idx: int):
        super().__init__(f"injected host crash after round {round_idx}")
        self.round_idx = round_idx


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault family over a (round window x learner set) region.

    ``prob`` is the per-(round, learner) hit probability; ``rounds`` is a
    half-open ``(start, stop)`` window (None = every round); ``learners``
    restricts the affected ids (None = all).  ``scale`` is the multiplier
    for ``kind="scale"`` (byzantine scaled garbage)."""
    kind: str
    prob: float = 1.0
    rounds: Optional[Tuple[int, int]] = None
    learners: Optional[Tuple[int, ...]] = None
    scale: float = 1e3

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


class FaultPlan:
    """Dense deterministic fault program over (rounds x n_learners)."""

    def __init__(self, n_learners: int, rounds: int,
                 specs: Sequence[FaultSpec] = (), seed: int = 0,
                 crash_after: Optional[int] = None,
                 crash_mode: str = "soft"):
        if crash_mode not in ("soft", "hard"):
            raise ValueError("crash_mode must be 'soft' or 'hard'")
        self.n_learners = int(n_learners)
        self.rounds = int(rounds)
        self.specs = tuple(specs)
        self.seed = int(seed)
        self.crash_after = crash_after
        self.crash_mode = crash_mode
        r, n = self.rounds, self.n_learners
        # draw order is fixed: one (R, n) uniform block per spec, in spec
        # order — the whole program is a pure function of (specs, seed)
        rng = np.random.default_rng(seed)
        self.corrupt = np.ones((r, n), np.float32)
        self._post_drop = np.zeros((r, n), bool)
        self._replay = np.zeros((r, n), bool)
        for spec in self.specs:
            hit = rng.random((r, n)) < spec.prob
            if spec.rounds is not None:
                m = np.zeros(r, bool)
                m[spec.rounds[0]:spec.rounds[1]] = True
                hit &= m[:, None]
            if spec.learners is not None:
                m = np.zeros(n, bool)
                m[list(spec.learners)] = True
                hit &= m[None, :]
            if spec.kind == "post_drop":
                self._post_drop |= hit
            elif spec.kind == "replay":
                self._replay |= hit
            else:
                val = {"nan": np.nan, "inf": np.inf,
                       "signflip": -1.0, "scale": spec.scale}[spec.kind]
                self.corrupt[hit] = np.float32(val)
        # NaN != 1.0 is True, so NaN overlays register as corruption
        self.has_corruption = bool(np.any(self.corrupt != 1.0))

    # ------------------------------------------------------------------
    def scale_for(self, r: int, lids) -> np.ndarray:
        """fp32 per-row delta multipliers for round ``r``'s cohort."""
        lids = np.asarray(lids, np.int64)
        if r >= self.rounds or not self.has_corruption:
            return np.ones(len(lids), np.float32)
        return self.corrupt[r, lids]

    def post_drop(self, r: int, lid: int) -> bool:
        return r < self.rounds and bool(self._post_drop[r, lid])

    def replay(self, r: int, lid: int) -> bool:
        return r < self.rounds and bool(self._replay[r, lid])

    # ------------------------------------------------------------------
    def crash_due(self, r_completed: int) -> bool:
        """True when the crash fires after round ``r_completed``."""
        return self.crash_after is not None and r_completed >= self.crash_after

    def trigger_crash(self, r_completed: int):
        if self.crash_mode == "hard":
            # unhandled-by-design: the CI chaos leg asserts exit code 137
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedCrash(r_completed)

    def without_crash(self) -> "FaultPlan":
        """The same fault program with the crash disarmed — what a resumed
        run carries, so corruption/drop/replay faults replay identically
        but the (already-fired) crash does not refire."""
        clone = FaultPlan.__new__(FaultPlan)
        clone.__dict__.update(self.__dict__)
        clone.crash_after = None
        return clone

    # ------------------------------------------------------------------
    def counts(self) -> dict:
        """Scheduled fault totals per kind (the chaos demo's table)."""
        c = self.corrupt
        finite = np.isfinite(c)
        return {
            "nan": int(np.isnan(c).sum()),
            "inf": int(np.isinf(c).sum()),
            "signflip": int((finite & (c == -1.0)).sum()),
            "scale": int((finite & (c != 1.0) & (c != -1.0)).sum()),
            "post_drop": int(self._post_drop.sum()),
            "replay": int(self._replay.sum()),
        }
