"""Deterministic, seeded fault injection for the round engine (chaos harness).

A ``FaultPlan`` is a *program* of faults, fully materialized at construction
from ``np.random.default_rng(seed)`` — per-(round, learner) overlays, so
the same plan replays the identical faults on every substrate (legacy,
per-stage flat, fused pipeline, batched sweeps) and across checkpoint/resume.
Four fault families:

  update corruption (``nan`` / ``inf`` / ``signflip`` / ``scale``) — a
      per-row fp32 multiplier applied to the learner's flat update delta
      right after local training.  The fused pipeline folds the multiplier
      into the round program (an extra fp32 lane in the packed floats
      buffer), so the transfer-guard and one-psum-per-round invariants
      survive; the host paths apply the identical IEEE multiply, keeping
      all substrates bit-identical under faults.

  ``post_drop`` — the learner finishes training but the result is lost
      before upload: full duration charged and wasted (the paper's §3
      wasted-work currency), device busy for the whole round, no arrival,
      no selector feedback.  Decided in ``Simulator._schedule_round``
      (host), hence identical across substrates.

  ``replay`` — a landing stale update is delivered twice in the same round
      (duplicate slot gather / duplicate cached row in the aggregation
      operand), exercising the slot cache's free-dedup discipline.

  host crash (``crash_after`` / ``crash_mode``) — after round
      ``crash_after`` completes: ``"soft"`` raises ``InjectedCrash`` (the
      in-process property tests), ``"hard"`` SIGKILLs the process (the CI
      chaos leg), leaving recovery to ``--resume`` from the last
      checkpoint.

Storage is dense ``(rounds, n)`` arrays for small plans and per-round COO
overlays for large ones (``sparse=None`` auto-switches above ~4M cells —
at the ROADMAP's n=1M target a dense fp32 corruption matrix alone is
~4 GB·rounds).  Both modes consume the RNG stream identically (a
``(rounds, n)`` uniform block row-major equals ``rounds`` sequential
``n``-draws), so sparse==dense replay bit-exactly; property-tested in
``tests/test_faults_guards.py``.

A plan may also carry an ``AttackSpec`` (``repro.faults.attacks``): a
seeded per-round *attacker id set* drawn from its own RNG stream (existing
fault draws untouched) that the aggregation paths use to rewrite colluding
rows jointly.  ``with_attack`` attaches one to an existing plan.

Rounds beyond the plan's horizon and learners beyond ``n_learners`` are
fault-free, so a crash-only plan may be built with ``FaultPlan(0, 0, ...)``.
"""
from __future__ import annotations

import dataclasses
import os
import signal
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.faults.attacks import AttackSpec

CORRUPTION_KINDS = ("nan", "inf", "signflip", "scale")
KINDS = CORRUPTION_KINDS + ("post_drop", "replay")

# dense storage above this many (round, learner) cells would dominate the
# host footprint; auto-switch to per-round COO overlays
_SPARSE_CELLS = 1 << 22

_ATTACK_STREAM = 0xA77AC3   # decorrelates attacker draws from fault draws


class InjectedCrash(RuntimeError):
    """A FaultPlan's scheduled soft host crash (``crash_mode="soft"``)."""

    def __init__(self, round_idx: int):
        super().__init__(f"injected host crash after round {round_idx}")
        self.round_idx = round_idx


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault family over a (round window x learner set) region.

    ``prob`` is the per-(round, learner) hit probability; ``rounds`` is a
    half-open ``(start, stop)`` window (None = every round); ``learners``
    restricts the affected ids (None = all).  ``scale`` is the multiplier
    for ``kind="scale"`` (byzantine scaled garbage)."""
    kind: str
    prob: float = 1.0
    rounds: Optional[Tuple[int, int]] = None
    learners: Optional[Tuple[int, ...]] = None
    scale: float = 1e3

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


class FaultPlan:
    """Deterministic fault program over (rounds x n_learners)."""

    def __init__(self, n_learners: int, rounds: int,
                 specs: Sequence[FaultSpec] = (), seed: int = 0,
                 crash_after: Optional[int] = None,
                 crash_mode: str = "soft",
                 sparse: Optional[bool] = None,
                 attack: Optional[AttackSpec] = None):
        if crash_mode not in ("soft", "hard"):
            raise ValueError("crash_mode must be 'soft' or 'hard'")
        self.n_learners = int(n_learners)
        self.rounds = int(rounds)
        self.specs = tuple(specs)
        self.seed = int(seed)
        self.crash_after = crash_after
        self.crash_mode = crash_mode
        r, n = self.rounds, self.n_learners
        self.sparse = (r * n > _SPARSE_CELLS) if sparse is None else \
            bool(sparse)
        # draw order is fixed: one (R, n) uniform block per spec, in spec
        # order — the whole program is a pure function of (specs, seed).
        # The sparse path consumes the identical stream one round-row at a
        # time (row-major), so both modes replay the same faults bit-exactly.
        rng = np.random.default_rng(seed)
        if not self.sparse:
            self.corrupt: Optional[np.ndarray] = np.ones((r, n), np.float32)
            self._post_drop: Optional[np.ndarray] = np.zeros((r, n), bool)
            self._replay: Optional[np.ndarray] = np.zeros((r, n), bool)
            for spec in self.specs:
                hit = rng.random((r, n)) < spec.prob
                hit = self._mask_spec(hit, spec, r, n)
                if spec.kind == "post_drop":
                    self._post_drop |= hit
                elif spec.kind == "replay":
                    self._replay |= hit
                else:
                    self.corrupt[hit] = np.float32(self._value(spec))
            # NaN != 1.0 is True, so NaN overlays register as corruption
            self.has_corruption = bool(np.any(self.corrupt != 1.0))
        else:
            self.corrupt = self._post_drop = self._replay = None
            cmaps: Dict[int, Dict[int, np.float32]] = {}
            pd_sets: Dict[int, set] = {}
            rp_sets: Dict[int, set] = {}
            for spec in self.specs:
                val = None if spec.kind in ("post_drop", "replay") \
                    else np.float32(self._value(spec))
                for rr in range(r):
                    row = rng.random(n) < spec.prob   # always drawn: the
                    # stream must match the dense block even in masked rounds
                    hit = self._mask_spec(row[None, :], spec, r, n,
                                          round_idx=rr)[0]
                    cols = np.nonzero(hit)[0]
                    if not len(cols):
                        continue
                    if spec.kind == "post_drop":
                        pd_sets.setdefault(rr, set()).update(cols.tolist())
                    elif spec.kind == "replay":
                        rp_sets.setdefault(rr, set()).update(cols.tolist())
                    else:
                        m = cmaps.setdefault(rr, {})
                        for c in cols:       # later specs overwrite, like
                            m[int(c)] = val  # the dense ``corrupt[hit] =``
            self._corrupt_coo = {
                rr: (np.array(sorted(m), np.int64),
                     np.array([m[c] for c in sorted(m)], np.float32))
                for rr, m in cmaps.items()}
            self._post_drop_sets = {rr: frozenset(s)
                                    for rr, s in pd_sets.items()}
            self._replay_sets = {rr: frozenset(s) for rr, s in rp_sets.items()}
            self.has_corruption = any(
                bool(np.any(v != 1.0))
                for _, v in self._corrupt_coo.values())
        self.attack: Optional[AttackSpec] = None
        self._attack_ids: Dict[int, np.ndarray] = {}
        if attack is not None:
            self._arm_attack(attack)

    @staticmethod
    def _value(spec: FaultSpec) -> float:
        return {"nan": np.nan, "inf": np.inf,
                "signflip": -1.0, "scale": spec.scale}[spec.kind]

    @staticmethod
    def _mask_spec(hit: np.ndarray, spec: FaultSpec, r: int, n: int,
                   round_idx: Optional[int] = None) -> np.ndarray:
        """Apply the spec's (round window x learner set) region mask."""
        if spec.rounds is not None:
            if round_idx is None:
                m = np.zeros(r, bool)
                m[spec.rounds[0]:spec.rounds[1]] = True
                hit = hit & m[:, None]
            elif not (spec.rounds[0] <= round_idx < spec.rounds[1]):
                hit = np.zeros_like(hit)
        if spec.learners is not None:
            m = np.zeros(n, bool)
            m[list(spec.learners)] = True
            hit = hit & m[None, :]
        return hit

    # -- coordinated attacks -------------------------------------------------
    def _arm_attack(self, spec: AttackSpec) -> None:
        self.attack = spec
        self._attack_ids = {}
        n, r = self.n_learners, self.rounds
        if spec.kind == "none" or spec.frac <= 0 or n <= 0:
            return
        k = min(int(np.ceil(spec.frac * n)), n)
        arng = np.random.default_rng((self.seed, _ATTACK_STREAM))
        for rr in range(r):
            self._attack_ids[rr] = np.sort(
                arng.choice(n, size=k, replace=False)).astype(np.int64)

    def with_attack(self, spec: AttackSpec) -> "FaultPlan":
        """The same fault program plus a coordinated attack: attacker id
        sets are drawn from a *separate* RNG stream keyed on
        ``(seed, attack)``, so every existing fault draw is untouched and
        two plans differing only in ``attack`` share identical faults
        (shared-seed attack×defense pairing)."""
        clone = FaultPlan.__new__(FaultPlan)
        clone.__dict__.update(self.__dict__)
        clone._arm_attack(spec)
        return clone

    def attackers(self, r: int) -> np.ndarray:
        """Sorted attacker learner ids scheduled for round ``r``."""
        return self._attack_ids.get(r, np.empty(0, np.int64))

    def attack_flags(self, r: int, lids) -> np.ndarray:
        """Bool mask over ``lids``: which operand rows belong to round
        ``r``'s attacker set (stale rows collude at *landing* time)."""
        lids = np.asarray(lids, np.int64)
        ids = self._attack_ids.get(r)
        if ids is None or not len(lids):
            return np.zeros(len(lids), bool)
        return np.isin(lids, ids)

    # ------------------------------------------------------------------
    def scale_for(self, r: int, lids) -> np.ndarray:
        """fp32 per-row delta multipliers for round ``r``'s cohort."""
        lids = np.asarray(lids, np.int64)
        if r >= self.rounds or not self.has_corruption:
            return np.ones(len(lids), np.float32)
        if not self.sparse:
            return self.corrupt[r, lids]
        out = np.ones(len(lids), np.float32)
        coo = self._corrupt_coo.get(r)
        if coo is not None:
            cols, vals = coo
            pos = np.searchsorted(cols, lids)
            pos = np.minimum(pos, len(cols) - 1)
            hit = cols[pos] == lids
            out[hit] = vals[pos[hit]]
        return out

    def post_drop(self, r: int, lid: int) -> bool:
        if r >= self.rounds:
            return False
        if not self.sparse:
            return bool(self._post_drop[r, lid])
        return lid in self._post_drop_sets.get(r, ())

    def replay(self, r: int, lid: int) -> bool:
        if r >= self.rounds:
            return False
        if not self.sparse:
            return bool(self._replay[r, lid])
        return lid in self._replay_sets.get(r, ())

    # ------------------------------------------------------------------
    def crash_due(self, r_completed: int) -> bool:
        """True when the crash fires after round ``r_completed``."""
        return self.crash_after is not None and r_completed >= self.crash_after

    def trigger_crash(self, r_completed: int):
        if self.crash_mode == "hard":
            # unhandled-by-design: the CI chaos leg asserts exit code 137
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedCrash(r_completed)

    def without_crash(self) -> "FaultPlan":
        """The same fault program with the crash disarmed — what a resumed
        run carries, so corruption/drop/replay faults replay identically
        but the (already-fired) crash does not refire."""
        clone = FaultPlan.__new__(FaultPlan)
        clone.__dict__.update(self.__dict__)
        clone.crash_after = None
        return clone

    # ------------------------------------------------------------------
    def counts(self) -> dict:
        """Scheduled fault totals per kind (the chaos demo's table)."""
        if not self.sparse:
            c, pd, rp = self.corrupt, self._post_drop, self._replay
            finite = np.isfinite(c)
            return {
                "nan": int(np.isnan(c).sum()),
                "inf": int(np.isinf(c).sum()),
                "signflip": int((finite & (c == -1.0)).sum()),
                "scale": int((finite & (c != 1.0) & (c != -1.0)).sum()),
                "post_drop": int(pd.sum()),
                "replay": int(rp.sum()),
            }
        out = {k: 0 for k in KINDS}
        for _, vals in self._corrupt_coo.values():
            finite = np.isfinite(vals)
            out["nan"] += int(np.isnan(vals).sum())
            out["inf"] += int(np.isinf(vals).sum())
            out["signflip"] += int((finite & (vals == -1.0)).sum())
            out["scale"] += int(
                (finite & (vals != 1.0) & (vals != -1.0)).sum())
        out["post_drop"] = sum(len(s) for s in self._post_drop_sets.values())
        out["replay"] = sum(len(s) for s in self._replay_sets.values())
        return out
