from repro.faults.attacks import (ATTACK_KINDS, AttackSpec,  # noqa: F401
                                  apply_attack, attack_key)
from repro.faults.plan import (CORRUPTION_KINDS, KINDS,  # noqa: F401
                               FaultPlan, FaultSpec, InjectedCrash)
