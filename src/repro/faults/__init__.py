from repro.faults.plan import (CORRUPTION_KINDS, KINDS,  # noqa: F401
                               FaultPlan, FaultSpec, InjectedCrash)
