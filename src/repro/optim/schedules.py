"""LR schedules. WSD (warmup-stable-decay) is MiniCPM's contribution
(arXiv:2404.06395) and ships with that assigned architecture."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, *, peak_lr, warmup_steps, stable_steps, decay_steps,
                 final_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    decay_frac = (step - warmup_steps - stable_steps) / jnp.maximum(decay_steps, 1)
    decayed = peak_lr * jnp.exp(jnp.log(final_ratio) * jnp.clip(decay_frac, 0, 1))
    return jnp.where(step < warmup_steps, warm,
                     jnp.where(step < warmup_steps + stable_steps, peak_lr, decayed))


def cosine_schedule(step, *, peak_lr, warmup_steps, total_steps, final_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
    cos = final_ratio + (1 - final_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)
