"""Client-side optimizers. FedAvg participants run plain SGD (Alg. 2);
momentum is available for the centralized-baseline comparisons."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {}
    return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def sgd_apply(params, grads, state, *, lr, momentum: float = 0.0):
    if momentum == 0.0:
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                          ).astype(p.dtype), params, grads)
        return new, state
    mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                       state["mom"], grads)
    new = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                       params, mom)
    return new, {"mom": mom}


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm
