from repro.optim.sgd import sgd_init, sgd_apply, clip_by_global_norm  # noqa: F401
from repro.optim.schedules import wsd_schedule, cosine_schedule  # noqa: F401
