"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8.
[arXiv:2501.kimi2 (paper-table)]

Assigned spec: 61L, d_model=7168, 64H (GQA kv=8), per-expert d_ff=2048,
vocab=163840, 384 routed experts top-8 (+1 shared, K2 card), first layer dense.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b", family="moe", source="arXiv:2501.kimi2",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432, vocab_size=163840, rope_theta=5e4,
    moe=True, n_experts=384, top_k=8, moe_d_ff=2048,
    n_shared_experts=1, shared_d_ff=2048, first_k_dense=1,
    moe_group_size=1024,
)

REDUCED = ModelConfig(
    arch_id="kimi-k2-1t-a32b-reduced", family="moe", source=CONFIG.source,
    n_layers=3, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    moe=True, n_experts=4, top_k=2, moe_d_ff=128,
    n_shared_experts=1, shared_d_ff=128, first_k_dense=1, moe_group_size=128,
)
