"""internlm2-1.8b [dense] — GQA (kv=8). [arXiv:2403.17297]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-1.8b", family="dense", source="arXiv:2403.17297",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92544, rope_theta=1e6,
)

REDUCED = ModelConfig(
    arch_id="internlm2-1.8b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512,
)
