"""internvl2-76b [vlm] — InternViT (stub frontend) + LLM backbone. [arXiv:2404.16821]

The vision encoder is a harness carve-out: ``input_specs()`` supplies
precomputed patch embeddings; only the projector + decoder are implemented.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b", family="vlm", source="arXiv:2404.16821",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, rope_theta=5e5,
    frontend="vision", d_frontend=3200, n_frontend_tokens=256,
)

REDUCED = ModelConfig(
    arch_id="internvl2-76b-reduced", family="vlm", source=CONFIG.source,
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    frontend="vision", d_frontend=64, n_frontend_tokens=8,
)
