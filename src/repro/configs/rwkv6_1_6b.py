"""rwkv6-1.6b "Finch" [ssm] — attention-free, data-dependent decay. [arXiv:2404.05892]

head dim 64 (RWKV6 convention) => 32 heads at d_model=2048.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b", family="ssm", source="arXiv:2404.05892",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536, block_pattern=("rwkv6",),
    rwkv_lora_rank=32, rwkv_w_lora_rank=64,
)

REDUCED = ModelConfig(
    arch_id="rwkv6-1.6b-reduced", family="ssm", source=CONFIG.source,
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512, block_pattern=("rwkv6",),
    rwkv_lora_rank=8, rwkv_w_lora_rank=8,
)
