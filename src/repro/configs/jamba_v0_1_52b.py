"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]

Jamba block structure: period-8 super-block with attention at index 3
(attn_layer_offset=4 in the release, 1 attention per 8 layers) and MoE
replacing the MLP every 2 layers (offset 1).
"""
from repro.models import ModelConfig

_PATTERN = ("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    arch_id="jamba-v0.1-52b", family="hybrid", source="arXiv:2403.19887",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536, block_pattern=_PATTERN,
    moe=True, n_experts=16, top_k=2, moe_d_ff=14336,
    moe_every=2, moe_offset=1,
    mamba_d_state=16, mamba_expand=2, mamba_conv_width=4,
)

REDUCED = ModelConfig(
    arch_id="jamba-v0.1-52b-reduced", family="hybrid", source=CONFIG.source,
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, block_pattern=("mamba", "attn"),
    moe=True, n_experts=4, top_k=2, moe_d_ff=256,
    moe_every=2, moe_offset=1, moe_group_size=128,
)
