"""minicpm-2b [dense] — MHA (kv=36), WSD LR schedule, tied embeddings.
[arXiv:2404.06395]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm-2b", family="dense", source="arXiv:2404.06395",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753, tie_embeddings=True,
    # §Perf iteration 7: 122753 defeats 16-way vocab sharding (prime-ish);
    # padding rows to a 128 multiple restores it (-36% flops, -31% HBM).
    # Logical vocab stays 122753; pad logits are masked out of the softmax.
    vocab_pad_to=128,
)

REDUCED = ModelConfig(
    arch_id="minicpm-2b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512, tie_embeddings=True,
)
