"""Config registry + the 4 assigned input shapes."""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "qwen2_5_32b",
    "rwkv6_1_6b",
    "internvl2_76b",
    "minicpm_2b",
    "internlm2_1_8b",
    "jamba_v0_1_52b",
    "qwen2_5_3b",
    "deepseek_v2_lite_16b",
    "kimi_k2_1t_a32b",
    "musicgen_medium",
)

# canonical external ids (hyphenated) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "qwen2.5-32b": "qwen2_5_32b", "qwen2.5-3b": "qwen2_5_3b",
    "rwkv6-1.6b": "rwkv6_1_6b", "internvl2-76b": "internvl2_76b",
    "minicpm-2b": "minicpm_2b", "internlm2-1.8b": "internlm2_1_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b", "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b", "musicgen-medium": "musicgen_medium",
})


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str):
    return _module(arch_id).REDUCED


# ---------------------------------------------------------------------------
# Assigned input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

SWA_WINDOW = 8_192  # sliding-window width for the long-context dense variant


def shape_for(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def adapt_for_shape(cfg, shape: InputShape):
    """Per-shape config adaptation:

    - ``long_500k`` on architectures with any full-attention layer switches to
      the sliding-window variant (DESIGN.md §4) — SSM layers are unaffected;
    - training chunks the LM loss to bound logits memory.
    """
    changes = {}
    if shape.name == "long_500k" and "attn" in cfg.block_pattern and cfg.window is None:
        changes["window"] = SWA_WINDOW
        changes["arch_id"] = cfg.arch_id + "+swa"
    if shape.kind == "train":
        if cfg.loss_chunk == 0:
            changes["loss_chunk"] = 1_024
        changes["remat"] = True      # activation checkpointing per super-block
    return dataclasses.replace(cfg, **changes) if changes else cfg
