"""qwen2.5-3b [dense] — GQA (kv=2), QKV bias. [hf:Qwen/Qwen2.5-0.5B family card]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-3b", family="dense", source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
)

REDUCED = ModelConfig(
    arch_id="qwen2.5-3b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, qkv_bias=True,
)
