"""qwen2.5-32b [dense] — GQA (kv=8), QKV bias. [hf:Qwen/Qwen2.5-0.5B family card]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-32b", family="dense", source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
)

REDUCED = ModelConfig(
    arch_id="qwen2.5-32b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, qkv_bias=True,
)
