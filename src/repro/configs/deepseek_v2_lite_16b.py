"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512), 2 shared + 64 routed top-6.
[arXiv:2405.04434]

First layer is dense (d_ff=10944); remaining 26 layers are MoE with
per-expert d_ff=1408 and 2 shared experts (2x1408).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b", family="moe", source="arXiv:2405.04434",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    attn_type="mla", kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    # §Perf iteration 11: absorbed-matmul decode attends in the 512-d latent
    # space instead of re-expanding k/v for the whole cache per token
    # (98x decode FLOPs reduction; logits match the naive path, test-verified).
    mla_absorb=True,
    moe=True, n_experts=64, top_k=6, moe_d_ff=1408,
    n_shared_experts=2, shared_d_ff=2816, first_k_dense=1,
)

REDUCED = ModelConfig(
    arch_id="deepseek-v2-lite-16b-reduced", family="moe", source=CONFIG.source,
    n_layers=3, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512,
    attn_type="mla", kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
    v_head_dim=32,
    moe=True, n_experts=4, top_k=2, moe_d_ff=128,
    n_shared_experts=1, shared_d_ff=128, first_k_dense=1, moe_group_size=128,
)
