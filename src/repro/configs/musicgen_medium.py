"""musicgen-medium [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284]

The EnCodec encoder is a harness carve-out: inputs are already-discrete codec
tokens (vocab 2048), so the frontend is the plain token embedding.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium", family="audio", source="arXiv:2306.05284",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
)

REDUCED = ModelConfig(
    arch_id="musicgen-medium-reduced", family="audio", source=CONFIG.source,
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=256,
)
