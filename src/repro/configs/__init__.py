"""Architecture registry: one module per assigned architecture.

Each module exports CONFIG (the exact assigned spec) and REDUCED (a 2-layer,
d_model<=512, <=4-expert variant of the same family for CPU smoke tests).
"""
from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    get_config,
    get_reduced,
    INPUT_SHAPES,
    shape_for,
    adapt_for_shape,
)
