"""The FL round as ONE distributed step (paper Alg. 2 on a TPU pod).

This module is the *pod-scale lowering* of the round, not a second round
API: host-scale federated training — selection, staleness cache, guards,
telemetry, sweeps — lives entirely in ``repro.sim`` (``Simulator`` +
``SimConfig(model=...)``, with the LM zoo a ``repro.learners`` strategy
table; see ``examples/federated_lm.py``).  What remains here is the thin
mesh-aware wrapper the multi-pod dry-run (``repro.launch.dryrun``) lowers
at scale: the same Alg. 2 + Eq. 2 numerics as one jitted SPMD program
over a ("pod","data") mesh, with the cohort-memory strategies below.
Keep simulation features out of this file — extend the model zoo instead.

``fl_train_step(params, batch, fresh, tau)`` runs a cohort of P participants:
each takes K local SGD steps on its own shard (participants ride the
("pod","data") mesh axes), produces a delta, and the server applies the
staleness-aware (Eq. 2) weighted aggregate — all inside one jitted program.

Two cohort strategies:

- ``vmap`` (paper-naive): all P deltas materialize simultaneously (P x params
  memory). Fine for <8B-param models; the faithful baseline.
- ``stream`` (beyond-paper, memory-optimal): three scans over participants with
  delta recomputation (the FL analogue of gradient checkpointing) —
    pass 1: accumulate the fresh-average and per-participant ||u||^2;
    pass 2: recompute deltas, collect <u_hat, u_s> -> exact Lam_s, Eq. 2 weights;
    pass 3: recompute deltas, accumulate the weighted aggregate.
  Memory is O(1) in P (2 param-sized accumulators); compute is 3x. Which side
  of that trade wins is a §Perf question (see EXPERIMENTS.md).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.staleness import EPS, SCALING_RULES
from repro.models import ModelConfig
from repro.models.transformer import lm_loss


# ---------------------------------------------------------------------------
# Pytree helpers (no giant concat — norms/inner products leaf-wise)
# ---------------------------------------------------------------------------


def _tree_dot(a, b):
    # NOTE: jnp.vdot ravels its operands — a flat reshape of a sharded tensor
    # forces an all-gather under SPMD. sum(x*y) keeps the layout sharded.
    return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _tree_sq(a):
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
               for x in jax.tree.leaves(a))


def _tree_axpy(alpha, x, y):
    """alpha * x + y over pytrees (fp32 accumulate)."""
    return jax.tree.map(lambda a, b: alpha * a.astype(jnp.float32) + b, x, y)


def _zeros_like_f32(tree, specs=None):
    z = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), tree)
    return _constrain_like(z, specs)


def _constrain_like(tree, specs):
    """Pin a param-shaped intermediate (accumulator/aggregate) to the param
    partition specs — freshly-created buffers are otherwise unconstrained and
    the partitioner happily replicates 50B-param fp32 accumulators."""
    if specs is None:
        return tree
    return jax.tree.map(
        lambda l, s: jax.lax.with_sharding_constraint(l, s), tree, specs)


def _relay_weights(fresh, tau, lam, *, rule, beta):
    lam_max = jnp.max(jnp.where(~fresh, lam, 0.0))
    w = jnp.where(fresh, 1.0, SCALING_RULES[rule](tau, lam, lam_max, beta))
    return w / jnp.maximum(w.sum(), EPS)


# ---------------------------------------------------------------------------
# Participant-local update (K local SGD steps; Alg. 2 inner loop)
# ---------------------------------------------------------------------------


def _participant_delta_fn(cfg: ModelConfig, local_lr: float, local_steps: int,
                          param_specs=None):
    def delta_fn(params, pbatch):
        def one_step(p, _):
            loss, grads = jax.value_and_grad(
                lambda q: lm_loss(cfg, q, pbatch))(p)
            # pin grads to the param layout: nudges XLA to reduce-scatter the
            # token-sharded partial grads instead of all-reducing full tensors
            grads = _constrain_like(grads, param_specs)
            p = jax.tree.map(
                lambda w, g: (w.astype(jnp.float32)
                              - local_lr * g.astype(jnp.float32)).astype(w.dtype),
                p, grads)
            return p, loss
        final, losses = jax.lax.scan(one_step, params, None, length=local_steps)
        delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                             final, params)
        return delta, losses.mean()
    return delta_fn


# ---------------------------------------------------------------------------
# Cohort strategies
# ---------------------------------------------------------------------------


def make_fl_aggregate_step(cfg: ModelConfig, *, local_lr: float = 1e-2,
                           rule: str = "relay", beta: float = 0.35,
                           local_steps: int = 1, cohort: str = "vmap",
                           param_specs=None) -> Callable:
    """Returns agg_step(params, batch, fresh, tau) -> (agg_delta, metrics) —
    the SAA-weighted cohort aggregate, before any server optimizer."""
    return _make_step_impl(cfg, local_lr=local_lr, rule=rule, beta=beta,
                           local_steps=local_steps, cohort=cohort,
                           param_specs=param_specs)


def make_fl_train_step(cfg: ModelConfig, *, local_lr: float = 1e-2,
                       server_lr: float = 1.0, rule: str = "relay",
                       beta: float = 0.35, local_steps: int = 1,
                       cohort: str = "vmap", param_specs=None) -> Callable:
    """FedAvg-server step (Alg. 2): step(params, batch, fresh, tau)
    -> (params, metrics). batch leaves have leading participant axis P."""
    impl = make_fl_aggregate_step(cfg, local_lr=local_lr, rule=rule, beta=beta,
                                  local_steps=local_steps, cohort=cohort,
                                  param_specs=param_specs)

    def step(params, batch, fresh, tau):
        agg, metrics = impl(params, batch, fresh, tau)
        new = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + server_lr * d
                          ).astype(p.dtype), params, agg)
        return new, metrics
    return step


def make_fl_train_step_yogi(cfg: ModelConfig, *, yogi_lr: float = 1e-2,
                            **kw) -> Callable:
    """YoGi-server step (the paper's aggregator for the non-CIFAR benchmarks):
    step(params, opt_state, batch, fresh, tau) -> (params, opt_state, metrics).
    opt_state from ``repro.core.aggregation.yogi_init``."""
    from repro.core.aggregation import yogi_apply
    impl = make_fl_aggregate_step(cfg, **kw)

    def step(params, opt_state, batch, fresh, tau):
        agg, metrics = impl(params, batch, fresh, tau)
        new, new_state = yogi_apply(params, agg, opt_state, lr=yogi_lr)
        return new, new_state, metrics
    return step


def _make_step_impl(cfg: ModelConfig, *, local_lr, rule, beta, local_steps,
                    cohort, param_specs) -> Callable:
    delta_fn = _participant_delta_fn(cfg, local_lr, local_steps, param_specs)

    def finish(params, agg, loss, weights):
        return agg, {"loss": loss, "weights": weights}

    if cohort == "vmap":
        def step(params, batch, fresh, tau):
            deltas, losses = jax.vmap(delta_fn, in_axes=(None, 0))(params, batch)
            fresh_f = fresh.astype(jnp.float32)
            n_f = jnp.maximum(fresh_f.sum(), 1.0)
            u_hat = _constrain_like(jax.tree.map(
                lambda d: jnp.einsum("p,p...->...", fresh_f, d) / n_f, deltas),
                param_specs)
            # Lam_s = ||u_hat - (u_s + n_F u_hat)/(n_F+1)||^2 / ||u_hat||^2
            #       = ||u_hat - u_s||^2 / ((n_F+1)^2 ||u_hat||^2)
            diff_sq = sum(
                jnp.sum((h[None] - d) ** 2, axis=tuple(range(1, d.ndim)))
                for h, d in zip(jax.tree.leaves(u_hat), jax.tree.leaves(deltas)))
            lam = diff_sq / ((n_f + 1.0) ** 2 * (_tree_sq(u_hat) + EPS))
            lam = jnp.where(fresh, 0.0, lam)
            w = _relay_weights(fresh, tau, lam, rule=rule, beta=beta)
            agg = _constrain_like(
                jax.tree.map(lambda d: jnp.einsum("p,p...->...", w, d), deltas),
                param_specs)
            return finish(params, agg, losses.mean(), w)
        return step

    if cohort == "stream":
        def step(params, batch, fresh, tau):
            fresh_f = fresh.astype(jnp.float32)
            n_f = jnp.maximum(fresh_f.sum(), 1.0)

            # pass 1: fresh average + per-participant squared norms
            def p1(carry, inp):
                acc, loss_acc = carry
                pbatch, is_fresh = inp
                delta, loss = delta_fn(params, pbatch)
                acc = _constrain_like(_tree_axpy(is_fresh, delta, acc),
                                      param_specs)
                return (acc, loss_acc + loss), _tree_sq(delta)
            (fresh_sum, loss_sum), sq = jax.lax.scan(
                p1, (_zeros_like_f32(params, param_specs), 0.0),
                (batch, fresh_f))
            u_hat = jax.tree.map(lambda a: a / n_f, fresh_sum)
            uhat_sq = _tree_sq(u_hat)

            # pass 2: exact deviations via <u_hat, u_s> (recompute deltas)
            def p2(carry, pbatch):
                delta, _loss = delta_fn(params, pbatch)
                return carry, _tree_dot(u_hat, delta)
            _, dots = jax.lax.scan(p2, None, batch)
            diff_sq = uhat_sq - 2.0 * dots + sq
            lam = jnp.where(fresh, 0.0,
                            diff_sq / ((n_f + 1.0) ** 2 * (uhat_sq + EPS)))
            w = _relay_weights(fresh, tau, lam, rule=rule, beta=beta)

            # pass 3: weighted aggregate (recompute deltas)
            def p3(acc, inp):
                pbatch, wi = inp
                delta, _ = delta_fn(params, pbatch)
                return _constrain_like(_tree_axpy(wi, delta, acc),
                                       param_specs), None
            agg, _ = jax.lax.scan(p3, _zeros_like_f32(params, param_specs),
                                  (batch, w))
            p_count = fresh.shape[0]
            return finish(params, agg, loss_sum / p_count, w)
        return step

    raise ValueError(cohort)


STREAM_THRESHOLD = 8e9
# §Perf iteration 8 (EXPERIMENTS.md): tried raising this to 20e9 so deepseek
# (15.7B) uses the vmap cohort — compute dropped 1.8x and collectives 2.3x but
# per-chip temp memory exploded 8 GB -> 171 GB (P x fp32 deltas). Net refuted;
# the 3x-recompute stream cohort is the right trade above ~8B params.


def default_cohort(cfg: ModelConfig, params_shape) -> str:
    import math
    n = sum(math.prod(l.shape) for l in jax.tree.leaves(params_shape))
    return "stream" if n > STREAM_THRESHOLD else "vmap"


# ---------------------------------------------------------------------------
# CLI driver: host-scale federated training of a reduced assigned arch
# ---------------------------------------------------------------------------


def _main():
    import argparse
    import numpy as np
    from repro.configs import get_reduced
    from repro.data import federated_token_shards
    from repro.models import init_params

    ap = argparse.ArgumentParser(description="FL-cohort training (reduced arch)")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--participants", type=int, default=4)
    ap.add_argument("--local-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--rule", default="relay")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    shards = federated_token_shards(cfg.vocab_size, 32, 64, args.seq, skew=0.3)
    rng = np.random.default_rng(0)
    step = jax.jit(make_fl_train_step(cfg, local_lr=0.05, rule=args.rule))
    for r in range(args.rounds):
        lids = rng.choice(len(shards), args.participants, replace=False)
        sel = lambda k: np.stack([shards[l][k][rng.integers(
            0, len(shards[l][k]), args.local_batch)] for l in lids])
        fresh = np.ones(args.participants, bool)
        tau = np.zeros(args.participants, np.int32)
        if r % 3 == 0 and args.participants > 1:
            fresh[-1] = False
            tau[-1] = 2
        params, m = step(params, {"tokens": sel("tokens"), "labels": sel("labels")},
                         jnp.asarray(fresh), jnp.asarray(tau))
        if (r + 1) % 10 == 0:
            print(f"round {r+1:4d} loss={float(m['loss']):.4f}")
    print("done")


if __name__ == "__main__":
    _main()
