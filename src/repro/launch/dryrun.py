import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (arch x input shape x mesh) lowers+compiles.

For each combination this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct inputs (repro.launch.shardings.input_specs),
  3. jit(...).lower(...).compile() the train / prefill / decode step,
  4. records memory_analysis(), cost_analysis(), and the parsed collective
     bytes into experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import math
import time

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, adapt_for_shape, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import input_specs, named, param_pspecs
from repro.launch.train import default_cohort, make_fl_train_step
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.launch.mesh import batch_axes as mesh_batch_axes
from repro.models import init_params, shard_hints
from repro.roofline.analysis import (active_params, collective_bytes_from_hlo,
                                     model_flops, roofline_terms)
from repro.roofline.hlo_cost import analyze_hlo

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              cohort: str = "auto", save: bool = True, verbose: bool = True,
              overrides: dict | None = None, variant: str = "",
              stream_participants: int = 8):
    shape = INPUT_SHAPES[shape_name]
    base_cfg = get_config(arch)
    arch = base_cfg.arch_id  # canonical hyphenated id for records
    cfg = adapt_for_shape(base_cfg, shape)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if variant:
        arch = f"{arch}@{variant}"
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: init_params(cfg, k), key)
    pspecs = param_pspecs(cfg, params_shape, mesh)
    chosen_cohort = (default_cohort(cfg, params_shape)
                     if cohort == "auto" else cohort)
    spec = input_specs(cfg, shape, mesh, cohort=chosen_cohort,
                       stream_participants=stream_participants)

    if shape.kind == "train":
        step = make_fl_train_step(cfg, cohort=chosen_cohort,
                                  param_specs=pspecs)
        in_shardings = (named(mesh, pspecs),
                        named(mesh, spec.arg_specs["batch"]),
                        named(mesh, spec.arg_specs["fresh"]),
                        named(mesh, spec.arg_specs["tau"]))
        args = (params_shape, spec.args["batch"], spec.args["fresh"],
                spec.args["tau"])
    elif shape.kind == "prefill":
        chosen_cohort = "-"
        step = make_prefill_step(cfg)
        in_shardings = (named(mesh, pspecs), named(mesh, spec.arg_specs["batch"]))
        args = (params_shape, spec.args["batch"])
    else:
        chosen_cohort = "-"
        step = make_decode_step(cfg)
        in_shardings = (named(mesh, pspecs), named(mesh, spec.arg_specs["state"]),
                        named(mesh, spec.arg_specs["tokens"]),
                        named(mesh, spec.arg_specs["position"]))
        args = (params_shape, spec.args["state"], spec.args["tokens"],
                spec.args["position"])

    # activation/expert layout pins (see repro.models.shard_hints):
    # - stream cohort & serve paths: batch dim rides the batch axes
    # - vmap cohort: participants consume the batch axes, inner batch unsharded
    baxes = mesh_batch_axes(mesh)
    if shape.kind == "train":
        hint_batch = baxes if chosen_cohort == "stream" else None
    else:
        n_shards = math.prod(mesh.shape[a] for a in baxes)
        hint_batch = baxes if shape.global_batch % n_shards == 0 else None

    t0 = time.time()
    with shard_hints.hints(batch_axes=hint_batch, model_axis="model"):
        with mesh:
            lowered = jax.jit(step, in_shardings=in_shardings).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: getattr(mem, k) for k in
                 ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)}
    except Exception:
        mem_d = {}
    hlo = compiled.as_text()
    # trip-count-aware accounting (XLA's cost_analysis counts scan bodies once)
    walked = analyze_hlo(hlo)
    cost = {"flops": walked.get("flops", 0.0),
            "bytes accessed": walked.get("bytes", 0.0),
            "xla_flops_raw": cost.get("flops", 0.0),
            "xla_bytes_raw": cost.get("bytes accessed", 0.0)}
    coll = {k.replace("coll_", ""): v for k, v in walked.items()
            if k.startswith("coll_")}
    coll.setdefault("total", walked.get("coll_total", 0.0))
    coll["counts"] = {}

    n_active = active_params(cfg, params_shape)
    n_total = sum(math.prod(l.shape) for l in jax.tree.leaves(params_shape))
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(n_active, tokens, "train")
    elif shape.kind == "prefill":
        mf = model_flops(n_active, shape.global_batch * shape.seq_len, "infer")
    else:
        mf = model_flops(n_active, shape.global_batch, "infer")

    arg_bytes = mem_d.get("argument_size_in_bytes", float("nan"))
    report = roofline_terms(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost=cost, coll_bytes=coll["total"], model_flops_val=mf,
        per_device_hbm=arg_bytes + mem_d.get("temp_size_in_bytes", 0))

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind, "cohort": chosen_cohort,
        "n_params": n_total, "n_active_params": n_active,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_d,
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "roofline": dataclasses.asdict(report),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        print(f"[OK] {arch:22s} {shape_name:12s} mesh={mesh_name:8s} "
              f"cohort={chosen_cohort:6s} "
              f"flops/chip={report.hlo_flops:.2e} coll={coll['total']:.2e}B "
              f"bottleneck={report.bottleneck:10s} "
              f"useful={report.useful_ratio:.2f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"     memory_analysis: {mem_d}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cohort", default="auto")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/float/bool literal)")
    ap.add_argument("--variant", default="", help="label for override records")
    ap.add_argument("--stream-participants", type=int, default=8)
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = eval(v, {}, {})  # noqa: S307 - CLI literals
        except Exception:
            overrides[k] = v

    archs = ([a.replace("_", "-").replace("-", "-") for a in ARCH_IDS]
             if args.arch == "all" else [args.arch])
    if args.arch == "all":
        archs = [a for a in ARCH_IDS]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    lower_one(arch, shape, multi_pod=mp, cohort=args.cohort,
                              overrides=overrides, variant=args.variant,
                              stream_participants=args.stream_participants)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((arch, shape, mp, repr(e)[:300]))
                    print(f"[FAIL] {arch} {shape} multi_pod={mp}: {e!r}"[:500])
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
