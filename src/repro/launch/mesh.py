"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries
pure data/cohort parallelism (params replicated per pod, deltas all-reduced
across pods), matching the FL-cohort mapping in DESIGN.md §3.

Defined as functions so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names — lets the same sharded
    programs run on this CPU container for smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch / participant-cohort dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
