"""Serve-path steps: prefill (full prompt) and single-token decode.

In the FL system these serve the *global* model (e.g. server-side eval or
deployment of the trained model); they are also the lowered programs for the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` input shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, prefill
from repro.models.transformer import forward, _logits


def make_prefill_step(cfg: ModelConfig):
    def step(params, batch):
        logits, states = prefill(cfg, params, batch)
        return logits, states
    return step


def make_decode_step(cfg: ModelConfig):
    def step(params, state, tokens, position):
        return decode_step(cfg, params, state, tokens, position)
    return step


def make_logits_fn(cfg: ModelConfig):
    """Full-sequence logits (eval/perplexity path)."""
    def fn(params, batch):
        x, aux, _ = forward(cfg, params, batch)
        return _logits(cfg, params, x)
    return fn


def greedy_generate(cfg: ModelConfig, params, state, first_token, start_pos,
                    n_tokens: int):
    """Host-loop greedy decoding used by the serving example."""
    toks = [first_token]
    pos = start_pos
    step = jax.jit(make_decode_step(cfg))
    cur = first_token
    for _ in range(n_tokens):
        logits, state = step(params, state, cur, pos)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(cur)
        pos = pos + 1
    return jnp.stack(toks, axis=1), state
