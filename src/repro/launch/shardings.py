"""Sharding rules: parameter partitioning + input specs per (arch x shape).

Logical plan (DESIGN.md §6):
- batch / participant cohort -> ("pod", "data") mesh axes;
- tensor parallelism -> "model": attention q/o heads, FFN hidden, MoE experts,
  vocab;
- FSDP for >8B-param archs: the non-"model" matrix dim additionally sharded on
  "data" (within a pod);
- decode KV caches are sharded over the *sequence* dim on "model" — kv-head
  counts (2..36) do not generally divide the 16-way axis, sequence always does;
- stacked super-block params carry a leading scan dim that is never sharded.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape
from repro.launch.mesh import batch_axes
from repro.models import ModelConfig, init_params, init_decode_state

FSDP_THRESHOLD = 8e9  # params; above this the "data" axis also shards weights


# ---------------------------------------------------------------------------
# Parameter partitioning
# ---------------------------------------------------------------------------

# leaf-name -> (spec for 2-D (in, out) matrices): "col" = out on model,
# "row" = in on model, "rep" = replicated
_MATRIX_RULE = {
    "w_q": "col", "w_k": "col", "w_v": "col", "w_gate": "col", "w_up": "col",
    "w_in": "col", "w_dt": "col", "w_r": "col", "w_g": "col", "w_out": "col",
    "lora_b": "col", "w_uk": "col", "w_uv": "col", "proj": "col",
    "w_o": "row", "w_down": "row",
    "w_dkv": "rep", "w_kr": "rep", "w_x": "row", "router": "rep",
    "lora_a": "rep",
    # rwkv w_k/w_v collide with attention names — both are (d, d) col. fine.
}

_VEC_MODEL = {"b_q", "b_k", "b_v", "conv_b", "dt_bias", "D"}


def _leaf_spec(name: str, shape, fsdp: bool, model_divides) -> P:
    nd = len(shape)
    f = "data" if fsdp else None
    if name == "embedding":                      # (V, d)
        return P("model", f)
    if name in ("A_log",):                       # (d_inner, N)
        return P("model", None)
    if name == "conv_w":                         # (W, d_inner)
        return P(None, "model")
    if name in _VEC_MODEL and nd == 1:
        return P("model") if model_divides(shape[0]) else P(None)
    if nd == 1 or name in ("w0", "u", "mu_x", "scale", "ln_x_scale") \
            or name.startswith("mu_"):
        return P(*([None] * nd))
    rule = _MATRIX_RULE.get(name)
    if rule == "col":
        if nd == 3:                              # MoE experts (E, d, f)
            return P("model", f, None)
        return P(f, "model") if model_divides(shape[-1]) else P(f, None)
    if rule == "row":
        if nd == 3:                              # MoE (E, f, d)
            return P("model", None, f)
        return P("model", f) if model_divides(shape[0]) else P(None, f)
    return P(*([None] * nd))


def param_pspecs(cfg: ModelConfig, params_shape, mesh) -> Any:
    """PartitionSpec pytree matching ``init_params`` structure.

    ``params_shape``: eval_shape of init_params (leaves have .shape).
    """
    n_params = sum(math.prod(l.shape) for l in jax.tree.leaves(params_shape))
    fsdp = n_params > FSDP_THRESHOLD and "data" in mesh.axis_names
    m_size = mesh.shape["model"]
    d_size = mesh.shape["data"]

    def divides(n):
        return n % m_size == 0

    def spec_for(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = names[-1]
        stacked = "stack" in names
        base = _leaf_spec(name, leaf.shape[1:] if stacked else leaf.shape,
                          fsdp, divides)
        # FSDP sanity: drop "data" from dims it doesn't divide
        dims = (leaf.shape[1:] if stacked else leaf.shape)
        fixed = []
        for ax, d in zip(base, dims):
            if ax == "data" and d % d_size != 0:
                ax = None
            if ax == "model" and d % m_size != 0:
                ax = None
            fixed.append(ax)
        base = P(*fixed)
        return P(None, *base) if stacked else base

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepSpec:
    """Everything dryrun/train/serve need to lower one step."""
    kind: str                  # train | prefill | decode
    args: dict                 # name -> ShapeDtypeStruct pytree
    arg_specs: dict            # name -> PartitionSpec pytree
    n_participants: int = 0


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_batch(cfg: ModelConfig, B: int, S: int, lead=()):
    """Token batch struct (+frontend embeds for VLM; text seq shrinks)."""
    s_text = S - (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    b = {"tokens": _sds(lead + (B, s_text), jnp.int32),
         "labels": _sds(lead + (B, s_text), jnp.int32)}
    if cfg.frontend == "vision":
        b["frontend_embeds"] = _sds(lead + (B, cfg.n_frontend_tokens,
                                            cfg.d_frontend), jnp.bfloat16)
    return b


def _batch_pspec(batch_struct, baxes):
    def spec(leaf):
        return P(baxes, *([None] * (len(leaf.shape) - 1)))
    return jax.tree.map(spec, batch_struct)


def input_specs(cfg: ModelConfig, shape: InputShape, mesh,
                cohort: str = "vmap", stream_participants: int = 8) -> StepSpec:
    baxes = batch_axes(mesh)
    n_batch_shards = math.prod(mesh.shape[a] for a in baxes)

    if shape.kind == "train":
        if cohort == "stream":
            # participants are scanned in time; each participant's LOCAL batch
            # rides the ("pod","data") axes so no chip idles during the scan
            p = stream_participants
            local_b = shape.global_batch // p
            assert local_b % n_batch_shards == 0, (local_b, n_batch_shards)
            def bspec(leaf):
                return P(None, baxes, *([None] * (len(leaf.shape) - 2)))
        else:
            # whole cohort in flight: the participant axis IS the batch axis
            p = max(16, n_batch_shards)
            local_b = shape.global_batch // p
            def bspec(leaf):
                return P(baxes, *([None] * (len(leaf.shape) - 1)))
        batch = _token_batch(cfg, local_b, shape.seq_len, lead=(p,))
        args = {"batch": batch,
                "fresh": _sds((p,), jnp.bool_),
                "tau": _sds((p,), jnp.int32)}
        arg_specs = {"batch": jax.tree.map(bspec, batch),
                     "fresh": P(None), "tau": P(None)}
        return StepSpec("train", args, arg_specs, n_participants=p)

    if shape.kind == "prefill":
        B = shape.global_batch
        batch = _token_batch(cfg, B, shape.seq_len)
        bspec = _batch_pspec(batch, baxes)
        return StepSpec("prefill", {"batch": batch}, {"batch": bspec})

    # decode: one new token against a seq_len cache
    B = shape.global_batch
    state = jax.eval_shape(lambda: init_decode_state(cfg, B, shape.seq_len))
    shard_batch = B % n_batch_shards == 0

    m_size = mesh.shape["model"]

    def state_spec(path, leaf):
        # caches: (B, Sc, ...) -> batch on baxes (if divisible), Sc on "model";
        # stacked super-block states carry a leading unsharded scan dim.
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        stacked = "stack" in names
        dims = list(leaf.shape[1:] if stacked else leaf.shape)
        spec = [baxes if shard_batch else None]
        if len(dims) >= 2:
            seq_ok = dims[1] >= 1024 and dims[1] % m_size == 0
            spec.append("model" if seq_ok else None)
        spec += [None] * (len(dims) - len(spec))
        spec = spec[:len(dims)]
        return P(None, *spec) if stacked else P(*spec)

    sspec = jax.tree_util.tree_map_with_path(state_spec, state)
    args = {"state": state,
            "tokens": _sds((B,), jnp.int32),
            "position": _sds((B,), jnp.int32)}
    arg_specs = {"state": sspec,
                 "tokens": P(baxes) if shard_batch else P(None),
                 "position": P(baxes) if shard_batch else P(None)}
    return StepSpec("decode", args, arg_specs)
