"""Jit wrapper matching the model-side (B, S, H, N) layout + padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.wkv6 import CHUNK, wkv6_bhsn


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r, k, v, w, u, state0=None, *, interpret: bool = True):
    """r,k,v,w: (B, S, H, N); u: (H, N); state0: (B, H, N, N) | None.

    Returns (y (B, S, H, N), final state (B, H, N, N)).
    """
    B, S, H, N = r.shape
    pad = (-S) % CHUNK
    if pad:
        # pad with w=1 (identity decay) and k=0 so padded steps leave S alone
        pz = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, pz)
        k = jnp.pad(k, pz)
        v = jnp.pad(v, pz)
        w = jnp.pad(w, pz, constant_values=1.0)
    Sp = S + pad

    def to_bh(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, Sp, N)

    s0 = (jnp.zeros((B * H, N, N), jnp.float32) if state0 is None
          else state0.reshape(B * H, N, N).astype(jnp.float32))
    u_bh = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)
    y, s_fin = wkv6_bhsn(to_bh(r).astype(jnp.float32), to_bh(k).astype(jnp.float32),
                         to_bh(v).astype(jnp.float32), to_bh(w).astype(jnp.float32),
                         u_bh.astype(jnp.float32), s0, interpret=interpret)
    y = y.reshape(B, H, Sp, N).transpose(0, 2, 1, 3)[:, :S]
    return y.astype(v.dtype), s_fin.reshape(B, H, N, N)
