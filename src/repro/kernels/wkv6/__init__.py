from repro.kernels.wkv6 import ops, ref  # noqa: F401
