"""RWKV6 WKV recurrence Pallas TPU kernel (chunked sequential scan).

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T) ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T

The GPU reference implementation (RWKV-CUDA) assigns one thread per (head,
channel) and marches time in registers.  The TPU adaptation keeps the
(N x N) per-head state resident in VMEM scratch across a grid of time chunks
(grid innermost = chunk index, sequential on TPU), and expresses each step's
rank-1 update as (N,1)x(1,N) outer products on the VPU.  HBM traffic is one
read of (r,k,v,w) and one write of y per chunk — the state never leaves VMEM.

Grid: (B*H, n_chunks); blocks: (CHUNK, N) per operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

CHUNK = 128


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_out_ref,
                 s_scr, *, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)   # (CHUNK, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # (1, N) bonus, per head

    def step(t, carry):
        s, y = carry                    # s: (N, N) keyed k-dim x v-dim
        kt = k[t][:, None]              # (N, 1)
        vt = v[t][None, :]              # (1, N)
        kv = kt * vt                    # (N, N)
        yt = (r[t][:, None] * (s + u.T * kv)).sum(axis=0)   # (N,)
        y = y.at[t].set(yt)
        s = w[t][:, None] * s + kv
        return s, y

    s0 = s_scr[...]
    y0 = jnp.zeros_like(r)
    s_fin, y = jax.lax.fori_loop(0, r.shape[0], step, (s0, y0))
    s_scr[...] = s_fin
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _final():
        s_out_ref[0] = s_fin


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_bhsn(r, k, v, w, u, s0, *, interpret: bool = True):
    """r,k,v,w: (BH, S, N); u: (BH, 1, N); s0: (BH, N, N); S % CHUNK == 0.

    Returns (y (BH, S, N), s_final (BH, N, N)).
    """
    BH, S, N = r.shape
    n_chunks = S // CHUNK
    kernel = functools.partial(_wkv6_kernel, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, CHUNK, N), lambda bh, c: (bh, c, 0))
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, 1, N), lambda bh, c: (bh, 0, 0)),
            pl.BlockSpec((1, N, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, N, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, N), v.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_fin
