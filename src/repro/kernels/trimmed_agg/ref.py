"""Pure-jnp oracle for the trimmed-mean kernel — delegates to the core
robust module (the sort-based formula IS the reference semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.robust.aggregators import trimmed_from_sorted


def trimmed_ref(y, k_eff, c):
    """Sort-based band mean for one cell: y (n, D), scalar k_eff / c."""
    return trimmed_from_sorted(jnp.sort(y, axis=0), c, k_eff)


def sweep_trimmed_ref(y, k_eff, c):
    """Batched oracle: y (S, n, D), k_eff / c (S,) -> (S, D)."""
    return jax.vmap(trimmed_ref)(y, k_eff, c)
