"""Per-coordinate trimmed-mean aggregation Pallas TPU kernel.

The robust coordinate-wise aggregators (``trimmed_mean``,
``coord_median`` — ``repro.robust``) need, per coordinate, the mean of
the sorted values inside the index band ``[k_eff, c - k_eff)``.  A full
per-column sort of the ``(n, D)`` operand is O(D·n log n) and Pallas has
no sort primitive; instead the kernel streams the rows and computes each
row's *rank* per coordinate (count of values strictly smaller, ties
broken by row index — exactly a stable sort's order), accumulating rows
whose rank falls inside the band.  O(n^2) per coordinate with n <= a few
hundred cohort rows, one grid traversal over ``(cell, D-block)``, no
host round-trip, and ``k_eff`` / ``c`` are *traced* per-cell scalars so
one compiled kernel serves every trim level and cohort size.

Excluded rows (invalid padding, screened rows, NaN scrub) arrive as
``+inf`` (``repro.robust.aggregators.weighted_rows``): their rank is
``>= c`` so they always fall past the band — appending them never
changes which finite values the band selects.

``interpret=None`` auto-detects the backend like ``staleness_agg``:
compiled on TPU, interpreter elsewhere (CPU tests / CI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.staleness_agg.staleness_agg import (D_BLK,
                                                       _resolve_interpret)


def _trimmed_kernel(y_ref, kp_ref, out_ref):
    """One (cell, D-block) tile of the rank-select trimmed mean.

    y_ref: (1, n, D_BLK) fp32 rows; kp_ref: (1, 2) fp32 ``[k_eff, c]``;
    out_ref: (1, D_BLK) the band mean.
    """
    y = y_ref[0]                                    # (n, D_BLK)
    k = kp_ref[0, 0]
    c = kp_ref[0, 1]
    n = y.shape[0]
    ridx = jax.lax.broadcasted_iota(jnp.float32, y.shape, 0)

    def body(i, acc):
        yi = jax.lax.dynamic_slice_in_dim(y, i, 1, axis=0)      # (1, D_BLK)
        fi = i.astype(jnp.float32)
        less = (y < yi) | ((y == yi) & (ridx < fi))
        rank = jnp.sum(less.astype(jnp.float32), axis=0, keepdims=True)
        inc = (rank >= k) & (rank < c - k)
        return acc + jnp.where(inc, yi, 0.0)

    acc = jax.lax.fori_loop(0, n, body,
                            jnp.zeros((1, y.shape[1]), jnp.float32))
    out_ref[...] = acc / jnp.maximum(c - 2.0 * k, 1.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sweep_trimmed_aggregate(y, k_eff, c, *, interpret=None):
    """Band means for S cells in one launch.

    y: (S, n, D) fp32 with excluded rows ``+inf``, D % D_BLK == 0;
    k_eff / c: (S,) int32 per-cell trim depth and valid-row count
    (traced — no recompile across trim levels).  Returns (S, D).
    """
    interpret = _resolve_interpret(interpret)
    s, n, d = y.shape
    assert d % D_BLK == 0
    kp = jnp.stack([k_eff.astype(jnp.float32),
                    c.astype(jnp.float32)], axis=1)
    out = pl.pallas_call(
        _trimmed_kernel,
        grid=(s, d // D_BLK),
        in_specs=[
            pl.BlockSpec((1, n, D_BLK), lambda s_, i: (s_, 0, i)),
            pl.BlockSpec((1, 2), lambda s_, i: (s_, 0)),
        ],
        out_specs=pl.BlockSpec((1, D_BLK), lambda s_, i: (s_, i)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=interpret,
    )(y.astype(jnp.float32), kp)
    return out
