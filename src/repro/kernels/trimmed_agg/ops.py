"""Padding wrapper for the trimmed-mean kernel (the entry every caller
uses: the fused round body, the sweep executor, the engine host paths)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.trimmed_agg.trimmed_agg import (D_BLK,
                                                   sweep_trimmed_aggregate
                                                   as _kernel)


def sweep_trimmed_aggregate(y, k_eff, c, *, interpret=None):
    """y: (S, n, D) fp32 with excluded rows ``+inf``; k_eff / c: (S,)
    int32.  Pads the feature axis to a ``D_BLK`` multiple (zero columns:
    every valid row ties at 0, the band mean of zeros is 0) and truncates
    it back.  Returns (S, D)."""
    s, n, d = y.shape
    pad = (-d) % D_BLK
    if pad:
        y = jnp.pad(y, ((0, 0), (0, 0), (0, pad)))
    out = _kernel(y, k_eff, c, interpret=interpret)
    return out[:, :d]
