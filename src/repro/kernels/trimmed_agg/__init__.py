from repro.kernels.trimmed_agg import ops, ref  # noqa: F401
