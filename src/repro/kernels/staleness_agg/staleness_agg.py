"""Fused staleness-aware aggregation (SAA, Eq. 2) Pallas TPU kernels.

Aggregating n participant updates of D parameters (D ~ 1e8+) is the server-side
hot-spot RELAY adds: a naive implementation materializes the mixed update
``(u_s + n_F u_hat)/(n_F+1)`` per straggler (n x D extra bytes).  The kernels
here never materialize the mixed tensor; three entry points:

  - ``deviation_partials`` / ``weighted_aggregate``: the original two-launch
    pair (deviation partials, then host-side weights, then a weighted matvec);
  - ``fused_staleness_aggregate``: ONE kernel launch, one grid traversal over a
    ``(phase, D-block)`` grid.  Phase 0 accumulates each update's deviation
    numerator and the ||u_hat||^2 denominator into resident VMEM accumulators;
    at the phase boundary the Eq. 2 weights are computed *in-kernel* (no host
    round-trip, O(n) work on the (n,1) accumulators); phase 1 streams U again
    for the weighted matvec ``w @ U``;
  - ``fused_staleness_apply``: same traversal, but phase 1 emits
    ``params + lr * (w @ U)`` with the params buffer aliased input->output, so
    the server step is a single in-place kernel.

All passes are grid-sequential with accumulator outputs (constant index maps
keep the (n,1)/(1,1) accumulators VMEM-resident across the whole grid), the
TPU-idiomatic replacement for the GPU's atomics-based reductions.

``interpret=None`` on every entry point auto-detects the backend: compiled on
TPU, interpreter elsewhere (CPU tests / CI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.staleness import EPS, SCALING_RULES

D_BLK = 2048  # lane-aligned (16 x 128); (n<=64) x 2048 fp32 = 512 KB per operand


def default_interpret() -> bool:
    """Pallas interpret mode unless running on a real TPU backend."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret):
    return default_interpret() if interpret is None else interpret


def _deviation_increments(u, fresh):
    """Eq. 2 deviation partials for one (n, D_BLK) tile.

    u: (n, D_BLK) fp32; fresh: (n, 1) fp32 {0,1}.  Returns the tile's
    contribution (num (n, 1), den (1, 1)) — the single implementation of the
    partials math shared by every kernel variant.
    """
    n_f = jnp.maximum(fresh.sum(), 1.0)
    u_hat = (u * fresh).sum(axis=0, keepdims=True) / n_f      # (1, D_BLK)
    mixed = (u + n_f * u_hat) / (n_f + 1.0)
    num = ((u_hat - mixed) ** 2).sum(axis=1, keepdims=True)   # (n, 1)
    den = (u_hat ** 2).sum().reshape(1, 1)
    return num, den


def _deviation_kernel(u_ref, fresh_ref, num_ref, den_ref):
    """Accumulate per-update deviation partials over D blocks.

    u_ref: (n, D_BLK) fp32; fresh_ref: (n, 1) fp32 {0,1}
    num_ref: (n, 1) accumulator; den_ref: (1, 1) accumulator.
    """
    i = pl.program_id(0)
    num, den = _deviation_increments(u_ref[...], fresh_ref[...])

    @pl.when(i == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    num_ref[...] += num
    den_ref[...] += den


def _aggregate_kernel(w_ref, u_ref, out_ref):
    """out[D_BLK] = w (1, n) @ U (n, D_BLK)."""
    out_ref[...] = jnp.dot(w_ref[...], u_ref[...],
                           preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Single-traversal fused kernel
# ---------------------------------------------------------------------------


def _accumulate_partials(u, fresh, num_ref, den_ref):
    """Deviation partials for one (n, D_BLK) tile into the accumulators."""
    num, den = _deviation_increments(u, fresh)
    num_ref[...] += num
    den_ref[...] += den


def _compute_weights(rule, fresh, tau, beta, num, den, valid):
    """Eq. 2 normalized weights from the accumulated partials — all (n, 1).

    ``valid`` masks bucket-padding rows (zero weight, excluded from the
    stale max), mirroring ``core.staleness.staleness_weights``'s mask.
    """
    lam = jnp.where(fresh > 0, 0.0, num / (den + EPS))
    stale = (fresh <= 0) & (valid > 0)
    lam_max = jnp.max(jnp.where(stale, lam, 0.0))
    w_stale = SCALING_RULES[rule](tau, lam, lam_max, beta)
    w = jnp.where(fresh > 0, 1.0, w_stale)
    w = jnp.where(valid > 0, w, 0.0)
    return w / jnp.maximum(w.sum(), EPS)


def _make_fused_kernel(rule: str):
    def kernel(u_ref, fresh_ref, tau_ref, valid_ref, beta_ref,
               num_ref, den_ref, w_ref, out_ref):
        p = pl.program_id(0)      # phase: 0 = partials, 1 = aggregate
        i = pl.program_id(1)      # D block
        fresh = fresh_ref[...]    # (n, 1) fp32 {0, 1}

        @pl.when((p == 0) & (i == 0))
        def _init():
            num_ref[...] = jnp.zeros_like(num_ref)
            den_ref[...] = jnp.zeros_like(den_ref)
            w_ref[...] = jnp.zeros_like(w_ref)

        @pl.when(p == 0)
        def _partials():
            _accumulate_partials(u_ref[...], fresh, num_ref, den_ref)
            # keep the revisited output block defined on every grid step
            out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when((p == 1) & (i == 0))
        def _weights():
            w = _compute_weights(rule, fresh, tau_ref[...], beta_ref[0, 0],
                                 num_ref[...], den_ref[...], valid_ref[...])
            w_ref[...] = w.reshape(w_ref.shape)

        @pl.when(p == 1)
        def _agg():
            out_ref[...] = jnp.dot(w_ref[...], u_ref[...],
                                   preferred_element_type=jnp.float32)

    return kernel


def _make_fused_apply_kernel(rule: str):
    def kernel(params_ref, u_ref, fresh_ref, tau_ref, valid_ref, scal_ref,
               out_ref, num_ref, den_ref, w_ref):
        p = pl.program_id(0)
        i = pl.program_id(1)
        fresh = fresh_ref[...]

        @pl.when((p == 0) & (i == 0))
        def _init():
            num_ref[...] = jnp.zeros_like(num_ref)
            den_ref[...] = jnp.zeros_like(den_ref)
            w_ref[...] = jnp.zeros_like(w_ref)

        @pl.when(p == 0)
        def _partials():
            _accumulate_partials(u_ref[...], fresh, num_ref, den_ref)
            # copy-through: the output buffer aliases params, so phase 0's
            # write-back must preserve the values phase 1 re-reads
            out_ref[...] = params_ref[...]

        @pl.when((p == 1) & (i == 0))
        def _weights():
            w = _compute_weights(rule, fresh, tau_ref[...], scal_ref[0, 0],
                                 num_ref[...], den_ref[...], valid_ref[...])
            w_ref[...] = w.reshape(w_ref.shape)

        @pl.when(p == 1)
        def _apply():
            agg = jnp.dot(w_ref[...], u_ref[...],
                          preferred_element_type=jnp.float32)
            out_ref[...] = params_ref[...] + scal_ref[0, 1] * agg

    return kernel


def _make_sweep_fused_kernel(rule: str):
    """Fused SAA kernel with a leading sweep-grid axis: grid (S, phase, D
    blocks).  Each simulation ``s`` owns its own accumulator blocks (index
    maps select row ``s``), re-initialized at its (phase 0, block 0) step, so
    one launch aggregates a whole sweep's round with per-cell Eq. 2 weights
    and per-cell beta."""
    def kernel(u_ref, fresh_ref, tau_ref, valid_ref, beta_ref,
               num_ref, den_ref, w_ref, out_ref):
        p = pl.program_id(1)      # phase: 0 = partials, 1 = aggregate
        i = pl.program_id(2)      # D block
        fresh = fresh_ref[0]      # (n, 1) fp32 {0, 1}

        @pl.when((p == 0) & (i == 0))
        def _init():
            num_ref[...] = jnp.zeros_like(num_ref)
            den_ref[...] = jnp.zeros_like(den_ref)
            w_ref[...] = jnp.zeros_like(w_ref)

        @pl.when(p == 0)
        def _partials():
            num, den = _deviation_increments(u_ref[0], fresh)
            num_ref[0] += num
            den_ref[0] += den
            # keep the revisited output block defined on every grid step
            out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when((p == 1) & (i == 0))
        def _weights():
            w = _compute_weights(rule, fresh, tau_ref[0], beta_ref[0, 0],
                                 num_ref[0], den_ref[0], valid_ref[0])
            w_ref[...] = w.reshape(w_ref.shape)

        @pl.when(p == 1)
        def _agg():
            out_ref[0] = jnp.dot(w_ref[0], u_ref[0],
                                 preferred_element_type=jnp.float32)

    return kernel


def _make_sweep_fused_apply_kernel(rule: str):
    """Sweep-axis fused SAA **server step**: grid (S, phase, D blocks), the
    params buffer aliased input->output.  Phase 0 accumulates each cell's
    deviation partials (copying params through to the aliased output so the
    revisited blocks stay defined); phase 1 computes the per-cell Eq. 2
    weights in-kernel and emits ``params + lr_s * (w_s @ U_s)`` — the whole
    sweep's aggregation *and* batched server apply in one launch."""
    def kernel(params_ref, u_ref, fresh_ref, tau_ref, valid_ref, scal_ref,
               out_ref, num_ref, den_ref, w_ref):
        p = pl.program_id(1)      # phase: 0 = partials, 1 = apply
        i = pl.program_id(2)      # D block
        fresh = fresh_ref[0]      # (n, 1) fp32 {0, 1}

        @pl.when((p == 0) & (i == 0))
        def _init():
            num_ref[...] = jnp.zeros_like(num_ref)
            den_ref[...] = jnp.zeros_like(den_ref)
            w_ref[...] = jnp.zeros_like(w_ref)

        @pl.when(p == 0)
        def _partials():
            num, den = _deviation_increments(u_ref[0], fresh)
            num_ref[0] += num
            den_ref[0] += den
            # copy-through: the output aliases params, so phase 0's
            # write-back must preserve the values phase 1 re-reads
            out_ref[...] = params_ref[...]

        @pl.when((p == 1) & (i == 0))
        def _weights():
            w = _compute_weights(rule, fresh, tau_ref[0], scal_ref[0, 0],
                                 num_ref[0], den_ref[0], valid_ref[0])
            w_ref[...] = w.reshape(w_ref.shape)

        @pl.when(p == 1)
        def _apply():
            agg = jnp.dot(w_ref[0], u_ref[0],
                          preferred_element_type=jnp.float32)
            out_ref[...] = params_ref[...] + scal_ref[0, 1] * agg

    return kernel


@functools.partial(jax.jit, static_argnames=("rule", "interpret"))
def sweep_fused_staleness_apply(params, updates, fresh, tau, valid, scal, *,
                                rule="relay", interpret=None):
    """Batched fused server step: new_params[s] = params[s] + lr_s * (w_s @ U_s).

    params: (S, D) fp32, D % D_BLK == 0, aliased input->output; updates:
    (S, n, D) fp32; fresh/valid: (S, n) bool; tau: (S, n) int; scal: (S, 2)
    fp32 rows ``(beta_s, server_lr_s)``.  One kernel launch computes every
    cell's deviation partials, in-kernel Eq. 2 weights and aggregate, and
    applies the aggregate to the cell's parameter row in place.  Returns
    (new_params (S, D), weights (S, n)); all-invalid cells get zero weights
    and therefore keep their parameter bits.
    """
    interpret = _resolve_interpret(interpret)
    s, n, d = updates.shape
    assert d % D_BLK == 0 and params.shape == (s, d)
    grid = (s, 2, d // D_BLK)
    new_params, num, den, w = pl.pallas_call(
        _make_sweep_fused_apply_kernel(rule),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, D_BLK), lambda s_, p, i: (s_, i)),
            pl.BlockSpec((1, n, D_BLK), lambda s_, p, i: (s_, 0, i)),
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, 2), lambda s_, p, i: (s_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D_BLK), lambda s_, p, i: (s_, i)),
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda s_, p, i: (s_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, d), jnp.float32),
            jax.ShapeDtypeStruct((s, n, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, 1, n), jnp.float32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(params.astype(jnp.float32),
      updates.astype(jnp.float32),
      fresh.astype(jnp.float32)[..., None],
      tau.astype(jnp.float32)[..., None],
      valid.astype(jnp.float32)[..., None],
      scal.astype(jnp.float32))
    return new_params, w[:, 0]


@functools.partial(jax.jit, static_argnames=("rule", "interpret"))
def sweep_fused_staleness_aggregate(updates, fresh, tau, beta, valid, *,
                                    rule="relay", interpret=None):
    """updates: (S, n, D) fp32, D % D_BLK == 0; fresh/valid: (S, n) bool;
    tau: (S, n) int; beta: (S,) per-simulation Eq. 2 averaging weight.

    One kernel launch aggregates S simulations' rounds: per-cell deviation
    partials, in-kernel per-cell Eq. 2 weights, per-cell weighted aggregate.
    Returns (aggregate (S, D), weights (S, n)); all-invalid cells produce
    zero weights and a zero aggregate row.
    """
    interpret = _resolve_interpret(interpret)
    s, n, d = updates.shape
    assert d % D_BLK == 0
    grid = (s, 2, d // D_BLK)
    num, den, w, out = pl.pallas_call(
        _make_sweep_fused_kernel(rule),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, D_BLK), lambda s_, p, i: (s_, 0, i)),
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, 1), lambda s_, p, i: (s_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda s_, p, i: (s_, 0, 0)),
            pl.BlockSpec((1, 1, D_BLK), lambda s_, p, i: (s_, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, n, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, 1, n), jnp.float32),
            jax.ShapeDtypeStruct((s, 1, d), jnp.float32),
        ],
        interpret=interpret,
    )(updates.astype(jnp.float32),
      fresh.astype(jnp.float32)[..., None],
      tau.astype(jnp.float32)[..., None],
      valid.astype(jnp.float32)[..., None],
      beta.astype(jnp.float32)[:, None])
    return out[:, 0], w[:, 0]


@functools.partial(jax.jit, static_argnames=("rule", "interpret"))
def fused_staleness_aggregate(updates, fresh, tau, beta, *, rule="relay",
                              interpret=None, valid=None):
    """updates: (n, D) fp32, D % D_BLK == 0; fresh: (n,) bool; tau: (n,) int.

    One kernel launch: deviation partials, in-kernel Eq. 2 weights, weighted
    aggregate. ``valid`` (n,) bool masks bucket-padding rows (default: all).
    Returns (aggregate (D,), weights (n,)).
    """
    interpret = _resolve_interpret(interpret)
    n, D = updates.shape
    assert D % D_BLK == 0
    if valid is None:
        valid = jnp.ones((n,), bool)
    grid = (2, D // D_BLK)
    num, den, w, out = pl.pallas_call(
        _make_fused_kernel(rule),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, D_BLK), lambda p, i: (0, i)),
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((1, 1), lambda p, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((1, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((1, n), lambda p, i: (0, 0)),
            pl.BlockSpec((1, D_BLK), lambda p, i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(updates.astype(jnp.float32),
      fresh.astype(jnp.float32)[:, None],
      tau.astype(jnp.float32)[:, None],
      valid.astype(jnp.float32)[:, None],
      jnp.asarray(beta, jnp.float32).reshape(1, 1))
    return out[0], w[0]


@functools.partial(jax.jit, static_argnames=("rule", "interpret"))
def fused_staleness_apply(params, updates, fresh, tau, beta, server_lr, *,
                          rule="relay", interpret=None, valid=None):
    """Fused server step: new_params = params + lr * (w @ U).

    The params buffer is aliased input->output at the kernel level
    (``input_output_aliases``), so the update is in-place within the program.
    params: (D,) fp32 (D % D_BLK == 0). Returns (new_params (D,), weights (n,)).
    """
    interpret = _resolve_interpret(interpret)
    n, D = updates.shape
    assert D % D_BLK == 0 and params.shape == (D,)
    if valid is None:
        valid = jnp.ones((n,), bool)
    scal = jnp.stack([jnp.asarray(beta, jnp.float32),
                      jnp.asarray(server_lr, jnp.float32)]).reshape(1, 2)
    new_params, num, den, w = pl.pallas_call(
        _make_fused_apply_kernel(rule),
        grid=(2, D // D_BLK),
        in_specs=[
            pl.BlockSpec((1, D_BLK), lambda p, i: (0, i)),
            pl.BlockSpec((n, D_BLK), lambda p, i: (0, i)),
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((1, 2), lambda p, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D_BLK), lambda p, i: (0, i)),
            pl.BlockSpec((n, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((1, 1), lambda p, i: (0, 0)),
            pl.BlockSpec((1, n), lambda p, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, D), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(params.astype(jnp.float32)[None, :],
      updates.astype(jnp.float32),
      fresh.astype(jnp.float32)[:, None],
      tau.astype(jnp.float32)[:, None],
      valid.astype(jnp.float32)[:, None],
      scal)
    return new_params[0], w[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def deviation_partials(updates, fresh, *, interpret=None):
    """updates: (n, D) fp32, D % D_BLK == 0; fresh: (n,) bool.

    Returns (num (n,), den ()) such that Lam = num / (den + eps).
    """
    interpret = _resolve_interpret(interpret)
    n, D = updates.shape
    assert D % D_BLK == 0
    grid = (D // D_BLK,)
    num, den = pl.pallas_call(
        _deviation_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, D_BLK), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(updates, fresh.astype(jnp.float32)[:, None])
    return num[:, 0], den[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate(weights, updates, *, interpret=None):
    """weights: (n,) fp32; updates: (n, D) -> (D,)."""
    interpret = _resolve_interpret(interpret)
    n, D = updates.shape
    assert D % D_BLK == 0
    out = pl.pallas_call(
        _aggregate_kernel,
        grid=(D // D_BLK,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, D_BLK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, D_BLK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(weights[None, :], updates)
    return out[0]
