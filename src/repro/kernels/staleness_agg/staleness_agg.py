"""Fused staleness-aware aggregation (SAA, Eq. 2) Pallas TPU kernels.

Aggregating n participant updates of D parameters (D ~ 1e8+) is the server-side
hot-spot RELAY adds: a naive implementation materializes the mixed update
``(u_s + n_F u_hat)/(n_F+1)`` per straggler (n x D extra bytes).  The fused
kernels stream U through VMEM in (n, D_BLK) tiles exactly twice:

  pass 1 (deviation): per tile, compute the fresh mean and accumulate each
      update's deviation numerator and the ||u_hat||^2 denominator — no mixed
      tensor is ever materialized;
  pass 2 (aggregate): weighted matvec w @ U per tile.

Both passes are grid-sequential over D/D_BLK with accumulator outputs, the
TPU-idiomatic replacement for the GPU's atomics-based reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

D_BLK = 2048  # lane-aligned (16 x 128); (n<=64) x 2048 fp32 = 512 KB per operand


def _deviation_kernel(u_ref, fresh_ref, num_ref, den_ref):
    """Accumulate per-update deviation partials over D blocks.

    u_ref: (n, D_BLK) fp32; fresh_ref: (n, 1) fp32 {0,1}
    num_ref: (n, 1) accumulator; den_ref: (1, 1) accumulator.
    """
    i = pl.program_id(0)
    u = u_ref[...]
    fresh = fresh_ref[...]                       # (n, 1)
    n_f = jnp.maximum(fresh.sum(), 1.0)
    u_hat = (u * fresh).sum(axis=0, keepdims=True) / n_f      # (1, D_BLK)
    mixed = (u + n_f * u_hat) / (n_f + 1.0)
    num = ((u_hat - mixed) ** 2).sum(axis=1, keepdims=True)   # (n, 1)
    den = (u_hat ** 2).sum().reshape(1, 1)

    @pl.when(i == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    num_ref[...] += num
    den_ref[...] += den


def _aggregate_kernel(w_ref, u_ref, out_ref):
    """out[D_BLK] = w (1, n) @ U (n, D_BLK)."""
    out_ref[...] = jnp.dot(w_ref[...], u_ref[...],
                           preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def deviation_partials(updates, fresh, *, interpret=True):
    """updates: (n, D) fp32, D % D_BLK == 0; fresh: (n,) bool.

    Returns (num (n,), den ()) such that Lam = num / (den + eps).
    """
    n, D = updates.shape
    assert D % D_BLK == 0
    grid = (D // D_BLK,)
    num, den = pl.pallas_call(
        _deviation_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, D_BLK), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(updates, fresh.astype(jnp.float32)[:, None])
    return num[:, 0], den[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate(weights, updates, *, interpret=True):
    """weights: (n,) fp32; updates: (n, D) -> (D,)."""
    n, D = updates.shape
    assert D % D_BLK == 0
    out = pl.pallas_call(
        _aggregate_kernel,
        grid=(D // D_BLK,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, D_BLK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, D_BLK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D), jnp.float32),
        interpret=interpret,
    )(weights[None, :], updates)
    return out[0]
