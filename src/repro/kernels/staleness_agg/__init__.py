from repro.kernels.staleness_agg import ops, ref  # noqa: F401
