"""Pure-jnp oracle for the fused SAA kernels — delegates to the core module
(the core implementation IS the reference semantics)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.staleness import EPS, deviation_scores, fresh_average, staleness_weights


def deviation_partials_ref(updates, fresh):
    u_hat = fresh_average(updates, fresh)
    n_f = fresh.sum().astype(updates.dtype)
    mixed = (updates + n_f * u_hat[None, :]) / (n_f + 1.0)
    num = jnp.sum((u_hat[None, :] - mixed) ** 2, axis=-1)
    den = jnp.sum(u_hat ** 2)
    return num, den


def staleness_aggregate_ref(updates, fresh, tau, *, rule="relay", beta=0.35):
    w = staleness_weights(updates, fresh, tau, rule=rule, beta=beta)
    return jnp.einsum("n,nd->d", w, updates), w
