"""Host-facing entry points: full SAA aggregation through the Pallas kernels.

The default path is the fused single-launch kernel (deviation partials,
in-kernel Eq. 2 weights, weighted aggregate in one grid traversal);
``fused=False`` keeps the original two-launch pipeline (partials kernel ->
host O(n) weights -> aggregate kernel) for A/B comparison.

These wrappers are deliberately *not* jitted: D is padded to the 2048-lane
block and (by default) the participant axis is padded to a power-of-two
bucket on the host, so repeated calls with varying fresh+stale counts reuse
one compiled kernel per bucket instead of recompiling per exact shape.
``interpret=None`` auto-detects the backend (compiled on TPU, interpreter
elsewhere).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import bucket_pad
from repro.core.staleness import EPS, SCALING_RULES
from repro.kernels.staleness_agg.staleness_agg import (
    D_BLK, deviation_partials, fused_staleness_aggregate,
    fused_staleness_apply, sweep_fused_staleness_aggregate,
    sweep_fused_staleness_apply, weighted_aggregate)


def staleness_aggregate(updates, fresh, tau, *, rule: str = "relay",
                        beta: float = 0.35, interpret: bool | None = None,
                        fused: bool = True, bucketed: bool = True):
    """updates: (n, D) any-D fp32; fresh: (n,) bool; tau: (n,) int.

    Returns (aggregate (D,), weights (n,)).
    """
    n, D = np.shape(updates)
    if fused:
        u, fr, ta, valid = bucket_pad(updates, fresh, tau, bucketed=bucketed,
                                      lane_block=D_BLK)
        agg, w = fused_staleness_aggregate(u, fr, ta, np.float32(beta),
                                           rule=rule, interpret=interpret,
                                           valid=valid)
        return agg[:D], w[:n]
    u = jnp.pad(jnp.asarray(updates, jnp.float32), ((0, 0), (0, (-D) % D_BLK)))
    fresh = jnp.asarray(fresh, bool)
    num, den = deviation_partials(u, fresh, interpret=interpret)
    lam = jnp.where(fresh, 0.0, num / (den + EPS))
    lam_max = jnp.max(jnp.where(~fresh, lam, 0.0))
    w_stale = SCALING_RULES[rule](jnp.asarray(tau, jnp.int32), lam, lam_max, beta)
    w = jnp.where(fresh, 1.0, w_stale)
    w = w / jnp.maximum(w.sum(), EPS)
    agg = weighted_aggregate(w, u, interpret=interpret)
    return agg[:D], w


def sweep_staleness_aggregate(updates, fresh, tau, *, valid=None,
                              rule: str = "relay", beta=0.35,
                              interpret: bool | None = None):
    """Batched SAA over a sweep axis: updates (S, n, any-D) fp32; fresh/tau
    (S, n); ``valid`` masks padded participant slots (default: all real);
    ``beta`` is a scalar or a (S,) per-simulation vector.

    Returns (aggregate (S, D), weights (S, n)) from ONE kernel launch over a
    (S, phase, D-block) grid — the sweep-grid extension of the fused kernel.
    """
    s, n, d = np.shape(updates)
    if valid is None:
        valid = np.ones((s, n), bool)
    u = np.zeros((s, n, d + ((-d) % D_BLK)), np.float32)
    u[:, :, :d] = np.asarray(updates)
    beta_vec = np.broadcast_to(np.asarray(beta, np.float32), (s,))
    agg, w = sweep_fused_staleness_aggregate(
        u, np.asarray(fresh), np.asarray(tau), beta_vec, np.asarray(valid),
        rule=rule, interpret=interpret)
    return agg[:, :d], w


def sweep_staleness_apply(params, updates, fresh, tau, *, valid=None,
                          rule: str = "relay", beta=0.35, server_lr=1.0,
                          interpret: bool | None = None):
    """Batched fused server step over a sweep axis: params (S, any-D) fp32,
    updates (S, n, any-D); ``beta``/``server_lr`` scalars or (S,) vectors.

    Returns (new_params (S, D), weights (S, n)) from ONE launch over a
    (S, phase, D-block) grid with the params buffer aliased input->output —
    the sweep-axis extension of ``staleness_apply``.
    """
    s, n, d = np.shape(updates)
    if valid is None:
        valid = np.ones((s, n), bool)
    dp = d + ((-d) % D_BLK)
    u = np.zeros((s, n, dp), np.float32)
    u[:, :, :d] = np.asarray(updates)
    p = np.zeros((s, dp), np.float32)
    p[:, :d] = np.asarray(params)
    scal = np.stack([np.broadcast_to(np.asarray(beta, np.float32), (s,)),
                     np.broadcast_to(np.asarray(server_lr, np.float32), (s,))],
                    axis=1)
    new_p, w = sweep_fused_staleness_apply(
        p, u, np.asarray(fresh), np.asarray(tau), np.asarray(valid), scal,
        rule=rule, interpret=interpret)
    return new_p[:, :d], w


def staleness_apply(params, updates, fresh, tau, *, rule: str = "relay",
                    beta: float = 0.35, server_lr: float = 1.0,
                    interpret: bool | None = None, bucketed: bool = True):
    """Fused server step on a flat parameter vector.

    params: (D,) fp32; updates: (n, D). Returns (new_params (D,), weights (n,))
    with ``new_params = params + server_lr * (w @ updates)`` computed in the
    same single grid traversal as the weights (params aliased input->output).
    """
    n, D = np.shape(updates)
    u, fr, ta, valid = bucket_pad(updates, fresh, tau, bucketed=bucketed,
                                  lane_block=D_BLK)
    p = np.zeros(u.shape[1], np.float32)
    p[:D] = np.asarray(params)
    new_p, w = fused_staleness_apply(p, u, fr, ta, np.float32(beta),
                                     np.float32(server_lr), rule=rule,
                                     interpret=interpret, valid=valid)
    return new_p[:D], w[:n]
