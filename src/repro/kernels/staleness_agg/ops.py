"""Jit wrapper: full SAA aggregation through the Pallas kernels.

Handles D padding to the 2048-lane block, computes the (n)-sized weight vector
on-host from the kernel's deviation partials (O(n) work), then runs the fused
weighted aggregate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.staleness import EPS, SCALING_RULES
from repro.kernels.staleness_agg.staleness_agg import (D_BLK, deviation_partials,
                                                       weighted_aggregate)


@functools.partial(jax.jit, static_argnames=("rule", "interpret"))
def staleness_aggregate(updates, fresh, tau, *, rule: str = "relay",
                        beta: float = 0.35, interpret: bool = True):
    """updates: (n, D) any-D fp32; fresh: (n,) bool; tau: (n,) int.

    Returns (aggregate (D,), weights (n,)).
    """
    n, D = updates.shape
    pad = (-D) % D_BLK
    u = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, pad)))
    num, den = deviation_partials(u, fresh, interpret=interpret)
    lam = jnp.where(fresh, 0.0, num / (den + EPS))
    lam_max = jnp.max(jnp.where(~fresh, lam, 0.0))
    w_stale = SCALING_RULES[rule](tau, lam, lam_max, beta)
    w = jnp.where(fresh, 1.0, w_stale)
    w = w / jnp.maximum(w.sum(), EPS)
    agg = weighted_aggregate(w, u, interpret=interpret)
    return agg[:D], w
