"""Jit wrapper: (B, S, H, Dh) layout handling, padding, GQA head mapping."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.swa_attention import BLK, swa_attention_bhsd


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def swa_attention(q, k, v, *, window: int, interpret: bool = True):
    """q: (B, S, H, Dh); k, v: (B, S, Hkv, Dh) -> (B, S, H, Dh).

    Pads S to the 128 block and window to a block multiple (a slightly larger
    window is attention-superset-safe only at block granularity, so we keep
    the *exact* window by requiring window % BLK == 0 — configs use 8192).
    """
    assert window % BLK == 0, "window must be a multiple of the 128 tile"
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    pad = (-S) % BLK
    if pad:
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, zq) for t in (q, k, v))
    Sp = S + pad
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, Sp, Dh)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sp, Dh)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sp, Dh)
    # mask padded keys structurally: kernel masks k_pos >= seq_len
    out = swa_attention_bhsd(qb, kb, vb, window=window, n_kv_heads=Hkv,
                             interpret=interpret)
    out = out.reshape(B, H, Sp, Dh).transpose(0, 2, 1, 3)
    return out[:, :S]
