"""Oracle: the model substrate's blocked online-softmax attention."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import blocked_attention


def swa_attention_ref(q, k, v, *, window: int):
    """q: (B, S, H, Dh); k, v: (B, S, Hkv, Dh) -> (B, S, H, Dh)."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out = blocked_attention(q.reshape(B, S, Hkv, G, Dh), k, v, pos, pos,
                            window=window)
    return out.reshape(B, S, H, Dh)
