"""Sliding-window flash attention Pallas TPU kernel (prefill / full-seq path).

The band structure is exploited *structurally*: the kv grid dimension only
spans the ``window/BLK + 1`` blocks that can intersect each query block's
band, so compute is O(S * window) instead of O(S^2) — this is what makes
``long_500k`` viable on the dense assigned architectures.

Grid: (B * H, n_q_blocks, n_band_blocks), innermost sequential; the online
softmax state (m, l, acc) lives in VMEM scratch across the band sweep.
Out-of-range band positions (left edge) load a clamped block and are fully
masked, which wastes at most one block per row.  BlockSpec tiles are
(BLK=128) x d_head — MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLK = 128
NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                window: int, n_band: int, seq_len: int, scale: float):
    qi = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_blk = qi - (n_band - 1) + j               # raw band block index
    q = q_ref[0].astype(jnp.float32)             # (BLK, Dh)
    k = k_ref[0].astype(jnp.float32)             # (BLK, Dh)
    v = v_ref[0].astype(jnp.float32)

    q_pos = qi * BLK + jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 0)
    k_pos = kv_blk * BLK + jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 1)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = (k_pos >= 0) & (k_pos < seq_len) & (k_pos <= q_pos) \
        & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_new = acc_prev * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(j == n_band - 1)
    def _finalize():
        o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "n_kv_heads", "interpret"))
def swa_attention_bhsd(q, k, v, *, window: int, n_kv_heads: int,
                       interpret: bool = True):
    """q: (BH, S, Dh); k, v: (B*Hkv, S, Dh); S % BLK == 0; window % BLK == 0.

    Query head bh maps to kv head bh // (H // Hkv) via the BlockSpec index map.
    """
    BH, S, Dh = q.shape
    BHkv = k.shape[0]
    G = BH // BHkv
    n_q = S // BLK
    n_band = window // BLK + 1
    scale = Dh ** -0.5

    kernel = functools.partial(_swa_kernel, window=window, n_band=n_band,
                               seq_len=S, scale=scale)

    def kv_index(bh, qi, j):
        blk = qi - (n_band - 1) + j
        return (bh // G, jnp.maximum(blk, 0), 0)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_band),
        in_specs=[
            pl.BlockSpec((1, BLK, Dh), lambda bh, qi, j: (bh, qi, 0)),
            pl.BlockSpec((1, BLK, Dh), kv_index),
            pl.BlockSpec((1, BLK, Dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, BLK, Dh), lambda bh, qi, j: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLK,), jnp.float32),
            pltpu.VMEM((BLK,), jnp.float32),
            pltpu.VMEM((BLK, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
