# Pallas TPU kernels for the compute hot-spots this system adds or relies on:
#   staleness_agg  — fused SAA deviation + weighted aggregation (server side)
#   swa_attention  — sliding-window flash attention (long-context serve path)
#   wkv6           — RWKV6 data-dependent-decay recurrence (chunked scan)
# Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
# ref.py (pure-jnp oracle).  Validated in interpret mode on CPU; TPU is the
# compile target.
