"""Chaos demo: a federated round pipeline under deterministic fault
injection and coordinated attacks.

Runs exactly ONE scenario per guard mode on the fused round pipeline —
same config, same seeded fault plan, so every delta is attributable to
the guard mode alone:

  1. clean baseline    — no faults, no guards;
  2. guard=off         — NaN/Inf emitters, byzantine scaled-garbage rows,
     post-training drops and replay duplicates poison the model;
  3. guard=reject      — median-norm reject + quorum: poison rows are
     rejected in-program and the run lands near the clean baseline;
  4. guard=clip+reject — adds an L2 clip on the surviving rows (the
     belt-and-braces mode the CI chaos leg runs).

A robustness phase then arms a coordinated ``collude_signflip`` attack
(seeded attacker sets, identical for both cells) and compares plain
``saa`` aggregation against the ``coord_median`` robust aggregator: the
defense must beat the undefended run or the demo exits non-zero (an
unexpected winner means the robust layer regressed).

A final phase crashes the guarded run mid-flight (soft crash at a
checkpoint boundary, full telemetry on) and resumes it from the snapshot:
the resumed run must land bit-identical to the uninterrupted one AND its
exported ``rounds.jsonl`` round log must byte-continue the crashed run's.

Prints the scheduled-fault table, the per-scenario rejection/quorum
counters and the attack outcome, and exits non-zero if the guarded run
diverges from the clean baseline beyond tolerance, the defense loses, or
the crash/resume round logs disagree (the CI chaos leg runs ``--smoke``).

  PYTHONPATH=src python examples/chaos_round.py [--smoke]
"""
import argparse
import math
import os
import sys
import tempfile

from repro.checkpoint import resume_run
from repro.faults import FaultPlan, FaultSpec, InjectedCrash
from repro.sim import SimConfig, Simulator
from repro.telemetry import TelemetrySession


def build(smoke: bool):
    common = dict(n_learners=40 if smoke else 100,
                  rounds=8 if smoke else 40,
                  eval_every=4 if smoke else 10,
                  n_target=4 if smoke else 10,
                  selector="priority", saa=True, scaling_rule="relay",
                  mapping="label_uniform", seed=0)
    plan = FaultPlan(
        n_learners=common["n_learners"], rounds=common["rounds"],
        specs=(FaultSpec("nan", prob=0.08),
               FaultSpec("inf", prob=0.04),
               FaultSpec("scale", prob=0.08, scale=1e4),
               FaultSpec("post_drop", prob=0.05),
               FaultSpec("replay", prob=0.10)),
        seed=42)
    return common, plan


# one scenario per guard mode: (label, config overrides, faulted?)
GUARD_MODES = (
    ("clean", dict(), False),
    ("guard=off", dict(), True),
    ("guard=reject", dict(guard=True, guard_reject_mult=5.0, quorum=1),
     True),
    ("guard=clip+reject", dict(guard=True, guard_clip=10.0,
                               guard_reject_mult=5.0, quorum=1), True),
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small CI run")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="max |guarded - clean| final-accuracy gap")
    args = ap.parse_args(argv)

    common, plan = build(args.smoke)
    counts = plan.counts()
    print("=== scheduled faults (deterministic, seed=42) ===")
    print("  " + "  ".join(f"{k}={v}" for k, v in counts.items() if v))

    runs = {}
    for i, (label, extra, faulted) in enumerate(GUARD_MODES):
        print(f"\n=== {i + 1}/{len(GUARD_MODES)} {label} ===")
        runs[label] = Simulator(
            SimConfig(**common, **extra),
            fault_plan=plan if faulted else None).run().summary()

    print("\n--- outcome ---")
    hdr = (f"{'':20s}{'accuracy':>10s}{'rej_nonfin':>12s}{'rej_norm':>10s}"
           f"{'quorum':>8s}")
    print(hdr)
    for label, s in runs.items():
        print(f"{label:20s}{s['final_accuracy']:10.3f}"
              f"{s['rejected_nonfinite']:12d}{s['rejected_norm']:10d}"
              f"{s['quorum_skips']:8d}")

    clean, raw = runs["clean"], runs["guard=off"]
    if math.isfinite(raw["final_accuracy"]):
        print("\nunguarded run survived numerically "
              "(faults landed but did not poison the aggregate this seed)")
    else:
        print("\nunguarded run was poisoned (non-finite accuracy) — "
              "exactly what the guard prevents")

    for label in ("guard=reject", "guard=clip+reject"):
        grd = runs[label]
        gap = abs(grd["final_accuracy"] - clean["final_accuracy"])
        rejected = grd["rejected_nonfinite"] + grd["rejected_norm"]
        print(f"{label}: rejected {rejected} poisoned rows, skipped "
              f"{grd['quorum_skips']} quorum-less applies, landed within "
              f"{gap:.3f} of clean (tolerance {args.tolerance})")
        if not math.isfinite(grd["final_accuracy"]) or gap > args.tolerance:
            print(f"FAIL: {label} diverged from the clean baseline",
                  file=sys.stderr)
            return 1
        if rejected == 0:
            print("FAIL: fault plan scheduled corruption but nothing was "
                  "rejected", file=sys.stderr)
            return 1

    print(f"\n=== {len(GUARD_MODES) + 1}/{len(GUARD_MODES) + 2} "
          "coordinated attack: saa vs coord_median ===")
    if not attacked_cohort_phase(args.smoke):
        return 1

    print(f"\n=== {len(GUARD_MODES) + 2}/{len(GUARD_MODES) + 2} "
          "crash mid-run, resume, compare round logs ===")
    if not crash_resume_round_log(common, plan):
        return 1
    print("OK")
    return 0


def attacked_cohort_phase(smoke: bool) -> bool:
    """Arm ``collude_signflip`` (seeded attacker sets, shared by both
    cells — the attacker stream is independent of the schedule) and race
    plain ``saa`` against the ``coord_median`` robust aggregator.  The
    deadline setting keeps cohorts large enough that the scheduled
    attacker fraction sits below the median's breakdown point, so the
    expected winner is the defense — anything else is a regression."""
    base = dict(n_learners=40 if smoke else 100,
                rounds=10 if smoke else 40,
                eval_every=5 if smoke else 10,
                n_target=10, selector="priority", saa=True,
                scaling_rule="relay", mapping="label_uniform", seed=0,
                setting="DL", deadline=1e6,
                attack="collude_signflip", attack_frac=0.1,
                attack_scale=50.0)
    under = Simulator(SimConfig(**base)).run().summary()
    defended = Simulator(SimConfig(**base, aggregator="coord_median")) \
        .run().summary()
    print(f"{'saa (attacked)':20s}{under['final_accuracy']:10.3f}")
    print(f"{'coord_median':20s}{defended['final_accuracy']:10.3f}"
          f"   trimmed {defended['robust_trimmed']} rows")
    if defended["robust_trimmed"] == 0:
        print("FAIL: the robust aggregator never trimmed a row under a "
              "live attack", file=sys.stderr)
        return False
    if defended["final_accuracy"] <= under["final_accuracy"]:
        print("FAIL: unexpected winner — plain saa beat coord_median "
              "under a coordinated attack", file=sys.stderr)
        return False
    print("coord_median held; undefended saa lost "
          f"{defended['final_accuracy'] - under['final_accuracy']:.3f} "
          "accuracy to the attack")
    return True


def crash_resume_round_log(common, plan) -> bool:
    """Guarded run at full telemetry, crashed after round 3 and resumed:
    the resumed run's summary must match the uninterrupted run's bitwise,
    and the two ``rounds.jsonl`` exports must be byte-equal — the session
    truncates the crashed log back to the snapshot offset and the resumed
    tail re-emits the same bytes."""
    cfg = SimConfig(guard=True, guard_reject_mult=5.0, quorum=1, telemetry=2,
                    **common)
    crash = FaultPlan(n_learners=common["n_learners"],
                      rounds=common["rounds"], specs=plan.specs,
                      seed=plan.seed, crash_after=3, crash_mode="soft")
    with tempfile.TemporaryDirectory() as tmp:
        dir_a, dir_b = os.path.join(tmp, "clean"), os.path.join(tmp, "crashed")
        ckpt = os.path.join(tmp, "run.pkl")

        sess = TelemetrySession(dir_a)
        ref = Simulator(cfg, fault_plan=plan.without_crash()) \
            .run(telemetry=sess).summary()
        sess.close()

        sess = TelemetrySession(dir_b)
        try:
            Simulator(cfg, fault_plan=crash).run(
                checkpoint_path=ckpt, checkpoint_every=2, telemetry=sess)
            print("FAIL: scheduled crash never fired", file=sys.stderr)
            return False
        except InjectedCrash:
            pass
        finally:
            sess.close()

        sess = TelemetrySession(dir_b)      # reopen the crashed run's dir
        got = resume_run(ckpt, telemetry=sess).summary()
        sess.close()

        if got != ref:
            print("FAIL: resumed run diverged from the uninterrupted one",
                  file=sys.stderr)
            return False
        a = open(os.path.join(dir_a, "rounds.jsonl"), "rb").read()
        b = open(os.path.join(dir_b, "rounds.jsonl"), "rb").read()
        if a != b or not a:
            print("FAIL: resumed round log does not byte-continue the "
                  "crashed run's", file=sys.stderr)
            return False
        print(f"resumed run bit-identical; round logs byte-equal "
              f"({len(a.splitlines())} events, {len(a)} bytes)")
    return True


if __name__ == "__main__":
    sys.exit(main())
