"""Scenario-sweep demo: one batched program reproduces a paper-style grid.

Expands a 3-axis grid — selection policy x SAA on/off x hardware scenario
(HS1 vs HS3), paired over 2 seeds — runs all cells through the vectorized
sweep executor (every policy sees bit-identical traces per seed), and prints
the resource-to-accuracy comparison the paper reports in Figs. 6/7.

  PYTHONPATH=src python examples/sweep_grid.py            # full demo grid
  PYTHONPATH=src python examples/sweep_grid.py --smoke    # tiny CI grid
  PYTHONPATH=src python examples/sweep_grid.py --smoke --sharded
      # sweep axis over the local device mesh (forced-multi-device CI leg)

``--smoke`` re-runs every cell serially and **exits non-zero** on any
per-cell metric divergence — the CI step is a real parity gate, not a demo.
"""
import sys
import time

from repro.sweeps import SweepRunner, SweepSpec, assert_parity, run_serial
from repro.sweeps.report import savings_line, text_table


def main() -> int:
    smoke = "--smoke" in sys.argv
    sharded = "--sharded" in sys.argv
    spec = SweepSpec(
        axes={"selector": ["random", "priority"] if smoke
              else ["random", "oort", "priority", "safa"],
              "saa": [False, True],
              "hardware": ["HS1", "HS3"]},
        base=dict(n_learners=40 if smoke else 100,
                  rounds=5 if smoke else 40,
                  eval_every=5 if smoke else 10,
                  mapping="label_uniform"),
        seeds=(0,) if smoke else (0, 1))
    cells = spec.expand()
    print(f"=== sweep: {len(cells)} cells, shared-seed pairing over "
          f"{len(spec.seeds)} seed(s){' [sharded]' if sharded else ''} ===")

    t0 = time.time()
    results = SweepRunner(cells, shard=sharded).run()
    print(f"(batched wall: {time.time() - t0:.1f}s for {len(cells)} "
          f"simulations)\n")

    if smoke:
        serial_summaries, _ = run_serial(cells)
        try:
            assert_parity(results, serial_summaries)
        except AssertionError as e:
            print(f"PARITY FAILURE:\n{e}", file=sys.stderr)
            return 1
        print("--- per-cell serial parity: OK ---\n")

    print("--- resource-to-accuracy (mean over seeds) ---")
    print(text_table(results))
    print()
    print(savings_line(results, {"selector": "priority", "saa": True},
                       {"selector": "random", "saa": False}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
