"""Selector-zoo race: every registered selection strategy on one
shared-seed grid, head-to-head on resource-to-accuracy.

Expands a one-axis sweep over the full ``repro.selection`` strategy table
(``--selectors`` trims it) with shared-seed pairing: every selector sees
bit-identical datasets, device populations and availability traces, so
accuracy/resource deltas are attributable to the selection policy alone.
The batched runner groups the zoo into selector-uniform compat batches
(``selector_key`` is part of ``pipeline_key``): the feedback selectors
(oort / ucb / contribution) run K=1 with the per-round stat-utility fetch
while the rest chunk freely — and every cell is re-run serially to assert
bit-identical metrics before the table prints.

With ``--telemetry-dir`` the run exports the PR-7 round timeline and
renders ``resource_to_accuracy_by_selector.png`` (one color per strategy)
via ``benchmarks.figures``.

  PYTHONPATH=src python examples/selector_zoo.py [--smoke]
  PYTHONPATH=src python examples/selector_zoo.py \
      --selectors random,oort,flips --telemetry-dir /tmp/zoo
"""
import argparse
import dataclasses
import sys

from repro.selection import SELECTOR_TABLE, describe_selectors
from repro.sweeps import SweepSpec, assert_parity, run_batched, run_serial
from repro.sweeps.report import text_table


def zoo_spec(selectors, smoke: bool, seeds) -> SweepSpec:
    return SweepSpec(
        axes={"selector": list(selectors)},
        base=dict(n_learners=60 if smoke else 100,
                  rounds=8 if smoke else 40,
                  eval_every=4 if smoke else 10,
                  n_target=5 if smoke else 10,
                  saa=True, mapping="label_uniform"),
        seeds=seeds)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized race")
    ap.add_argument("--selectors", default=",".join(SELECTOR_TABLE),
                    help="comma list from the registered zoo "
                         "(default: all of it)")
    ap.add_argument("--seeds", default="0", help="comma list of shared seeds")
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="export the run timeline and render the zoo "
                         "resource-to-accuracy figure there")
    args = ap.parse_args(argv)

    selectors = args.selectors.split(",")
    unknown = [s for s in selectors if s not in SELECTOR_TABLE]
    if unknown:
        print(f"unknown selectors {unknown}; registered zoo:\n")
        print(describe_selectors())
        return 2
    seeds = tuple(int(s) for s in args.seeds.split(","))
    spec = zoo_spec(selectors, args.smoke, seeds)
    cells = spec.expand()
    print(f"# zoo race: {len(selectors)} selectors x {len(seeds)} shared "
          f"seed(s) = {len(cells)} cells")

    telemetry = None
    if args.telemetry_dir:
        from repro.telemetry import TelemetrySession
        telemetry = TelemetrySession(args.telemetry_dir)
        cells = [dataclasses.replace(c, config=dataclasses.replace(
            c.config, telemetry=2)) for c in cells]
    try:
        results, batched_wall = run_batched(cells, telemetry=telemetry)
    finally:
        if telemetry is not None:
            telemetry.close()
    serial_cells = [dataclasses.replace(c, config=dataclasses.replace(
        c.config, telemetry=0)) for c in cells]
    serial_summaries, serial_wall = run_serial(serial_cells)
    assert_parity(results, serial_summaries)
    print(f"# batched {batched_wall:.2f}s vs serial {serial_wall:.2f}s, "
          f"per-cell metrics bit-identical\n")
    print(text_table(results))

    if args.telemetry_dir:
        # the figures module lives at the repo root, which isn't on
        # sys.path when this file is launched as a script
        import pathlib
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
        from benchmarks.figures import render_telemetry
        written = render_telemetry(args.telemetry_dir,
                                   f"{args.telemetry_dir}/figures")
        for p in written:
            print(f"# wrote {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
