"""End-to-end driver: federated training of a transformer LM with the
pod-native FL train step (Alg. 2 + Eq. 2 as ONE jitted program).

Trains a ~10M-param qwen-family model for a few hundred FedAvg rounds on
synthetic federated token shards, with a stale participant in every round —
exercising the same code path the multi-pod dry-run lowers at scale.

  PYTHONPATH=src python examples/federated_lm.py [--rounds 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.data import federated_token_shards
from repro.launch.train import make_fl_train_step
from repro.models import ModelConfig, init_params
from repro.models.transformer import lm_loss

CFG = ModelConfig(arch_id="fed-lm-10m", n_layers=4, d_model=256, n_heads=8,
                  n_kv_heads=4, d_ff=1024, vocab_size=2048, qkv_bias=True,
                  param_dtype=jnp.float32)
P_COHORT, LOCAL_B, SEQ = 8, 4, 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--stale-every", type=int, default=3,
                    help="every k-th round, 2 participants report stale")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    params = init_params(CFG, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, cohort={P_COHORT}x{LOCAL_B}x{SEQ}")

    shards = federated_token_shards(CFG.vocab_size, 64, 128, SEQ, skew=0.3)
    rng = np.random.default_rng(0)
    step = jax.jit(make_fl_train_step(CFG, local_lr=0.05, rule="relay",
                                      local_steps=2))
    eval_batch = {"tokens": shards[0]["tokens"][:16],
                  "labels": shards[0]["labels"][:16]}

    t0 = time.time()
    for r in range(args.rounds):
        lids = rng.choice(len(shards), P_COHORT, replace=False)
        toks = np.stack([shards[l]["tokens"][
            rng.integers(0, len(shards[l]["tokens"]), LOCAL_B)] for l in lids])
        labs = np.stack([shards[l]["labels"][
            rng.integers(0, len(shards[l]["labels"]), LOCAL_B)] for l in lids])
        batch = {"tokens": toks, "labels": labs}
        stale = (r % args.stale_every == 0)
        fresh = np.ones(P_COHORT, bool)
        tau = np.zeros(P_COHORT, np.int32)
        if stale:
            fresh[-2:] = False
            tau[-2:] = rng.integers(1, 4, 2)
        params, m = step(params, batch, jnp.asarray(fresh), jnp.asarray(tau))
        if (r + 1) % 25 == 0:
            ev = float(lm_loss(CFG, params, eval_batch))
            print(f"round {r+1:4d}  train_loss={float(m['loss']):.3f} "
                  f"eval_loss={ev:.3f}  ({time.time()-t0:.0f}s)")
    save_pytree("experiments/fed_lm_final.npz", params)
    print("saved checkpoint to experiments/fed_lm_final.npz")


if __name__ == "__main__":
    main()
