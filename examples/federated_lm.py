"""End-to-end driver: federated transformer-LM training through the fused
round pipeline — the SAME round API every benchmark uses.

The LM is just a model-zoo entry (``SimConfig(model="transformer")``,
``repro.learners``): selection, staleness-aware aggregation, guards,
telemetry and the fused/chunked/sharded substrates all come along for
free, and per-round host->device traffic stays index-arrays-only.  With
``--race`` the same cells re-run under several selection strategies on a
shared substrate (matched seeds), showing selector choice moving LM eval
loss at equal resource budget — the FLIPS/survey claim on a real model.

  PYTHONPATH=src python examples/federated_lm.py [--rounds 30]
  PYTHONPATH=src python examples/federated_lm.py --race random,oort,flips
  PYTHONPATH=src python examples/federated_lm.py --rounds 6 --parity

(The pod-scale lowering of the same round — one jitted Alg. 2 + Eq. 2
step over a ("pod","data") mesh — lives in ``repro.launch.train``; this
host-scale driver replaced its hand-rolled cohort loop.)
"""
import argparse
import dataclasses
import time

from repro.sim import SimConfig, Simulator
from repro.sim.engine import Substrate

MODEL_PARAMS = (("n_layers", 2), ("d_model", 64), ("n_heads", 2),
                ("d_ff", 128))


def run_cell(selector: str, rounds: int, seed: int, substrate=None,
             fused=True):
    # static availability: all learners check in every round, so the
    # n_target budget forces a real selection decision (dynamic traces at
    # this small scale leave fewer checked-in than the budget, collapsing
    # every strategy to "take everyone")
    cfg = SimConfig(benchmark="tokens_skew", model="transformer",
                    model_params=MODEL_PARAMS, selector=selector,
                    n_learners=32, rounds=rounds, eval_every=max(rounds // 4, 1),
                    n_target=6, local_steps=2, local_batch=4, saa=True,
                    dynamic_availability=False, seed=seed)
    if not fused:
        cfg = dataclasses.replace(cfg, fused_rounds=False)
    sub = substrate if substrate is not None else Substrate.build(cfg)
    t0 = time.time()
    acct = Simulator(cfg, substrate=sub).run()
    s = dict(acct.summary())
    losses = [r.loss for r in acct.records if r.loss == r.loss]
    return sub, {"selector": selector,
                 "eval_loss": losses[-1] if losses else float("nan"),
                 "accuracy": s["final_accuracy"],
                 "resource": s["resource_used"],
                 "wall_s": time.time() - t0,
                 "summary": s}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--race", default=None, metavar="A,B",
                    help="comma list of selectors to race under matched "
                         "seeds (see python -m repro.sweeps --list-selectors)")
    ap.add_argument("--parity", action="store_true",
                    help="rerun the first cell on the per-stage flat path "
                         "(fused_rounds=False) and require a bit-identical "
                         "summary — the CI lm-smoke gate")
    args = ap.parse_args()

    selectors = args.race.split(",") if args.race else ["random"]
    sub, rows = None, []
    for sel in selectors:
        sub, row = run_cell(sel, args.rounds, args.seed, substrate=sub)
        rows.append(row)
        print(f"{row['selector']:>10s}  eval_loss={row['eval_loss']:.4f}  "
              f"acc={row['accuracy']:.4f}  resource={row['resource']:.1f}  "
              f"({row['wall_s']:.0f}s)")
    if len(rows) > 1:
        best = min(rows, key=lambda r: r["eval_loss"])
        print(f"# best at equal budget: {best['selector']} "
              f"(eval loss {best['eval_loss']:.4f})")
    if args.parity:
        _, flat = run_cell(selectors[0], args.rounds, args.seed,
                           substrate=sub, fused=False)
        assert flat["summary"] == rows[0]["summary"], \
            "fused/flat LM summary divergence"
        print("# parity: fused == flat (bit-identical summary)")


if __name__ == "__main__":
    main()
