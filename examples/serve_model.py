"""Serve a (reduced) assigned architecture with batched greedy decoding.

Demonstrates the serve path the decode_32k / long_500k dry-run shapes lower:
prefill a batch of prompts, then step the ring-buffered KV/state caches.

  PYTHONPATH=src python examples/serve_model.py --arch deepseek-v2-lite-16b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.launch.serve import greedy_generate, make_decode_step
from repro.models import init_decode_state, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B = args.batch
    max_seq = args.prompt_len + args.gen_len + 1
    state = init_decode_state(cfg, B, max_seq)
    step = jax.jit(make_decode_step(cfg))

    # feed the prompt token-by-token through the decode path (cache warmup)
    prompt = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, state = step(params, state, prompt[:, t],
                             jnp.full((B,), t, jnp.int32))
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks, state = greedy_generate(cfg, params, state, next_tok,
                                  jnp.full((B,), args.prompt_len, jnp.int32),
                                  args.gen_len)
    dt = time.time() - t0
    total = B * (args.prompt_len + args.gen_len)
    print(f"arch={cfg.arch_id} ({cfg.family})  batch={B}")
    print(f"generated {toks.shape[1]} tokens/seq in {dt:.1f}s "
          f"({total/dt:.0f} tok/s on CPU, reduced config)")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
