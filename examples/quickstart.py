"""Quickstart: RELAY vs Random selection on a simulated FL population.

Runs two short federated campaigns on the speech-like benchmark (non-IID,
dynamic availability) and prints the resource-to-accuracy comparison — the
paper's headline metric.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.sim import SimConfig, Simulator

COMMON = dict(n_learners=100, rounds=60, eval_every=15, seed=0,
              mapping="label_uniform", dynamic_availability=True)


def main():
    print("=== Random selection (FedAvg default) ===")
    rand = Simulator(SimConfig(selector="random", **COMMON)).run(progress=True)

    print("\n=== RELAY (IPS + APT + SAA, Eq. 2 weights) ===")
    relay = Simulator(SimConfig(selector="priority", saa=True, apt=True,
                                scaling_rule="relay", **COMMON)).run(progress=True)

    r, s = rand.summary(), relay.summary()
    print("\n--- resource-to-accuracy ---")
    print(f"{'':14s}{'accuracy':>10s}{'resources':>12s}{'waste':>8s}{'unique':>8s}")
    print(f"{'Random':14s}{r['final_accuracy']:10.3f}"
          f"{r['resource_used']:11.0f}s{r['waste_fraction']:8.1%}"
          f"{r['unique_participants']:8d}")
    print(f"{'RELAY':14s}{s['final_accuracy']:10.3f}"
          f"{s['resource_used']:11.0f}s{s['waste_fraction']:8.1%}"
          f"{s['unique_participants']:8d}")
    save = 1 - s["resource_used"] / r["resource_used"]
    print(f"\nRELAY used {save:.0%} fewer learner resources "
          f"(paper reports up to 2x savings at full scale).")


if __name__ == "__main__":
    main()
